#!/usr/bin/env python3
"""Perf-trajectory gate for BENCH_*.json artifacts.

Compares a fresh benchmark JSON (``bench json`` or ``service load``)
against the committed baseline and fails if any gated field regressed
below RATIO (default 0.8) x its baseline value, or disappeared entirely.

Usage: bench_diff.py BASELINE.json FRESH.json [RATIO]

Gated fields:

* ``speedup_*`` — optimization ratios (packed vs wide, compiled plan vs
  dispatch, coalesced service vs serial per-request). Absolute
  wall-times vary with runner hardware, but these ratios are what the
  optimization claims are made of and must not silently decay.
  Exception: ``speedup_rowsplit_*`` is reported as ADVISORY only — the
  fig11 row-split speedup compares two multi-threaded timings on shared
  CI runners, whose core counts and noise floors swing it well past any
  honest regression threshold (the kernels themselves are gated for
  correctness by ``bench smoke``'s checksum parity instead).
* ``ratchet_*`` — scheduler-quality scalars (e.g. the service's mean
  coalesced batch size) that must not silently decay either.

New gated fields in the fresh run are allowed (the gate is
forward-compatible); refresh a baseline by rerunning the producing
command on a quiet machine and committing the result.

Sharded-service fields (``shards``, ``shards_detail``,
``head_of_line``) are ADVISORY: they are printed for trend-watching but
never gated, because per-shard wall-clock splits and the head-of-line
p99 probe depend on runner core counts. Their correctness (per-shard
accounting, cold-p99 decoupling) is asserted directly in CI against the
fresh run instead.
"""

import json
import sys


def is_gated(key: str) -> bool:
    if key.startswith("speedup_rowsplit_"):
        return False  # advisory: cross-thread timing ratio, too noisy to gate
    return key.startswith("speedup_") or key.startswith("ratchet_")


def is_advisory(key: str) -> bool:
    return key.startswith("speedup_rowsplit_")


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)
    ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 0.8

    failures = []
    checked = 0
    for key in sorted(base):
        if is_advisory(key):
            floor = base[key]
            got = fresh.get(key)
            if isinstance(floor, (int, float)) and isinstance(got, (int, float)):
                print(f"advisory {key}: {got:.3f} (baseline {floor:.3f}, not gated)")
            else:
                print(f"advisory {key}: baseline {floor!r}, fresh {got!r} (not gated)")
            continue
        if not is_gated(key):
            continue
        floor = base[key]
        if not isinstance(floor, (int, float)) or floor <= 0:
            failures.append(f"{key}: baseline value {floor!r} is not a positive number")
            continue
        got = fresh.get(key)
        if not isinstance(got, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            continue
        checked += 1
        if got < ratio * floor:
            failures.append(
                f"{key}: {got:.3f} < {ratio} x baseline {floor:.3f} (floor {ratio * floor:.3f})"
            )
        else:
            print(f"ok {key}: {got:.3f} (baseline {floor:.3f}, floor {ratio * floor:.3f})")

    hol = fresh.get("head_of_line")
    if isinstance(hol, dict):
        single = hol.get("cold_p99_us_single")
        sharded = hol.get("cold_p99_us_sharded")
        if isinstance(single, (int, float)) and isinstance(sharded, (int, float)):
            print(
                f"advisory head_of_line: cold p99 {single} us @1 shard -> "
                f"{sharded} us @{hol.get('shards')} shards (not gated)"
            )
    rows = fresh.get("shards_detail")
    if isinstance(rows, list) and rows:
        split = ", ".join(f"s{r.get('shard')}={r.get('completed')}" for r in rows)
        print(f"advisory shards_detail: completed split {split} (not gated)")

    if checked == 0 and not failures:
        failures.append("baseline contains no gated speedup_*/ratchet_* fields — nothing was gated")
    if failures:
        print("bench regression check FAILED:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"bench regression check passed ({checked} gated fields)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
