#!/usr/bin/env python3
"""Perf-trajectory gate for BENCH_*.json artifacts.

Compares a fresh benchmark JSON (``bench json`` or ``service load``)
against the committed baseline and fails if any gated field regressed
below RATIO (default 0.8) x its baseline value, or disappeared entirely.

Usage: bench_diff.py BASELINE.json FRESH.json [RATIO]

Gated fields:

* ``speedup_*`` — optimization ratios (packed vs wide, compiled plan vs
  dispatch, coalesced service vs serial per-request). Absolute
  wall-times vary with runner hardware, but these ratios are what the
  optimization claims are made of and must not silently decay.
  Exception: ``speedup_rowsplit_*`` is reported as ADVISORY only — the
  fig11 row-split speedup compares two multi-threaded timings on shared
  CI runners, whose core counts and noise floors swing it well past any
  honest regression threshold (the kernels themselves are gated for
  correctness by ``bench smoke``'s checksum parity instead).
* ``ratchet_*`` — scheduler-quality scalars (e.g. the service's mean
  coalesced batch size) that must not silently decay either.

Per-key ratio: ``speedup_simd_*`` fields compare two single-threaded
runs of the same binary on the same core (forced-scalar vs
runtime-dispatched SIMD), so they carry far less runner noise than the
cross-configuration ratios. They are gated at a fixed, tighter 0.9
regardless of the CLI RATIO; every other gated key uses RATIO.

When ``GITHUB_STEP_SUMMARY`` is set (GitHub Actions), a Markdown table
of every gated/advisory comparison is appended to the job summary.

New gated fields in the fresh run are allowed (the gate is
forward-compatible); refresh a baseline by rerunning the producing
command on a quiet machine and committing the result.

Sharded-service fields (``shards``, ``shards_detail``,
``head_of_line``) are ADVISORY: they are printed for trend-watching but
never gated, because per-shard wall-clock splits and the head-of-line
p99 probe depend on runner core counts. Their correctness (per-shard
accounting, cold-p99 decoupling) is asserted directly in CI against the
fresh run instead.
"""

import json
import os
import sys

# Tighter fixed ratio for the same-core forced-scalar-vs-SIMD ratios.
SIMD_RATIO = 0.9


def is_gated(key: str) -> bool:
    if key.startswith("speedup_rowsplit_"):
        return False  # advisory: cross-thread timing ratio, too noisy to gate
    return key.startswith("speedup_") or key.startswith("ratchet_")


def is_advisory(key: str) -> bool:
    return key.startswith("speedup_rowsplit_")


def ratio_for(key: str, cli_ratio: float) -> float:
    return SIMD_RATIO if key.startswith("speedup_simd_") else cli_ratio


def write_job_summary(summary_rows) -> None:
    """Append a Markdown comparison table to the GitHub job summary."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "### Bench regression check",
        "",
        "| field | fresh | baseline | floor | status |",
        "|---|---|---|---|---|",
    ]
    for key, got, floor, gate, status in summary_rows:
        fmt = lambda v: f"{v:.3f}" if isinstance(v, (int, float)) else "—"
        lines.append(f"| `{key}` | {fmt(got)} | {fmt(floor)} | {fmt(gate)} | {status} |")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n\n")


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)
    ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 0.8

    failures = []
    checked = 0
    summary_rows = []  # (key, fresh, baseline, floor, status)
    for key in sorted(base):
        if is_advisory(key):
            floor = base[key]
            got = fresh.get(key)
            if isinstance(floor, (int, float)) and isinstance(got, (int, float)):
                print(f"advisory {key}: {got:.3f} (baseline {floor:.3f}, not gated)")
            else:
                print(f"advisory {key}: baseline {floor!r}, fresh {got!r} (not gated)")
            summary_rows.append((key, got, floor, None, "advisory"))
            continue
        if not is_gated(key):
            continue
        floor = base[key]
        if not isinstance(floor, (int, float)) or floor <= 0:
            failures.append(f"{key}: baseline value {floor!r} is not a positive number")
            summary_rows.append((key, None, floor, None, "BAD BASELINE"))
            continue
        got = fresh.get(key)
        if not isinstance(got, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            summary_rows.append((key, None, floor, None, "MISSING"))
            continue
        checked += 1
        r = ratio_for(key, ratio)
        if got < r * floor:
            failures.append(
                f"{key}: {got:.3f} < {r} x baseline {floor:.3f} (floor {r * floor:.3f})"
            )
            summary_rows.append((key, got, floor, r * floor, "**FAIL**"))
        else:
            print(f"ok {key}: {got:.3f} (baseline {floor:.3f}, floor {r * floor:.3f})")
            summary_rows.append((key, got, floor, r * floor, "ok"))

    hol = fresh.get("head_of_line")
    if isinstance(hol, dict):
        single = hol.get("cold_p99_us_single")
        sharded = hol.get("cold_p99_us_sharded")
        if isinstance(single, (int, float)) and isinstance(sharded, (int, float)):
            print(
                f"advisory head_of_line: cold p99 {single} us @1 shard -> "
                f"{sharded} us @{hol.get('shards')} shards (not gated)"
            )
    rows = fresh.get("shards_detail")
    if isinstance(rows, list) and rows:
        split = ", ".join(f"s{r.get('shard')}={r.get('completed')}" for r in rows)
        print(f"advisory shards_detail: completed split {split} (not gated)")

    if checked == 0 and not failures:
        failures.append("baseline contains no gated speedup_*/ratchet_* fields — nothing was gated")
    write_job_summary(summary_rows)
    if failures:
        print("bench regression check FAILED:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"bench regression check passed ({checked} gated fields)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
