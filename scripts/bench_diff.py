#!/usr/bin/env python3
"""Perf-trajectory gate for BENCH_kernels.json.

Compares a fresh `cargo run --release -- bench json` output against the
committed baseline and fails if any `speedup_*` field regressed below
RATIO (default 0.8) x its baseline value, or disappeared entirely.

Usage: bench_diff.py BASELINE.json FRESH.json [RATIO]

Only `speedup_*` fields are gated: absolute wall-times vary with runner
hardware, but the *ratios* (packed vs wide, compiled plan vs dispatch,
row-split vs serial) are what the optimization claims are made of, and
those must not silently decay. New speedup fields in the fresh run are
allowed (the gate is forward-compatible); refresh the baseline by
rerunning `bench json` on a quiet machine and committing the result.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)
    ratio = float(sys.argv[3]) if len(sys.argv) > 3 else 0.8

    failures = []
    checked = 0
    for key in sorted(base):
        if not key.startswith("speedup_"):
            continue
        floor = base[key]
        if not isinstance(floor, (int, float)) or floor <= 0:
            failures.append(f"{key}: baseline value {floor!r} is not a positive number")
            continue
        got = fresh.get(key)
        if not isinstance(got, (int, float)):
            failures.append(f"{key}: missing from the fresh run")
            continue
        checked += 1
        if got < ratio * floor:
            failures.append(
                f"{key}: {got:.3f} < {ratio} x baseline {floor:.3f} (floor {ratio * floor:.3f})"
            )
        else:
            print(f"ok {key}: {got:.3f} (baseline {floor:.3f}, floor {ratio * floor:.3f})")

    if checked == 0 and not failures:
        failures.append("baseline contains no speedup_* fields — nothing was gated")
    if failures:
        print("bench regression check FAILED:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"bench regression check passed ({checked} speedup fields)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
