//! The Sec. IV-B big-little scenario end to end: a tiny always-on onset
//! detector on the FC screens sensor windows; the 8-core cluster wakes
//! only on onsets to run the full gesture classifier.
//!
//! ```text
//! cargo run --release --example big_little
//! ```

use anyhow::Result;
use fann_on_mcu::apps::biglittle::BigLittle;
use fann_on_mcu::apps::energy::{autonomy, platform_sleep_mw, HARVEST_J_PER_DAY};
use fann_on_mcu::apps::{self, GESTURE};
use fann_on_mcu::datasets;
use fann_on_mcu::fann::cascade::{cascade_train, CascadeConfig};
use fann_on_mcu::fann::FixedNetwork;
use fann_on_mcu::simulator::{self, CostOptions, Executable};
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::{fmt_energy, Table};

fn main() -> Result<()> {
    println!("=== Big-little deployment (Sec. IV-B) ===\n");

    // --- little: cascade-grown onset detector -----------------------------
    // Binary task: "is there any gesture activity in this window?"
    // Built with cascade training (FANN's automatic topology growth) on a
    // 2-class version of the activity data, then quantized for the FC.
    println!("growing the little onset detector with cascade training...");
    let mut onset_data = datasets::generate(
        datasets::SyntheticSpec {
            num_features: 7,
            num_classes: 2,
            samples_per_class: 300,
            separation: 2.5,
            spread: 1.0,
            seed: 91,
        },
        true,
    );
    onset_data.normalize_inputs();
    let mut rng = Rng::new(91);
    let (little_float, report) = cascade_train(
        &onset_data,
        CascadeConfig {
            max_neurons: 8,
            desired_error: 0.02,
            ..CascadeConfig::default()
        },
        &mut rng,
    )?;
    println!(
        "  grew {} hidden neurons (MSE curve: {:.4} -> {:.4})",
        report.neurons_installed,
        report.mse_curve[0],
        report.mse_curve.last().unwrap()
    );
    let little = FixedNetwork::from_float(&little_float, 1.0)?;

    // --- big: the app-A gesture classifier --------------------------------
    println!("\ntraining the big gesture classifier (app A)...");
    let big_app = apps::train_app(&GESTURE, 23)?;
    println!("  test accuracy {:.2}%", big_app.test_accuracy * 100.0);

    // --- deploy the pair ---------------------------------------------------
    let bl = BigLittle::deploy(&little, &big_app.net)?;
    println!("\ndeployment:");
    println!(
        "  little: {} ({} bytes est.)",
        bl.little_plan.region.name(),
        bl.little_plan.est_memory_bytes
    );
    println!(
        "  big:    {} via {:?} DMA",
        bl.big_plan.region.name(),
        bl.big_plan.dma.unwrap()
    );

    // --- duty-cycle energy analysis ---------------------------------------
    println!("\nduty-cycle energy (10,000 windows):");
    let probe = vec![0.1f32; 7];
    let mut t = Table::new(vec![
        "onset rate",
        "big-little energy",
        "always-big energy",
        "saving",
    ]);
    for rate in [0.001, 0.01, 0.05, 0.2, 1.0] {
        let r = bl.duty_cycle(10_000, rate, &probe)?;
        t.row(vec![
            format!("{:.1}%", rate * 100.0),
            fmt_energy(r.total_energy_uj * 1e-6),
            fmt_energy(r.always_big_energy_uj * 1e-6),
            format!("{:.1}%", r.saving() * 100.0),
        ]);
    }
    t.print();

    // --- energy autonomy (Sec. III-C) --------------------------------------
    let x = vec![0.1f32; 76];
    let big_report = simulator::simulate(
        &bl.big_plan,
        &Executable::Float(&big_app.net),
        &x,
        CostOptions::default(),
    )?;
    let a = autonomy(
        &big_report,
        Target::WolfCluster { cores: 8 },
        10,
        platform_sleep_mw(Target::WolfCluster { cores: 8 }),
        HARVEST_J_PER_DAY,
    );
    println!(
        "\nenergy autonomy (InfiniWolf harvest budget {HARVEST_J_PER_DAY} J/day):"
    );
    println!(
        "  sustainable big classifications: {:.0}/day ({:.2} Hz continuous)",
        a.classifications_per_day, a.rate_hz
    );
    println!("  sleep budget: {:.2} J/day", a.sleep_j);
    println!("\nbig-little OK: low power (FC screening) + low latency (cluster on demand).");
    Ok(())
}
