//! Quickstart: the FANN classic — train XOR, quantize it, deploy it to
//! every supported target, and compare the simulated runtime/energy.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use fann_on_mcu::datasets;
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::fann::train::rprop::{Rprop, RpropConfig};
use fann_on_mcu::fann::train::mse;
use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::simulator::{self, CostOptions, Executable};
use fann_on_mcu::targets::{Chip, DataType, Target};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn main() -> Result<()> {
    // 1. Train a 2-4-1 MLP on XOR with iRPROP− (FANN's default trainer).
    let data = datasets::xor();
    let mut rng = Rng::new(42);
    let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);
    let mut trainer = Rprop::new(&net, RpropConfig::default());
    let curve = trainer.train_until(&mut net, &data, 500, 0.001);
    println!(
        "trained XOR in {} epochs (final MSE {:.5})",
        curve.len(),
        mse(&net, &data)
    );
    for x in [[0.0f32, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]] {
        println!("  {:?} -> {:.3}", x, net.run(&x)[0]);
    }

    // 2. Convert to fixed point (fann_save_to_fixed).
    let fixed = FixedNetwork::from_float(&net, 1.0)?;
    println!("\nfixed-point conversion: Q{} decimal point", fixed.decimal_point);

    // 3. Deploy everywhere and compare (Table II, in miniature).
    let shape = NetShape::from(&net);
    let mut table = Table::new(vec!["target", "placement", "dtype", "time", "energy"]);
    let targets = [
        Target::CortexM4(Chip::Nrf52832),
        Target::CortexM7(Chip::Stm32f769),
        Target::CortexM0(Chip::Nrf52832),
        Target::WolfFc,
        Target::WolfCluster { cores: 1 },
        Target::WolfCluster { cores: 8 },
    ];
    for target in targets {
        let dtype = if target.supports_float() {
            DataType::Float32
        } else {
            DataType::Fixed
        };
        let plan = deploy::plan(&shape, target, dtype)?;
        let exe = match dtype {
            DataType::Float32 => Executable::Float(&net),
            DataType::Fixed => Executable::Fixed(&fixed),
        };
        let r = simulator::simulate(&plan, &exe, &[1.0, 0.0], CostOptions::default())?;
        table.row(vec![
            target.label(),
            plan.region.name().to_string(),
            format!("{dtype:?}"),
            fmt_time(r.seconds),
            fmt_energy(r.energy_uj * 1e-6),
        ]);
    }
    println!();
    table.print();
    println!("\n(microsecond latencies at milliwatt power — the paper's point)");
    Ok(())
}
