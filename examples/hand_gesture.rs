//! Application A showcase — hand-gesture recognition (Sec. VI-A).
//!
//! Reproduces the paper's largest showcase: a 76-300-200-100-10 MLP
//! (103 800 MACs) trained on synthetic EMG+IMU-like features, deployed to
//! all four Table II targets, with the amortization analysis that yields
//! the paper's headline 22× / −73 % numbers.
//!
//! ```text
//! cargo run --release --example hand_gesture
//! ```

use anyhow::Result;
use fann_on_mcu::apps::{self, GESTURE};
use fann_on_mcu::simulator::PowerTrace;
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn main() -> Result<()> {
    println!("=== {} ===", GESTURE.title);
    println!(
        "topology {:?} = {} MACs (paper: 103800)\n",
        GESTURE.sizes,
        GESTURE.macs()
    );

    let app = apps::train_app(&GESTURE, 23)?;
    println!(
        "trained {} epochs | train acc {:.2}% | test acc {:.2}% (paper 85.58%)",
        app.mse_curve.len(),
        app.train_accuracy * 100.0,
        app.test_accuracy * 100.0
    );

    // Table II row.
    let data = GESTURE.dataset(23);
    let x = data.input(0);
    let mut table = Table::new(vec![
        "target", "placement", "runtime", "power", "energy", "vs M4",
    ]);
    let mut m4_time = 0.0;
    for target in Target::table2_targets() {
        let (plan, r) = apps::run_on_target(&app, target, x)?;
        if m4_time == 0.0 {
            m4_time = r.seconds;
        }
        table.row(vec![
            target.label(),
            plan.region.name().to_string(),
            fmt_time(r.seconds),
            format!("{:.2} mW", r.active_mw),
            fmt_energy(r.energy_uj * 1e-6),
            format!("{:.1}x", m4_time / r.seconds),
        ]);
    }
    println!();
    table.print();

    // Amortization: the asymptotic numbers (paper: 22x, −73%).
    let (plan, r) = apps::run_on_target(&app, Target::WolfCluster { cores: 8 }, x)?;
    let (_, m4) = apps::run_on_target(&app, Target::CortexM4(fann_on_mcu::targets::Chip::Nrf52832), x)?;
    println!("\ncluster amortization (classifications per activation):");
    let mut amort = Table::new(vec!["N", "time/classification", "energy/classification", "speedup vs M4", "energy saving"]);
    for n in [1u64, 2, 5, 10, 100, 1000] {
        let t = r.amortized_seconds(plan.target, n);
        let e = r.amortized_energy_uj(plan.target, n);
        amort.row(vec![
            n.to_string(),
            fmt_time(t),
            fmt_energy(e * 1e-6),
            format!("{:.1}x", m4.seconds / t),
            format!("{:.0}%", (1.0 - e / m4.energy_uj) * 100.0),
        ]);
    }
    amort.print();

    // Continuous real-time classification: sustainable window rates and
    // the duty-cycled vs always-on cluster policy crossover.
    println!("\ncontinuous classification (simulator::stream):");
    let mut st = Table::new(vec![
        "window rate",
        "M4 feasible",
        "cluster policy",
        "cluster energy/window",
        "M4 energy/window",
    ]);
    use fann_on_mcu::simulator::stream;
    let m4_sleep = 0.0057;
    let wolf_sleep = 0.0072;
    for rate in [1.0, 20.0, 50.0, 200.0, 1000.0] {
        let s_m4 = stream::analyze(&m4, Target::CortexM4(fann_on_mcu::targets::Chip::Nrf52832),
                                   m4_sleep, rate, stream::ClusterPolicy::DutyCycled);
        let (pol, s_w) = stream::best_cluster_policy(&r, plan.target, wolf_sleep, rate);
        st.row(vec![
            format!("{rate} Hz"),
            if s_m4.feasible { "yes".into() } else { format!("no (max {:.0} Hz)", s_m4.max_rate_hz) },
            format!("{pol:?}"),
            fmt_energy(s_w.energy_per_window_uj * 1e-6),
            fmt_energy(s_m4.energy_per_window_uj * 1e-6),
        ]);
    }
    st.print();

    // Fig. 13: the power trace of one end-to-end classification.
    println!("\npower trace of one classification (Fig. 13):");
    let trace = PowerTrace::for_cluster_run(&r, plan.target);
    for p in &trace.phases {
        println!(
            "  {:<28} {:>10}  {:>8.2} mW",
            p.name,
            fmt_time(p.seconds),
            p.milliwatts
        );
    }
    println!(
        "  total: {} / {}",
        fmt_time(trace.total_seconds()),
        fmt_energy(trace.total_energy_uj() * 1e-6)
    );
    Ok(())
}
