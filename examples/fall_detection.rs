//! Application B showcase — fall detection for elderly people (Sec. VI-B).
//!
//! A small 117-20-2 MLP where the paper's break-even analysis matters:
//! the cluster's 13 µJ activation overhead only pays off after ~6
//! classifications; for single classifications the FC (IBEX) wins.
//!
//! ```text
//! cargo run --release --example fall_detection
//! ```

use anyhow::Result;
use fann_on_mcu::apps::{self, FALL};
use fann_on_mcu::codegen::{self, NetSource};
use fann_on_mcu::deploy;
use fann_on_mcu::targets::{DataType, Target};
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn main() -> Result<()> {
    println!("=== {} ===", FALL.title);
    let app = apps::train_app(&FALL, 21)?;
    println!(
        "trained {} epochs | test acc {:.2}% (paper 84%)\n",
        app.mse_curve.len(),
        app.test_accuracy * 100.0
    );

    let data = FALL.dataset(21);
    let x = data.input(1);

    // Table II row for app B.
    let mut table = Table::new(vec!["target", "runtime", "power", "energy"]);
    for target in Target::table2_targets() {
        let (_, r) = apps::run_on_target(&app, target, x)?;
        table.row(vec![
            target.label(),
            fmt_time(r.seconds),
            format!("{:.2} mW", r.active_mw),
            fmt_energy(r.energy_uj * 1e-6),
        ]);
    }
    table.print();

    // The paper's break-even: IBEX 2.86 µJ/classification vs cluster
    // 0.67 µJ + 13 µJ one-time -> parallel pays off beyond ~6.
    let (_, ibex) = apps::run_on_target(&app, Target::WolfFc, x)?;
    let (plan, multi) = apps::run_on_target(&app, Target::WolfCluster { cores: 8 }, x)?;
    println!("\nbreak-even analysis (paper: parallel pays off after ~6 classifications):");
    let mut n = 1u64;
    let break_even = loop {
        let cluster_total = multi.amortized_energy_uj(plan.target, n) * n as f64;
        let ibex_total = ibex.energy_uj * n as f64;
        if cluster_total < ibex_total {
            break n;
        }
        n += 1;
        if n > 1000 {
            break 0;
        }
    };
    println!("  modeled break-even: {break_even} classifications");
    println!(
        "  continuous operation: cluster is {:.1}x more energy-efficient than IBEX",
        ibex.energy_uj / multi.energy_uj
    );

    // Generated C for the wearable's FC deployment.
    let plan_fc = deploy::plan(&app.spec.shape(), Target::WolfFc, DataType::Fixed)?;
    let code = codegen::generate(&plan_fc, NetSource::Fixed(&app.fixed));
    println!(
        "\ngenerated C bundle for the FC deployment: {} files, {} bytes",
        code.files.len(),
        code.total_bytes()
    );
    for (name, _) in &code.files {
        println!("  {name}");
    }
    Ok(())
}
