//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **L1/L2 (build time)**: `make artifacts` lowered the Pallas-kernel
//!    MLP and its SGD training step to HLO text.
//! 2. **L3 (this binary)**: loads the artifacts via PJRT, trains the
//!    gesture network (76-300-200-100-10, ~104k parameters) for several
//!    hundred steps on the synthetic EMG/IMU dataset, logging the loss
//!    curve.
//! 3. Exports the trained parameters into the FANN toolkit, quantizes,
//!    deploys to all Table II targets, and reports latency/energy —
//!    training (JAX/PJRT) and deployment (toolkit) composing end to end.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_train
//! ```

use anyhow::Result;
use fann_on_mcu::apps::{self, GESTURE};
use fann_on_mcu::fann::train::accuracy;
use fann_on_mcu::fann::FixedNetwork;
use fann_on_mcu::runtime::{ArtifactDir, PjrtTrainer, Runtime};
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

const STEPS: usize = 800;

fn main() -> Result<()> {
    // --- L3 loads the AOT artifacts -------------------------------------
    let art = ArtifactDir::locate(None)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut trainer = PjrtTrainer::new(&rt, &art, "gesture", 23)?;
    println!(
        "loaded gesture artifacts: {} params, train batch {}",
        trainer.manifest.num_params, trainer.manifest.train_batch
    );

    // --- dataset ---------------------------------------------------------
    let mut data = GESTURE.dataset(23);
    data.normalize_inputs();
    let (train, test) = data.split(0.8);
    println!("dataset: {} train / {} test samples\n", train.len(), test.len());

    // --- training loop (L3 drives the L2/L1 program) ---------------------
    let mut rng = Rng::new(77);
    let t0 = std::time::Instant::now();
    println!("training {STEPS} steps of SGD (lr baked into the artifact):");
    let curve = trainer.train(&train, STEPS, &mut rng)?;
    let wall = t0.elapsed().as_secs_f64();
    for (i, loss) in curve.iter().enumerate() {
        if i % 40 == 0 || i + 1 == curve.len() {
            println!("  step {i:>4}: loss {loss:.5}");
        }
    }
    println!(
        "\nloss {:.5} -> {:.5} in {:.1}s ({:.1} steps/s)",
        curve[0],
        curve.last().unwrap(),
        wall,
        STEPS as f64 / wall
    );
    let acc_train = trainer.accuracy(&train)?;
    let acc_test = trainer.accuracy(&test)?;
    println!("accuracy: train {:.2}% / test {:.2}% (paper: 85.58%)", acc_train * 100.0, acc_test * 100.0);

    // --- export to the toolkit and deploy --------------------------------
    let net = trainer.to_network()?;
    let native_acc = accuracy(&net, &test);
    println!(
        "\nexported to FANN toolkit; native forward test accuracy {:.2}% (must match PJRT)",
        native_acc * 100.0
    );
    let fixed = FixedNetwork::from_float(&net, 1.0)?;
    println!("quantized to Q{}", fixed.decimal_point);

    let trained = apps::TrainedApp {
        spec: &GESTURE,
        net,
        fixed,
        train_accuracy: acc_train,
        test_accuracy: acc_test,
        mse_curve: curve,
    };
    let x = test.input(0);
    let mut table = Table::new(vec!["target", "placement", "runtime", "energy"]);
    for target in Target::table2_targets() {
        let (plan, r) = apps::run_on_target(&trained, target, x)?;
        table.row(vec![
            target.label(),
            plan.region.name().to_string(),
            fmt_time(r.seconds),
            fmt_energy(r.energy_uj * 1e-6),
        ]);
    }
    println!();
    table.print();
    println!("\nend-to-end OK: JAX/Pallas-trained network deployed through the toolkit.");
    Ok(())
}
