//! Application C showcase — human activity classification (Sec. VI-C).
//!
//! The tiniest network (7-6-5): runtimes sit in the microsecond range and
//! the paper compares against the FPGA implementation of [46]
//! (270 ns @ 241 mW): the IBEX core is slower but 400x+ more
//! energy-efficient.
//!
//! ```text
//! cargo run --release --example activity_classification
//! ```

use anyhow::Result;
use fann_on_mcu::apps::{self, ACTIVITY};
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

/// The FPGA baseline of Gaikwad et al. [46].
const FPGA_TIME_S: f64 = 270e-9;
const FPGA_POWER_MW: f64 = 241.0;

fn main() -> Result<()> {
    println!("=== {} ===", ACTIVITY.title);
    let app = apps::train_app(&ACTIVITY, 22)?;
    println!(
        "trained {} epochs | test acc {:.2}% (paper 94.6%)\n",
        app.mse_curve.len(),
        app.test_accuracy * 100.0
    );

    let data = ACTIVITY.dataset(22);
    let x = data.input(0);

    let fpga_energy = FPGA_TIME_S * FPGA_POWER_MW * 1e3; // µJ
    let mut table = Table::new(vec![
        "implementation",
        "runtime",
        "power",
        "energy",
        "energy vs FPGA",
    ]);
    table.row(vec![
        "FPGA (Gaikwad et al. [46])".to_string(),
        fmt_time(FPGA_TIME_S),
        format!("{FPGA_POWER_MW:.0} mW"),
        fmt_energy(fpga_energy * 1e-6),
        "1x".to_string(),
    ]);
    for target in Target::table2_targets() {
        let (_, r) = apps::run_on_target(&app, target, x)?;
        table.row(vec![
            target.label(),
            fmt_time(r.seconds),
            format!("{:.2} mW", r.active_mw),
            fmt_energy(r.energy_uj * 1e-6),
            format!("{:.0}x better", fpga_energy / r.energy_uj),
        ]);
    }
    table.print();

    // Per-sample classification demo on the deployed fixed-point net.
    println!("\nsample classifications (fixed-point deployment on IBEX):");
    let mut correct = 0;
    let n = 10;
    for i in 0..n {
        let (_, r) = apps::run_on_target(&app, Target::WolfFc, data.input(i))?;
        let pred = fann_on_mcu::util::argmax(&r.outputs);
        let truth = data.label(i);
        if pred == truth {
            correct += 1;
        }
        println!("  sample {i}: predicted class {pred}, true class {truth}");
    }
    println!("  {correct}/{n} correct");

    // Continuous stream, batched: ONE deployment classifies every window
    // (batched kernel dispatch on the host) and the cluster's 1.2 ms
    // bring-up is paid once for the stream instead of once per window —
    // the amortization the paper's Table II footnote describes.
    let n_windows = 64;
    let mut xs = Vec::with_capacity(n_windows * 7);
    for i in 0..n_windows {
        xs.extend_from_slice(data.input(i % data.len()));
    }
    let target = Target::WolfCluster { cores: 8 };
    let (preds, report) = apps::classify_stream(&app, target, &xs, n_windows)?;
    let correct = (0..n_windows)
        .filter(|&i| preds[i] == data.label(i % data.len()))
        .count();
    println!(
        "\nbatched stream on the 8-core cluster: {n_windows} windows in {} (modeled, {:.0} windows/s), {correct}/{n_windows} correct",
        fmt_time(report.total_seconds),
        report.throughput_hz
    );
    println!(
        "  vs {n_windows} independent end-to-end classifications: {} (bring-up paid once, not {n_windows}x)",
        fmt_time(n_windows as f64 * report.per_sample.e2e_seconds)
    );
    Ok(())
}
