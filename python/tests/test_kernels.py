"""L1 float kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute hot path: hypothesis
sweeps shapes, activations and streaming block sizes, and every output is
pinned to the reference with assert_allclose.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec, ref

ACTS = ["linear", "sigmoid", "tanh", "relu"]


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    batch=st.integers(1, 9),
    n_in=st.integers(1, 70),
    n_out=st.integers(1, 70),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(batch, n_in, n_out, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, batch, n_in), rand(rng, n_in, n_out), rand(rng, n_out)
    got = matvec.dense(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    want = ref.dense(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    n_out=st.integers(2, 64),
    blk=st.integers(1, 64),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_streaming_block_invariant(n_out, blk, act, seed):
    """Neuron-wise streaming (any out_block) must match the layer-wise
    single-block result — the Pallas analogue of the paper's claim that
    DMA transfer granularity never changes results, only cycles."""
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, 3, 17), rand(rng, 17, n_out), rand(rng, n_out)
    xa, wa, ba = jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)
    layerwise = matvec.dense(xa, wa, ba, act, out_block=n_out)
    neuronwise = matvec.dense(xa, wa, ba, act, out_block=blk)
    np.testing.assert_allclose(neuronwise, layerwise, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("act", ACTS)
def test_dense_layer_vjp_matches_autodiff_of_ref(act):
    rng = np.random.default_rng(7)
    x, w, b = rand(rng, 5, 23), rand(rng, 23, 11), rand(rng, 11)
    xa, wa, ba = jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)

    def f_ref(x, w, b):
        return (ref.dense(x, w, b, act) * jnp.arange(11.0)).sum()

    def f_ker(x, w, b):
        return (matvec.dense_layer(x, w, b, act) * jnp.arange(11.0)).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(xa, wa, ba)
    g_ker = jax.grad(f_ker, argnums=(0, 1, 2))(xa, wa, ba)
    for a, c in zip(g_ref, g_ker):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(1, 6),
    n_in=st.integers(1, 40),
    n_out=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_kernels_match_ref(batch, n_in, n_out, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, batch, n_in), rand(rng, n_in, n_out)
    dz = rand(rng, batch, n_out)
    np.testing.assert_allclose(
        matvec.dense_bwd_dx(jnp.asarray(dz), jnp.asarray(w)),
        np.dot(dz, w.T), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        matvec.dense_bwd_dw(jnp.asarray(x), jnp.asarray(dz)),
        np.dot(x.T, dz), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        matvec.dense_bwd_db(jnp.asarray(dz)),
        dz.sum(axis=0), rtol=2e-5, atol=2e-5)


def test_choose_out_block_layerwise_when_fits():
    # 100x100 f32 = 40 kB << budget -> whole matrix resident.
    assert matvec.choose_out_block(100, 100) == 100


def test_choose_out_block_streams_when_too_large():
    budget = matvec.VMEM_WEIGHT_BUDGET
    n_in = 4096
    n_out = 8192  # 128 MiB matrix
    blk = matvec.choose_out_block(n_in, n_out)
    assert blk < n_out
    assert n_in * blk * 4 <= budget
    assert blk % matvec.MXU_LANES == 0


def test_vmem_footprint_fits_budget_after_block_choice():
    for n_in, n_out in [(76, 300), (4096, 8192), (300, 200), (2048, 2048)]:
        blk = matvec.choose_out_block(n_in, n_out)
        fp = matvec.vmem_footprint_bytes(32, n_in, n_out, blk)
        assert fp <= 16 * 1024 * 1024, (n_in, n_out, blk, fp)


def test_mxu_utilization_bounds():
    for b, i, o in [(1, 76, 300), (32, 128, 128), (8, 117, 20)]:
        u = matvec.mxu_utilization_estimate(b, i, o)
        assert 0.0 < u <= 1.0
    # Perfectly tiled shape has utilization exactly 1.
    assert matvec.mxu_utilization_estimate(8, 128, 256) == 1.0
