"""Fixed-point Pallas kernel vs the numpy oracle — bit-exact.

The fixed-point path is what actually runs on FPU-less MCUs (M0, IBEX);
FANN's fann_mult semantics (per-product shift, saturating accumulate,
step-linear activations) must match across Pallas / numpy / Rust. Rust is
pinned via artifacts/parity_fixed.tsv; these tests pin Pallas to numpy.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fixedpoint, ref

ACTS = ["linear", "sigmoid", "tanh", "relu"]


def randq(rng, one, lo, hi, *shape):
    return (rng.uniform(lo, hi, shape) * one).astype(np.int32)


@settings(max_examples=30, deadline=None)
@given(
    batch=st.integers(1, 5),
    n_in=st.integers(1, 40),
    n_out=st.integers(1, 40),
    dec=st.integers(4, 20),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_q_bit_exact(batch, n_in, n_out, dec, act, seed):
    rng = np.random.default_rng(seed)
    one = 1 << dec
    x = randq(rng, one, -2, 2, batch, n_in)
    w = randq(rng, one, -2, 2, n_in, n_out)
    b = randq(rng, one, -1, 1, n_out)
    got = np.asarray(fixedpoint.dense_q(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), dec, act))
    want = ref.dense_q(x, w, b, dec, act)
    np.testing.assert_array_equal(got.astype(np.int64), want)


@settings(max_examples=15, deadline=None)
@given(
    blk=st.integers(1, 32),
    dec=st.integers(6, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_q_streaming_block_invariant(blk, dec, seed):
    rng = np.random.default_rng(seed)
    one = 1 << dec
    x = randq(rng, one, -1, 1, 2, 19)
    w = randq(rng, one, -2, 2, 19, 27)
    b = randq(rng, one, -1, 1, 27)
    a = np.asarray(fixedpoint.dense_q(jnp.asarray(x), jnp.asarray(w),
                                      jnp.asarray(b), dec, "tanh"))
    c = np.asarray(fixedpoint.dense_q(jnp.asarray(x), jnp.asarray(w),
                                      jnp.asarray(b), dec, "tanh",
                                      out_block=blk))
    np.testing.assert_array_equal(a, c)


@pytest.mark.parametrize("dec", [6, 12, 13])
def test_activation_tables_match_oracle_at_breakpoints(dec):
    one = np.int64(1) << dec
    # Exactly at / around every breakpoint, both directions.
    pts = np.concatenate([
        np.array([-6, -4, -3, -2, -1, 0, 1, 2, 3, 4, 6], dtype=np.int64) * one,
        np.array([-6, -4, -3, -2, -1, 0, 1, 2, 3, 4, 6], dtype=np.int64) * one + 1,
        np.array([-6, -4, -3, -2, -1, 0, 1, 2, 3, 4, 6], dtype=np.int64) * one - 1,
        np.array([-100 * one, 100 * one], dtype=np.int64),
    ])
    pts = np.clip(pts, ref.I32_MIN, ref.I32_MAX).astype(np.int32)
    x = pts.reshape(1, -1)
    eye_w = np.zeros((x.shape[1], x.shape[1]), dtype=np.int32)
    np.fill_diagonal(eye_w, int(one))  # identity in Q(dec): w=1.0
    zero_b = np.zeros(x.shape[1], dtype=np.int32)
    for act in ("sigmoid", "tanh"):
        got = np.asarray(fixedpoint.dense_q(
            jnp.asarray(x), jnp.asarray(eye_w), jnp.asarray(zero_b), dec, act))
        want = ref.dense_q(x, eye_w, zero_b, dec, act)
        np.testing.assert_array_equal(got.astype(np.int64), want)


def test_sigmoid_q_range_and_monotonicity():
    dec = 12
    one = 1 << dec
    xs = np.arange(-8 * one, 8 * one, 97, dtype=np.int64)
    ys = ref.step_linear_sigmoid_q(xs, dec)
    assert ys.min() >= 0 and ys.max() <= one
    assert (np.diff(ys) >= 0).all()
    # Odd symmetry around the midpoint: sigmoid(x) + sigmoid(-x) ~= one.
    s = ref.step_linear_sigmoid_q(xs, dec) + ref.step_linear_sigmoid_q(-xs, dec)
    assert np.abs(s - one).max() <= 2


def test_tanh_q_range_and_symmetry():
    dec = 12
    one = 1 << dec
    xs = np.arange(-5 * one, 5 * one, 113, dtype=np.int64)
    ys = ref.step_linear_tanh_q(xs, dec)
    assert ys.min() >= -one and ys.max() <= one
    assert (np.diff(ys) >= 0).all()
    # anti-symmetry within one LSB (integer floor-div asymmetry)
    s = ref.step_linear_tanh_q(xs, dec) + ref.step_linear_tanh_q(-xs, dec)
    assert np.abs(s).max() <= 1


def test_accumulator_saturation():
    """Large products must saturate to i32, not wrap."""
    dec = 4
    one = 1 << dec
    n = 64
    x = np.full((1, n), 100_000 * one, dtype=np.int32)
    w = np.full((n, 1), 100_000 * one, dtype=np.int32)
    b = np.zeros(1, dtype=np.int32)
    got = np.asarray(fixedpoint.dense_q(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), dec, "linear"))
    want = ref.dense_q(x, w, b, dec, "linear")
    np.testing.assert_array_equal(got.astype(np.int64), want)
    assert want[0, 0] == ref.I32_MAX  # saturated, not wrapped
