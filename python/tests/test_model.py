"""L2 model tests: shapes, loss descent, flat-arg calling convention."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.topologies import TOPOLOGIES


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_forward_shapes(name):
    topo = TOPOLOGIES[name]
    params = model.init_params(0, topo.layer_sizes)
    x = jnp.zeros((3, topo.inputs))
    out = model.forward(params, x, topo.hidden_activation,
                        topo.output_activation)
    assert out.shape == (3, topo.outputs)


def test_forward_matches_ref_oracle():
    topo = TOPOLOGIES["example"]
    params = model.init_params(3, topo.layer_sizes)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((8, topo.inputs)).astype(np.float32)
    got = model.forward(params, jnp.asarray(x))
    want = ref.mlp_forward(params, jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_train_step_descends_on_xor():
    topo = TOPOLOGIES["xor"]
    params = model.init_params(42, topo.layer_sizes)
    x = jnp.array([[0., 0.], [0., 1.], [1., 0.], [1., 1.]])
    y = jnp.array([[0.], [1.], [1.], [0.]])
    losses = []
    for _ in range(300):
        params, loss = model.train_step(params, x, y, topo.learning_rate)
        losses.append(float(loss))
    assert losses[-1] < 0.05, losses[-1]
    assert losses[-1] < losses[0]


def test_train_step_flat_roundtrip():
    """The flat calling convention used by the AOT artifacts must agree
    with the pytree API."""
    topo = TOPOLOGIES["activity"]
    params = model.init_params(1, topo.layer_sizes)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((32, topo.inputs)).astype(np.float32))
    y = jnp.asarray(rng.uniform(0, 1, (32, topo.outputs)).astype(np.float32))

    flat = []
    for w, b in params:
        flat.extend((w, b))
    out = model.train_step_flat(topo, *flat, x, y)
    new_params, loss = model.train_step(params, x, y, topo.learning_rate)

    assert len(out) == 2 * len(params) + 1
    for i, (w, b) in enumerate(new_params):
        np.testing.assert_allclose(out[2 * i], w, rtol=1e-6)
        np.testing.assert_allclose(out[2 * i + 1], b, rtol=1e-6)
    np.testing.assert_allclose(out[-1], loss, rtol=1e-6)


def test_arg_specs_counts():
    topo = TOPOLOGIES["gesture"]
    fwd = model.arg_specs(topo, 1, with_labels=False)
    tr = model.arg_specs(topo, 32, with_labels=True)
    n_layers = len(topo.layer_sizes) - 1
    assert len(fwd) == 2 * n_layers + 1
    assert len(tr) == 2 * n_layers + 2
    assert fwd[-1].shape == (1, topo.inputs)
    assert tr[-1].shape == (32, topo.outputs)


def test_macs_and_params_registry():
    # Paper: application A (gesture) = 103800 MACs.
    assert TOPOLOGIES["gesture"].macs == 103800
    assert TOPOLOGIES["fall"].macs == 117 * 20 + 20 * 2
    assert TOPOLOGIES["activity"].macs == 7 * 6 + 6 * 5
