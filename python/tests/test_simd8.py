"""int8 packed-SIMD kernel vs its numpy reference — bit-exact."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import simd8


@settings(max_examples=30, deadline=None)
@given(
    batch=st.integers(1, 5),
    n_in=st.integers(1, 48),
    n_out=st.integers(1, 48),
    dw=st.integers(2, 6),
    act=st.sampled_from(["linear", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_q8_bit_exact(batch, n_in, n_out, dw, act, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (batch, n_in), dtype=np.int8)
    w = rng.integers(-128, 128, (n_in, n_out), dtype=np.int8)
    b = rng.integers(-(1 << 12), 1 << 12, n_out, dtype=np.int32)
    got = np.asarray(simd8.dense_q8(jnp.asarray(x), jnp.asarray(w),
                                    jnp.asarray(b), dw, act))
    want = simd8.dense_q8_ref(x, w, b, dw, act)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(blk=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_dense_q8_streaming_block_invariant(blk, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (2, 19), dtype=np.int8)
    w = rng.integers(-128, 128, (19, 23), dtype=np.int8)
    b = rng.integers(-1000, 1000, 23, dtype=np.int32)
    a = np.asarray(simd8.dense_q8(jnp.asarray(x), jnp.asarray(w),
                                  jnp.asarray(b), 4, "relu"))
    c = np.asarray(simd8.dense_q8(jnp.asarray(x), jnp.asarray(w),
                                  jnp.asarray(b), 4, "relu", out_block=blk))
    np.testing.assert_array_equal(a, c)


def test_int8_tracks_float_within_quantization_noise():
    rng = np.random.default_rng(3)
    dx, dw = 4, 5
    x = rng.uniform(-1, 1, (4, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 8)).astype(np.float32)
    b = rng.uniform(-0.5, 0.5, 8).astype(np.float32)

    x_q8 = simd8.quantize8(x, dx)
    w_q8, b_q32 = simd8.quantize_layer8(w, b, dx, dw)
    out_q = simd8.dense_q8_ref(x_q8, w_q8, b_q32, dw, "relu")
    out_f = np.maximum(x @ w + b, 0.0)
    # Dequantized int8 output within coarse-quantization noise of float.
    got = out_q.astype(np.float64) / (1 << dx)
    # int8 at Q(4) has LSB 1/16 and inputs carry Q(4) error through a
    # 32-deep accumulation.
    assert np.abs(got - out_f).max() < 0.35, np.abs(got - out_f).max()


def test_output_saturates_to_int8():
    x = np.full((1, 16), 127, dtype=np.int8)
    w = np.full((16, 1), 127, dtype=np.int8)
    b = np.zeros(1, dtype=np.int32)
    out = simd8.dense_q8_ref(x, w, b, 2, "linear")
    assert out[0, 0] == 127  # saturated, not wrapped
    out = simd8.dense_q8_ref(-x, w, b, 2, "linear")
    assert out[0, 0] == -128


def test_sigmoid_rejected_on_int8_path():
    x = np.zeros((1, 4), dtype=np.int8)
    w = np.zeros((4, 2), dtype=np.int8)
    b = np.zeros(2, dtype=np.int32)
    with pytest.raises(ValueError):
        simd8.dense_q8_ref(x, w, b, 4, "sigmoid")
    with pytest.raises(ValueError):
        simd8.dense_q8(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 4, "tanh")
