"""AOT pipeline tests: HLO text validity and manifest/parity emission."""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot
from compile.topologies import TOPOLOGIES


def test_lower_forward_emits_hlo_text():
    text = aot.lower_forward(TOPOLOGIES["xor"], batch=1)
    assert text.startswith("HloModule")
    # return_tuple=True -> root is a tuple
    assert "ROOT" in text
    # parameters: 2 layers * (w, b) + x = 5
    assert text.count("parameter(") >= 5


def test_lower_train_emits_hlo_text():
    text = aot.lower_train(TOPOLOGIES["xor"], batch=32)
    assert text.startswith("HloModule")
    # The training step must not leak python callbacks into HLO.
    assert "CustomCall" not in text or "Mosaic" not in text


def test_manifest_roundtrip():
    topo = TOPOLOGIES["fall"]
    with tempfile.TemporaryDirectory() as d:
        aot.write_manifest(topo, d)
        path = os.path.join(d, "fall_manifest.txt")
        fields = {}
        with open(path) as f:
            for line in f:
                k, _, v = line.strip().partition(" ")
                fields[k] = v
    assert fields["inputs"] == "117"
    assert fields["outputs"] == "2"
    assert fields["hidden"] == "20"
    assert fields["macs"] == str(topo.macs)


def test_parity_files_parse():
    with tempfile.TemporaryDirectory() as d:
        aot.emit_parity_float(d)
        aot.emit_parity_fixed(d)
        for fname, n_cases in [("parity_float.tsv", len(TOPOLOGIES)),
                               ("parity_fixed.tsv", len(TOPOLOGIES))]:
            cases = 0
            with open(os.path.join(d, fname)) as f:
                for line in f:
                    parts = line.rstrip("\n").split("\t")
                    if parts[0] == "case":
                        cases += 1
                    elif parts[0] not in ("acts", "dec"):
                        tag, shape, data = parts
                        dims = [int(x) for x in shape.split("x")]
                        vals = data.split(" ")
                        assert len(vals) == int.__mul__(
                            *dims) if len(dims) == 2 else len(vals) == dims[0]
            assert cases == n_cases


@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_artifacts_exist_after_make(name):
    """If `make artifacts` ran (CI flow), the files must all be present.
    Skipped when artifacts/ has not been built yet."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("artifacts/ not built")
    for suffix in ("_fwd_b1.hlo.txt", "_fwd_b32.hlo.txt",
                   "_train_b32.hlo.txt", "_manifest.txt"):
        assert os.path.exists(os.path.join(art, name + suffix))
