"""Pure-jnp correctness oracles for the Pallas kernels.

Everything in here is deliberately written with plain ``jnp`` ops and no
Pallas so that a disagreement between ``matvec.py`` / ``fixedpoint.py`` and
this module localizes the bug to the kernel.

The fixed-point reference mirrors FANN's semantics (``fann_mult``): each
product is computed at double width and arithmetic-shifted right by the
network-wide decimal point before accumulation; the final sum saturates to
i32. The identical semantics are implemented in Rust
(``rust/src/quantize/mod.rs``) — the three implementations are pinned
together by parity tests.
"""

import jax.numpy as jnp
import numpy as np

I32_MIN = -(2**31)
I32_MAX = 2**31 - 1


# ---------------------------------------------------------------------------
# Float reference
# ---------------------------------------------------------------------------

def activation(name: str, x):
    """FANN activation functions (float reference, exact math)."""
    if name == "linear":
        return x
    if name == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if name == "tanh":
        # FANN_SIGMOID_SYMMETRIC.
        return jnp.tanh(x)
    if name == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(f"unknown activation {name!r}")


def activation_grad_from_output(name: str, y):
    """Derivative expressed in terms of the activation *output*, as FANN
    does during backprop (it only keeps neuron outputs, not pre-acts)."""
    if name == "linear":
        return jnp.ones_like(y)
    if name == "sigmoid":
        return y * (1.0 - y)
    if name == "tanh":
        return 1.0 - y * y
    if name == "relu":
        return (y > 0.0).astype(y.dtype)
    raise ValueError(f"unknown activation {name!r}")


def dense(x, w, b, act: str = "linear"):
    """Reference for the L1 forward kernel: ``act(x @ w + b)``.

    x: (B, In) f32, w: (In, Out) f32, b: (Out,) f32 -> (B, Out) f32.
    """
    return activation(act, jnp.dot(x, w) + b[None, :])


def dense_bwd(x, w, y, dy, act: str = "linear"):
    """Reference for the L1 backward kernels.

    Given the forward residuals (x, w, y) and the cotangent dy, returns
    (dx, dw, db) with the activation derivative taken from the output y.
    """
    dz = dy * activation_grad_from_output(act, y)
    dx = jnp.dot(dz, w.T)
    dw = jnp.dot(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


def mlp_forward(params, x, hidden_act="tanh", output_act="sigmoid"):
    """Reference MLP forward over a list of (w, b) pairs."""
    h = x
    for i, (w, b) in enumerate(params):
        act = output_act if i == len(params) - 1 else hidden_act
        h = dense(h, w, b, act)
    return h


# ---------------------------------------------------------------------------
# Fixed-point reference (FANN fann_mult semantics)
# ---------------------------------------------------------------------------

def sat_i32(x):
    return np.clip(x, I32_MIN, I32_MAX).astype(np.int64)


def _interp_table_q(x: np.ndarray, xs: np.ndarray, vs: np.ndarray,
                    lo: np.int64, hi: np.int64) -> np.ndarray:
    """Integer piecewise-linear interpolation over breakpoint table
    (xs, vs), clamped to [lo, hi] outside the table. Floor division —
    matches the Rust implementation exactly."""
    out = np.empty_like(x)
    out[x <= xs[0]] = lo
    out[x >= xs[-1]] = hi
    for i in range(len(xs) - 1):
        m = (x > xs[i]) & (x < xs[i + 1])
        if not m.any():
            continue
        dxs = xs[i + 1] - xs[i]
        out[m] = vs[i] + (x[m] - xs[i]) * (vs[i + 1] - vs[i]) // dxs
    for i in range(1, len(xs) - 1):
        out[x == xs[i]] = vs[i]
    return out


def step_linear_sigmoid_q(x_q: np.ndarray, dec: int) -> np.ndarray:
    """FANN's piecewise step-linear approximation of the sigmoid, in
    Q(dec) fixed point. Mirrors ``quantize::step_linear_sigmoid_q`` in Rust
    bit-for-bit. Input/output are int64 arrays holding Q(dec) values."""
    one = np.int64(1) << dec
    pts = np.array([-6, -4, -2, -1, 0, 1, 2, 4, 6], dtype=np.int64)
    xs = pts * one
    vs_real = 1.0 / (1.0 + np.exp(-pts.astype(np.float64)))
    vs = np.round(vs_real * float(one)).astype(np.int64)
    return _interp_table_q(x_q.astype(np.int64), xs, vs, np.int64(0), one)


def step_linear_tanh_q(x_q: np.ndarray, dec: int) -> np.ndarray:
    """Symmetric step-linear sigmoid (tanh) in Q(dec) (matches Rust)."""
    one = np.int64(1) << dec
    pts = np.array([-3, -2, -1, 0, 1, 2, 3], dtype=np.int64)
    xs = pts * one
    vs = np.round(np.tanh(pts.astype(np.float64)) * float(one)).astype(np.int64)
    return _interp_table_q(x_q.astype(np.int64), xs, vs, -one, one)


def activation_q(name: str, x_q: np.ndarray, dec: int) -> np.ndarray:
    if name == "linear":
        return x_q.astype(np.int64)
    if name == "sigmoid":
        return step_linear_sigmoid_q(x_q, dec)
    if name == "tanh":
        return step_linear_tanh_q(x_q, dec)
    if name == "relu":
        return np.maximum(x_q.astype(np.int64), 0)
    raise ValueError(f"unknown activation {name!r}")


def dense_q(x_q: np.ndarray, w_q: np.ndarray, b_q: np.ndarray, dec: int,
            act: str = "linear") -> np.ndarray:
    """Fixed-point dense layer reference.

    x_q: (B, In) i32-valued, w_q: (In, Out), b_q: (Out,), all Q(dec).
    Per-product shift (FANN fann_mult), i64 accumulation, i32 saturation
    before the activation.
    """
    x = x_q.astype(np.int64)
    w = w_q.astype(np.int64)
    prods = (x[:, :, None] * w[None, :, :]) >> dec  # (B, In, Out)
    acc = prods.sum(axis=1) + b_q.astype(np.int64)[None, :]
    acc = sat_i32(acc)
    return sat_i32(activation_q(act, acc, dec))


def mlp_forward_q(params_q, x_q, dec: int, hidden_act="tanh",
                  output_act="sigmoid") -> np.ndarray:
    h = x_q
    for i, (w, b) in enumerate(params_q):
        act = output_act if i == len(params_q) - 1 else hidden_act
        h = dense_q(h, w, b, dec, act)
    return h
