"""L1 Pallas kernels: the paper's compute hot-spot (Eq. 1 dense layer).

The paper's inner loop is a dot product streamed through an MCU memory
hierarchy (L2 -> L1 via DMA double-buffering, either *layer-wise* — whole
weight matrix resident in L1 — or *neuron-wise* — one output neuron's
weights at a time). The TPU adaptation (DESIGN.md §Hardware-Adaptation)
maps L1 SRAM to VMEM and the cluster DMA to the BlockSpec-scheduled
HBM->VMEM pipeline: when the weight matrix fits the VMEM budget we run a
single-block kernel (layer-wise); when it does not, the grid tiles the
output dimension and Pallas double-buffers consecutive weight column-blocks
exactly like the paper's neuron-wise DMA.

Forward *and* backward are hand-written Pallas kernels wired through
``jax.custom_vjp`` (autodiff cannot see through ``pallas_call``). The
activation derivative is taken from the activation *output*, mirroring
FANN's backprop.

All kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls. Real-TPU efficiency is estimated analytically in
EXPERIMENTS.md §Perf from the VMEM footprint / MXU tile occupancy of the
chosen block shapes.
"""

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for a weight block, in bytes. Half of a typical 16 MiB TPU
# VMEM, leaving room for x/out blocks and double-buffering (Pallas keeps
# two in-flight copies of each streamed block).
VMEM_WEIGHT_BUDGET = 4 * 1024 * 1024

# MXU lane geometry used for tile-shape selection and utilization estimates.
MXU_LANES = 128
SUBLANES = 8

ACTIVATIONS = ("linear", "sigmoid", "tanh", "relu")


def _apply_activation(act: str, x):
    if act == "linear":
        return x
    if act == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if act == "tanh":
        return jnp.tanh(x)
    if act == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(f"unknown activation {act!r}")


def _grad_from_output(act: str, y):
    if act == "linear":
        return jnp.ones_like(y)
    if act == "sigmoid":
        return y * (1.0 - y)
    if act == "tanh":
        return 1.0 - y * y
    if act == "relu":
        return (y > 0.0).astype(y.dtype)
    raise ValueError(f"unknown activation {act!r}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def choose_out_block(n_in: int, n_out: int,
                     budget: int = VMEM_WEIGHT_BUDGET) -> int:
    """Pick the output-dimension block size for streaming the weights.

    Mirrors ``deploy::placement``'s L1-fit decision on the Rust side:
    *layer-wise* (whole W resident -> block = n_out) when the matrix fits
    the budget, else the largest MXU-lane-aligned column block that does
    (*neuron-wise* streaming).
    """
    if n_in * n_out * 4 <= budget:
        return n_out
    blk = max(budget // (n_in * 4), 1)
    # Align down to the MXU lane count when possible.
    if blk >= MXU_LANES:
        blk = (blk // MXU_LANES) * MXU_LANES
    return max(blk, 1)


def vmem_footprint_bytes(batch: int, n_in: int, n_out: int,
                         out_block: int) -> int:
    """Estimated peak VMEM use of the forward kernel: double-buffered
    weight block + resident x block + out block (f32)."""
    w_blk = n_in * out_block * 4 * 2       # 2x: pipeline double-buffering
    x_blk = batch * n_in * 4
    o_blk = batch * out_block * 4
    b_blk = out_block * 4 * 2
    return w_blk + x_blk + o_blk + b_blk


def mxu_utilization_estimate(batch: int, n_in: int, n_out: int) -> float:
    """Fraction of MXU tile slots doing useful work for this layer shape
    (pad-to-tile model). Analytical only — interpret mode gives no HW
    counters."""
    eff_b = batch / _round_up(batch, SUBLANES)
    eff_i = n_in / _round_up(n_in, MXU_LANES)
    eff_o = n_out / _round_up(n_out, MXU_LANES)
    return eff_b * eff_i * eff_o


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _dense_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One grid step: o[:, j*blk:(j+1)*blk] = act(x @ w_blk + b_blk)."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = _apply_activation(act, acc)


def dense(x, w, b, act: str = "linear", *,
          out_block: int | None = None,
          interpret: bool = True):
    """Pallas forward dense layer: ``act(x @ w + b)``.

    x: (B, In) f32, w: (In, Out) f32, b: (Out,) f32 -> (B, Out) f32.
    ``out_block`` overrides the VMEM-driven block selection (used by tests
    to force the neuron-wise streaming path on small shapes).
    """
    batch, n_in = x.shape
    n_in_w, n_out = w.shape
    assert n_in == n_in_w, (x.shape, w.shape)
    assert b.shape == (n_out,)
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")

    blk = out_block or choose_out_block(n_in, n_out)
    blk = min(blk, n_out)
    padded = _round_up(n_out, blk)
    if padded != n_out:
        w = jnp.pad(w, ((0, 0), (0, padded - n_out)))
        b = jnp.pad(b, (0, padded - n_out))

    grid = (padded // blk,)
    out = pl.pallas_call(
        functools.partial(_dense_fwd_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, n_in), lambda j: (0, 0)),
            pl.BlockSpec((n_in, blk), lambda j: (0, j)),
            pl.BlockSpec((blk,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((batch, blk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, padded), jnp.float32),
        interpret=interpret,
    )(x, w, b)
    return out[:, :n_out]


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------

def _dense_bwd_dx_kernel(dz_ref, w_ref, dx_ref):
    """One grid step over input tiles: dx[:, i_blk] = dz @ w[i_blk, :].T"""
    dz = dz_ref[...]
    w = w_ref[...]
    dx_ref[...] = jnp.dot(dz, w.T, preferred_element_type=jnp.float32)


def _dense_bwd_dw_kernel(x_ref, dz_ref, dw_ref):
    """One grid step over output tiles: dw[:, j_blk] = x.T @ dz[:, j_blk]"""
    x = x_ref[...]
    dz = dz_ref[...]
    dw_ref[...] = jnp.dot(x.T, dz, preferred_element_type=jnp.float32)


def _dense_bwd_db_kernel(dz_ref, db_ref):
    db_ref[...] = jnp.sum(dz_ref[...], axis=0)


def dense_bwd_dx(dz, w, *, in_block: int | None = None, interpret=True):
    """dx = dz @ w.T as a Pallas kernel, streaming weight *row* blocks."""
    batch, n_out = dz.shape
    n_in, n_out_w = w.shape
    assert n_out == n_out_w

    blk = in_block or choose_out_block(n_out, n_in)
    blk = min(blk, n_in)
    padded = _round_up(n_in, blk)
    if padded != n_in:
        w = jnp.pad(w, ((0, padded - n_in), (0, 0)))

    out = pl.pallas_call(
        _dense_bwd_dx_kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((batch, n_out), lambda i: (0, 0)),
            pl.BlockSpec((blk, n_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, padded), jnp.float32),
        interpret=interpret,
    )(dz, w)
    return out[:, :n_in]


def dense_bwd_dw(x, dz, *, out_block: int | None = None, interpret=True):
    """dw = x.T @ dz as a Pallas kernel, tiling the output dimension."""
    batch, n_in = x.shape
    batch_dz, n_out = dz.shape
    assert batch == batch_dz

    blk = out_block or choose_out_block(n_in, n_out)
    blk = min(blk, n_out)
    padded = _round_up(n_out, blk)
    if padded != n_out:
        dz = jnp.pad(dz, ((0, 0), (0, padded - n_out)))

    out = pl.pallas_call(
        _dense_bwd_dw_kernel,
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((batch, n_in), lambda j: (0, 0)),
            pl.BlockSpec((batch, blk), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_in, blk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_in, padded), jnp.float32),
        interpret=interpret,
    )(x, dz)
    return out[:, :n_out]


def dense_bwd_db(dz, *, interpret=True):
    """db = sum(dz, axis=0) as a (single-block) Pallas kernel."""
    batch, n_out = dz.shape
    return pl.pallas_call(
        _dense_bwd_db_kernel,
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.float32),
        interpret=interpret,
    )(dz)


# ---------------------------------------------------------------------------
# custom_vjp wiring: the differentiable layer primitive used by the L2 model
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_layer(x, w, b, act: str = "linear"):
    return dense(x, w, b, act)


def _dense_layer_fwd(x, w, b, act):
    y = dense(x, w, b, act)
    return y, (x, w, y)


def _dense_layer_bwd(act, res, dy):
    x, w, y = res
    dz = dy * _grad_from_output(act, y)
    dx = dense_bwd_dx(dz, w)
    dw = dense_bwd_dw(x, dz)
    db = dense_bwd_db(dz)
    return dx, dw, db


dense_layer.defvjp(_dense_layer_fwd, _dense_layer_bwd)
