"""L1 Pallas kernel: int8 packed-SIMD dense layer.

The Fig. 3 ladder ends at packed 8-bit SIMD (`pv.sdotsp`: four 8x8->32
MACs per instruction, ~10x over the RV32IMC baseline). The MCU-side cycle
model lives in ``rust/src/targets/isa.rs`` (``IsaExtensions::XPULP_SIMD4``);
this kernel is the numeric counterpart: the int8 quantization scheme such
a deployment would execute, expressed for the TPU the same way the
32-bit kernel is.

Scheme (symmetric, power-of-two scales — MCU-friendly):

* activations ``x``: int8 holding Q(dx),
* weights ``w``: int8 holding Q(dw),
* accumulator: int32 holding Q(dx+dw) — 8x8 products need no per-product
  shift (|prod| <= 2^14, and <= 2^21 after a 128-deep accumulation),
  exactly why the MCU SIMD path is cheap;
* bias: int32 pre-scaled to Q(dx+dw);
* requantization: arithmetic shift by ``dw`` back to Q(dx), saturate to
  int8 — ReLU/linear only (the saturating int8 range cannot hold the
  step-linear sigmoid tables; MCU int8 deployments use ReLU for the same
  reason).

``dense_q8`` (Pallas) is pinned to ``dense_q8_ref`` (numpy) by
``python/tests/test_simd8.py``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

I8_MIN, I8_MAX = -128, 127


# ---------------------------------------------------------------------------
# numpy reference
# ---------------------------------------------------------------------------

def quantize8(v: np.ndarray, dec: int) -> np.ndarray:
    """Round-to-nearest symmetric int8 quantization to Q(dec)."""
    q = np.round(np.asarray(v, dtype=np.float64) * (1 << dec))
    return np.clip(q, I8_MIN, I8_MAX).astype(np.int8)


def dense_q8_ref(x_q8: np.ndarray, w_q8: np.ndarray, b_q32: np.ndarray,
                 dw: int, act: str = "linear") -> np.ndarray:
    """Reference int8 dense layer.

    x_q8: (B, In) i8 Q(dx); w_q8: (In, Out) i8 Q(dw);
    b_q32: (Out,) i32 Q(dx+dw). Returns (B, Out) i8 Q(dx).
    """
    acc = x_q8.astype(np.int32) @ w_q8.astype(np.int32)  # Q(dx+dw)
    acc = acc + b_q32.astype(np.int32)[None, :]
    if act == "relu":
        acc = np.maximum(acc, 0)
    elif act != "linear":
        raise ValueError(f"int8 path supports linear/relu, not {act!r}")
    out = acc >> dw  # back to Q(dx)
    return np.clip(out, I8_MIN, I8_MAX).astype(np.int8)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _dense_q8_kernel(x_ref, w_ref, b_ref, o_ref, *, dw: int, act: str):
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    b = b_ref[...]
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ) + b[None, :]
    if act == "relu":
        acc = jnp.maximum(acc, 0)
    out = jnp.clip(acc >> dw, I8_MIN, I8_MAX)
    o_ref[...] = out.astype(jnp.int8)


def dense_q8(x_q8, w_q8, b_q32, dw: int, act: str = "linear", *,
             out_block: int | None = None, interpret: bool = True):
    """Pallas int8 dense layer; same streaming structure as ``dense``/
    ``dense_q`` (grid over output blocks, the neuron-wise DMA analogue).
    """
    if act not in ("linear", "relu"):
        raise ValueError(f"int8 path supports linear/relu, not {act!r}")
    batch, n_in = x_q8.shape
    _, n_out = w_q8.shape
    blk = min(out_block or n_out, n_out)
    padded = ((n_out + blk - 1) // blk) * blk
    if padded != n_out:
        w_q8 = jnp.pad(w_q8, ((0, 0), (0, padded - n_out)))
        b_q32 = jnp.pad(b_q32, (0, padded - n_out))

    out = pl.pallas_call(
        functools.partial(_dense_q8_kernel, dw=dw, act=act),
        grid=(padded // blk,),
        in_specs=[
            pl.BlockSpec((batch, n_in), lambda j: (0, 0)),
            pl.BlockSpec((n_in, blk), lambda j: (0, j)),
            pl.BlockSpec((blk,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((batch, blk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, padded), jnp.int8),
        interpret=interpret,
    )(x_q8, w_q8, b_q32)
    return out[:, :n_out]


def quantize_layer8(w: np.ndarray, b: np.ndarray, dx: int, dw: int):
    """Quantize a float layer for the int8 path: weights to Q(dw) i8,
    bias to Q(dx+dw) i32."""
    w_q8 = quantize8(w, dw)
    b_q32 = np.clip(
        np.round(np.asarray(b, dtype=np.float64) * (1 << (dx + dw))),
        -(2**31), 2**31 - 1,
    ).astype(np.int32)
    return w_q8, b_q32
