"""AOT pipeline: lower every L2 program to HLO *text* + emit parity vectors.

HLO text (not ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the Rust
``xla`` crate links) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Per topology in ``topologies.TOPOLOGIES`` this writes:

* ``<name>_fwd_b{1,32}.hlo.txt``  — forward pass, flat arg convention
* ``<name>_train_b32.hlo.txt``    — one SGD step (lr baked in)
* ``<name>_manifest.txt``         — arg shapes for the Rust runtime

plus parity vectors that pin the Rust-native inference paths to the Pallas
kernels:

* ``parity_float.tsv`` — per-topology random params/inputs + Pallas outputs
* ``parity_fixed.tsv`` — Q-format params/inputs + Pallas dense_q outputs

Usage: ``python -m compile.aot --out ../artifacts`` (from ``python/``).
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # i64 accumulation in dense_q

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fixedpoint
from .topologies import FWD_BATCHES, TOPOLOGIES, TRAIN_BATCH, Topology


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(topo: Topology, batch: int) -> str:
    specs = model.arg_specs(topo, batch, with_labels=False)

    def fn(*args):
        return model.forward_flat(topo, *args)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_train(topo: Topology, batch: int) -> str:
    specs = model.arg_specs(topo, batch, with_labels=True)

    def fn(*args):
        return model.train_step_flat(topo, *args)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_manifest(topo: Topology, out_dir: str) -> None:
    """Plain-text arg manifest consumed by rust/src/runtime/artifacts.rs."""
    lines = [
        f"name {topo.name}",
        f"inputs {topo.inputs}",
        f"outputs {topo.outputs}",
        f"hidden {' '.join(str(h) for h in topo.hidden)}",
        f"hidden_activation {topo.hidden_activation}",
        f"output_activation {topo.output_activation}",
        f"learning_rate {topo.learning_rate}",
        f"fwd_batches {' '.join(str(b) for b in FWD_BATCHES)}",
        f"train_batch {TRAIN_BATCH}",
        f"macs {topo.macs}",
        f"num_params {topo.num_params}",
    ]
    with open(os.path.join(out_dir, f"{topo.name}_manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


# ---------------------------------------------------------------------------
# Parity vectors (TSV: no serde on the Rust side, keep the format trivial)
# ---------------------------------------------------------------------------

def _emit_array(f, tag: str, arr: np.ndarray) -> None:
    flat = np.asarray(arr).reshape(-1)
    shape = "x".join(str(d) for d in arr.shape)
    f.write(f"{tag}\t{shape}\t" + " ".join(repr(float(v)) if flat.dtype.kind == "f"
                                           else str(int(v)) for v in flat) + "\n")


def emit_parity_float(out_dir: str, seed: int = 1234) -> None:
    rng = np.random.default_rng(seed)
    path = os.path.join(out_dir, "parity_float.tsv")
    with open(path, "w") as f:
        for topo in TOPOLOGIES.values():
            params = model.init_params(seed, topo.layer_sizes)
            x = rng.standard_normal((4, topo.inputs)).astype(np.float32)
            y = np.asarray(model.forward(params, jnp.asarray(x),
                                         topo.hidden_activation,
                                         topo.output_activation))
            f.write(f"case\t{topo.name}\n")
            f.write(f"acts\t{topo.hidden_activation}\t{topo.output_activation}\n")
            for i, (w, b) in enumerate(params):
                _emit_array(f, f"w{i}", np.asarray(w))
                _emit_array(f, f"b{i}", np.asarray(b))
            _emit_array(f, "x", x)
            _emit_array(f, "out", y)


def emit_parity_fixed(out_dir: str, seed: int = 4321, dec: int = 12) -> None:
    rng = np.random.default_rng(seed)
    path = os.path.join(out_dir, "parity_fixed.tsv")
    one = 1 << dec
    with open(path, "w") as f:
        for topo in TOPOLOGIES.values():
            sizes = topo.layer_sizes
            params_q = []
            for n_in, n_out in zip(sizes, sizes[1:]):
                w = (rng.uniform(-2.0, 2.0, (n_in, n_out)) * one).astype(np.int64)
                b = (rng.uniform(-1.0, 1.0, n_out) * one).astype(np.int64)
                params_q.append((w.astype(np.int32), b.astype(np.int32)))
            x = (rng.uniform(-1.0, 1.0, (4, topo.inputs)) * one).astype(np.int32)
            h = jnp.asarray(x)
            out = np.asarray(fixedpoint.mlp_forward_q(
                [(jnp.asarray(w), jnp.asarray(b)) for w, b in params_q],
                h, dec, topo.hidden_activation, topo.output_activation))
            f.write(f"case\t{topo.name}\n")
            f.write(f"dec\t{dec}\n")
            f.write(f"acts\t{topo.hidden_activation}\t{topo.output_activation}\n")
            for i, (w, b) in enumerate(params_q):
                _emit_array(f, f"w{i}", w)
                _emit_array(f, f"b{i}", b)
            _emit_array(f, "x", x)
            _emit_array(f, "out", out.astype(np.int64))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--only", default=None,
                        help="lower a single topology (debugging)")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    topos = TOPOLOGIES
    if args.only:
        topos = {args.only: TOPOLOGIES[args.only]}

    for topo in topos.values():
        for batch in FWD_BATCHES:
            text = lower_forward(topo, batch)
            path = os.path.join(args.out, f"{topo.name}_fwd_b{batch}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        text = lower_train(topo, TRAIN_BATCH)
        path = os.path.join(args.out, f"{topo.name}_train_b{TRAIN_BATCH}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
        write_manifest(topo, args.out)

    emit_parity_float(args.out)
    emit_parity_fixed(args.out)
    print("parity vectors written")


if __name__ == "__main__":
    main()
