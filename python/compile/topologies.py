"""Network topology registry shared by the L2 model, the AOT pipeline, and
the Rust coordinator (mirrored in ``rust/src/apps/mod.rs``).

Topologies come straight from the paper:

* ``example``  — the profiling network of Sec. V-A (5-100-100-3, tanh).
* ``gesture``  — application A, hand-gesture recognition [47]:
                 76-300-200-100-10, 103 800 MACs.
* ``fall``     — application B, fall detection [48]: 117-20-2.
* ``activity`` — application C, human activity classification [46]: 7-6-5.
* ``xor``      — the canonical FANN quickstart network.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class Topology:
    name: str
    inputs: int
    hidden: Tuple[int, ...]
    outputs: int
    hidden_activation: str = "tanh"
    output_activation: str = "sigmoid"
    # Learning rate baked into the AOT-lowered training step.
    learning_rate: float = 0.7

    @property
    def layer_sizes(self) -> List[int]:
        return [self.inputs, *self.hidden, self.outputs]

    @property
    def macs(self) -> int:
        sizes = self.layer_sizes
        return sum(a * b for a, b in zip(sizes, sizes[1:]))

    @property
    def num_params(self) -> int:
        sizes = self.layer_sizes
        return sum(a * b + b for a, b in zip(sizes, sizes[1:]))


TOPOLOGIES = {
    t.name: t
    for t in [
        Topology("xor", 2, (4,), 1, learning_rate=0.9),
        Topology("example", 5, (100, 100), 3),
        Topology("gesture", 76, (300, 200, 100), 10, learning_rate=0.4),
        Topology("fall", 117, (20,), 2, learning_rate=0.1),
        Topology("activity", 7, (6,), 5, learning_rate=0.3),
    ]
}

# Batch sizes we AOT-lower forward passes for. Batch 1 is the wearable
# request path (one classification per sensor window); batch 32 serves
# dataset-level evaluation and the training step.
FWD_BATCHES = (1, 32)
TRAIN_BATCH = 32
