"""L2: the JAX MLP (Eq. 1 of the paper) built on the L1 Pallas kernels.

This is the *training/compile-time* half of the stack. FANN's inference
semantics (layer chain of dense + activation, MSE loss) are expressed as a
JAX program whose per-layer primitive is ``kernels.matvec.dense_layer`` — a
Pallas forward kernel with hand-written Pallas backward kernels under
``jax.custom_vjp``. ``aot.py`` lowers ``forward`` and ``train_step`` per
topology to HLO text for the Rust runtime; Python never runs at inference
time.
"""

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import matvec
from .topologies import Topology

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


def init_params(seed: int, layer_sizes: Sequence[int]) -> Params:
    """FANN-style init: weights uniform in [-0.1, 0.1] by default; we use
    Glorot-uniform scaling which FANNTool's init option also offers and
    which trains far more reliably at these widths."""
    key = jax.random.PRNGKey(seed)
    params = []
    for n_in, n_out in zip(layer_sizes, layer_sizes[1:]):
        key, kw = jax.random.split(key)
        limit = jnp.sqrt(6.0 / (n_in + n_out))
        w = jax.random.uniform(kw, (n_in, n_out), jnp.float32, -limit, limit)
        b = jnp.zeros((n_out,), jnp.float32)
        params.append((w, b))
    return params


def forward(params: Params, x: jnp.ndarray, hidden_act: str = "tanh",
            output_act: str = "sigmoid") -> jnp.ndarray:
    """MLP forward pass over Pallas dense layers. x: (B, In) -> (B, Out)."""
    h = x
    last = len(params) - 1
    for i, (w, b) in enumerate(params):
        act = output_act if i == last else hidden_act
        h = matvec.dense_layer(h, w, b, act)
    return h


def mse_loss(params: Params, x: jnp.ndarray, y: jnp.ndarray,
             hidden_act: str = "tanh", output_act: str = "sigmoid"):
    """FANN's error measure: mean squared error over outputs."""
    out = forward(params, x, hidden_act, output_act)
    return jnp.mean((out - y) ** 2)


def train_step(params: Params, x: jnp.ndarray, y: jnp.ndarray,
               lr: float, hidden_act: str = "tanh",
               output_act: str = "sigmoid"):
    """One full-batch gradient-descent step.

    FANN's default trainer is iRPROP− (implemented natively on the Rust
    side, `fann::train`); the AOT path uses plain SGD because it is
    stateless and lowers to a single pure function — DESIGN.md §1 records
    this substitution. Returns (new_params, loss).
    """
    loss, grads = jax.value_and_grad(mse_loss)(params, x, y,
                                               hidden_act, output_act)
    new_params = [
        (w - lr * gw, b - lr * gb)
        for (w, b), (gw, gb) in zip(params, grads)
    ]
    return new_params, loss


# ---------------------------------------------------------------------------
# Flat-argument wrappers for AOT lowering (PJRT executables take positional
# buffers; the Rust runtime passes [w0, b0, w1, b1, ..., x(, y)]).
# ---------------------------------------------------------------------------

def unflatten(flat: Sequence[jnp.ndarray]) -> Params:
    assert len(flat) % 2 == 0
    return [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]


def forward_flat(topo: Topology, *args):
    *flat, x = args
    return (forward(unflatten(flat), x, topo.hidden_activation,
                    topo.output_activation),)


def train_step_flat(topo: Topology, *args):
    *flat, x, y = args
    new_params, loss = train_step(unflatten(flat), x, y, topo.learning_rate,
                                  topo.hidden_activation,
                                  topo.output_activation)
    out = []
    for w, b in new_params:
        out.extend((w, b))
    out.append(loss)
    return tuple(out)


def arg_specs(topo: Topology, batch: int, with_labels: bool):
    """ShapeDtypeStructs for the flat calling convention."""
    specs = []
    sizes = topo.layer_sizes
    for n_in, n_out in zip(sizes, sizes[1:]):
        specs.append(jax.ShapeDtypeStruct((n_in, n_out), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((n_out,), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((batch, topo.inputs), jnp.float32))
    if with_labels:
        specs.append(jax.ShapeDtypeStruct((batch, topo.outputs), jnp.float32))
    return specs
