//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real `xla` crate links `xla_extension` and needs an XLA install,
//! neither of which exists in the offline build environment. This stub
//! mirrors exactly the API surface `runtime::client` uses so that
//! `cargo build --features pjrt` always compiles; every entry point
//! returns [`Error::Stub`] at runtime. To run against real PJRT, point
//! the `xla` dependency in `rust/Cargo.toml` at the actual crate — no
//! source change in the toolkit is needed.

use std::fmt;

/// The single error every stub entry point returns.
#[derive(Debug, Clone)]
pub enum Error {
    /// PJRT is unavailable: this binary was built against the offline
    /// xla stub.
    Stub,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: PJRT unavailable in this build (rebuild against the real `xla` crate)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub of a PJRT client handle.
pub struct PjRtClient(());

/// Stub of a compiled-and-loaded PJRT executable.
pub struct PjRtLoadedExecutable(());

/// Stub of a device buffer returned by execution.
pub struct PjRtBuffer(());

/// Stub of a host literal (tensor value).
pub struct Literal(());

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto(());

/// Stub of an XLA computation.
pub struct XlaComputation(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Stub)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub)
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub)
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub)
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Stub)
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal(())
    }

    pub fn reshape(&self, _shape: &[i64]) -> Result<Literal> {
        Err(Error::Stub)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Stub)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Stub)
    }
}
