//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The offline build environment has no crates.io registry, so this
//! workspace ships the small part of `anyhow`'s API the toolkit uses as
//! a path dependency: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match
//! upstream for this subset (error chains print outermost-first, `?`
//! converts any `std::error::Error`, `Context` works on both `Result`
//! and `Option`). Swapping back to the real crate is a one-line change
//! in `rust/Cargo.toml`.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recently attached)
/// message, later entries are the causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message (what `Display` prints).
    pub fn root_cause_message(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated (anyhow-style).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coexist with the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option` errors.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file/anywhere")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "inner",
        ));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert!(format!("{e:?}").contains("inner"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("literal");
        assert_eq!(e.to_string(), "literal");
    }

    #[test]
    fn alternate_display_prints_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
