//! Offline packing of row-major Q-format weights into the tiled,
//! word-streamed panel layout the packed kernels consume — the
//! software analogue of CMSIS-NN's `q7`/`q15` weight reordering and the
//! paper's neuron-wise DMA streaming order. Packing happens **once at
//! load time** (`FixedNetwork::pack`), never on the inference path.
//!
//! See the byte-order diagram in the [`crate::kernels`] module docs.
//! The invariants the kernels rely on:
//!
//! * Rows are grouped into panels of [`ROWS_PER_PANEL`] consecutive
//!   output neurons; the last panel is padded to full height with
//!   all-zero rows (their outputs are never written back).
//! * Within a panel, words are stored column-chunk-major: the words of
//!   the panel's rows for input chunk `c` are adjacent, so the inner
//!   loop over `c` reads `words[]` strictly forward — a straight word
//!   stream.
//! * A ragged trailing input chunk (`n_in % elems != 0`) pads its
//!   unused lanes with weight 0, which is exact: `qmul(0, x) == 0`
//!   contributes nothing to the accumulator.
//! * Packing is lossless: every weight must be representable at the
//!   narrow width ([`pack_rows`] returns an error otherwise), so
//!   unpack(pack(w)) == w and the packed kernels can reproduce
//!   [`super::FixedQ`]'s arithmetic bit for bit.

use anyhow::{bail, Result};

/// Output rows interleaved per panel (the register-tile height of the
/// packed kernels).
pub const ROWS_PER_PANEL: usize = 4;

/// The two narrow storage widths (CMSIS-NN's q7/q15 naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PackedWidth {
    /// 4 × i8 per u32 word.
    Q7,
    /// 2 × i16 per u32 word.
    Q15,
}

impl PackedWidth {
    /// Weights packed into one u32 word.
    #[inline]
    pub fn elems_per_word(self) -> usize {
        match self {
            PackedWidth::Q7 => 4,
            PackedWidth::Q15 => 2,
        }
    }

    /// Inclusive representable weight range at this width.
    #[inline]
    pub fn range(self) -> (i32, i32) {
        match self {
            PackedWidth::Q7 => (i8::MIN as i32, i8::MAX as i32),
            PackedWidth::Q15 => (i16::MIN as i32, i16::MAX as i32),
        }
    }

    /// `true` when every value fits the narrow width.
    pub fn fits(self, weights: &[i32]) -> bool {
        let (lo, hi) = self.range();
        weights.iter().all(|&w| (lo..=hi).contains(&w))
    }

    /// Largest extra fractional bits a weight magnitude bound allows:
    /// the biggest `dec` with `round(max_abs_w · 2^dec)` still in
    /// range. Used to choose a packable decimal point.
    pub fn max_dec_for(self, max_abs_w: f32) -> u32 {
        let limit = match self {
            PackedWidth::Q7 => i8::MAX as f64,
            PackedWidth::Q15 => i16::MAX as f64,
        };
        let w = (max_abs_w.abs() as f64).max(1e-30);
        let mut dec = 0u32;
        // floor(log2(limit / w)), computed by the same round-and-check
        // the quantizer applies so the bound is never off by one.
        while dec < 30 && (w * (1u64 << (dec + 1)) as f64).round() <= limit {
            dec += 1;
        }
        dec
    }

    /// Stable lowercase name (`q7`, `q15`).
    pub fn label(self) -> &'static str {
        match self {
            PackedWidth::Q7 => "q7",
            PackedWidth::Q15 => "q15",
        }
    }

    /// Inclusive bound on `|x|` under which `w · x` provably fits i32
    /// for ANY weight representable at this width — the packed
    /// kernels' narrow-multiply fast-path condition (`|x| < 2^24` for
    /// q7, `|x| < 2^16` for q15), exposed so a compiled execution plan
    /// can hoist the input scan out of its row-split jobs.
    pub fn fast_input_bound(self) -> u32 {
        match self {
            PackedWidth::Q7 => (1 << 24) - 1,
            PackedWidth::Q15 => (1 << 16) - 1,
        }
    }
}

/// One dense layer's weights in packed panel form. `words` length is
/// `panels(n_out) · words_per_row · ROWS_PER_PANEL`.
#[derive(Debug, Clone)]
pub struct PackedPanels {
    /// Packed element width.
    pub width: PackedWidth,
    /// Input width (columns per row).
    pub n_in: usize,
    /// Output rows packed into the panels.
    pub n_out: usize,
    /// Words covering one row's `n_in` weights: `ceil(n_in / elems)`.
    pub words_per_row: usize,
    /// The packed word stream, panel-major.
    pub words: Vec<u32>,
}

impl PackedPanels {
    /// Number of row panels (last one possibly padded).
    #[inline]
    pub fn panels(&self) -> usize {
        self.n_out.div_ceil(ROWS_PER_PANEL)
    }

    /// Packed weight storage in bytes (the bytes-per-network metric's
    /// per-layer contribution).
    pub fn weight_bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Unpack back to row-major `[n_out][n_in]` i32 weights (test and
    /// round-trip support; inference never calls this).
    pub fn unpack(&self) -> Vec<i32> {
        let elems = self.width.elems_per_word();
        let mut out = vec![0i32; self.n_in * self.n_out];
        for o in 0..self.n_out {
            let panel = o / ROWS_PER_PANEL;
            let r = o % ROWS_PER_PANEL;
            let base = panel * self.words_per_row * ROWS_PER_PANEL;
            for c in 0..self.words_per_row {
                let word = self.words[base + c * ROWS_PER_PANEL + r];
                for e in 0..elems {
                    let i = c * elems + e;
                    if i < self.n_in {
                        out[o * self.n_in + i] = unpack_lane(self.width, word, e);
                    }
                }
            }
        }
        out
    }
}

/// Extract lane `e` of a packed word as a sign-extended i32.
#[inline]
pub fn unpack_lane(width: PackedWidth, word: u32, e: usize) -> i32 {
    match width {
        PackedWidth::Q7 => (word >> (8 * e)) as u8 as i8 as i32,
        PackedWidth::Q15 => (word >> (16 * e)) as u16 as i16 as i32,
    }
}

/// Pack one row-chunk of up to `elems` weights into a word
/// (little-endian lane order; missing tail lanes stay 0).
#[inline]
fn pack_word(width: PackedWidth, chunk: &[i32]) -> u32 {
    let mut word = 0u32;
    match width {
        PackedWidth::Q7 => {
            for (e, &w) in chunk.iter().enumerate() {
                word |= ((w as i8 as u8) as u32) << (8 * e);
            }
        }
        PackedWidth::Q15 => {
            for (e, &w) in chunk.iter().enumerate() {
                word |= ((w as i16 as u16) as u32) << (16 * e);
            }
        }
    }
    word
}

/// Pack a row-major `[n_out][n_in]` Q-format weight matrix into panel
/// layout. Errors if any weight does not fit the narrow width (packing
/// must be lossless — choose the decimal point with
/// [`PackedWidth::max_dec_for`] first).
pub fn pack_rows(
    width: PackedWidth,
    n_in: usize,
    n_out: usize,
    weights: &[i32],
) -> Result<PackedPanels> {
    debug_assert_eq!(weights.len(), n_in * n_out);
    let (lo, hi) = width.range();
    if let Some(&w) = weights.iter().find(|&&w| !(lo..=hi).contains(&w)) {
        bail!(
            "weight {w} does not fit packed {} range [{lo}, {hi}] — requantize with a smaller decimal point",
            width.label()
        );
    }
    let elems = width.elems_per_word();
    let words_per_row = n_in.div_ceil(elems);
    let panels = n_out.div_ceil(ROWS_PER_PANEL);
    let mut words = vec![0u32; panels * words_per_row * ROWS_PER_PANEL];
    for o in 0..n_out {
        let panel = o / ROWS_PER_PANEL;
        let r = o % ROWS_PER_PANEL;
        let base = panel * words_per_row * ROWS_PER_PANEL;
        let row = &weights[o * n_in..(o + 1) * n_in];
        for c in 0..words_per_row {
            let i0 = c * elems;
            let chunk = &row[i0..n_in.min(i0 + elems)];
            words[base + c * ROWS_PER_PANEL + r] = pack_word(width, chunk);
        }
    }
    Ok(PackedPanels {
        width,
        n_in,
        n_out,
        words_per_row,
        words,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn q7_word_byte_order_matches_diagram() {
        // w[0] in the low byte, w[3] in the high byte.
        let p = pack_rows(PackedWidth::Q7, 4, 1, &[1, -2, 3, -4]).unwrap();
        assert_eq!(p.words.len(), ROWS_PER_PANEL); // 1 row padded to a panel
        let word = p.words[0];
        assert_eq!(word & 0xFF, 1);
        assert_eq!((word >> 8) & 0xFF, (-2i8 as u8) as u32);
        assert_eq!((word >> 16) & 0xFF, 3);
        assert_eq!((word >> 24) & 0xFF, (-4i8 as u8) as u32);
        // Padding rows of the panel are zero words.
        assert_eq!(&p.words[1..], &[0, 0, 0]);
    }

    #[test]
    fn roundtrip_all_shapes_q7_q15() {
        let mut rng = Rng::new(0x9ACC);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (lo, hi) = width.range();
            for &n_in in &[1usize, 2, 3, 4, 5, 7, 8, 9, 33] {
                for &n_out in &[1usize, 2, 3, 4, 5, 9] {
                    let w: Vec<i32> = (0..n_in * n_out)
                        .map(|_| lo + (rng.below((hi - lo + 1) as usize) as i32))
                        .collect();
                    let p = pack_rows(width, n_in, n_out, &w).unwrap();
                    assert_eq!(p.unpack(), w, "{width:?} n_in={n_in} n_out={n_out}");
                    assert_eq!(
                        p.words.len(),
                        n_out.div_ceil(ROWS_PER_PANEL)
                            * ROWS_PER_PANEL
                            * n_in.div_ceil(width.elems_per_word())
                    );
                }
            }
        }
    }

    #[test]
    fn out_of_range_weight_rejected() {
        assert!(pack_rows(PackedWidth::Q7, 1, 1, &[128]).is_err());
        assert!(pack_rows(PackedWidth::Q7, 1, 1, &[-129]).is_err());
        assert!(pack_rows(PackedWidth::Q7, 1, 1, &[127]).is_ok());
        assert!(pack_rows(PackedWidth::Q15, 1, 1, &[32768]).is_err());
        assert!(pack_rows(PackedWidth::Q15, 1, 1, &[-32768]).is_ok());
    }

    #[test]
    fn max_dec_respects_rounding() {
        // max|w| = 1.0: round(1.0 * 2^6) = 64 <= 127, round(1.0 * 2^7) =
        // 128 > 127 -> dec 6 for q7.
        assert_eq!(PackedWidth::Q7.max_dec_for(1.0), 6);
        // q15: round(1.0 * 2^14) = 16384 <= 32767 -> 14.
        assert_eq!(PackedWidth::Q15.max_dec_for(1.0), 14);
        // Tiny weights are capped at 30 bits, not unbounded.
        assert!(PackedWidth::Q7.max_dec_for(1e-9) <= 30);
    }

    #[test]
    fn fits_check() {
        assert!(PackedWidth::Q7.fits(&[-128, 0, 127]));
        assert!(!PackedWidth::Q7.fits(&[-128, 0, 128]));
        assert!(PackedWidth::Q15.fits(&[-32768, 32767]));
    }
}
