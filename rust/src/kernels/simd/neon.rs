//! aarch64 NEON panel kernels — the host mirror of the PULP-NN
//! `pv.sdotsp.b`/`pv.sdotsp.h` lanes and the CMSIS f32 inner loop.
//!
//! NEON is mandatory on aarch64, so no runtime detection is needed; the
//! dispatcher selects [`super::SimdLevel::Neon`] unconditionally there.
//!
//! The arithmetic-shift-right uses `vshlq_s32` with a *negative* count,
//! which is a truncating arithmetic shift matching Rust's `>>` on i32.
//! (`vrshlq_s32` rounds toward nearest and must NOT be used here.)
//!
//! # Safety
//!
//! Functions are `unsafe` for the `#[target_feature]` contract only; NEON
//! is always present on aarch64. Slice bounds are asserted on entry.

#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

use super::super::layout::ROWS_PER_PANEL;

/// NEON q7 panel: `chunks` packed words per row, four rows per panel.
/// Layout and accumulation semantics match `x86::avx2_panel_q7`.
///
/// # Safety
/// NEON is baseline on aarch64; safe to call on any aarch64 host.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn neon_panel_q7(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 4);
    let shift = vdupq_n_s32(-(dec as i32));
    let mut acc = [vdupq_n_s64(0); ROWS_PER_PANEL];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [vdupq_n_s64(0); ROWS_PER_PANEL];
        while c + 2 <= chunks {
            neon_q7_chunk(words, x, c, shift, &mut acc);
            neon_q7_chunk(words, x, c + 1, shift, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = vaddq_s64(*a, *a2);
        }
    }
    while c < chunks {
        neon_q7_chunk(words, x, c, shift, &mut acc);
        c += 1;
    }
    for (r, a) in acc.iter().enumerate() {
        sums[r] += vgetq_lane_s64::<0>(*a) + vgetq_lane_s64::<1>(*a);
    }
}

/// One q7 chunk (4 inputs × 4 rows) of the NEON panel loop.
///
/// # Safety
/// NEON baseline on aarch64.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn neon_q7_chunk(
    words: &[u32],
    x: &[i32],
    c: usize,
    shift: int32x4_t,
    acc: &mut [int64x2_t; ROWS_PER_PANEL],
) {
    // 16 bytes = the four row-words of chunk c.
    let w8 = vld1q_s8(words.as_ptr().add(c * ROWS_PER_PANEL) as *const i8);
    let lo16 = vmovl_s8(vget_low_s8(w8)); // rows 0,1 as 8 × i16
    let hi16 = vmovl_s8(vget_high_s8(w8)); // rows 2,3
    let rows = [
        vmovl_s16(vget_low_s16(lo16)),
        vmovl_s16(vget_high_s16(lo16)),
        vmovl_s16(vget_low_s16(hi16)),
        vmovl_s16(vget_high_s16(hi16)),
    ];
    let xx = vld1q_s32(x.as_ptr().add(c * 4));
    for (r, w) in rows.into_iter().enumerate() {
        // Per-product (w * x) >> dec; negative vshlq = truncating asr.
        let s = vshlq_s32(vmulq_s32(w, xx), shift);
        acc[r] = vaddw_s32(acc[r], vget_low_s32(s));
        acc[r] = vaddw_s32(acc[r], vget_high_s32(s));
    }
}

/// NEON q15 panel: `chunks` packed words per row (2 inputs per word).
///
/// # Safety
/// NEON is baseline on aarch64; safe to call on any aarch64 host.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn neon_panel_q15(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 2);
    let shift = vdupq_n_s32(-(dec as i32));
    let mut acc = [vdupq_n_s64(0); ROWS_PER_PANEL];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [vdupq_n_s64(0); ROWS_PER_PANEL];
        while c + 2 <= chunks {
            neon_q15_chunk(words, x, c, shift, &mut acc);
            neon_q15_chunk(words, x, c + 1, shift, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = vaddq_s64(*a, *a2);
        }
    }
    while c < chunks {
        neon_q15_chunk(words, x, c, shift, &mut acc);
        c += 1;
    }
    for (r, a) in acc.iter().enumerate() {
        sums[r] += vgetq_lane_s64::<0>(*a) + vgetq_lane_s64::<1>(*a);
    }
}

/// One q15 chunk (2 inputs × 4 rows) of the NEON panel loop.
///
/// # Safety
/// NEON baseline on aarch64.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn neon_q15_chunk(
    words: &[u32],
    x: &[i32],
    c: usize,
    shift: int32x4_t,
    acc: &mut [int64x2_t; ROWS_PER_PANEL],
) {
    // 8 halfwords: [r0lo, r0hi, r1lo, r1hi, r2lo, r2hi, r3lo, r3hi].
    let w16 = vld1q_s16(words.as_ptr().add(c * ROWS_PER_PANEL) as *const i16);
    let lo = vmovl_s16(vget_low_s16(w16)); // [r0lo, r0hi, r1lo, r1hi]
    let hi = vmovl_s16(vget_high_s16(w16)); // [r2lo, r2hi, r3lo, r3hi]
    // Inputs [x0, x1] duplicated: [x0, x1, x0, x1].
    let xp = vld1_s32(x.as_ptr().add(c * 2));
    let xx = vcombine_s32(xp, xp);
    let s_lo = vshlq_s32(vmulq_s32(lo, xx), shift);
    let s_hi = vshlq_s32(vmulq_s32(hi, xx), shift);
    // s_lo = [p_r0_0, p_r0_1, p_r1_0, p_r1_1]: low pair -> row of half.
    acc[0] = vaddw_s32(acc[0], vget_low_s32(s_lo));
    acc[1] = vaddw_s32(acc[1], vget_high_s32(s_lo));
    acc[2] = vaddw_s32(acc[2], vget_low_s32(s_hi));
    acc[3] = vaddw_s32(acc[3], vget_high_s32(s_hi));
}

/// NEON 16-lane f32 accumulation (four 4-wide fused multiply-adds per
/// 16-element step) into the shared lane structure. Bit-identical to
/// `simd::portable_lanes16` — same per-lane fma chains.
///
/// # Safety
/// NEON is baseline on aarch64; safe to call on any aarch64 host.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn neon_f32_lanes16(w: &[f32], x: &[f32], main: usize, lanes: &mut [f32; 16]) {
    debug_assert!(main % 16 == 0);
    debug_assert!(w.len() >= main && x.len() >= main);
    let mut a = [
        vld1q_f32(lanes.as_ptr()),
        vld1q_f32(lanes.as_ptr().add(4)),
        vld1q_f32(lanes.as_ptr().add(8)),
        vld1q_f32(lanes.as_ptr().add(12)),
    ];
    let mut i = 0usize;
    while i < main {
        for (j, aj) in a.iter_mut().enumerate() {
            let wv = vld1q_f32(w.as_ptr().add(i + j * 4));
            let xv = vld1q_f32(x.as_ptr().add(i + j * 4));
            *aj = vfmaq_f32(*aj, wv, xv);
        }
        i += 16;
    }
    for (j, aj) in a.into_iter().enumerate() {
        vst1q_f32(lanes.as_mut_ptr().add(j * 4), aj);
    }
}
