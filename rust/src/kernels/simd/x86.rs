//! x86_64 SIMD panel kernels for the packed q7/q15 cores and the 16-lane
//! f32 FMA tile.
//!
//! Every function here mirrors one of the emitted C dot-product lanes
//! (see `rust/src/codegen/README.md`):
//!
//! * `sse2_panel_q7` / `sse2_panel_q15` — the SXTB16 + SMLAD lane from
//!   CMSIS-NN, expressed as `_mm_madd_epi16` over *zero-interleaved*
//!   operands so each i32 madd lane holds exactly one product (pair-summing
//!   before the per-product `>> dec` would not be bit-exact). Requires the
//!   extra-narrow input bound `|x| <= i16::MAX`, checked by the dispatcher.
//! * `avx2_panel_q7` / `avx2_panel_q15` — the `pv.sdotsp.b` / `pv.sdotsp.h`
//!   lane from PULP-NN, expressed as widen-to-i32 + `_mm256_mullo_epi32`
//!   + arithmetic shift, valid under the ordinary narrow fast bound.
//! * `avx2_f32_lanes16` — the 8-wide FMA tile mirroring the emitted CMSIS
//!   f32 inner loop; accumulates into a fixed 16-lane structure shared
//!   bit-for-bit with the portable mirror in `simd::portable_lanes16`.
//!
//! All panel kernels accumulate per-product i64 sums exactly like the
//! scalar fast path `((w * x) >> dec) as i64`, so any traversal order is
//! bit-exact (integer addition commutes). Saturation and bias addition stay
//! in the caller (`packed.rs`), once per output row.
//!
//! # Safety
//!
//! Functions are `unsafe` because they require their `#[target_feature]`
//! ISA level; the dispatcher in `simd/mod.rs` only calls them after runtime
//! feature detection. Slice bounds are asserted on entry.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use super::super::layout::ROWS_PER_PANEL;

/// AVX2 q7 panel: `chunks` packed words per row, four rows per panel.
///
/// `words` holds the panel's word block laid out `words[c * 4 + r]`
/// (chunk-major, the four row-words of one chunk are consecutive);
/// `x` holds at least `chunks * 4` inputs. Adds into `sums[r]`.
///
/// # Safety
/// Requires AVX2. Caller must have verified `is_x86_feature_detected!("avx2")`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn avx2_panel_q7(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 4);
    let cnt = _mm_cvtsi32_si128(dec as i32);
    let mut acc = [_mm256_setzero_si256(); ROWS_PER_PANEL];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [_mm256_setzero_si256(); ROWS_PER_PANEL];
        while c + 2 <= chunks {
            avx2_q7_chunk(words, x, c, cnt, &mut acc);
            avx2_q7_chunk(words, x, c + 1, cnt, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = _mm256_add_epi64(*a, *a2);
        }
    }
    while c < chunks {
        avx2_q7_chunk(words, x, c, cnt, &mut acc);
        c += 1;
    }
    for (r, a) in acc.iter().enumerate() {
        let mut lanes = [0i64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, *a);
        sums[r] += (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    }
}

/// One q7 chunk (4 inputs × 4 rows) of the AVX2 panel loop.
///
/// # Safety
/// Requires AVX2; `c < chunks` for the bounds asserted by the caller.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn avx2_q7_chunk(
    words: &[u32],
    x: &[i32],
    c: usize,
    cnt: __m128i,
    acc: &mut [__m256i; ROWS_PER_PANEL],
) {
    // 16 bytes = the four row-words of chunk c: rows 0..3, 4 weights each.
    let w128 = _mm_loadu_si128(words.as_ptr().add(c * ROWS_PER_PANEL) as *const __m128i);
    // Sign-extend bytes to i32: lanes 0-3 = row 0, lanes 4-7 = row 1.
    let rows01 = _mm256_cvtepi8_epi32(w128);
    let rows23 = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(w128));
    // Inputs 4c..4c+3, duplicated across both 128-bit halves.
    let x128 = _mm_loadu_si128(x.as_ptr().add(c * 4) as *const __m128i);
    let xx = _mm256_broadcastsi128_si256(x128);
    // Per-product (w * x) >> dec, exactly the scalar fast path on i32.
    let s01 = _mm256_sra_epi32(_mm256_mullo_epi32(rows01, xx), cnt);
    let s23 = _mm256_sra_epi32(_mm256_mullo_epi32(rows23, xx), cnt);
    // Widen to i64 and accumulate per row.
    acc[0] = _mm256_add_epi64(acc[0], _mm256_cvtepi32_epi64(_mm256_castsi256_si128(s01)));
    acc[1] = _mm256_add_epi64(acc[1], _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(s01)));
    acc[2] = _mm256_add_epi64(acc[2], _mm256_cvtepi32_epi64(_mm256_castsi256_si128(s23)));
    acc[3] = _mm256_add_epi64(acc[3], _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(s23)));
}

/// AVX2 q15 panel: `chunks` packed words per row (2 inputs per word).
///
/// # Safety
/// Requires AVX2. Caller must have verified `is_x86_feature_detected!("avx2")`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn avx2_panel_q15(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 2);
    let cnt = _mm_cvtsi32_si128(dec as i32);
    // acc01 lanes: 0,1 = row 0; 2,3 = row 1. acc23 the same for rows 2,3.
    let mut acc = [_mm256_setzero_si256(); 2];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [_mm256_setzero_si256(); 2];
        while c + 2 <= chunks {
            avx2_q15_chunk(words, x, c, cnt, &mut acc);
            avx2_q15_chunk(words, x, c + 1, cnt, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = _mm256_add_epi64(*a, *a2);
        }
    }
    while c < chunks {
        avx2_q15_chunk(words, x, c, cnt, &mut acc);
        c += 1;
    }
    for (h, a) in acc.iter().enumerate() {
        let mut lanes = [0i64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, *a);
        sums[h * 2] += lanes[0] + lanes[1];
        sums[h * 2 + 1] += lanes[2] + lanes[3];
    }
}

/// One q15 chunk (2 inputs × 4 rows) of the AVX2 panel loop.
///
/// # Safety
/// Requires AVX2; `c < chunks` for the bounds asserted by the caller.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn avx2_q15_chunk(words: &[u32], x: &[i32], c: usize, cnt: __m128i, acc: &mut [__m256i; 2]) {
    // 8 halfwords: [r0lo, r0hi, r1lo, r1hi, r2lo, r2hi, r3lo, r3hi].
    let w128 = _mm_loadu_si128(words.as_ptr().add(c * ROWS_PER_PANEL) as *const __m128i);
    let w32 = _mm256_cvtepi16_epi32(w128);
    // Inputs [x_{2c}, x_{2c+1}] repeated four times: one pair per row.
    let xq = _mm_loadl_epi64(x.as_ptr().add(c * 2) as *const __m128i);
    let xx = _mm256_broadcastq_epi64(xq);
    let s = _mm256_sra_epi32(_mm256_mullo_epi32(w32, xx), cnt);
    acc[0] = _mm256_add_epi64(acc[0], _mm256_cvtepi32_epi64(_mm256_castsi256_si128(s)));
    acc[1] = _mm256_add_epi64(acc[1], _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(s)));
}

/// SSE2 q7 panel using `_mm_madd_epi16` with zero-interleaved operands —
/// the direct SMLAD analogue. Each madd lane multiplies one (weight, input)
/// i16 pair against a zero, so every i32 lane holds exactly one product and
/// the per-product `>> dec` stays bit-exact.
///
/// Only valid when all inputs satisfy `|x| <= i16::MAX` (the dispatcher's
/// extra-narrow scan guarantees this).
///
/// # Safety
/// Requires SSE2 (x86_64 baseline — always true).
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn sse2_panel_q7(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 4);
    let cnt = _mm_cvtsi32_si128(dec as i32);
    let mut acc = [_mm_setzero_si128(); ROWS_PER_PANEL];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [_mm_setzero_si128(); ROWS_PER_PANEL];
        while c + 2 <= chunks {
            sse2_q7_chunk(words, x, c, cnt, &mut acc);
            sse2_q7_chunk(words, x, c + 1, cnt, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = _mm_add_epi64(*a, *a2);
        }
    }
    while c < chunks {
        sse2_q7_chunk(words, x, c, cnt, &mut acc);
        c += 1;
    }
    for (r, a) in acc.iter().enumerate() {
        let mut lanes = [0i64; 2];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, *a);
        sums[r] += lanes[0] + lanes[1];
    }
}

/// One q7 chunk of the SSE2 madd panel loop.
///
/// # Safety
/// Requires SSE2; inputs must satisfy `|x| <= i16::MAX`.
#[target_feature(enable = "sse2")]
#[inline]
unsafe fn sse2_q7_chunk(
    words: &[u32],
    x: &[i32],
    c: usize,
    cnt: __m128i,
    acc: &mut [__m128i; ROWS_PER_PANEL],
) {
    let zero = _mm_setzero_si128();
    let w128 = _mm_loadu_si128(words.as_ptr().add(c * ROWS_PER_PANEL) as *const __m128i);
    // Manual sign extension (SSE2 has no cvtepi8): bytes -> i16.
    let sign = _mm_cmpgt_epi8(zero, w128);
    let lo16 = _mm_unpacklo_epi8(w128, sign); // rows 0,1 as 8 × i16
    let hi16 = _mm_unpackhi_epi8(w128, sign); // rows 2,3 as 8 × i16
    // Zero-interleave each row's 4 weights: [w0,0,w1,0,w2,0,w3,0].
    let we = [
        _mm_unpacklo_epi16(lo16, zero),
        _mm_unpackhi_epi16(lo16, zero),
        _mm_unpacklo_epi16(hi16, zero),
        _mm_unpackhi_epi16(hi16, zero),
    ];
    // Inputs as i16 pairs [x0,0,x1,0,x2,0,x3,0]: the low 16 bits of each
    // i32 lane are the exact i16 value because |x| <= i16::MAX.
    let xe = _mm_and_si128(
        _mm_loadu_si128(x.as_ptr().add(c * 4) as *const __m128i),
        _mm_set1_epi32(0xFFFF),
    );
    for (r, w) in we.iter().enumerate() {
        // madd: (w_k * x_k + 0 * 0) per i32 lane — one exact product each.
        let s = _mm_sra_epi32(_mm_madd_epi16(*w, xe), cnt);
        // Widen i32 -> i64 with manual sign extension.
        let sgn = _mm_srai_epi32::<31>(s);
        let lo = _mm_unpacklo_epi32(s, sgn);
        let hi = _mm_unpackhi_epi32(s, sgn);
        acc[r] = _mm_add_epi64(acc[r], _mm_add_epi64(lo, hi));
    }
}

/// SSE2 q15 panel via zero-interleaved `_mm_madd_epi16`; same extra-narrow
/// input bound as [`sse2_panel_q7`].
///
/// # Safety
/// Requires SSE2 (x86_64 baseline — always true).
#[target_feature(enable = "sse2")]
pub(crate) unsafe fn sse2_panel_q15(
    words: &[u32],
    x: &[i32],
    chunks: usize,
    dec: u32,
    unroll2: bool,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    debug_assert!(words.len() >= chunks * ROWS_PER_PANEL);
    debug_assert!(x.len() >= chunks * 2);
    let cnt = _mm_cvtsi32_si128(dec as i32);
    let mut acc = [_mm_setzero_si128(); ROWS_PER_PANEL];
    let mut c = 0usize;
    if unroll2 {
        let mut acc2 = [_mm_setzero_si128(); ROWS_PER_PANEL];
        while c + 2 <= chunks {
            sse2_q15_chunk(words, x, c, cnt, &mut acc);
            sse2_q15_chunk(words, x, c + 1, cnt, &mut acc2);
            c += 2;
        }
        for (a, a2) in acc.iter_mut().zip(acc2.iter()) {
            *a = _mm_add_epi64(*a, *a2);
        }
    }
    while c < chunks {
        sse2_q15_chunk(words, x, c, cnt, &mut acc);
        c += 1;
    }
    for (r, a) in acc.iter().enumerate() {
        let mut lanes = [0i64; 2];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, *a);
        sums[r] += lanes[0] + lanes[1];
    }
}

/// One q15 chunk of the SSE2 madd panel loop.
///
/// # Safety
/// Requires SSE2; inputs must satisfy `|x| <= i16::MAX`.
#[target_feature(enable = "sse2")]
#[inline]
unsafe fn sse2_q15_chunk(
    words: &[u32],
    x: &[i32],
    c: usize,
    cnt: __m128i,
    acc: &mut [__m128i; ROWS_PER_PANEL],
) {
    let zero = _mm_setzero_si128();
    // 8 halfwords: [r0lo, r0hi, r1lo, r1hi, r2lo, r2hi, r3lo, r3hi].
    let w128 = _mm_loadu_si128(words.as_ptr().add(c * ROWS_PER_PANEL) as *const __m128i);
    // Zero-interleave: we_lo = [r0lo,0,r0hi,0,r1lo,0,r1hi,0] (rows 0,1).
    let we_lo = _mm_unpacklo_epi16(w128, zero);
    let we_hi = _mm_unpackhi_epi16(w128, zero);
    // xe = [x0,0,x1,0,x0,0,x1,0]: shuffle 0x44 repeats the i32 pair, then
    // mask each lane to its low 16 bits (exact for |x| <= i16::MAX).
    let xq = _mm_loadl_epi64(x.as_ptr().add(c * 2) as *const __m128i);
    let xe = _mm_and_si128(_mm_shuffle_epi32::<0x44>(xq), _mm_set1_epi32(0xFFFF));
    // madd lanes: [r0lo*x0, r0hi*x1, r1lo*x0, r1hi*x1] (and rows 2,3).
    let s_lo = _mm_sra_epi32(_mm_madd_epi16(we_lo, xe), cnt);
    let s_hi = _mm_sra_epi32(_mm_madd_epi16(we_hi, xe), cnt);
    for (half, s) in [s_lo, s_hi].into_iter().enumerate() {
        let sgn = _mm_srai_epi32::<31>(s);
        acc[half * 2] = _mm_add_epi64(acc[half * 2], _mm_unpacklo_epi32(s, sgn));
        acc[half * 2 + 1] = _mm_add_epi64(acc[half * 2 + 1], _mm_unpackhi_epi32(s, sgn));
    }
}

/// AVX2+FMA 16-lane f32 accumulation: processes `main = n & !15` elements
/// of `w`/`x` into the shared 16-lane structure (two 8-wide FMA registers),
/// leaving the tail to the caller's scalar loop.
///
/// Bit-identical to `simd::portable_lanes16`: both are per-lane fused
/// multiply-add chains over the same fixed lane assignment.
///
/// # Safety
/// Requires AVX2 and FMA. Caller must have verified both via
/// `is_x86_feature_detected!`.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn avx2_f32_lanes16(w: &[f32], x: &[f32], main: usize, lanes: &mut [f32; 16]) {
    debug_assert!(main % 16 == 0);
    debug_assert!(w.len() >= main && x.len() >= main);
    let mut a0 = _mm256_loadu_ps(lanes.as_ptr());
    let mut a1 = _mm256_loadu_ps(lanes.as_ptr().add(8));
    let mut i = 0usize;
    while i < main {
        let w0 = _mm256_loadu_ps(w.as_ptr().add(i));
        let x0 = _mm256_loadu_ps(x.as_ptr().add(i));
        a0 = _mm256_fmadd_ps(w0, x0, a0);
        let w1 = _mm256_loadu_ps(w.as_ptr().add(i + 8));
        let x1 = _mm256_loadu_ps(x.as_ptr().add(i + 8));
        a1 = _mm256_fmadd_ps(w1, x1, a1);
        i += 16;
    }
    _mm256_storeu_ps(lanes.as_mut_ptr(), a0);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), a1);
}
