//! Host SIMD microkernels behind runtime dispatch — the `std::arch`
//! mirror of the dot-product lanes the codegen emits in C.
//!
//! The paper's speedups come from packed dot-product instructions:
//! CMSIS-NN's `SXTB16` + `SMLAD` on Cortex-M and PULP-NN's `pv.sdotsp`
//! on Mr. Wolf. Our codegen emits those lanes (see
//! `rust/src/codegen/README.md`), but the host kernels serving bench
//! drivers, [`super::ExecPlan`] execution and the `service/` layer ran
//! scalar Rust. This module closes that gap:
//!
//! | emitted C lane                  | host mirror                         |
//! |---------------------------------|-------------------------------------|
//! | `SXTB16` + `SMLAD` (CMSIS-NN)   | SSE2 `_mm_madd_epi16`, zero-interleaved (`x86::sse2_panel_*`) |
//! | `pv.sdotsp.b` / `.h` (PULP-NN)  | AVX2 widen+`mullo`+shift (`x86::avx2_panel_*`), NEON `vmulq_s32` (`neon::neon_panel_*`) |
//! | CMSIS f32 inner loop            | AVX2/NEON 16-lane FMA tile ([`SimdF32`]) |
//!
//! # Bit-exactness contract
//!
//! The integer panels accumulate the *same* per-product value as the
//! scalar fast path — `((w * x) >> dec) as i64` — into i64 sums, one per
//! output row. Integer addition commutes, so any SIMD traversal order is
//! bit-exact vs the scalar cores; saturation and bias stay in
//! `packed.rs`, applied once per output. The SSE2 `madd` tier only
//! engages under an extra-narrow input bound (`|x| <= i16::MAX`,
//! [`madd_narrow`]'s scan) because its products are computed in the
//! 16×16→32 domain.
//!
//! The f32 kernel keeps a *fixed 16-lane structure* shared bit-for-bit
//! by the AVX2, NEON and portable paths (all are per-lane fused
//! multiply-add chains with a shared reduction), so forced-scalar runs
//! are bit-identical to hardware runs and `matvec == matmul` holds
//! bitwise within [`SimdF32`] for every tile setting.
//!
//! # Runtime selection
//!
//! [`detected_level`] probes the CPU once (cached): x86_64 picks
//! [`SimdLevel::Avx2`] when AVX2+FMA are present, else the baseline
//! [`SimdLevel::Sse2`]; aarch64 always has NEON; other arches fall back
//! to [`SimdLevel::Scalar`]. Tests and the bench `speedup_simd_*` rows
//! pin a level with [`with_forced_level`] (serialized, panic-safe,
//! clamped to available levels). The packed kernels resolve dispatch
//! per *call* via [`q_dispatch`], so forcing is live everywhere without
//! call-site changes; [`super::ExecPlan`] additionally snapshots the
//! level at compile time as metadata.

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

use super::autotune;
use super::layout::{PackedWidth, ROWS_PER_PANEL};
use super::{DenseKernel, DenseLayerRef};
use crate::fann::activation::Activation;

/// The SIMD capability tiers the dispatcher can select, ordered by
/// capability. `Scalar` is always available; the others are
/// arch-specific.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// No SIMD: the portable scalar paths (always available).
    Scalar = 0,
    /// x86_64 baseline: `_mm_madd_epi16` integer panels (extra-narrow
    /// inputs only), portable f32.
    Sse2 = 1,
    /// x86_64 with AVX2 + FMA: 8-wide integer panels and FMA f32 tiles.
    Avx2 = 2,
    /// aarch64 NEON (mandatory on aarch64): 4-wide integer panels and
    /// FMA f32 tiles.
    Neon = 3,
}

impl SimdLevel {
    /// Stable lower-case label used in `BENCH_kernels.json` metadata.
    pub fn label(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    fn from_u8(v: u8) -> SimdLevel {
        match v {
            1 => SimdLevel::Sse2,
            2 => SimdLevel::Avx2,
            3 => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// Sentinel for "not yet detected / not forced".
const LEVEL_UNSET: u8 = 0xFF;

static DETECTED: AtomicU8 = AtomicU8::new(LEVEL_UNSET);
static FORCED: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// Serializes [`with_forced_level`] callers so concurrent tests cannot
/// observe each other's forced level.
static FORCE_GATE: Mutex<()> = Mutex::new(());

fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdLevel::Avx2;
        }
        // SSE2 is part of the x86_64 baseline.
        SimdLevel::Sse2
    }
    #[cfg(target_arch = "aarch64")]
    {
        SimdLevel::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        SimdLevel::Scalar
    }
}

/// The host's detected SIMD level (probed once, then cached).
pub fn detected_level() -> SimdLevel {
    let v = DETECTED.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return SimdLevel::from_u8(v);
    }
    let l = detect();
    DETECTED.store(l as u8, Ordering::Relaxed);
    l
}

/// The level dispatch actually uses right now: the forced override if
/// one is active (see [`with_forced_level`]), else [`detected_level`].
pub fn selected_level() -> SimdLevel {
    let f = FORCED.load(Ordering::Relaxed);
    if f != LEVEL_UNSET {
        SimdLevel::from_u8(f)
    } else {
        detected_level()
    }
}

/// Whether `level` can actually execute on this host (a level is
/// available when the detected tier implies it).
pub fn available(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Scalar => true,
        SimdLevel::Sse2 => matches!(detected_level(), SimdLevel::Sse2 | SimdLevel::Avx2),
        SimdLevel::Avx2 => detected_level() == SimdLevel::Avx2,
        SimdLevel::Neon => detected_level() == SimdLevel::Neon,
    }
}

/// Run `f` with the dispatcher pinned to `level` (clamped to
/// [`SimdLevel::Scalar`] if the host cannot execute `level`, so forcing
/// an unavailable ISA can never fault). Callers are serialized by a
/// global gate and the override is reset even if `f` panics. Not
/// reentrant: `f` must not itself call [`with_forced_level`].
pub fn with_forced_level<R>(level: SimdLevel, f: impl FnOnce() -> R) -> R {
    let _gate = FORCE_GATE.lock().unwrap_or_else(|p| p.into_inner());
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            FORCED.store(LEVEL_UNSET, Ordering::Relaxed);
        }
    }
    let _reset = Reset;
    let eff = if available(level) {
        level
    } else {
        SimdLevel::Scalar
    };
    FORCED.store(eff as u8, Ordering::Relaxed);
    f()
}

/// Snapshot of the host's SIMD-relevant CPU features, for
/// `BENCH_kernels.json` metadata (baseline comparability across
/// runners) and selection tests.
#[derive(Debug, Clone)]
pub struct CpuFeatures {
    /// Compile-time target architecture (`x86_64`, `aarch64`, ...).
    pub arch: &'static str,
    /// The cached detection result.
    pub detected: SimdLevel,
    /// The level dispatch uses right now (differs from `detected` only
    /// inside [`with_forced_level`]).
    pub selected: SimdLevel,
    /// SSE2 present (always true on x86_64).
    pub sse2: bool,
    /// AVX2 present.
    pub avx2: bool,
    /// FMA present.
    pub fma: bool,
    /// NEON present (always true on aarch64).
    pub neon: bool,
}

/// Probe the host's CPU features (see [`CpuFeatures`]).
pub fn cpu_features() -> CpuFeatures {
    #[cfg(target_arch = "x86_64")]
    let (sse2, avx2, fma, neon) = (
        true,
        is_x86_feature_detected!("avx2"),
        is_x86_feature_detected!("fma"),
        false,
    );
    #[cfg(target_arch = "aarch64")]
    let (sse2, avx2, fma, neon) = (false, false, false, true);
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let (sse2, avx2, fma, neon) = (false, false, false, false);
    CpuFeatures {
        arch: std::env::consts::ARCH,
        detected: detected_level(),
        selected: selected_level(),
        sse2,
        avx2,
        fma,
        neon,
    }
}

// ---------------------------------------------------------------------------
// Integer (packed q7/q15) panel dispatch
// ---------------------------------------------------------------------------

/// How one packed matvec/matmul call executes its panel product loops.
/// Resolved once per kernel call by [`q_dispatch`], then threaded
/// through `matvec_core`/`matmul_core` in `packed.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum QDispatch {
    /// Portable scalar chunk loops (also the slow `qmul` path).
    Scalar,
    /// Widen-to-i32 multiply lanes: AVX2 on x86_64, NEON on aarch64.
    /// Valid under the ordinary narrow fast bound.
    Wide {
        /// Process two chunks per iteration with a second accumulator
        /// set (autotuned; exact — integer adds commute).
        unroll2: bool,
    },
    /// SSE2 `_mm_madd_epi16` with zero-interleaved operands — requires
    /// the extra-narrow bound `|x| <= i16::MAX` ([`madd_narrow`]).
    Madd {
        /// Two-chunk unroll (see [`QDispatch::Wide`]).
        unroll2: bool,
    },
}

/// Per-call SIMD decision for a packed kernel: the dispatch arm plus
/// the layer's decimal point (the per-product shift count).
#[derive(Debug, Clone, Copy)]
pub(crate) struct SimdQ {
    pub(crate) disp: QDispatch,
    pub(crate) dec: u32,
}

impl SimdQ {
    /// Scalar dispatch (used by the exact `qmul` slow path, which the
    /// SIMD panels never implement).
    pub(crate) fn scalar(dec: u32) -> Self {
        Self {
            disp: QDispatch::Scalar,
            dec,
        }
    }
}

/// True when every input satisfies the SSE2 `madd` bound
/// `|x| <= i16::MAX` (products then fit the 16×16→32 madd domain).
pub(crate) fn madd_narrow(xs: &[i32]) -> bool {
    xs.iter().all(|v| v.unsigned_abs() <= i16::MAX as u32)
}

/// Resolve the SIMD dispatch for one packed call whose inputs `xs`
/// already passed the narrow fast-path bound. `width` selects the
/// autotuned path knob (q7 vs q15).
pub(crate) fn q_dispatch(width: PackedWidth, xs: &[i32], dec: u32) -> SimdQ {
    let unroll2 = match autotune::q_path(width) {
        autotune::QPath::Scalar => return SimdQ::scalar(dec),
        autotune::QPath::Simd { unroll2 } => unroll2,
    };
    let disp = match selected_level() {
        SimdLevel::Avx2 | SimdLevel::Neon => QDispatch::Wide { unroll2 },
        SimdLevel::Sse2 => {
            if madd_narrow(xs) {
                QDispatch::Madd { unroll2 }
            } else {
                QDispatch::Scalar
            }
        }
        SimdLevel::Scalar => QDispatch::Scalar,
    };
    SimdQ { disp, dec }
}

/// Dispatch for the hinted (row-split) packed path, where the narrow
/// verdict arrives as a precomputed bool and the inputs are not
/// re-scanned: only the `Wide` tiers apply (the SSE2 `madd` tier needs
/// the extra-narrow scan, which the hint cannot carry).
pub(crate) fn q_dispatch_hinted(width: PackedWidth, dec: u32) -> SimdQ {
    let unroll2 = match autotune::q_path(width) {
        autotune::QPath::Scalar => return SimdQ::scalar(dec),
        autotune::QPath::Simd { unroll2 } => unroll2,
    };
    match selected_level() {
        SimdLevel::Avx2 | SimdLevel::Neon => SimdQ {
            disp: QDispatch::Wide { unroll2 },
            dec,
        },
        _ => SimdQ::scalar(dec),
    }
}

/// Execute one q7 panel (`chunks` whole words per row) through the
/// dispatch in `sq`, adding into `sums[r]` with the exact scalar
/// fast-path semantics.
pub(crate) fn panel_q7(
    sq: SimdQ,
    words: &[u32],
    x: &[i32],
    chunks: usize,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    match sq.disp {
        QDispatch::Scalar => unreachable!("scalar dispatch never reaches the SIMD panels"),
        QDispatch::Wide { unroll2 } => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Wide` is only produced when AVX2(+FMA) was detected.
            unsafe {
                x86::avx2_panel_q7(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            unsafe {
                neon::neon_panel_q7(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            {
                let _ = (words, x, chunks, unroll2, sums);
                unreachable!("wide dispatch is never selected on this arch");
            }
        }
        QDispatch::Madd { unroll2 } => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 is the x86_64 baseline; the dispatcher's
            // `madd_narrow` scan established `|x| <= i16::MAX`.
            unsafe {
                x86::sse2_panel_q7(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(not(target_arch = "x86_64"))]
            {
                let _ = (words, x, chunks, unroll2, sums);
                unreachable!("madd dispatch is never selected on this arch");
            }
        }
    }
}

/// q15 counterpart of [`panel_q7`].
pub(crate) fn panel_q15(
    sq: SimdQ,
    words: &[u32],
    x: &[i32],
    chunks: usize,
    sums: &mut [i64; ROWS_PER_PANEL],
) {
    match sq.disp {
        QDispatch::Scalar => unreachable!("scalar dispatch never reaches the SIMD panels"),
        QDispatch::Wide { unroll2 } => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Wide` is only produced when AVX2(+FMA) was detected.
            unsafe {
                x86::avx2_panel_q15(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is baseline on aarch64.
            unsafe {
                neon::neon_panel_q15(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            {
                let _ = (words, x, chunks, unroll2, sums);
                unreachable!("wide dispatch is never selected on this arch");
            }
        }
        QDispatch::Madd { unroll2 } => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 baseline; extra-narrow bound established.
            unsafe {
                x86::sse2_panel_q15(words, x, chunks, sq.dec, unroll2, sums)
            };
            #[cfg(not(target_arch = "x86_64"))]
            {
                let _ = (words, x, chunks, unroll2, sums);
                unreachable!("madd dispatch is never selected on this arch");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// f32 SIMD kernel
// ---------------------------------------------------------------------------

/// Fixed reduction over the shared 16-lane structure: pairwise within
/// quads, then across quads. One copy, used by every dot regardless of
/// which path filled the lanes, so the reduction order can never drift
/// between hardware and portable runs.
#[inline]
fn reduce16(l: &[f32; 16]) -> f32 {
    let q0 = (l[0] + l[1]) + (l[2] + l[3]);
    let q1 = (l[4] + l[5]) + (l[6] + l[7]);
    let q2 = (l[8] + l[9]) + (l[10] + l[11]);
    let q3 = (l[12] + l[13]) + (l[14] + l[15]);
    (q0 + q1) + (q2 + q3)
}

/// Portable mirror of the hardware 16-lane accumulation: the same
/// per-lane fused multiply-add chains (`mul_add` is a single-rounding
/// IEEE fma, exactly what `vfmaq_f32`/`_mm256_fmadd_ps` compute), so
/// results are bit-identical to the hardware paths.
fn portable_lanes16(w: &[f32], x: &[f32], main: usize, lanes: &mut [f32; 16]) {
    let mut i = 0usize;
    while i < main {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane = w[i + l].mul_add(x[i + l], *lane);
        }
        i += 16;
    }
}

/// Fill the 16-lane accumulators over `main` elements using the best
/// available path for the currently selected level.
fn accumulate_lanes16(w: &[f32], x: &[f32], main: usize, lanes: &mut [f32; 16]) {
    #[cfg(target_arch = "x86_64")]
    if selected_level() == SimdLevel::Avx2 {
        // SAFETY: Avx2 level implies AVX2 and FMA were detected.
        unsafe { x86::avx2_f32_lanes16(w, x, main, lanes) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if selected_level() == SimdLevel::Neon {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { neon::neon_f32_lanes16(w, x, main, lanes) };
        return;
    }
    portable_lanes16(w, x, main, lanes);
}

/// SIMD dot product over the fixed 16-lane structure plus a scalar fma
/// tail. Bit-identical across hardware and portable paths, and across
/// every caller ([`SimdF32`]'s `matvec` and `matmul` both route every
/// output through this one function).
pub fn dot_simd(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let main = n & !15;
    let mut lanes = [0.0f32; 16];
    if main > 0 {
        accumulate_lanes16(w, x, main, &mut lanes);
    }
    let mut tail = 0.0f32;
    for (wv, xv) in w[main..n].iter().zip(&x[main..n]) {
        tail = wv.mul_add(*xv, tail);
    }
    reduce16(&lanes) + tail
}

/// The host-SIMD float kernel: 16-lane FMA dot products (AVX2+FMA on
/// x86_64, NEON on aarch64, bit-identical portable mirror elsewhere)
/// with an autotuned row tile for the batched entry point.
///
/// Numerics: `matvec == matmul` bitwise for every tile setting (every
/// output goes through [`dot_simd`]); within the crate-wide 3e-5
/// tolerance vs [`super::ScalarF32`] (FMA contraction + lane
/// reassociation); forced-scalar runs are bit-identical to hardware
/// runs. [`super::BlockedF32`] remains the crate default — this kernel
/// is additive and selected explicitly (bench sweeps, parity suites,
/// callers that opt in).
#[derive(Debug, Clone, Copy, Default)]
pub struct SimdF32;

impl SimdF32 {
    #[inline]
    fn row_tile() -> usize {
        autotune::f32_rows_per_tile().max(1)
    }
}

impl DenseKernel<f32> for SimdF32 {
    fn name(&self) -> &'static str {
        "simd_f32"
    }

    fn apply_epilogue(&self, act: Activation, steepness: f32, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = super::epilogue_f32(act, steepness, *v);
        }
    }

    fn matvec(&self, layer: &DenseLayerRef<f32>, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            out[o] = dot_simd(row, x) + layer.biases[o];
        }
    }

    fn matmul(&self, layer: &DenseLayerRef<f32>, xs: &[f32], n_samples: usize, out: &mut [f32]) {
        debug_assert_eq!(xs.len(), layer.n_in * n_samples);
        debug_assert_eq!(out.len(), layer.n_out * n_samples);
        let tile = Self::row_tile();
        // Row-tile outer, samples inner: the tile's weight rows stay hot
        // across the whole batch. Per-(row, sample) arithmetic is exactly
        // `matvec`'s, so batching never changes numerics.
        let mut r0 = 0usize;
        while r0 < layer.n_out {
            let r1 = (r0 + tile).min(layer.n_out);
            for s in 0..n_samples {
                let x = &xs[s * layer.n_in..(s + 1) * layer.n_in];
                for r in r0..r1 {
                    let row = &layer.weights[r * layer.n_in..(r + 1) * layer.n_in];
                    out[s * layer.n_out + r] = dot_simd(row, x) + layer.biases[r];
                }
            }
            r0 = r1;
        }
    }

    fn matvec_act(
        &self,
        layer: &DenseLayerRef<f32>,
        x: &[f32],
        out: &mut [f32],
        act: Activation,
        steepness: f32,
    ) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            let v = dot_simd(row, x) + layer.biases[o];
            out[o] = super::epilogue_f32(act, steepness, v);
        }
    }

    fn matmul_act(
        &self,
        layer: &DenseLayerRef<f32>,
        xs: &[f32],
        n_samples: usize,
        out: &mut [f32],
        act: Activation,
        steepness: f32,
    ) {
        debug_assert_eq!(xs.len(), layer.n_in * n_samples);
        debug_assert_eq!(out.len(), layer.n_out * n_samples);
        let tile = Self::row_tile();
        let mut r0 = 0usize;
        while r0 < layer.n_out {
            let r1 = (r0 + tile).min(layer.n_out);
            for s in 0..n_samples {
                let x = &xs[s * layer.n_in..(s + 1) * layer.n_in];
                for r in r0..r1 {
                    let row = &layer.weights[r * layer.n_in..(r + 1) * layer.n_in];
                    let v = dot_simd(row, x) + layer.biases[r];
                    out[s * layer.n_out + r] = super::epilogue_f32(act, steepness, v);
                }
            }
            r0 = r1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.731).sin() * scale).collect()
    }

    /// Assert no forced level is active. Holding the gate is what makes
    /// this sound under parallel tests: while the gate is held no
    /// [`with_forced_level`] body can be running, and every completed
    /// one reset `FORCED` before releasing the gate.
    fn assert_unforced() {
        let _g = FORCE_GATE.lock().unwrap_or_else(|p| p.into_inner());
        assert_eq!(FORCED.load(Ordering::Relaxed), LEVEL_UNSET);
    }

    #[test]
    fn detection_is_cached_and_consistent() {
        let a = detected_level();
        let b = detected_level();
        assert_eq!(a, b);
        #[cfg(target_arch = "x86_64")]
        assert!(matches!(a, SimdLevel::Sse2 | SimdLevel::Avx2));
        #[cfg(target_arch = "aarch64")]
        assert_eq!(a, SimdLevel::Neon);
    }

    #[test]
    fn forced_level_applies_and_resets() {
        with_forced_level(SimdLevel::Scalar, || {
            assert_eq!(selected_level(), SimdLevel::Scalar);
        });
        assert_unforced();
    }

    #[test]
    fn forcing_unavailable_level_clamps_to_scalar() {
        // Neon can never be available on x86_64 and vice versa; pick a
        // level that cannot match the current arch.
        let foreign = if cfg!(target_arch = "aarch64") {
            SimdLevel::Avx2
        } else {
            SimdLevel::Neon
        };
        with_forced_level(foreign, || {
            assert_eq!(selected_level(), SimdLevel::Scalar);
        });
    }

    #[test]
    fn forced_level_resets_after_panic() {
        let r = std::panic::catch_unwind(|| {
            with_forced_level(SimdLevel::Scalar, || panic!("boom"));
        });
        assert!(r.is_err());
        assert_unforced();
    }

    #[test]
    fn madd_narrow_bound_is_exact() {
        assert!(madd_narrow(&[0, 1, -1, i16::MAX as i32, -(i16::MAX as i32)]));
        assert!(!madd_narrow(&[i16::MAX as i32 + 1]));
        assert!(!madd_narrow(&[-(i16::MAX as i32) - 1]));
    }

    #[test]
    fn q_dispatch_scalar_level_is_scalar() {
        with_forced_level(SimdLevel::Scalar, || {
            let sq = q_dispatch(PackedWidth::Q7, &[1, 2, 3], 13);
            assert_eq!(sq.disp, QDispatch::Scalar);
            let sq = q_dispatch_hinted(PackedWidth::Q15, 6);
            assert_eq!(sq.disp, QDispatch::Scalar);
        });
    }

    #[test]
    fn dot_simd_matches_naive_within_tolerance() {
        for n in [0usize, 1, 5, 15, 16, 17, 31, 32, 33, 100, 257] {
            let w = seq(n, 0.9);
            let x = seq(n, 1.1);
            let naive: f64 = w
                .iter()
                .zip(&x)
                .map(|(a, b)| *a as f64 * *b as f64)
                .sum();
            let got = dot_simd(&w, &x);
            assert!(
                (got as f64 - naive).abs() <= 3e-5 * (1.0 + naive.abs()),
                "n={n}: got {got}, naive {naive}"
            );
        }
    }

    #[test]
    fn dot_simd_forced_scalar_is_bit_identical() {
        // The portable 16-lane mirror must reproduce the hardware path
        // bit-for-bit: same per-lane fma chains, same fixed reduction.
        for n in [16usize, 33, 64, 127, 256] {
            let w = seq(n, 1.3);
            let x = seq(n, 0.7);
            let hw = dot_simd(&w, &x);
            let sc = with_forced_level(SimdLevel::Scalar, || dot_simd(&w, &x));
            assert_eq!(hw.to_bits(), sc.to_bits(), "n={n}");
        }
    }

    #[test]
    fn cpu_features_are_consistent_with_detection() {
        let f = cpu_features();
        assert_eq!(f.detected, detected_level());
        match f.detected {
            SimdLevel::Avx2 => assert!(f.avx2 && f.fma && f.sse2),
            SimdLevel::Sse2 => assert!(f.sse2),
            SimdLevel::Neon => assert!(f.neon),
            SimdLevel::Scalar => {}
        }
    }
}
