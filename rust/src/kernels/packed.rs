//! The low-bitwidth packed kernels — host-side counterparts of
//! CMSIS-NN's `arm_fully_connected_q7`/`_q15` and PULP-NN's 4×i8
//! per-word dot products: weights stream as `u32` words out of the
//! panel layout built by [`super::layout`], four (Q7) or two (Q15)
//! MAC operands per load, with a panel of four output rows sharing
//! every input load.
//!
//! # Bit-exactness contract
//!
//! Per-product arithmetic is *identical* to [`super::FixedQ`]: widen,
//! multiply, arithmetic-shift-right by `dec` (`quantize::qmul`),
//! accumulate in i64, saturate to i32 once per output. Because integer
//! adds commute and zero-padded lanes contribute exactly 0, any
//! traversal order over the packed panels produces the same i64 sum —
//! so packed results are **bit-exact** vs `FixedQ` on the same Q(dec)
//! parameters whenever the weights fit the narrow width (which the
//! lossless `pack_rows` step guarantees). `rust/tests/parity_packed.rs`
//! pins this, ragged tails included.
//!
//! # The narrow-multiply fast path
//!
//! The actual speedup over `FixedQ` comes from exploiting the narrow
//! weights: when every input of the call satisfies `|x| < 2^24` (Q7,
//! `|w| ≤ 2^7`) or `|x| < 2^16` (Q15, `|w| ≤ 2^15`), every product
//! fits in i32, so the multiply+shift runs in 32-bit arithmetic — which
//! the compiler can vectorize twice as wide as the generic i64 path —
//! and only the accumulate widens to i64. The bound is checked once per
//! call with a linear scan (negligible vs the `n_in · n_out` MAC work);
//! inputs that exceed it (possible in principle: activations are full
//! i32 Q(dec)) take the exact i64 path. Both paths compute the same
//! value bit for bit: a product that fits i32 shifts identically at
//! either width.

use super::layout::{PackedPanels, PackedWidth, ROWS_PER_PANEL};
use crate::fann::activation::Activation;
use crate::quantize::{qmul, sat_i32};

/// Borrowed view of one packed dense layer: panel-form weights plus
/// plain i32 Q(dec) biases (biases stay wide, as in CMSIS-NN).
#[derive(Debug, Clone, Copy)]
pub struct PackedLayerRef<'a> {
    pub panels: &'a PackedPanels,
    pub biases: &'a [i32],
}

impl<'a> PackedLayerRef<'a> {
    pub fn new(panels: &'a PackedPanels, biases: &'a [i32]) -> Self {
        debug_assert_eq!(biases.len(), panels.n_out);
        Self { panels, biases }
    }
}

/// Compile-time description of one packed width (lane count, unpack,
/// fast-path input bound). Monomorphizes the shared cores below into
/// two straight-line kernels.
trait Width: 'static {
    const WIDTH: PackedWidth;
    const ELEMS: usize;
    /// Exclusive bound on `|x|` under which `w · x` fits in i32.
    const FAST_LIMIT: u32;
    /// Sign-extended lanes of one word; only the first `ELEMS` entries
    /// are meaningful.
    fn lanes(word: u32) -> [i32; 4];
}

struct W7;
impl Width for W7 {
    const WIDTH: PackedWidth = PackedWidth::Q7;
    const ELEMS: usize = 4;
    // |w| <= 2^7, |x| < 2^24  =>  |w·x| < 2^31.
    const FAST_LIMIT: u32 = 1 << 24;
    #[inline(always)]
    fn lanes(word: u32) -> [i32; 4] {
        [
            word as u8 as i8 as i32,
            (word >> 8) as u8 as i8 as i32,
            (word >> 16) as u8 as i8 as i32,
            (word >> 24) as u8 as i8 as i32,
        ]
    }
}

struct W15;
impl Width for W15 {
    const WIDTH: PackedWidth = PackedWidth::Q15;
    const ELEMS: usize = 2;
    // |w| <= 2^15, |x| < 2^16  =>  |w·x| < 2^31.
    const FAST_LIMIT: u32 = 1 << 16;
    #[inline(always)]
    fn lanes(word: u32) -> [i32; 4] {
        [word as u16 as i16 as i32, (word >> 16) as u16 as i16 as i32, 0, 0]
    }
}

#[inline(always)]
fn all_fast<W: Width>(xs: &[i32]) -> bool {
    xs.iter().all(|&v| v.unsigned_abs() < W::FAST_LIMIT)
}

/// One sample through one packed layer; `prod` is the per-product
/// arithmetic (fast i32 or exact i64 `qmul`), `epi` the write-back
/// epilogue on the saturated i32 pre-activation.
#[inline(always)]
fn matvec_core<W, P, F>(layer: &PackedLayerRef, x: &[i32], out: &mut [i32], prod: P, epi: F)
where
    W: Width,
    P: Fn(i32, i32) -> i64,
    F: Fn(i32) -> i32,
{
    let p = layer.panels;
    debug_assert_eq!(p.width, W::WIDTH);
    debug_assert_eq!(x.len(), p.n_in);
    debug_assert_eq!(out.len(), p.n_out);
    let wpr = p.words_per_row;
    let full = p.n_in / W::ELEMS;
    for panel in 0..p.panels() {
        let o0 = panel * ROWS_PER_PANEL;
        let base = panel * wpr * ROWS_PER_PANEL;
        let mut acc = [0i64; ROWS_PER_PANEL];
        for c in 0..full {
            let i0 = c * W::ELEMS;
            let wbase = base + c * ROWS_PER_PANEL;
            for (r, a) in acc.iter_mut().enumerate() {
                let lanes = W::lanes(p.words[wbase + r]);
                for e in 0..W::ELEMS {
                    *a += prod(lanes[e], x[i0 + e]);
                }
            }
        }
        if full < wpr {
            // Ragged tail chunk: the padded weight lanes are 0 and are
            // simply not multiplied (identical sum either way).
            let i0 = full * W::ELEMS;
            let wbase = base + full * ROWS_PER_PANEL;
            for (r, a) in acc.iter_mut().enumerate() {
                let lanes = W::lanes(p.words[wbase + r]);
                for (e, &xv) in x[i0..].iter().enumerate() {
                    *a += prod(lanes[e], xv);
                }
            }
        }
        let rows = (p.n_out - o0).min(ROWS_PER_PANEL);
        for r in 0..rows {
            out[o0 + r] = epi(sat_i32(acc[r] + layer.biases[o0 + r] as i64) as i32);
        }
    }
}

/// Batched core: 4-sample tiles over the same panel word-stream, so
/// each weight word is loaded once per 4 samples × 4 rows = 16 MACs
/// (the weight-reuse the paper's DMA double-buffering banks on).
#[inline(always)]
fn matmul_core<W, P, F>(
    layer: &PackedLayerRef,
    xs: &[i32],
    n_samples: usize,
    out: &mut [i32],
    prod: P,
    epi: F,
) where
    W: Width,
    P: Fn(i32, i32) -> i64,
    F: Fn(i32) -> i32,
{
    let p = layer.panels;
    debug_assert_eq!(p.width, W::WIDTH);
    let n_in = p.n_in;
    let n_out = p.n_out;
    debug_assert_eq!(xs.len(), n_in * n_samples);
    debug_assert_eq!(out.len(), n_out * n_samples);
    let wpr = p.words_per_row;
    let full = n_in / W::ELEMS;
    let mut s0 = 0;
    while s0 < n_samples {
        let sb = (n_samples - s0).min(4);
        for panel in 0..p.panels() {
            let o0 = panel * ROWS_PER_PANEL;
            let base = panel * wpr * ROWS_PER_PANEL;
            let mut acc = [[0i64; ROWS_PER_PANEL]; 4];
            for c in 0..full {
                let i0 = c * W::ELEMS;
                let wbase = base + c * ROWS_PER_PANEL;
                for r in 0..ROWS_PER_PANEL {
                    let lanes = W::lanes(p.words[wbase + r]);
                    for (si, a) in acc.iter_mut().enumerate().take(sb) {
                        let xb = (s0 + si) * n_in + i0;
                        for e in 0..W::ELEMS {
                            a[r] += prod(lanes[e], xs[xb + e]);
                        }
                    }
                }
            }
            if full < wpr {
                let i0 = full * W::ELEMS;
                let tail = n_in - i0;
                let wbase = base + full * ROWS_PER_PANEL;
                for r in 0..ROWS_PER_PANEL {
                    let lanes = W::lanes(p.words[wbase + r]);
                    for (si, a) in acc.iter_mut().enumerate().take(sb) {
                        let xb = (s0 + si) * n_in + i0;
                        for e in 0..tail {
                            a[r] += prod(lanes[e], xs[xb + e]);
                        }
                    }
                }
            }
            let rows = (n_out - o0).min(ROWS_PER_PANEL);
            for (si, a) in acc.iter().enumerate().take(sb) {
                for r in 0..rows {
                    out[(s0 + si) * n_out + o0 + r] =
                        epi(sat_i32(a[r] + layer.biases[o0 + r] as i64) as i32);
                }
            }
        }
        s0 += sb;
    }
}

macro_rules! packed_kernel {
    ($kernel:ident, $w:ty, $name:literal, $doc:literal) => {
        #[doc = $doc]
        #[derive(Debug, Clone, Copy)]
        pub struct $kernel {
            /// Q(dec) decimal point — part of the kernel value, exactly
            /// as in [`super::FixedQ`].
            pub dec: u32,
        }

        impl $kernel {
            pub fn new(dec: u32) -> Self {
                Self { dec }
            }

            pub fn name(&self) -> &'static str {
                $name
            }

            /// Pre-activation single-sample pass (packed analogue of
            /// [`super::DenseKernel::matvec`]).
            pub fn matvec(&self, layer: &PackedLayerRef, x: &[i32], out: &mut [i32]) {
                self.matvec_impl(layer, x, out, |v| v);
            }

            /// Fused single-sample pass: step-linear activation applied
            /// at write-back.
            pub fn matvec_act(
                &self,
                layer: &PackedLayerRef,
                x: &[i32],
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matvec_impl(layer, x, out, |v| super::epilogue_q(act, dec, v));
            }

            /// Pre-activation batched pass (packed analogue of
            /// [`super::DenseKernel::matmul`]).
            pub fn matmul(&self, layer: &PackedLayerRef, xs: &[i32], n_samples: usize, out: &mut [i32]) {
                self.matmul_impl(layer, xs, n_samples, out, |v| v);
            }

            /// Fused batched pass.
            pub fn matmul_act(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matmul_impl(layer, xs, n_samples, out, |v| super::epilogue_q(act, dec, v));
            }

            #[inline]
            fn matvec_impl<F: Fn(i32) -> i32>(
                &self,
                layer: &PackedLayerRef,
                x: &[i32],
                out: &mut [i32],
                epi: F,
            ) {
                let dec = self.dec;
                if all_fast::<$w>(x) {
                    matvec_core::<$w, _, _>(layer, x, out, |w, xv| ((w * xv) >> dec) as i64, epi);
                } else {
                    matvec_core::<$w, _, _>(layer, x, out, |w, xv| qmul(w, xv, dec), epi);
                }
            }

            #[inline]
            fn matmul_impl<F: Fn(i32) -> i32>(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                out: &mut [i32],
                epi: F,
            ) {
                let dec = self.dec;
                if all_fast::<$w>(xs) {
                    matmul_core::<$w, _, _>(
                        layer,
                        xs,
                        n_samples,
                        out,
                        |w, xv| ((w * xv) >> dec) as i64,
                        epi,
                    );
                } else {
                    matmul_core::<$w, _, _>(layer, xs, n_samples, out, |w, xv| qmul(w, xv, dec), epi);
                }
            }
        }
    };
}

packed_kernel!(
    PackedQ7,
    W7,
    "packed_q7",
    "Q(dec) dense kernel over 4×i8-per-word packed panels (CMSIS-NN \
     `arm_fully_connected_q7` analogue). Bit-exact vs [`super::FixedQ`] \
     on the same parameters."
);

packed_kernel!(
    PackedQ15,
    W15,
    "packed_q15",
    "Q(dec) dense kernel over 2×i16-per-word packed panels (CMSIS-NN \
     `arm_fully_connected_q15` analogue). Bit-exact vs [`super::FixedQ`] \
     on the same parameters."
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::layout::pack_rows;
    use crate::kernels::{DenseKernel, DenseLayerRef, FixedQ};
    use crate::util::rng::Rng;

    fn random_layer(
        rng: &mut Rng,
        width: PackedWidth,
        n_in: usize,
        n_out: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let (lo, hi) = width.range();
        let span = (hi - lo + 1) as usize;
        let w: Vec<i32> = (0..n_in * n_out).map(|_| lo + rng.below(span) as i32).collect();
        let b: Vec<i32> = (0..n_out).map(|_| rng.below(4001) as i32 - 2000).collect();
        (w, b)
    }

    #[test]
    fn bit_exact_vs_fixedq_including_ragged_tails() {
        let mut rng = Rng::new(0xBEEF);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            for &n_in in &[1usize, 2, 3, 4, 5, 7, 9, 16] {
                for &n_out in &[1usize, 3, 4, 5, 8] {
                    let dec = 6;
                    let (w, b) = random_layer(&mut rng, width, n_in, n_out);
                    let x: Vec<i32> =
                        (0..n_in).map(|_| rng.below(2001) as i32 - 1000).collect();
                    let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                    let mut want = vec![0i32; n_out];
                    FixedQ::new(dec).matvec(&layer, &x, &mut want);
                    let panels = pack_rows(width, n_in, n_out, &w).unwrap();
                    let pref = PackedLayerRef::new(&panels, &b);
                    let mut got = vec![0i32; n_out];
                    match width {
                        PackedWidth::Q7 => PackedQ7::new(dec).matvec(&pref, &x, &mut got),
                        PackedWidth::Q15 => PackedQ15::new(dec).matvec(&pref, &x, &mut got),
                    }
                    assert_eq!(got, want, "{width:?} n_in={n_in} n_out={n_out}");
                }
            }
        }
    }

    #[test]
    fn slow_path_large_inputs_bit_exact() {
        // Inputs beyond the fast-path bound force the exact i64 route;
        // results must still match FixedQ bit for bit.
        let mut rng = Rng::new(0x51077);
        let dec = 4;
        let (n_in, n_out) = (9, 5);
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let x: Vec<i32> = (0..n_in)
            .map(|i| if i % 2 == 0 { i32::MAX - i as i32 } else { i32::MIN + i as i32 })
            .collect();
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let mut want = vec![0i32; n_out];
        FixedQ::new(dec).matvec(&layer, &x, &mut want);
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let mut got = vec![0i32; n_out];
        PackedQ7::new(dec).matvec(&pref, &x, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn matmul_bit_exact_vs_matvec_per_sample() {
        let mut rng = Rng::new(0xABCD);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let dec = 7;
            let (n_in, n_out, n_samples) = (11, 6, 7);
            let (w, b) = random_layer(&mut rng, width, n_in, n_out);
            let xs: Vec<i32> =
                (0..n_in * n_samples).map(|_| rng.below(512) as i32 - 256).collect();
            let panels = pack_rows(width, n_in, n_out, &w).unwrap();
            let pref = PackedLayerRef::new(&panels, &b);
            let mut batched = vec![0i32; n_out * n_samples];
            let mut single = vec![0i32; n_out];
            match width {
                PackedWidth::Q7 => {
                    let k = PackedQ7::new(dec);
                    k.matmul(&pref, &xs, n_samples, &mut batched);
                    for s in 0..n_samples {
                        k.matvec(&pref, &xs[s * n_in..(s + 1) * n_in], &mut single);
                        assert_eq!(&batched[s * n_out..(s + 1) * n_out], &single[..]);
                    }
                }
                PackedWidth::Q15 => {
                    let k = PackedQ15::new(dec);
                    k.matmul(&pref, &xs, n_samples, &mut batched);
                    for s in 0..n_samples {
                        k.matvec(&pref, &xs[s * n_in..(s + 1) * n_in], &mut single);
                        assert_eq!(&batched[s * n_out..(s + 1) * n_out], &single[..]);
                    }
                }
            }
        }
    }

    #[test]
    fn fused_equals_unfused() {
        let mut rng = Rng::new(0xFACE);
        let dec = 6;
        let (n_in, n_out, n_samples) = (10, 7, 5);
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples).map(|_| rng.below(257) as i32 - 128).collect();
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let k = PackedQ7::new(dec);
        for act in crate::fann::activation::ALL {
            let mut fused = vec![0i32; n_out * n_samples];
            k.matmul_act(&pref, &xs, n_samples, &mut fused, act);
            let mut unfused = vec![0i32; n_out * n_samples];
            k.matmul(&pref, &xs, n_samples, &mut unfused);
            for v in unfused.iter_mut() {
                *v = crate::quantize::activation_q(act, *v as i64, dec) as i32;
            }
            assert_eq!(fused, unfused, "{act:?}");
        }
    }
}
