//! The low-bitwidth packed kernels — host-side counterparts of
//! CMSIS-NN's `arm_fully_connected_q7`/`_q15` and PULP-NN's 4×i8
//! per-word dot products: weights stream as `u32` words out of the
//! panel layout built by [`super::layout`], four (Q7) or two (Q15)
//! MAC operands per load, with a panel of four output rows sharing
//! every input load.
//!
//! # Bit-exactness contract
//!
//! Per-product arithmetic is *identical* to [`super::FixedQ`]: widen,
//! multiply, arithmetic-shift-right by `dec` (`quantize::qmul`),
//! accumulate in i64, saturate to i32 once per output. Because integer
//! adds commute and zero-padded lanes contribute exactly 0, any
//! traversal order over the packed panels produces the same i64 sum —
//! so packed results are **bit-exact** vs `FixedQ` on the same Q(dec)
//! parameters whenever the weights fit the narrow width (which the
//! lossless `pack_rows` step guarantees). `rust/tests/parity_packed.rs`
//! pins this, ragged tails included.
//!
//! # The narrow-multiply fast path
//!
//! The actual speedup over `FixedQ` comes from exploiting the narrow
//! weights: when every input of the call satisfies `|x| < 2^24` (Q7,
//! `|w| ≤ 2^7`) or `|x| < 2^16` (Q15, `|w| ≤ 2^15`), every product
//! fits in i32, so the multiply+shift runs in 32-bit arithmetic — which
//! the compiler can vectorize twice as wide as the generic i64 path —
//! and only the accumulate widens to i64. The bound is checked once per
//! call with a linear scan (negligible vs the `n_in · n_out` MAC work);
//! inputs that exceed it (possible in principle: activations are full
//! i32 Q(dec)) take the exact i64 path. Both paths compute the same
//! value bit for bit: a product that fits i32 shifts identically at
//! either width.
//!
//! On top of the fast path, the host SIMD dispatcher ([`super::simd`])
//! may execute the whole-word chunk loops with explicit `std::arch`
//! panel kernels (AVX2/SSE2 on x86_64, NEON on aarch64) carrying
//! identical per-product arithmetic — still bit-exact, still sharing
//! the scalar ragged-tail and saturate/bias/epilogue write-back.
//!
//! # Unrolled word stream and panel ranges
//!
//! The single-sample core consumes **four panel words per iteration**
//! into four independent accumulator lanes per row (the PULP-NN
//! unrolled-MAC recipe), reduced once at panel end — bit-exact because
//! integer adds commute. Both cores also take a *panel range*, so a
//! row-split executor ([`crate::kernels::exec_plan`]) can hand each
//! core a contiguous block of panels and stay bit-exact vs the
//! whole-layer call (per-panel accumulation is independent).

use std::ops::Range;

use super::layout::{PackedPanels, PackedWidth, ROWS_PER_PANEL};
use super::simd::{self, QDispatch, SimdQ};
use crate::fann::activation::Activation;
use crate::quantize::{qmul, sat_i32};

/// Borrowed view of one packed dense layer: panel-form weights plus
/// plain i32 Q(dec) biases (biases stay wide, as in CMSIS-NN). Holds
/// the panel geometry and a borrowed word slice directly — rather than
/// a `&PackedPanels` — so layers can be viewed straight out of a flat
/// word arena ([`crate::kernels::ExecPlan`]) with no per-call copy.
#[derive(Debug, Clone, Copy)]
pub struct PackedLayerRef<'a> {
    /// Packed element width.
    pub width: PackedWidth,
    /// Input width of the layer.
    pub n_in: usize,
    /// Output rows of the layer.
    pub n_out: usize,
    /// Words covering one row's `n_in` weights: `ceil(n_in / elems)`.
    pub words_per_row: usize,
    /// Borrowed packed word stream, panel-major.
    pub words: &'a [u32],
    /// Borrowed wide i32 biases.
    pub biases: &'a [i32],
}

impl<'a> PackedLayerRef<'a> {
    /// Borrowed view over one packed layer's parameters.
    pub fn new(panels: &'a PackedPanels, biases: &'a [i32]) -> Self {
        Self::from_raw(
            panels.width,
            panels.n_in,
            panels.n_out,
            panels.words_per_row,
            &panels.words,
            biases,
        )
    }

    /// Borrow a packed layer out of a flat word arena (the compiled
    /// execution-plan form). `words` must hold exactly the layer's
    /// panel stream: `panels · words_per_row · ROWS_PER_PANEL` words.
    pub fn from_raw(
        width: PackedWidth,
        n_in: usize,
        n_out: usize,
        words_per_row: usize,
        words: &'a [u32],
        biases: &'a [i32],
    ) -> Self {
        debug_assert_eq!(biases.len(), n_out);
        debug_assert_eq!(
            words.len(),
            n_out.div_ceil(ROWS_PER_PANEL) * words_per_row * ROWS_PER_PANEL
        );
        Self {
            width,
            n_in,
            n_out,
            words_per_row,
            words,
            biases,
        }
    }

    /// Number of row panels (last one possibly padded).
    #[inline]
    pub fn panels(&self) -> usize {
        self.n_out.div_ceil(ROWS_PER_PANEL)
    }
}

/// Compile-time description of one packed width (lane count, unpack,
/// fast-path input bound). Monomorphizes the shared cores below into
/// two straight-line kernels.
trait Width: 'static {
    const WIDTH: PackedWidth;
    const ELEMS: usize;
    /// Exclusive bound on `|x|` under which `w · x` fits in i32.
    const FAST_LIMIT: u32;
    /// Sign-extended lanes of one word; only the first `ELEMS` entries
    /// are meaningful.
    fn lanes(word: u32) -> [i32; 4];
    /// Run one panel's whole-word product loop (`chunks` words per row)
    /// through the SIMD dispatch, adding into `sums[r]`. Must be
    /// bit-exact vs the scalar fast-path chunk loops (see
    /// [`super::simd`]); only called when `sq.disp` is a SIMD arm.
    fn simd_panel(sq: SimdQ, words: &[u32], x: &[i32], chunks: usize, sums: &mut [i64; 4]);
}

struct W7;
impl Width for W7 {
    const WIDTH: PackedWidth = PackedWidth::Q7;
    const ELEMS: usize = 4;
    // |w| <= 2^7, |x| < 2^24  =>  |w·x| < 2^31.
    const FAST_LIMIT: u32 = 1 << 24;
    #[inline(always)]
    fn lanes(word: u32) -> [i32; 4] {
        [
            word as u8 as i8 as i32,
            (word >> 8) as u8 as i8 as i32,
            (word >> 16) as u8 as i8 as i32,
            (word >> 24) as u8 as i8 as i32,
        ]
    }
    #[inline(always)]
    fn simd_panel(sq: SimdQ, words: &[u32], x: &[i32], chunks: usize, sums: &mut [i64; 4]) {
        simd::panel_q7(sq, words, x, chunks, sums);
    }
}

struct W15;
impl Width for W15 {
    const WIDTH: PackedWidth = PackedWidth::Q15;
    const ELEMS: usize = 2;
    // |w| <= 2^15, |x| < 2^16  =>  |w·x| < 2^31.
    const FAST_LIMIT: u32 = 1 << 16;
    #[inline(always)]
    fn lanes(word: u32) -> [i32; 4] {
        [word as u16 as i16 as i32, (word >> 16) as u16 as i16 as i32, 0, 0]
    }
    #[inline(always)]
    fn simd_panel(sq: SimdQ, words: &[u32], x: &[i32], chunks: usize, sums: &mut [i64; 4]) {
        simd::panel_q15(sq, words, x, chunks, sums);
    }
}

#[inline(always)]
fn all_fast<W: Width>(xs: &[i32]) -> bool {
    xs.iter().all(|&v| v.unsigned_abs() < W::FAST_LIMIT)
}

/// One sample through panels `panels` of one packed layer; `prod` is
/// the per-product arithmetic (fast i32 or exact i64 `qmul`), `epi` the
/// write-back epilogue on the saturated i32 pre-activation. `out`
/// covers exactly the range's rows (`panels.start * ROWS_PER_PANEL` up
/// to `n_out`-clipped range end).
///
/// Inner loop: four panel words consumed per iteration into four
/// independent accumulator lanes per row (reduced at panel end) — the
/// unrolled-MAC loop structure of PULP-NN / Table I, exposing ILP/SIMD
/// to the compiler. Integer adds commute, so lane splitting and the
/// end-of-panel reduction are bit-exact vs the one-accumulator loop.
///
/// When `sq` carries a SIMD dispatch arm (resolved by the caller via
/// [`simd::q_dispatch`], only ever on the narrow fast path), the
/// whole-word chunk loops are replaced by an explicit `std::arch` panel
/// kernel with identical per-product arithmetic; the ragged tail and
/// the saturate/bias/epilogue write-back below are shared by both
/// routes, so the SIMD path is bit-exact by construction.
#[inline(always)]
fn matvec_core<W, P, F>(
    layer: &PackedLayerRef,
    x: &[i32],
    panels: Range<usize>,
    out: &mut [i32],
    sq: SimdQ,
    prod: P,
    epi: F,
) where
    W: Width,
    P: Fn(i32, i32) -> i64,
    F: Fn(i32) -> i32,
{
    debug_assert_eq!(layer.width, W::WIDTH);
    debug_assert_eq!(x.len(), layer.n_in);
    debug_assert!(panels.end <= layer.panels());
    let r_base = panels.start * ROWS_PER_PANEL;
    debug_assert_eq!(
        out.len(),
        (panels.end * ROWS_PER_PANEL).min(layer.n_out) - r_base
    );
    let wpr = layer.words_per_row;
    let full = layer.n_in / W::ELEMS;
    let full4 = full & !3;
    let simd_on = sq.disp != QDispatch::Scalar && full > 0;
    for panel in panels {
        let o0 = panel * ROWS_PER_PANEL;
        let base = panel * wpr * ROWS_PER_PANEL;
        // acc[row][lane]: four independent unroll lanes per output row.
        let mut acc = [[0i64; 4]; ROWS_PER_PANEL];
        if simd_on {
            let mut sums = [0i64; ROWS_PER_PANEL];
            W::simd_panel(
                sq,
                &layer.words[base..base + wpr * ROWS_PER_PANEL],
                x,
                full,
                &mut sums,
            );
            for (a, s) in acc.iter_mut().zip(sums) {
                a[0] = s;
            }
        } else {
            let mut c = 0;
            while c < full4 {
                for (r, a) in acc.iter_mut().enumerate() {
                    for (u, au) in a.iter_mut().enumerate() {
                        let lanes = W::lanes(layer.words[base + (c + u) * ROWS_PER_PANEL + r]);
                        let i0 = (c + u) * W::ELEMS;
                        for e in 0..W::ELEMS {
                            *au += prod(lanes[e], x[i0 + e]);
                        }
                    }
                }
                c += 4;
            }
            for c in full4..full {
                let i0 = c * W::ELEMS;
                let wbase = base + c * ROWS_PER_PANEL;
                for (r, a) in acc.iter_mut().enumerate() {
                    let lanes = W::lanes(layer.words[wbase + r]);
                    for e in 0..W::ELEMS {
                        a[0] += prod(lanes[e], x[i0 + e]);
                    }
                }
            }
        }
        if full < wpr {
            // Ragged tail chunk: the padded weight lanes are 0 and are
            // simply not multiplied (identical sum either way).
            let i0 = full * W::ELEMS;
            let wbase = base + full * ROWS_PER_PANEL;
            for (r, a) in acc.iter_mut().enumerate() {
                let lanes = W::lanes(layer.words[wbase + r]);
                for (e, &xv) in x[i0..].iter().enumerate() {
                    a[0] += prod(lanes[e], xv);
                }
            }
        }
        let rows = (layer.n_out - o0).min(ROWS_PER_PANEL);
        for r in 0..rows {
            let sum = (acc[r][0] + acc[r][2]) + (acc[r][1] + acc[r][3]);
            out[o0 - r_base + r] = epi(sat_i32(sum + layer.biases[o0 + r] as i64) as i32);
        }
    }
}

/// Batched core: 4-sample tiles over the panel word-stream of the
/// `panels` range, so each weight word is loaded once per 4 samples × 4
/// rows = 16 MACs (the weight-reuse the paper's DMA double-buffering
/// banks on). `out` is the range's rows only, sample-major with row
/// stride equal to the range's row count — the full-range call is
/// therefore exactly the historical whole-layer layout.
///
/// `sq` as in [`matvec_core`]: a SIMD dispatch arm replaces the
/// whole-word chunk loop per (panel, sample) with the bit-exact
/// `std::arch` panel kernel; tail and write-back are shared.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn matmul_core<W, P, F>(
    layer: &PackedLayerRef,
    xs: &[i32],
    n_samples: usize,
    panels: Range<usize>,
    out: &mut [i32],
    sq: SimdQ,
    prod: P,
    epi: F,
) where
    W: Width,
    P: Fn(i32, i32) -> i64,
    F: Fn(i32) -> i32,
{
    debug_assert_eq!(layer.width, W::WIDTH);
    let n_in = layer.n_in;
    let n_out = layer.n_out;
    debug_assert_eq!(xs.len(), n_in * n_samples);
    debug_assert!(panels.end <= layer.panels());
    let r_base = panels.start * ROWS_PER_PANEL;
    let range_rows = (panels.end * ROWS_PER_PANEL).min(n_out) - r_base;
    debug_assert_eq!(out.len(), range_rows * n_samples);
    let wpr = layer.words_per_row;
    let full = n_in / W::ELEMS;
    let simd_on = sq.disp != QDispatch::Scalar && full > 0;
    let mut s0 = 0;
    while s0 < n_samples {
        let sb = (n_samples - s0).min(4);
        for panel in panels.clone() {
            let o0 = panel * ROWS_PER_PANEL;
            let base = panel * wpr * ROWS_PER_PANEL;
            let mut acc = [[0i64; ROWS_PER_PANEL]; 4];
            if simd_on {
                let pw = &layer.words[base..base + wpr * ROWS_PER_PANEL];
                for (si, a) in acc.iter_mut().enumerate().take(sb) {
                    let xb = (s0 + si) * n_in;
                    W::simd_panel(sq, pw, &xs[xb..xb + n_in], full, a);
                }
            } else {
                for c in 0..full {
                    let i0 = c * W::ELEMS;
                    let wbase = base + c * ROWS_PER_PANEL;
                    for r in 0..ROWS_PER_PANEL {
                        let lanes = W::lanes(layer.words[wbase + r]);
                        for (si, a) in acc.iter_mut().enumerate().take(sb) {
                            let xb = (s0 + si) * n_in + i0;
                            for e in 0..W::ELEMS {
                                a[r] += prod(lanes[e], xs[xb + e]);
                            }
                        }
                    }
                }
            }
            if full < wpr {
                let i0 = full * W::ELEMS;
                let tail = n_in - i0;
                let wbase = base + full * ROWS_PER_PANEL;
                for r in 0..ROWS_PER_PANEL {
                    let lanes = W::lanes(layer.words[wbase + r]);
                    for (si, a) in acc.iter_mut().enumerate().take(sb) {
                        let xb = (s0 + si) * n_in + i0;
                        for e in 0..tail {
                            a[r] += prod(lanes[e], xs[xb + e]);
                        }
                    }
                }
            }
            let rows = (n_out - o0).min(ROWS_PER_PANEL);
            for (si, a) in acc.iter().enumerate().take(sb) {
                for r in 0..rows {
                    out[(s0 + si) * range_rows + (o0 - r_base) + r] =
                        epi(sat_i32(a[r] + layer.biases[o0 + r] as i64) as i32);
                }
            }
        }
        s0 += sb;
    }
}

macro_rules! packed_kernel {
    ($kernel:ident, $w:ty, $name:literal, $doc:literal) => {
        #[doc = $doc]
        #[derive(Debug, Clone, Copy)]
        pub struct $kernel {
            /// Q(dec) decimal point — part of the kernel value, exactly
            /// as in [`super::FixedQ`].
            pub dec: u32,
        }

        impl $kernel {
            /// Kernel for Q(dec) arithmetic.
            pub fn new(dec: u32) -> Self {
                Self { dec }
            }

            /// Kernel display name (`packed_q7` / `packed_q15`).
            pub fn name(&self) -> &'static str {
                $name
            }

            /// Pre-activation single-sample pass (packed analogue of
            /// [`super::DenseKernel::matvec`]).
            pub fn matvec(&self, layer: &PackedLayerRef, x: &[i32], out: &mut [i32]) {
                self.matvec_impl(layer, x, 0..layer.panels(), out, |v| v);
            }

            /// Fused single-sample pass: step-linear activation applied
            /// at write-back.
            pub fn matvec_act(
                &self,
                layer: &PackedLayerRef,
                x: &[i32],
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matvec_impl(layer, x, 0..layer.panels(), out, |v| {
                    super::epilogue_q(act, dec, v)
                });
            }

            /// Fused single-sample pass over panels `panels` only —
            /// the row-split worker entry point. `out` covers exactly
            /// the range's rows. Bit-exact vs the whole-layer call
            /// (per-panel accumulation is independent).
            pub fn matvec_act_panels(
                &self,
                layer: &PackedLayerRef,
                x: &[i32],
                panels: std::ops::Range<usize>,
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matvec_impl(layer, x, panels, out, |v| super::epilogue_q(act, dec, v));
            }

            /// Pre-activation batched pass (packed analogue of
            /// [`super::DenseKernel::matmul`]).
            pub fn matmul(&self, layer: &PackedLayerRef, xs: &[i32], n_samples: usize, out: &mut [i32]) {
                self.matmul_impl(layer, xs, n_samples, 0..layer.panels(), out, |v| v);
            }

            /// Fused batched pass.
            pub fn matmul_act(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matmul_impl(layer, xs, n_samples, 0..layer.panels(), out, |v| {
                    super::epilogue_q(act, dec, v)
                });
            }

            /// Fused batched pass over panels `panels` only. `out`
            /// holds the range's rows sample-major (row stride = the
            /// range's row count).
            pub fn matmul_act_panels(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                panels: std::ops::Range<usize>,
                out: &mut [i32],
                act: Activation,
            ) {
                let dec = self.dec;
                self.matmul_impl(layer, xs, n_samples, panels, out, |v| {
                    super::epilogue_q(act, dec, v)
                });
            }

            /// [`matmul_act_panels`](Self::matmul_act_panels) with the
            /// fast-path verdict hoisted by the caller: `job` is the
            /// panel range plus the result of scanning every input of
            /// the layer against this width's bound (`|x| < FAST_LIMIT`
            /// for all of `xs`), so N row-split jobs share one input
            /// scan instead of each rescanning `n_in × n_samples`
            /// elements. A wrong `false` costs speed, never
            /// correctness; `true` must come from a full scan.
            pub fn matmul_act_panels_hinted(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                job: (std::ops::Range<usize>, bool),
                out: &mut [i32],
                act: Activation,
            ) {
                let (panels, fast) = job;
                let dec = self.dec;
                if fast {
                    // The hint's narrow verdict cannot carry the SSE2
                    // extra-narrow bound, so only the Wide SIMD tiers
                    // apply here (no input re-scan).
                    matmul_core::<$w, _, _>(
                        layer,
                        xs,
                        n_samples,
                        panels,
                        out,
                        simd::q_dispatch_hinted(<$w as Width>::WIDTH, dec),
                        |w, xv| ((w * xv) >> dec) as i64,
                        |v| super::epilogue_q(act, dec, v),
                    );
                } else {
                    matmul_core::<$w, _, _>(
                        layer,
                        xs,
                        n_samples,
                        panels,
                        out,
                        SimdQ::scalar(dec),
                        |w, xv| qmul(w, xv, dec),
                        |v| super::epilogue_q(act, dec, v),
                    );
                }
            }

            #[inline]
            fn matvec_impl<F: Fn(i32) -> i32>(
                &self,
                layer: &PackedLayerRef,
                x: &[i32],
                panels: std::ops::Range<usize>,
                out: &mut [i32],
                epi: F,
            ) {
                let dec = self.dec;
                if all_fast::<$w>(x) {
                    let sq = simd::q_dispatch(<$w as Width>::WIDTH, x, dec);
                    matvec_core::<$w, _, _>(layer, x, panels, out, sq, |w, xv| ((w * xv) >> dec) as i64, epi);
                } else {
                    matvec_core::<$w, _, _>(layer, x, panels, out, SimdQ::scalar(dec), |w, xv| qmul(w, xv, dec), epi);
                }
            }

            #[inline]
            fn matmul_impl<F: Fn(i32) -> i32>(
                &self,
                layer: &PackedLayerRef,
                xs: &[i32],
                n_samples: usize,
                panels: std::ops::Range<usize>,
                out: &mut [i32],
                epi: F,
            ) {
                let dec = self.dec;
                if all_fast::<$w>(xs) {
                    let sq = simd::q_dispatch(<$w as Width>::WIDTH, xs, dec);
                    matmul_core::<$w, _, _>(
                        layer,
                        xs,
                        n_samples,
                        panels,
                        out,
                        sq,
                        |w, xv| ((w * xv) >> dec) as i64,
                        epi,
                    );
                } else {
                    matmul_core::<$w, _, _>(
                        layer,
                        xs,
                        n_samples,
                        panels,
                        out,
                        SimdQ::scalar(dec),
                        |w, xv| qmul(w, xv, dec),
                        epi,
                    );
                }
            }
        }
    };
}

packed_kernel!(
    PackedQ7,
    W7,
    "packed_q7",
    "Q(dec) dense kernel over 4×i8-per-word packed panels (CMSIS-NN \
     `arm_fully_connected_q7` analogue). Bit-exact vs [`super::FixedQ`] \
     on the same parameters."
);

packed_kernel!(
    PackedQ15,
    W15,
    "packed_q15",
    "Q(dec) dense kernel over 2×i16-per-word packed panels (CMSIS-NN \
     `arm_fully_connected_q15` analogue). Bit-exact vs [`super::FixedQ`] \
     on the same parameters."
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::layout::pack_rows;
    use crate::kernels::{DenseKernel, DenseLayerRef, FixedQ};
    use crate::util::rng::Rng;

    fn random_layer(
        rng: &mut Rng,
        width: PackedWidth,
        n_in: usize,
        n_out: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let (lo, hi) = width.range();
        let span = (hi - lo + 1) as usize;
        let w: Vec<i32> = (0..n_in * n_out).map(|_| lo + rng.below(span) as i32).collect();
        let b: Vec<i32> = (0..n_out).map(|_| rng.below(4001) as i32 - 2000).collect();
        (w, b)
    }

    #[test]
    fn bit_exact_vs_fixedq_including_ragged_tails() {
        let mut rng = Rng::new(0xBEEF);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            for &n_in in &[1usize, 2, 3, 4, 5, 7, 9, 16] {
                for &n_out in &[1usize, 3, 4, 5, 8] {
                    let dec = 6;
                    let (w, b) = random_layer(&mut rng, width, n_in, n_out);
                    let x: Vec<i32> =
                        (0..n_in).map(|_| rng.below(2001) as i32 - 1000).collect();
                    let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                    let mut want = vec![0i32; n_out];
                    FixedQ::new(dec).matvec(&layer, &x, &mut want);
                    let panels = pack_rows(width, n_in, n_out, &w).unwrap();
                    let pref = PackedLayerRef::new(&panels, &b);
                    let mut got = vec![0i32; n_out];
                    match width {
                        PackedWidth::Q7 => PackedQ7::new(dec).matvec(&pref, &x, &mut got),
                        PackedWidth::Q15 => PackedQ15::new(dec).matvec(&pref, &x, &mut got),
                    }
                    assert_eq!(got, want, "{width:?} n_in={n_in} n_out={n_out}");
                }
            }
        }
    }

    #[test]
    fn slow_path_large_inputs_bit_exact() {
        // Inputs beyond the fast-path bound force the exact i64 route;
        // results must still match FixedQ bit for bit.
        let mut rng = Rng::new(0x51077);
        let dec = 4;
        let (n_in, n_out) = (9, 5);
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let x: Vec<i32> = (0..n_in)
            .map(|i| if i % 2 == 0 { i32::MAX - i as i32 } else { i32::MIN + i as i32 })
            .collect();
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let mut want = vec![0i32; n_out];
        FixedQ::new(dec).matvec(&layer, &x, &mut want);
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let mut got = vec![0i32; n_out];
        PackedQ7::new(dec).matvec(&pref, &x, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn matmul_bit_exact_vs_matvec_per_sample() {
        let mut rng = Rng::new(0xABCD);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let dec = 7;
            let (n_in, n_out, n_samples) = (11, 6, 7);
            let (w, b) = random_layer(&mut rng, width, n_in, n_out);
            let xs: Vec<i32> =
                (0..n_in * n_samples).map(|_| rng.below(512) as i32 - 256).collect();
            let panels = pack_rows(width, n_in, n_out, &w).unwrap();
            let pref = PackedLayerRef::new(&panels, &b);
            let mut batched = vec![0i32; n_out * n_samples];
            let mut single = vec![0i32; n_out];
            match width {
                PackedWidth::Q7 => {
                    let k = PackedQ7::new(dec);
                    k.matmul(&pref, &xs, n_samples, &mut batched);
                    for s in 0..n_samples {
                        k.matvec(&pref, &xs[s * n_in..(s + 1) * n_in], &mut single);
                        assert_eq!(&batched[s * n_out..(s + 1) * n_out], &single[..]);
                    }
                }
                PackedWidth::Q15 => {
                    let k = PackedQ15::new(dec);
                    k.matmul(&pref, &xs, n_samples, &mut batched);
                    for s in 0..n_samples {
                        k.matvec(&pref, &xs[s * n_in..(s + 1) * n_in], &mut single);
                        assert_eq!(&batched[s * n_out..(s + 1) * n_out], &single[..]);
                    }
                }
            }
        }
    }

    #[test]
    fn unrolled_word_stream_bit_exact_on_long_rows() {
        // n_in large enough to exercise the 4-word unrolled inner loop
        // (full4 > 0) plus a remainder chunk and a ragged tail.
        let mut rng = Rng::new(0x10C4);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            for &n_in in &[17usize, 32, 40, 65, 130] {
                let n_out = 6;
                let dec = 5;
                let (w, b) = random_layer(&mut rng, width, n_in, n_out);
                let x: Vec<i32> = (0..n_in).map(|_| rng.below(4001) as i32 - 2000).collect();
                let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                let mut want = vec![0i32; n_out];
                FixedQ::new(dec).matvec(&layer, &x, &mut want);
                let panels = pack_rows(width, n_in, n_out, &w).unwrap();
                let pref = PackedLayerRef::new(&panels, &b);
                let mut got = vec![0i32; n_out];
                match width {
                    PackedWidth::Q7 => PackedQ7::new(dec).matvec(&pref, &x, &mut got),
                    PackedWidth::Q15 => PackedQ15::new(dec).matvec(&pref, &x, &mut got),
                }
                assert_eq!(got, want, "{width:?} n_in={n_in}");
            }
        }
    }

    #[test]
    fn panel_ranges_reassemble_the_whole_layer() {
        // Computing each panel block separately (the row-split worker
        // granularity) reproduces the whole-layer call bit for bit,
        // single-sample and batched.
        let mut rng = Rng::new(0x50_1177);
        let dec = 6;
        let (n_in, n_out, n_samples) = (13, 11, 5); // 3 panels, last ragged
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples).map(|_| rng.below(801) as i32 - 400).collect();
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let k = PackedQ7::new(dec);
        let act = crate::fann::activation::Activation::Tanh;
        let mut whole = vec![0i32; n_out * n_samples];
        k.matmul_act(&pref, &xs, n_samples, &mut whole, act);
        for (p0, p1) in [(0usize, 1usize), (1, 3), (0, 3), (2, 3)] {
            let r0 = p0 * ROWS_PER_PANEL;
            let r1 = (p1 * ROWS_PER_PANEL).min(n_out);
            let rr = r1 - r0;
            let mut part = vec![0i32; rr * n_samples];
            k.matmul_act_panels(&pref, &xs, n_samples, p0..p1, &mut part, act);
            for s in 0..n_samples {
                assert_eq!(
                    &part[s * rr..(s + 1) * rr],
                    &whole[s * n_out + r0..s * n_out + r1],
                    "panels {p0}..{p1} sample {s}"
                );
            }
            // Single-sample range form agrees too.
            let mut single = vec![0i32; rr];
            k.matvec_act_panels(&pref, &xs[..n_in], p0..p1, &mut single, act);
            assert_eq!(&single[..], &whole[r0..r1]);
        }
    }

    #[test]
    fn hinted_panels_match_unhinted_for_both_verdicts() {
        // The hoisted fast-path verdict only selects between two
        // bit-identical kernels: `true` (inputs really do clear the
        // bound) and a conservative `false` must both reproduce the
        // scanning entry point exactly.
        let mut rng = Rng::new(0x41D7);
        let dec = 6;
        let (n_in, n_out, n_samples) = (10, 9, 5);
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples).map(|_| rng.below(2001) as i32 - 1000).collect();
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let k = PackedQ7::new(dec);
        let act = crate::fann::activation::Activation::Sigmoid;
        let all = pref.panels();
        let mut want = vec![0i32; n_out * n_samples];
        k.matmul_act_panels(&pref, &xs, n_samples, 0..all, &mut want, act);
        for fast in [true, false] {
            let mut got = vec![0i32; n_out * n_samples];
            k.matmul_act_panels_hinted(&pref, &xs, n_samples, (0..all, fast), &mut got, act);
            assert_eq!(got, want, "fast={fast}");
        }
    }

    #[test]
    fn fused_equals_unfused() {
        let mut rng = Rng::new(0xFACE);
        let dec = 6;
        let (n_in, n_out, n_samples) = (10, 7, 5);
        let (w, b) = random_layer(&mut rng, PackedWidth::Q7, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples).map(|_| rng.below(257) as i32 - 128).collect();
        let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let k = PackedQ7::new(dec);
        for act in crate::fann::activation::ALL {
            let mut fused = vec![0i32; n_out * n_samples];
            k.matmul_act(&pref, &xs, n_samples, &mut fused, act);
            let mut unfused = vec![0i32; n_out * n_samples];
            k.matmul(&pref, &xs, n_samples, &mut unfused);
            for v in unfused.iter_mut() {
                *v = crate::quantize::activation_q(act, *v as i64, dec) as i32;
            }
            assert_eq!(fused, unfused, "{act:?}");
        }
    }
}
