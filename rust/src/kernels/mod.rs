//! The kernel-dispatch layer: every dense forward path in the crate —
//! [`crate::fann::Network`] (float), [`crate::fann::FixedNetwork`]
//! (Q-format) and the deployment simulator's
//! [`crate::simulator::Executable`] — funnels its inner loop through one
//! [`DenseKernel`] implementation instead of carrying a private copy.
//!
//! This is the software analogue of the paper's central optimization
//! story: the *math* of a fully-connected layer is fixed (Eq. 1), but
//! the *loop structure* is what throughput is won from (Table I —
//! reorganized matvec inner loops; CMSIS-NN makes the same point). The
//! implementations here are the host-side counterparts of those MCU
//! variants:
//!
//! * [`ScalarF32`] — textbook one-accumulator loop; the float reference.
//! * [`BlockedF32`] — 4-lane ILP accumulators (the paper's unrolled
//!   MAC loop), extended to 4×4 sample×neuron register tiles for the
//!   batched entry point. Per-sample results are **bit-identical** to
//!   its own `matvec`, so batching never changes numerics.
//! * [`FixedQ`] — i32/i64 Q-format with FANN `fann_mult` semantics,
//!   bit-exact with [`crate::quantize`] (and therefore with the Pallas
//!   fixed-point kernel pinned by the parity tests).
//!
//! Kernels compute the *pre-activation* affine part (`W·x + b`, Q-format
//! saturated); activations stay with the caller, which is what lets the
//! float and fixed networks share one dispatch layer.

pub mod blocked;
pub mod fixedq;
pub mod scalar;

pub use blocked::{dot_f32, BlockedF32};
pub use fixedq::FixedQ;
pub use scalar::ScalarF32;

/// Borrowed view of one dense layer's parameters, element type `E`
/// (`f32` for the float path, `i32` for Q-format). Weights are row-major
/// per output neuron (`weights[o * n_in + i]`), the MCU streaming order.
#[derive(Debug, Clone, Copy)]
pub struct DenseLayerRef<'a, E> {
    pub n_in: usize,
    pub n_out: usize,
    pub weights: &'a [E],
    pub biases: &'a [E],
}

impl<'a, E> DenseLayerRef<'a, E> {
    pub fn new(n_in: usize, n_out: usize, weights: &'a [E], biases: &'a [E]) -> Self {
        debug_assert_eq!(weights.len(), n_in * n_out);
        debug_assert_eq!(biases.len(), n_out);
        Self {
            n_in,
            n_out,
            weights,
            biases,
        }
    }
}

/// A dense (fully-connected) compute kernel over element type `E`.
///
/// `matvec` is the single-sample hot loop; `matmul` is the batched entry
/// point (`n_samples` inputs packed row-major). The default `matmul`
/// just loops `matvec`, so per-sample/batched parity holds by
/// construction for kernels that don't specialize it; kernels that do
/// specialize (e.g. [`BlockedF32`]) must preserve per-sample results
/// bit-for-bit — `rust/tests/batch_consistency.rs` enforces this.
pub trait DenseKernel<E>: Send + Sync {
    /// Kernel name for reports and bench tables.
    fn name(&self) -> &'static str;

    /// `out[o] = b[o] + Σ_i w[o][i]·x[i]` (pre-activation). `x` has
    /// `n_in` elements, `out` has `n_out`.
    fn matvec(&self, layer: &DenseLayerRef<E>, x: &[E], out: &mut [E]);

    /// Batched forward: `xs` packs `n_samples` rows of `n_in` elements;
    /// `out` receives `n_samples` rows of `n_out` elements.
    fn matmul(&self, layer: &DenseLayerRef<E>, xs: &[E], n_samples: usize, out: &mut [E]) {
        debug_assert_eq!(xs.len(), layer.n_in * n_samples);
        debug_assert_eq!(out.len(), layer.n_out * n_samples);
        for s in 0..n_samples {
            self.matvec(
                layer,
                &xs[s * layer.n_in..(s + 1) * layer.n_in],
                &mut out[s * layer.n_out..(s + 1) * layer.n_out],
            );
        }
    }
}

/// The crate-wide default float kernel: what `Network::run` dispatches
/// to. [`BlockedF32`] reproduces the seed implementation's 4-lane
/// reduction order, so default-path numerics are unchanged.
pub fn default_f32() -> &'static dyn DenseKernel<f32> {
    &BlockedF32
}

/// All float kernels, for parity tests and bench sweeps.
pub fn f32_kernels() -> [&'static dyn DenseKernel<f32>; 2] {
    [&ScalarF32, &BlockedF32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_kernel_is_blocked() {
        assert_eq!(default_f32().name(), "blocked_f32");
    }

    #[test]
    fn default_matmul_loops_matvec() {
        // ScalarF32 has no custom matmul: the trait default must equal
        // per-sample matvec exactly.
        let w = [0.5f32, -1.0, 2.0, 0.25, 1.5, -0.5];
        let b = [0.1f32, -0.2];
        let layer = DenseLayerRef::new(3, 2, &w, &b);
        let xs = [1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        let mut batched = [0.0f32; 4];
        ScalarF32.matmul(&layer, &xs, 2, &mut batched);
        for s in 0..2 {
            let mut single = [0.0f32; 2];
            ScalarF32.matvec(&layer, &xs[s * 3..(s + 1) * 3], &mut single);
            assert_eq!(&batched[s * 2..(s + 1) * 2], &single[..]);
        }
    }
}
