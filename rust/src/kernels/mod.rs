//! The kernel-dispatch layer: every dense forward path in the crate —
//! [`crate::fann::Network`] (float), [`crate::fann::FixedNetwork`]
//! (Q-format) and the deployment simulator's
//! [`crate::simulator::Executable`] — funnels its inner loop through one
//! [`DenseKernel`] implementation instead of carrying a private copy.
//!
//! This is the software analogue of the paper's central optimization
//! story: the *math* of a fully-connected layer is fixed (Eq. 1), but
//! the *loop structure* is what throughput is won from (Table I —
//! reorganized matvec inner loops; CMSIS-NN makes the same point). The
//! implementations here are the host-side counterparts of those MCU
//! variants:
//!
//! * [`ScalarF32`] — textbook one-accumulator loop; the float reference.
//! * [`BlockedF32`] — 4-lane ILP accumulators (the paper's unrolled
//!   MAC loop), extended to 4×4 sample×neuron register tiles for the
//!   batched entry point. Per-sample results are **bit-identical** to
//!   its own `matvec`, so batching never changes numerics.
//! * [`SimdF32`] — explicit `std::arch` FMA tiles (AVX2 on x86_64,
//!   NEON on aarch64) over a fixed 16-lane structure with a
//!   bit-identical portable mirror; selected at runtime by
//!   [`simd::detected_level`]. Additive: [`BlockedF32`] stays the
//!   crate default. The packed q7/q15 kernels gain their SIMD panel
//!   loops internally via the same dispatcher (see [`simd`]).
//! * [`FixedQ`] — i32/i64 Q-format with FANN `fann_mult` semantics,
//!   bit-exact with [`crate::quantize`] (and therefore with the Pallas
//!   fixed-point kernel pinned by the parity tests).
//! * [`PackedQ7`] / [`PackedQ15`] — the low-bitwidth kernels: weights
//!   stored 4×i8 (resp. 2×i16) per `u32` word in the row-panel layout
//!   of [`layout::PackedPanels`], with the *same* per-product `qmul`
//!   arithmetic as [`FixedQ`], so results are bit-exact whenever the
//!   weights are representable at the narrow width (see below).
//!
//! # Packed layout
//!
//! [`layout::PackedPanels`] stores a row-major `[n_out][n_in]` weight
//! matrix as panels of `R = 4` consecutive output rows. Within a panel
//! the inner dimension is split into words (4 bytes = 4 i8 weights for
//! Q7, 2 half-words = 2 i16 weights for Q15) and the words of the four
//! rows are interleaved column-chunk-major, so the inner loop is a
//! single forward `u32` word-stream — the software mirror of the
//! paper's neuron-wise DMA streaming order. Byte order within a Q7 word
//! (little-endian, `w[i]` = weight for input `i` of that chunk):
//!
//! ```text
//!   word for (row r, inputs 4c..4c+4):
//!   bits  31..24   23..16   15..8    7..0
//!         w[4c+3]  w[4c+2]  w[4c+1]  w[4c+0]
//!
//!   words[] stream for one panel (R = 4 rows, W = words per row):
//!   (r0,c0)(r1,c0)(r2,c0)(r3,c0) (r0,c1)(r1,c1)(r2,c1)(r3,c1) ...
//! ```
//!
//! Ragged edges are zero-padded: a trailing input chunk pads unused
//! byte lanes with weight 0 (`qmul(0, x) == 0`, exact), and a trailing
//! row panel pads to `R` rows of zero words whose outputs are never
//! written back.
//!
//! # Fused activation epilogues
//!
//! Kernels compute the *pre-activation* affine part (`W·x + b`,
//! Q-format saturated); [`DenseKernel::matvec_act`] /
//! [`DenseKernel::matmul_act`] additionally apply the layer activation
//! (with steepness) as an *epilogue*. The default implementation is
//! `matmul` + a second pass over `out` (what the seed's callers did by
//! hand); kernels that specialize it ([`BlockedF32`], [`FixedQ`], the
//! packed pair) apply the activation at tile write-back while the
//! accumulator is still in registers, saving one full read-modify-write
//! sweep of the output per layer. Fused and unfused are numerically
//! identical by construction (same value, same function, applied once).

pub mod autotune;
pub mod blocked;
pub mod exec_plan;
pub mod fixedq;
pub mod layout;
pub mod packed;
pub mod scalar;
pub mod simd;

use std::cell::RefCell;

pub use blocked::{dot_f32, BlockedF32};
pub use exec_plan::{
    rows_per_core_block_max, rows_per_core_max, split_row_blocks, split_rows, ExecPlan,
    PlanScratch, PlanSource,
};
pub use fixedq::FixedQ;
pub use layout::{PackedPanels, PackedWidth};
pub use packed::{PackedLayerRef, PackedQ15, PackedQ7};
pub use scalar::ScalarF32;
pub use simd::{
    cpu_features, detected_level, dot_simd, selected_level, with_forced_level, CpuFeatures,
    SimdF32, SimdLevel,
};

use crate::fann::activation::Activation;
use crate::quantize;

/// THE float activation epilogue: every float kernel (fused or
/// unfused) routes each pre-activation value through this one
/// function, so the fused-equals-unfused contract can never drift.
#[inline(always)]
pub fn epilogue_f32(act: Activation, steepness: f32, v: f32) -> f32 {
    act.apply(steepness * v)
}

/// THE Q-format activation epilogue (step-linear integer activation at
/// `dec`); single copy shared by [`FixedQ`] and the packed kernels.
/// Steepness does not appear: fixed-point conversion folds it into the
/// weights.
#[inline(always)]
pub fn epilogue_q(act: Activation, dec: u32, v: i32) -> i32 {
    quantize::activation_q(act, v as i64, dec) as i32
}

/// Borrowed view of one dense layer's parameters, element type `E`
/// (`f32` for the float path, `i32` for Q-format). Weights are row-major
/// per output neuron (`weights[o * n_in + i]`), the MCU streaming order.
#[derive(Debug, Clone, Copy)]
pub struct DenseLayerRef<'a, E> {
    /// Input width of the layer.
    pub n_in: usize,
    /// Output rows of the layer.
    pub n_out: usize,
    /// Row-major `[n_out][n_in]` weights.
    pub weights: &'a [E],
    /// One bias per output row.
    pub biases: &'a [E],
}

impl<'a, E> DenseLayerRef<'a, E> {
    /// Borrowed view over one layer's parameters (length-checked).
    pub fn new(n_in: usize, n_out: usize, weights: &'a [E], biases: &'a [E]) -> Self {
        debug_assert_eq!(weights.len(), n_in * n_out);
        debug_assert_eq!(biases.len(), n_out);
        Self {
            n_in,
            n_out,
            weights,
            biases,
        }
    }
}

/// A dense (fully-connected) compute kernel over element type `E`.
///
/// `matvec` is the single-sample hot loop; `matmul` is the batched entry
/// point (`n_samples` inputs packed row-major). The default `matmul`
/// just loops `matvec`, so per-sample/batched parity holds by
/// construction for kernels that don't specialize it; kernels that do
/// specialize (e.g. [`BlockedF32`]) must preserve per-sample results
/// bit-for-bit — `rust/tests/batch_consistency.rs` enforces this.
///
/// The `_act` variants fuse the activation epilogue (see the module
/// docs); `apply_epilogue` is the one place a kernel defines what that
/// epilogue *means* for its element type (float kernels evaluate
/// `act(steepness · v)`, Q-format kernels evaluate the step-linear
/// integer activation at their decimal point and ignore `steepness`,
/// which quantization already folded into the weights).
pub trait DenseKernel<E>: Send + Sync {
    /// Kernel name for reports and bench tables.
    fn name(&self) -> &'static str;

    /// `out[o] = b[o] + Σ_i w[o][i]·x[i]` (pre-activation). `x` has
    /// `n_in` elements, `out` has `n_out`.
    fn matvec(&self, layer: &DenseLayerRef<E>, x: &[E], out: &mut [E]);

    /// Batched forward: `xs` packs `n_samples` rows of `n_in` elements;
    /// `out` receives `n_samples` rows of `n_out` elements.
    fn matmul(&self, layer: &DenseLayerRef<E>, xs: &[E], n_samples: usize, out: &mut [E]) {
        debug_assert_eq!(xs.len(), layer.n_in * n_samples);
        debug_assert_eq!(out.len(), layer.n_out * n_samples);
        for s in 0..n_samples {
            self.matvec(
                layer,
                &xs[s * layer.n_in..(s + 1) * layer.n_in],
                &mut out[s * layer.n_out..(s + 1) * layer.n_out],
            );
        }
    }

    /// Apply this kernel's activation epilogue in place over
    /// pre-activation values (the unfused second pass).
    fn apply_epilogue(&self, act: Activation, steepness: f32, out: &mut [E]);

    /// `matvec` with the activation fused into the same pass. Default:
    /// affine part, then the epilogue as a separate sweep.
    fn matvec_act(
        &self,
        layer: &DenseLayerRef<E>,
        x: &[E],
        out: &mut [E],
        act: Activation,
        steepness: f32,
    ) {
        self.matvec(layer, x, out);
        self.apply_epilogue(act, steepness, out);
    }

    /// `matmul` with the activation fused into the same pass. Default:
    /// affine part, then the epilogue as a separate sweep.
    fn matmul_act(
        &self,
        layer: &DenseLayerRef<E>,
        xs: &[E],
        n_samples: usize,
        out: &mut [E],
        act: Activation,
        steepness: f32,
    ) {
        self.matmul(layer, xs, n_samples, out);
        self.apply_epilogue(act, steepness, out);
    }
}

/// Reusable ping-pong arena for batched layer-to-layer activations:
/// grown once to `max_layer_width × n_samples` per buffer, then sliced
/// per layer on every call — the zero-allocation replacement for the
/// per-call `vec![0; width * n_samples]` pair the seed's batch path
/// paid. Never shrinks, so repeated same-shape (or smaller) batches
/// perform no allocation at all.
#[derive(Debug, Default)]
pub struct BatchScratch<E> {
    a: Vec<E>,
    b: Vec<E>,
}

impl<E: Copy + Default> BatchScratch<E> {
    /// Empty arena; buffers grow on first use.
    pub fn new() -> Self {
        Self {
            a: Vec::new(),
            b: Vec::new(),
        }
    }

    /// Borrow both ping-pong buffers at `len` elements each, growing
    /// (never shrinking) the backing storage first.
    pub fn buffers(&mut self, len: usize) -> (&mut [E], &mut [E]) {
        if self.a.len() < len {
            self.a.resize(len, E::default());
        }
        if self.b.len() < len {
            self.b.resize(len, E::default());
        }
        (&mut self.a[..len], &mut self.b[..len])
    }

    /// Current capacity of each backing buffer — the regression hook for
    /// the zero-reallocation test (stable across repeated same-shape
    /// calls).
    pub fn capacity(&self) -> (usize, usize) {
        (self.a.capacity(), self.b.capacity())
    }

    /// Base pointers of the backing buffers (stable across repeated
    /// same-shape calls; moves only when the arena has to grow).
    pub fn base_ptrs(&self) -> (*const E, *const E) {
        (self.a.as_ptr(), self.b.as_ptr())
    }
}

/// The (src, dst) buffer routing of the allocation-free batch drivers,
/// shared by `Network::run_batch_into`, `FixedNetwork::run_batch_q_into`
/// and `PackedNetwork::run_batch_q_into` so the subtlest part of the
/// ping-pong path lives exactly once: layer 0 reads `inputs`; layer
/// `li > 0` reads what layer `li-1` wrote (`a` for odd `li`, `b` for
/// even, since layer 0 writes `a`); the last layer writes straight into
/// `out`. All three `&mut` buffers are borrowed for the returned pair's
/// lifetime; callers reborrow per layer.
#[inline]
pub(crate) fn batch_route<'s, E>(
    li: usize,
    last: bool,
    inputs: &'s [E],
    a: &'s mut [E],
    b: &'s mut [E],
    out: &'s mut [E],
) -> (&'s [E], &'s mut [E]) {
    match (li == 0, last, li % 2 == 1) {
        (true, true, _) => (inputs, out),
        (true, false, _) => (inputs, a),
        (false, true, odd) => (if odd { &*a } else { &*b }, out),
        (false, false, true) => (&*a, b),
        (false, false, false) => (&*b, a),
    }
}

thread_local! {
    static TLS_F32: RefCell<BatchScratch<f32>> = RefCell::new(BatchScratch::new());
    static TLS_I32: RefCell<BatchScratch<i32>> = RefCell::new(BatchScratch::new());
}

/// Run `f` with this thread's persistent float batch scratch. The
/// arena lives for the thread's lifetime, so steady-state batch calls
/// through the convenience (`Vec`-returning) APIs allocate only their
/// output vector. Not reentrant (the closure must not itself call a
/// `with_thread_scratch_*` helper of the same type).
pub fn with_thread_scratch_f32<R>(f: impl FnOnce(&mut BatchScratch<f32>) -> R) -> R {
    TLS_F32.with(|s| f(&mut s.borrow_mut()))
}

/// Q-format counterpart of [`with_thread_scratch_f32`].
pub fn with_thread_scratch_i32<R>(f: impl FnOnce(&mut BatchScratch<i32>) -> R) -> R {
    TLS_I32.with(|s| f(&mut s.borrow_mut()))
}

/// The crate-wide default float kernel: what `Network::run` dispatches
/// to. [`BlockedF32`] reproduces the seed implementation's 4-lane
/// reduction order, so default-path numerics are unchanged.
pub fn default_f32() -> &'static dyn DenseKernel<f32> {
    &BlockedF32
}

/// All float kernels, for parity tests and bench sweeps. [`SimdF32`]
/// is always present: on hosts without a SIMD level it runs its
/// bit-identical portable mirror.
pub fn f32_kernels() -> [&'static dyn DenseKernel<f32>; 3] {
    [&ScalarF32, &BlockedF32, &SimdF32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_kernel_is_blocked() {
        assert_eq!(default_f32().name(), "blocked_f32");
    }

    #[test]
    fn default_matmul_loops_matvec() {
        // ScalarF32 has no custom matmul: the trait default must equal
        // per-sample matvec exactly.
        let w = [0.5f32, -1.0, 2.0, 0.25, 1.5, -0.5];
        let b = [0.1f32, -0.2];
        let layer = DenseLayerRef::new(3, 2, &w, &b);
        let xs = [1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        let mut batched = [0.0f32; 4];
        ScalarF32.matmul(&layer, &xs, 2, &mut batched);
        for s in 0..2 {
            let mut single = [0.0f32; 2];
            ScalarF32.matvec(&layer, &xs[s * 3..(s + 1) * 3], &mut single);
            assert_eq!(&batched[s * 2..(s + 1) * 2], &single[..]);
        }
    }

    #[test]
    fn default_matmul_act_is_matmul_plus_epilogue() {
        let w = [0.5f32, -1.0, 2.0, 0.25, 1.5, -0.5];
        let b = [0.1f32, -0.2];
        let layer = DenseLayerRef::new(3, 2, &w, &b);
        let xs = [1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        let mut fused = [0.0f32; 4];
        ScalarF32.matmul_act(&layer, &xs, 2, &mut fused, Activation::Tanh, 0.5);
        let mut unfused = [0.0f32; 4];
        ScalarF32.matmul(&layer, &xs, 2, &mut unfused);
        for v in unfused.iter_mut() {
            *v = Activation::Tanh.apply(0.5 * *v);
        }
        assert_eq!(fused, unfused);
    }

    #[test]
    fn scratch_grows_once_and_stays_put() {
        let mut s: BatchScratch<f32> = BatchScratch::new();
        {
            let (a, b) = s.buffers(64);
            assert_eq!(a.len(), 64);
            assert_eq!(b.len(), 64);
            a[0] = 1.0;
            b[63] = 2.0;
        }
        let cap = s.capacity();
        let ptrs = s.base_ptrs();
        for _ in 0..10 {
            let _ = s.buffers(64);
            let _ = s.buffers(16); // smaller: must not shrink
        }
        assert_eq!(s.capacity(), cap);
        assert_eq!(s.base_ptrs(), ptrs);
    }

    #[test]
    fn thread_scratch_is_persistent() {
        let p0 = with_thread_scratch_f32(|s| {
            let _ = s.buffers(128);
            s.base_ptrs().0 as usize
        });
        let p1 = with_thread_scratch_f32(|s| {
            let _ = s.buffers(128);
            s.base_ptrs().0 as usize
        });
        assert_eq!(p0, p1);
    }
}
