//! The Q-format fixed-point kernel — the FPU-less inference inner loop
//! (Table I right column: `mul / sra / add`), with FANN `fann_mult`
//! semantics shared with [`crate::quantize`]: widen to i64, arithmetic
//! shift right by `dec` per product, accumulate in i64, saturate to the
//! i32 range on write-back.
//!
//! Integer accumulation is order-independent (two's-complement adds
//! commute), so the batched entry point's 4-sample blocking is bit-exact
//! against per-sample `matvec` *and* against the scalar Q-format oracle
//! in `rust/tests/parity_kernels.rs` — which in turn is pinned to the
//! Pallas fixed-point kernel by the TSV parity vectors.

use super::{DenseKernel, DenseLayerRef};
use crate::fann::activation::Activation;
use crate::quantize::{qmul, sat_i32};

/// Q(dec) dense kernel. The decimal point is part of the kernel value,
/// because the shift amount is what defines the arithmetic.
#[derive(Debug, Clone, Copy)]
pub struct FixedQ {
    /// Q-format decimal point of every operand.
    pub dec: u32,
}

impl FixedQ {
    /// Kernel for Q(dec) arithmetic.
    pub fn new(dec: u32) -> Self {
        Self { dec }
    }
}

impl DenseKernel<i32> for FixedQ {
    fn name(&self) -> &'static str {
        "fixed_q"
    }

    /// Step-linear integer activation at this kernel's decimal point.
    /// `steepness` is ignored: fixed-point conversion folds it into the
    /// weights (`FixedNetwork::from_float_with_dec`), so the Q-format
    /// epilogue always runs at steepness 1.
    fn apply_epilogue(&self, act: Activation, _steepness: f32, out: &mut [i32]) {
        for v in out.iter_mut() {
            *v = super::epilogue_q(act, self.dec, *v);
        }
    }

    fn matvec(&self, layer: &DenseLayerRef<i32>, x: &[i32], out: &mut [i32]) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            let mut acc: i64 = layer.biases[o] as i64;
            for (&w, &xv) in row.iter().zip(x) {
                acc += qmul(w, xv, self.dec);
            }
            out[o] = sat_i32(acc) as i32;
        }
    }

    /// 4-sample blocked batch: each weight is loaded once and multiplied
    /// against 4 samples' inputs — the same weight-reuse the paper's DMA
    /// double-buffering banks on. Bit-exact vs `matvec` (integer adds
    /// commute; saturation happens once per output, after the sum).
    fn matmul(&self, layer: &DenseLayerRef<i32>, xs: &[i32], n_samples: usize, out: &mut [i32]) {
        self.matmul_impl(layer, xs, n_samples, out, |v| v);
    }

    /// Fused batch pass: the step-linear activation runs on the
    /// saturated accumulator at write-back. Bit-exact vs `matmul` + the
    /// epilogue sweep (same value through the same function).
    fn matmul_act(
        &self,
        layer: &DenseLayerRef<i32>,
        xs: &[i32],
        n_samples: usize,
        out: &mut [i32],
        act: Activation,
        _steepness: f32,
    ) {
        let dec = self.dec;
        self.matmul_impl(layer, xs, n_samples, out, |v| super::epilogue_q(act, dec, v));
    }
}

impl FixedQ {
    /// Shared 4-sample blocked loop; `epilogue` is applied to each
    /// saturated i32 pre-activation at write-back.
    #[inline]
    fn matmul_impl<F: Fn(i32) -> i32>(
        &self,
        layer: &DenseLayerRef<i32>,
        xs: &[i32],
        n_samples: usize,
        out: &mut [i32],
        epilogue: F,
    ) {
        let n_in = layer.n_in;
        let n_out = layer.n_out;
        debug_assert_eq!(xs.len(), n_in * n_samples);
        debug_assert_eq!(out.len(), n_out * n_samples);
        let mut s0 = 0;
        while s0 < n_samples {
            let sb = (n_samples - s0).min(4);
            for o in 0..n_out {
                let row = &layer.weights[o * n_in..(o + 1) * n_in];
                let mut acc = [layer.biases[o] as i64; 4];
                for (i, &w) in row.iter().enumerate() {
                    for si in 0..sb {
                        acc[si] += qmul(w, xs[(s0 + si) * n_in + i], self.dec);
                    }
                }
                for si in 0..sb {
                    out[(s0 + si) * n_out + o] = epilogue(sat_i32(acc[si]) as i32);
                }
            }
            s0 += sb;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantize::{dequantize, quantize};

    #[test]
    fn matches_float_affine_within_lsb_noise() {
        let dec = 12;
        let k = FixedQ::new(dec);
        let wf = [0.5f32, -0.25, 1.0, 0.125, -0.5, 0.75];
        let bf = [0.1f32, -0.1];
        let xf = [0.3f32, -0.6, 0.9];
        let w: Vec<i32> = wf.iter().map(|&v| quantize(v, dec)).collect();
        let b: Vec<i32> = bf.iter().map(|&v| quantize(v, dec)).collect();
        let x: Vec<i32> = xf.iter().map(|&v| quantize(v, dec)).collect();
        let layer = DenseLayerRef::new(3, 2, &w, &b);
        let mut out = [0i32; 2];
        k.matvec(&layer, &x, &mut out);
        for o in 0..2 {
            let want: f32 =
                bf[o] + (0..3).map(|i| wf[o * 3 + i] * xf[i]).sum::<f32>();
            let got = dequantize(out[o] as i64, dec);
            assert!((want - got).abs() < 4.0 / (1 << dec) as f32, "{want} vs {got}");
        }
    }

    #[test]
    fn saturates_on_overflow() {
        let dec = 4;
        let k = FixedQ::new(dec);
        let w = vec![i32::MAX / 2; 8];
        let b = vec![0i32];
        let x = vec![i32::MAX / 2; 8];
        let layer = DenseLayerRef::new(8, 1, &w, &b);
        let mut out = [0i32];
        k.matvec(&layer, &x, &mut out);
        assert_eq!(out[0], i32::MAX);
    }

    #[test]
    fn batched_bit_exact_vs_single() {
        use crate::util::rng::Rng;
        let dec = 10;
        let k = FixedQ::new(dec);
        let mut rng = Rng::new(0xF1);
        let (n_in, n_out, n_samples) = (7, 5, 6);
        let w: Vec<i32> = (0..n_in * n_out)
            .map(|_| quantize(rng.range_f32(-1.0, 1.0), dec))
            .collect();
        let b: Vec<i32> = (0..n_out)
            .map(|_| quantize(rng.range_f32(-1.0, 1.0), dec))
            .collect();
        let xs: Vec<i32> = (0..n_in * n_samples)
            .map(|_| quantize(rng.range_f32(-1.0, 1.0), dec))
            .collect();
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let mut batched = vec![0i32; n_out * n_samples];
        k.matmul(&layer, &xs, n_samples, &mut batched);
        for s in 0..n_samples {
            let mut single = vec![0i32; n_out];
            k.matvec(&layer, &xs[s * n_in..(s + 1) * n_in], &mut single);
            assert_eq!(&batched[s * n_out..(s + 1) * n_out], &single[..]);
        }
    }
}
