//! The reference float kernel: one accumulator, strictly sequential
//! adds. This is the left column of the paper's Table I (the naive MAC
//! loop) and the numeric baseline the blocked kernel is compared against
//! in `rust/tests/parity_kernels.rs` (tolerance 3e-5 for the float-add
//! reassociation the 4-lane kernel performs).

use super::{DenseKernel, DenseLayerRef};
use crate::fann::activation::Activation;

/// Textbook dense layer: `acc = b[o]; acc += w·x` in index order.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarF32;

impl DenseKernel<f32> for ScalarF32 {
    fn name(&self) -> &'static str {
        "scalar_f32"
    }

    fn apply_epilogue(&self, act: Activation, steepness: f32, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = super::epilogue_f32(act, steepness, *v);
        }
    }

    fn matvec(&self, layer: &DenseLayerRef<f32>, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            let mut acc = layer.biases[o];
            for (&w, &xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            out[o] = acc;
        }
    }

    // No matmul/matmul_act override: the trait defaults (loop of
    // matvec; matmul + separate epilogue sweep) ARE the scalar batched
    // semantics — this kernel is the reference the fused paths are
    // tested against.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_affine_values() {
        // 2 outputs, 3 inputs: y = W x + b with hand computable numbers.
        let w = [1.0f32, 0.0, -1.0, 2.0, 0.5, 0.0];
        let b = [0.5f32, -1.0];
        let layer = DenseLayerRef::new(3, 2, &w, &b);
        let x = [2.0f32, 4.0, 6.0];
        let mut out = [0.0f32; 2];
        ScalarF32.matvec(&layer, &x, &mut out);
        assert_eq!(out[0], 0.5 + 2.0 - 6.0);
        assert_eq!(out[1], -1.0 + 4.0 + 2.0);
    }

    #[test]
    fn single_input_single_output() {
        let w = [3.0f32];
        let b = [1.0f32];
        let layer = DenseLayerRef::new(1, 1, &w, &b);
        let mut out = [0.0f32];
        ScalarF32.matvec(&layer, &[2.0], &mut out);
        assert_eq!(out[0], 7.0);
    }
}
