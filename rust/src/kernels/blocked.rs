//! The ILP-blocked float kernel — the host-side analogue of the paper's
//! reorganized inner loop (Table I: unrolled MACs feeding independent
//! accumulators), generalized to 4×4 sample×neuron register tiles for
//! the batched entry point.
//!
//! Numerics: `matvec` keeps the seed implementation's exact reduction
//! order (`(acc0+acc2)+(acc1+acc3)+tail`, bias added last), and `matmul`
//! keeps the *same per-(sample, neuron) accumulation order* inside its
//! tiles, so batched results are bit-identical to single-sample results
//! — `rust/tests/batch_consistency.rs` pins this. Cross-kernel float
//! parity vs [`super::ScalarF32`] is within 3e-5 (add reassociation
//! only), pinned by `rust/tests/parity_kernels.rs`.

use super::{DenseKernel, DenseLayerRef};
use crate::fann::activation::Activation;

/// Four-lane dot product: independent accumulators expose instruction-
/// level parallelism / SIMD to the compiler. Reassociates float adds
/// relative to the scalar kernel (parity tolerance 3e-5).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// 4-lane blocked dense kernel with 4×4 batch tiling.
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockedF32;

impl DenseKernel<f32> for BlockedF32 {
    fn name(&self) -> &'static str {
        "blocked_f32"
    }

    fn apply_epilogue(&self, act: Activation, steepness: f32, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = super::epilogue_f32(act, steepness, *v);
        }
    }

    fn matvec(&self, layer: &DenseLayerRef<f32>, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            out[o] = layer.biases[o] + dot_f32(row, x);
        }
    }

    /// Fused single-sample pass: the activation is applied to the
    /// bias+dot value while it is still a register, instead of a second
    /// read-modify-write sweep over `out`. Same value, same function —
    /// bit-identical to the unfused default.
    fn matvec_act(
        &self,
        layer: &DenseLayerRef<f32>,
        x: &[f32],
        out: &mut [f32],
        act: Activation,
        steepness: f32,
    ) {
        debug_assert_eq!(x.len(), layer.n_in);
        debug_assert_eq!(out.len(), layer.n_out);
        for o in 0..layer.n_out {
            let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
            out[o] = super::epilogue_f32(act, steepness, layer.biases[o] + dot_f32(row, x));
        }
    }

    /// 4×4 register-blocked batch tiles: each weight chunk is loaded
    /// once and reused across 4 samples; each input chunk is reused
    /// across 4 output neurons. Per-(sample, neuron) accumulation order
    /// is identical to `matvec`, so tiling is invisible to numerics.
    fn matmul(&self, layer: &DenseLayerRef<f32>, xs: &[f32], n_samples: usize, out: &mut [f32]) {
        self.matmul_impl(layer, xs, n_samples, out, |v| v);
    }

    /// Fused batch pass: the activation runs at tile write-back, on the
    /// accumulator value still in registers. Bit-identical to `matmul`
    /// followed by the epilogue sweep.
    fn matmul_act(
        &self,
        layer: &DenseLayerRef<f32>,
        xs: &[f32],
        n_samples: usize,
        out: &mut [f32],
        act: Activation,
        steepness: f32,
    ) {
        self.matmul_impl(layer, xs, n_samples, out, |v| super::epilogue_f32(act, steepness, v));
    }
}

impl BlockedF32 {
    /// The shared 4×4 tile loop; `epilogue` is applied to each
    /// bias-added accumulator at write-back (identity for the plain
    /// `matmul`).
    #[inline]
    fn matmul_impl<F: Fn(f32) -> f32>(
        &self,
        layer: &DenseLayerRef<f32>,
        xs: &[f32],
        n_samples: usize,
        out: &mut [f32],
        epilogue: F,
    ) {
        let n_in = layer.n_in;
        let n_out = layer.n_out;
        debug_assert_eq!(xs.len(), n_in * n_samples);
        debug_assert_eq!(out.len(), n_out * n_samples);
        let chunks = n_in / 4;
        let mut s0 = 0;
        while s0 < n_samples {
            let sb = (n_samples - s0).min(4);
            let mut o0 = 0;
            while o0 < n_out {
                let ob = (n_out - o0).min(4);
                // acc[si][oi] holds the 4 ILP lanes of sample s0+si,
                // neuron o0+oi — the same lanes matvec's dot_f32 keeps.
                let mut acc = [[[0.0f32; 4]; 4]; 4];
                for c in 0..chunks {
                    let i = c * 4;
                    for oi in 0..ob {
                        let wbase = (o0 + oi) * n_in + i;
                        let w = &layer.weights[wbase..wbase + 4];
                        for si in 0..sb {
                            let xbase = (s0 + si) * n_in + i;
                            let x = &xs[xbase..xbase + 4];
                            let a = &mut acc[si][oi];
                            a[0] += w[0] * x[0];
                            a[1] += w[1] * x[1];
                            a[2] += w[2] * x[2];
                            a[3] += w[3] * x[3];
                        }
                    }
                }
                for si in 0..sb {
                    for oi in 0..ob {
                        let mut tail = 0.0f32;
                        for i in chunks * 4..n_in {
                            tail += layer.weights[(o0 + oi) * n_in + i]
                                * xs[(s0 + si) * n_in + i];
                        }
                        let a = &acc[si][oi];
                        out[(s0 + si) * n_out + o0 + oi] = epilogue(
                            layer.biases[o0 + oi] + ((a[0] + a[2]) + (a[1] + a[3]) + tail),
                        );
                    }
                }
                o0 += ob;
            }
            s0 += sb;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dot_handles_all_tail_lengths() {
        for len in 0..=9 {
            let a: Vec<f32> = (0..len).map(|i| i as f32 + 1.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 2.0 - i as f32).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_f32(&a, &b);
            assert!((want - got).abs() < 1e-4, "len={len}: {want} vs {got}");
        }
    }

    #[test]
    fn matmul_tile_boundaries_match_matvec_bitwise() {
        // Shapes straddling every tile boundary: 1..=9 covers partial
        // and full 4-tiles in samples, outputs and the input tail.
        let mut rng = Rng::new(0xB10C);
        for &n_in in &[1usize, 3, 4, 5, 8, 11] {
            for &n_out in &[1usize, 2, 4, 5, 9] {
                for &n_samples in &[1usize, 3, 4, 5, 7] {
                    let w: Vec<f32> =
                        (0..n_in * n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                    let b: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                    let xs: Vec<f32> = (0..n_in * n_samples)
                        .map(|_| rng.range_f32(-1.0, 1.0))
                        .collect();
                    let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                    let mut batched = vec![0.0f32; n_out * n_samples];
                    BlockedF32.matmul(&layer, &xs, n_samples, &mut batched);
                    for s in 0..n_samples {
                        let mut single = vec![0.0f32; n_out];
                        BlockedF32.matvec(&layer, &xs[s * n_in..(s + 1) * n_in], &mut single);
                        assert_eq!(
                            &batched[s * n_out..(s + 1) * n_out],
                            &single[..],
                            "n_in={n_in} n_out={n_out} n_samples={n_samples} s={s}"
                        );
                    }
                }
            }
        }
    }
}
