//! A small per-host autotune pass for the SIMD kernel knobs.
//!
//! The SIMD microkernels in [`super::simd`] leave two things to taste
//! per host: how many chunks the integer panel loops process per
//! iteration (the `unroll2` second accumulator set — a win on wide
//! out-of-order cores, a wash on small ones) and how many output rows
//! the [`super::SimdF32`] batched path tiles together (weight-row reuse
//! vs register pressure). Every candidate is **bit-exact** with every
//! other (integer adds commute; the f32 tile only reorders the row
//! loop, never a reduction), so tuning is purely a speed decision —
//! the pass asserts candidate agreement outright.
//!
//! [`autotune`] times each candidate on a fixed synthetic workload
//! (64×64 layer, narrow inputs so the SSE2 `madd` tier can engage) and
//! installs the winner in process-wide atomics that the dispatcher
//! reads on every call ([`q_path`], [`f32_rows_per_tile`]). The bench
//! CLI exposes it as `bench autotune`, and `bench json` runs a quick
//! pass before measuring so `speedup_simd_*` rows reflect tuned
//! kernels. The pass mutates the global knobs while it runs — call it
//! before serving traffic, not during.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::time::Instant;

use super::layout::{pack_rows, PackedWidth};
use super::packed::{PackedLayerRef, PackedQ15, PackedQ7};
use super::simd::{self, SimdLevel};
use super::{DenseKernel, DenseLayerRef, SimdF32};
use crate::util::rng::Rng;

/// How the packed q7/q15 product loops execute on this host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QPath {
    /// Keep the portable scalar chunk loops.
    Scalar,
    /// Use the SIMD panel kernels for the selected level.
    Simd {
        /// Process two chunks per iteration with a second accumulator
        /// set (exact: integer adds commute).
        unroll2: bool,
    },
}

impl QPath {
    /// Stable label for bench metadata (`scalar` / `simd` /
    /// `simd_unroll2`).
    pub fn label(self) -> &'static str {
        match self {
            QPath::Scalar => "scalar",
            QPath::Simd { unroll2: false } => "simd",
            QPath::Simd { unroll2: true } => "simd_unroll2",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            QPath::Scalar => 0,
            QPath::Simd { unroll2: false } => 1,
            QPath::Simd { unroll2: true } => 2,
        }
    }

    fn from_u8(v: u8) -> QPath {
        match v {
            1 => QPath::Simd { unroll2: false },
            2 => QPath::Simd { unroll2: true },
            _ => QPath::Scalar,
        }
    }
}

/// The tunable knob set. [`Tuning::default`] is the conservative
/// pre-tune state (SIMD on where available, no unroll, 4-row f32 tile).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tuning {
    /// Output rows per tile of [`SimdF32`]'s batched path.
    pub f32_rows_per_tile: usize,
    /// q7 panel-loop path.
    pub q7: QPath,
    /// q15 panel-loop path.
    pub q15: QPath,
}

impl Default for Tuning {
    fn default() -> Self {
        Self {
            f32_rows_per_tile: 4,
            q7: QPath::Simd { unroll2: false },
            q15: QPath::Simd { unroll2: false },
        }
    }
}

static F32_TILE: AtomicUsize = AtomicUsize::new(4);
static Q7_PATH: AtomicU8 = AtomicU8::new(1);
static Q15_PATH: AtomicU8 = AtomicU8::new(1);

/// The currently installed knob values.
pub fn current() -> Tuning {
    Tuning {
        f32_rows_per_tile: F32_TILE.load(Ordering::Relaxed),
        q7: QPath::from_u8(Q7_PATH.load(Ordering::Relaxed)),
        q15: QPath::from_u8(Q15_PATH.load(Ordering::Relaxed)),
    }
}

/// Install `t` as the process-wide knob values.
pub fn apply(t: &Tuning) {
    F32_TILE.store(t.f32_rows_per_tile.max(1), Ordering::Relaxed);
    Q7_PATH.store(t.q7.to_u8(), Ordering::Relaxed);
    Q15_PATH.store(t.q15.to_u8(), Ordering::Relaxed);
}

/// Row-tile knob read by [`SimdF32`]'s batched path.
pub(crate) fn f32_rows_per_tile() -> usize {
    F32_TILE.load(Ordering::Relaxed).max(1)
}

/// Panel-loop knob read by [`simd::q_dispatch`] per call.
pub(crate) fn q_path(width: PackedWidth) -> QPath {
    match width {
        PackedWidth::Q7 => QPath::from_u8(Q7_PATH.load(Ordering::Relaxed)),
        PackedWidth::Q15 => QPath::from_u8(Q15_PATH.load(Ordering::Relaxed)),
    }
}

/// One timed candidate of the autotune pass, for bench reporting.
#[derive(Debug, Clone)]
pub struct CandidateTiming {
    /// Which knob the candidate belongs to (`f32_rows_per_tile`,
    /// `q7_path`, `q15_path`).
    pub knob: &'static str,
    /// Candidate value label.
    pub candidate: String,
    /// Best-of-reps wall time for the fixed workload.
    pub seconds: f64,
    /// Whether this candidate won its knob.
    pub chosen: bool,
}

/// Best-of-`reps` wall time of `f` after one warmup call.
fn time_min(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Time every candidate knob value on a fixed synthetic workload,
/// assert all candidates agree bit-for-bit, install the winners and
/// return them plus the per-candidate timings. `quick` shrinks the
/// workload and rep count (used by `bench json`'s pre-measure pass);
/// `bench autotune` runs the full grid.
pub fn autotune(quick: bool) -> (Tuning, Vec<CandidateTiming>) {
    let (n_in, n_out) = (64usize, 64usize);
    let samples = if quick { 64 } else { 256 };
    let iters = if quick { 2 } else { 8 };
    let reps = if quick { 1 } else { 3 };
    let mut rng = Rng::new(0x51D0_7E57);
    let mut timings = Vec::new();
    let mut tuning = current();

    // --- f32 row tile -----------------------------------------------------
    let wf: Vec<f32> = (0..n_in * n_out)
        .map(|_| rng.below(2001) as f32 / 1000.0 - 1.0)
        .collect();
    let bf: Vec<f32> = (0..n_out).map(|_| rng.below(201) as f32 / 100.0 - 1.0).collect();
    let layer_f = DenseLayerRef::new(n_in, n_out, &wf, &bf);
    let xf: Vec<f32> = (0..n_in * samples)
        .map(|_| rng.below(2001) as f32 / 1000.0 - 1.0)
        .collect();
    let mut out_f = vec![0.0f32; n_out * samples];
    let mut reference: Option<Vec<f32>> = None;
    let mut best = (f64::INFINITY, tuning.f32_rows_per_tile);
    let mut f32_rows = Vec::new();
    for tile in [1usize, 2, 4, 8] {
        apply(&Tuning {
            f32_rows_per_tile: tile,
            ..tuning
        });
        let secs = time_min(reps, || {
            for _ in 0..iters {
                SimdF32.matmul(&layer_f, &xf, samples, &mut out_f);
            }
        });
        match &reference {
            // Every tile reorders only the row loop: outputs must be
            // bit-identical.
            Some(want) => assert_eq!(&out_f, want, "f32 tile {tile} changed results"),
            None => reference = Some(out_f.clone()),
        }
        if secs < best.0 {
            best = (secs, tile);
        }
        f32_rows.push((tile, secs));
    }
    tuning.f32_rows_per_tile = best.1;
    for (tile, secs) in f32_rows {
        timings.push(CandidateTiming {
            knob: "f32_rows_per_tile",
            candidate: tile.to_string(),
            seconds: secs,
            chosen: tile == tuning.f32_rows_per_tile,
        });
    }

    // --- q7 / q15 panel paths --------------------------------------------
    // Narrow inputs (|x| <= 1000) so every SIMD tier — including the
    // SSE2 extra-narrow madd path — can engage.
    if simd::selected_level() != SimdLevel::Scalar {
        let dec = 6u32;
        let xs: Vec<i32> = (0..n_in * samples)
            .map(|_| rng.below(2001) as i32 - 1000)
            .collect();
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (lo, hi) = width.range();
            let span = (hi - lo + 1) as usize;
            let wq: Vec<i32> = (0..n_in * n_out).map(|_| lo + rng.below(span) as i32).collect();
            let bq: Vec<i32> = (0..n_out).map(|_| rng.below(4001) as i32 - 2000).collect();
            let panels = pack_rows(width, n_in, n_out, &wq).expect("weights fit width");
            let pref = PackedLayerRef::new(&panels, &bq);
            let mut out_q = vec![0i32; n_out * samples];
            let mut reference: Option<Vec<i32>> = None;
            let mut best: (f64, QPath) = (f64::INFINITY, QPath::Simd { unroll2: false });
            let mut rows = Vec::new();
            for path in [
                QPath::Scalar,
                QPath::Simd { unroll2: false },
                QPath::Simd { unroll2: true },
            ] {
                let mut t = tuning;
                match width {
                    PackedWidth::Q7 => t.q7 = path,
                    PackedWidth::Q15 => t.q15 = path,
                }
                apply(&t);
                let secs = time_min(reps, || {
                    for _ in 0..iters {
                        match width {
                            PackedWidth::Q7 => {
                                PackedQ7::new(dec).matmul(&pref, &xs, samples, &mut out_q)
                            }
                            PackedWidth::Q15 => {
                                PackedQ15::new(dec).matmul(&pref, &xs, samples, &mut out_q)
                            }
                        }
                    }
                });
                match &reference {
                    // SIMD panels are bit-exact vs the scalar loops.
                    Some(want) => {
                        assert_eq!(&out_q, want, "{width:?} path {} changed results", path.label())
                    }
                    None => reference = Some(out_q.clone()),
                }
                if secs < best.0 {
                    best = (secs, path);
                }
                rows.push((path, secs));
            }
            match width {
                PackedWidth::Q7 => tuning.q7 = best.1,
                PackedWidth::Q15 => tuning.q15 = best.1,
            }
            let knob = match width {
                PackedWidth::Q7 => "q7_path",
                PackedWidth::Q15 => "q15_path",
            };
            for (path, secs) in rows {
                timings.push(CandidateTiming {
                    knob,
                    candidate: path.label().to_string(),
                    seconds: secs,
                    chosen: path == best.1,
                });
            }
        }
    }

    apply(&tuning);
    (tuning, timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Serializes tests that mutate the process-wide knobs.
    static KNOB_GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn apply_current_roundtrip() {
        let _g = KNOB_GATE.lock().unwrap_or_else(|p| p.into_inner());
        let before = current();
        let t = Tuning {
            f32_rows_per_tile: 2,
            q7: QPath::Simd { unroll2: true },
            q15: QPath::Scalar,
        };
        apply(&t);
        assert_eq!(current(), t);
        assert_eq!(f32_rows_per_tile(), 2);
        assert_eq!(q_path(PackedWidth::Q7), QPath::Simd { unroll2: true });
        assert_eq!(q_path(PackedWidth::Q15), QPath::Scalar);
        apply(&before);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(QPath::Scalar.label(), "scalar");
        assert_eq!(QPath::Simd { unroll2: false }.label(), "simd");
        assert_eq!(QPath::Simd { unroll2: true }.label(), "simd_unroll2");
    }

    #[test]
    fn quick_autotune_runs_and_installs_a_tuning() {
        let _g = KNOB_GATE.lock().unwrap_or_else(|p| p.into_inner());
        let (t, timings) = autotune(true);
        assert_eq!(current(), t);
        assert!(t.f32_rows_per_tile >= 1);
        // The f32 knob always times its candidates; q knobs only when a
        // SIMD level is live.
        assert!(timings.iter().any(|c| c.knob == "f32_rows_per_tile"));
        if simd::selected_level() != SimdLevel::Scalar {
            assert!(timings.iter().any(|c| c.knob == "q7_path"));
            assert!(timings.iter().any(|c| c.knob == "q15_path"));
        }
        for knob in ["f32_rows_per_tile", "q7_path", "q15_path"] {
            let of_knob: Vec<_> = timings.iter().filter(|c| c.knob == knob).collect();
            if !of_knob.is_empty() {
                assert_eq!(of_knob.iter().filter(|c| c.chosen).count(), 1, "{knob}");
            }
        }
    }
}
