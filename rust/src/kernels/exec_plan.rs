//! Ahead-of-time **compiled execution plans** — the host-side analogue
//! of the paper's generator resolving everything it can before the
//! first inference runs (CMSIS-NN's "resolve layout and fusion ahead of
//! time, not per call").
//!
//! [`ExecPlan::compile`] walks a [`Network`], [`FixedNetwork`] or
//! [`PackedNetwork`] **once** and freezes, per layer:
//!
//! * the **concrete kernel** — static dispatch, no per-call
//!   `dyn DenseKernel` vtable hop. For Q32 plans the compiler also
//!   inspects the weights: it records the largest `|w|` and derives the
//!   input bound under which every product fits 32-bit arithmetic, so
//!   the run path picks the narrow-multiply kernel with one cheap input
//!   scan instead of paying the generic widening `qmul` per product
//!   (bit-exact either way — a product that fits i32 shifts
//!   identically at both widths; see [`crate::kernels::packed`]).
//! * the **fused activation epilogue** (activation + steepness), baked
//!   next to the kernel choice.
//! * the **parameters**, copied into a single contiguous arena in
//!   traversal order — weights then biases, layer after layer — so an
//!   inference streams one flat allocation front to back (the software
//!   mirror of the paper's L1-resident parameter image).
//!
//! Execution then needs **zero steady-state allocation**: one flat
//! [`PlanScratch`] buffer is split in half for the inter-layer
//! ping-pong, the first layer reads the caller's input and the last
//! writes the caller's output directly.
//!
//! # Row-split (neuron-parallel) execution
//!
//! [`split_rows`] is THE row partition of the paper's intra-network
//! parallelization (neuron-wise splitting of each layer across the Mr.
//! Wolf cluster's cores): near-equal contiguous ranges, first `n % w`
//! ranges one row longer. It is shared by three consumers so they can
//! never disagree: the multicore host driver
//! (`bench::batch::run_plan_rowsplit*`), the emulator's per-core
//! cluster walk, and the analytic cost model
//! ([`rows_per_core_max`] == `ceil(n/cores)` — the wall-clock rows of a
//! layer are whatever the fullest core received). Because every output
//! row's accumulation is independent, any split is bit-exact; for
//! packed plans the partition is panel-aligned (four rows share a word
//! block).

use std::ops::Range;

use super::layout::{PackedWidth, ROWS_PER_PANEL};
use super::{DenseKernel, DenseLayerRef, FixedQ, PackedLayerRef, PackedQ15, PackedQ7};
use crate::fann::activation::Activation;
use crate::fann::{FixedNetwork, Network, PackedNetwork};
use crate::quantize::{self, sat_i32};

/// Split `n` rows into at most `workers` contiguous `(start, len)`
/// ranges of near-equal size (first `n % workers` ranges get one extra
/// row). The one row-split schedule shared by the host driver, the
/// emulator and the cost model.
pub fn split_rows(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let workers = workers.max(1).min(n.max(1));
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        if len == 0 {
            break;
        }
        out.push((start, len));
        start += len;
    }
    out
}

/// Rows of the fullest range of [`split_rows`]`(n, cores)` — the
/// per-layer wall-clock work of a parallel section. Identical to
/// `n.div_ceil(cores)` for this near-equal split; the cost model uses
/// this accessor so its arithmetic provably follows the schedule the
/// executors walk.
pub fn rows_per_core_max(n: usize, cores: usize) -> usize {
    split_rows(n, cores).first().map_or(0, |&(_, len)| len)
}

/// Block-aligned row partition: distribute `ceil(n / block)` blocks of
/// `block` rows across `workers` with [`split_rows`], clipping the last
/// range to `n`. Returns `(r0, r1)` half-open row ranges. `block == 1`
/// is exactly the row-granular split; the packed representations use
/// `block == ROWS_PER_PANEL` because four output rows share one word
/// panel, so a core's work quantizes to whole panels. This is the one
/// partition the host row-split driver, the emulator's cluster walk and
/// the cost model all derive from.
pub fn split_row_blocks(n: usize, block: usize, workers: usize) -> Vec<(usize, usize)> {
    let block = block.max(1);
    split_rows(n.div_ceil(block), workers)
        .into_iter()
        .map(|(b0, blen)| (b0 * block, ((b0 + blen) * block).min(n)))
        .collect()
}

/// Wall-clock rows of the fullest core under
/// [`split_row_blocks`]`(n, block, cores)` — what a parallel layer's
/// compute is billed at. Equals `ceil(n / cores)` for `block == 1`.
pub fn rows_per_core_block_max(n: usize, block: usize, cores: usize) -> usize {
    split_row_blocks(n, block, cores)
        .into_iter()
        .map(|(r0, r1)| r1 - r0)
        .max()
        .unwrap_or(0)
}

/// Sources an [`ExecPlan`] can be compiled from.
pub trait PlanSource {
    fn compile_exec_plan(&self) -> ExecPlan;
}

/// Frozen per-layer record: shape, arena offsets, fused epilogue, and
/// the compile-time kernel-selection facts.
#[derive(Debug, Clone)]
struct PlanLayer {
    n_in: usize,
    n_out: usize,
    /// Offset of this layer's weights in the plan arena (elements for
    /// dense plans, `u32` words for packed plans).
    w_off: usize,
    /// Weight span at `w_off` (elements / words).
    w_len: usize,
    /// Offset of this layer's biases (same arena for dense plans, the
    /// bias arena for packed plans).
    b_off: usize,
    act: Activation,
    steepness: f32,
    /// Q32 plans: inclusive bound on `|x|` under which every product
    /// `w · x` of this layer fits in i32 (derived from `max |w|` at
    /// compile time). Unused by f32/packed plans.
    narrow_x_bound: u32,
    /// Packed plans: words covering one row (`ceil(n_in / elems)`).
    words_per_row: usize,
}

/// The representation a plan executes in, with its parameter arena.
#[derive(Debug, Clone)]
enum Repr {
    F32 {
        arena: Vec<f32>,
    },
    Q32 {
        arena: Vec<i32>,
        dec: u32,
    },
    Packed {
        words: Vec<u32>,
        biases: Vec<i32>,
        dec: u32,
        width: PackedWidth,
    },
}

/// A compiled, immediately executable network: concrete kernels, fused
/// epilogues and a contiguous parameter arena resolved once at compile
/// time (see the module docs). `Sync`, so one plan can be shared by
/// every worker of a row-split or batch-parallel driver.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    repr: Repr,
    layers: Vec<PlanLayer>,
    sizes: Vec<usize>,
    /// SIMD level detected when the plan was compiled. Metadata for
    /// reports: dispatch itself stays live per call (see
    /// [`super::simd`]), so a forced level during execution wins.
    simd: super::simd::SimdLevel,
}

/// The single flat scratch of a plan execution: one buffer per element
/// type, split in half for the inter-layer ping-pong. Grown once,
/// never shrunk — steady-state plan runs allocate nothing.
#[derive(Debug, Default)]
pub struct PlanScratch {
    f: Vec<f32>,
    q: Vec<i32>,
}

impl PlanScratch {
    /// Empty scratch; the flat buffer grows on first run.
    pub fn new() -> Self {
        Self::default()
    }

    fn halves_f32(&mut self, len: usize) -> (&mut [f32], &mut [f32]) {
        if self.f.len() < 2 * len {
            self.f.resize(2 * len, 0.0);
        }
        let (a, b) = self.f.split_at_mut(len);
        (a, &mut b[..len])
    }

    fn halves_q(&mut self, len: usize) -> (&mut [i32], &mut [i32]) {
        if self.q.len() < 2 * len {
            self.q.resize(2 * len, 0);
        }
        let (a, b) = self.q.split_at_mut(len);
        (a, &mut b[..len])
    }
}

impl ExecPlan {
    /// Compile an execution plan from any supported network form
    /// (`&Network`, `&FixedNetwork`, `&PackedNetwork`).
    pub fn compile<S: PlanSource + ?Sized>(src: &S) -> ExecPlan {
        src.compile_exec_plan()
    }

    /// Input width of the compiled network.
    pub fn num_inputs(&self) -> usize {
        self.sizes[0]
    }

    /// Output width of the compiled network.
    pub fn num_outputs(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Number of compiled dense layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layer sizes `[in, h1, ..., out]` of the compiled network.
    pub fn layer_sizes(&self) -> Vec<usize> {
        self.sizes.clone()
    }

    /// `(n_in, n_out)` of layer `li`.
    pub fn layer_dims(&self, li: usize) -> (usize, usize) {
        (self.layers[li].n_in, self.layers[li].n_out)
    }

    /// Per-layer activations (in order).
    pub fn activations(&self) -> Vec<Activation> {
        self.layers.iter().map(|l| l.act).collect()
    }

    /// Widest layer (sizes the ping-pong scratch halves).
    pub fn max_layer_width(&self) -> usize {
        self.sizes.iter().copied().max().unwrap()
    }

    /// `true` for plans compiled from a float network.
    pub fn is_float(&self) -> bool {
        matches!(self.repr, Repr::F32 { .. })
    }

    /// The SIMD level that was selected when this plan was compiled
    /// (report metadata; per-call dispatch remains live).
    pub fn simd_level(&self) -> super::simd::SimdLevel {
        self.simd
    }

    /// The Q(dec) decimal point of fixed-point plans (`None` for f32).
    pub fn decimal_point(&self) -> Option<u32> {
        match &self.repr {
            Repr::F32 { .. } => None,
            Repr::Q32 { dec, .. } => Some(*dec),
            Repr::Packed { dec, .. } => Some(*dec),
        }
    }

    /// Short representation label for reports (`f32`/`q32`/`q7`/`q15`).
    pub fn repr_label(&self) -> &'static str {
        match &self.repr {
            Repr::F32 { .. } => "f32",
            Repr::Q32 { .. } => "q32",
            Repr::Packed { width, .. } => width.label(),
        }
    }

    /// Parameter arena footprint in bytes (weights + biases in the
    /// plan's representation).
    pub fn param_bytes(&self) -> usize {
        match &self.repr {
            Repr::F32 { arena } => arena.len() * 4,
            Repr::Q32 { arena, .. } => arena.len() * 4,
            Repr::Packed { words, biases, .. } => words.len() * 4 + biases.len() * 4,
        }
    }

    /// The parallel split granularity of this plan's rows: packed plans
    /// quantize to whole word panels, dense plans split per row.
    pub fn row_block(&self) -> usize {
        match &self.repr {
            Repr::Packed { .. } => ROWS_PER_PANEL,
            _ => 1,
        }
    }

    /// Row partition of layer `li` for `workers` cores: the shared
    /// [`split_row_blocks`] schedule at this plan's
    /// [`row_block`](Self::row_block) (the end of the last range is
    /// clipped to `n_out`). Returns `(r0, r1)` half-open row ranges.
    pub fn partition_rows(&self, li: usize, workers: usize) -> Vec<(usize, usize)> {
        split_row_blocks(self.layers[li].n_out, self.row_block(), workers)
    }

    /// Whether layer `li`'s inputs clear the compile-time narrow bound,
    /// i.e. the 32-bit multiply kernel is exact for this call (for Q32
    /// plans the bound comes from `max |w|`; for packed plans it is the
    /// width's `|x| < FAST_LIMIT` condition). Always `false` for f32
    /// plans. Row-split drivers hoist this one scan per layer and share
    /// the verdict across row jobs instead of rescanning per job.
    pub fn narrow_ok(&self, li: usize, src: &[i32]) -> bool {
        match &self.repr {
            Repr::F32 { .. } => false,
            _ => {
                let bound = self.layers[li].narrow_x_bound;
                src.iter().all(|&v| v.unsigned_abs() <= bound)
            }
        }
    }

    /// Run one float sample end to end: f32 plans run directly; fixed
    /// plans quantize at the compiled decimal point, run the integer
    /// path and dequantize (what [`crate::simulator::Executable`] needs).
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        assert_eq!(input.len(), self.num_inputs());
        let mut scratch = PlanScratch::new();
        match &self.repr {
            Repr::F32 { .. } => {
                let mut out = vec![0.0f32; self.num_outputs()];
                self.run_batch_f32_into(input, 1, &mut scratch, &mut out);
                out
            }
            _ => {
                let dec = self.decimal_point().unwrap();
                let xq: Vec<i32> = input.iter().map(|&v| quantize::quantize(v, dec)).collect();
                let mut out = vec![0i32; self.num_outputs()];
                self.run_batch_q_into(&xq, 1, &mut scratch, &mut out);
                out.into_iter()
                    .map(|q| quantize::dequantize(q as i64, dec))
                    .collect()
            }
        }
    }

    /// Batched f32 execution (f32 plans only): `xs` packs `n_samples`
    /// rows, `out` receives `n_samples × n_out`. Bit-identical to the
    /// dispatch path ([`Network::run_batch`]) — same kernel, same
    /// parameter values, same order — with zero per-layer dispatch and
    /// zero steady-state allocation.
    pub fn run_batch_f32_into(
        &self,
        xs: &[f32],
        n_samples: usize,
        scratch: &mut PlanScratch,
        out: &mut [f32],
    ) {
        assert!(self.is_float(), "f32 entry point on a {} plan", self.repr_label());
        assert_eq!(xs.len(), n_samples * self.num_inputs());
        assert_eq!(out.len(), n_samples * self.num_outputs());
        if n_samples == 0 {
            return;
        }
        let n_layers = self.layers.len();
        let (a, b) = scratch.halves_f32(self.max_layer_width() * n_samples);
        for li in 0..n_layers {
            let last = li + 1 == n_layers;
            let (n_in, n_out) = self.layer_dims(li);
            let (src, dst) = super::batch_route(li, last, xs, a, b, out);
            self.run_layer_rows_f32(
                li,
                &src[..n_in * n_samples],
                n_samples,
                0..n_out,
                &mut dst[..n_out * n_samples],
            );
        }
    }

    /// Vec-returning convenience for [`run_batch_f32_into`](Self::run_batch_f32_into).
    pub fn run_batch_f32(&self, xs: &[f32], n_samples: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n_samples * self.num_outputs()];
        let mut scratch = PlanScratch::new();
        self.run_batch_f32_into(xs, n_samples, &mut scratch, &mut out);
        out
    }

    /// Batched Q(dec) execution (Q32 and packed plans): bit-exact vs
    /// the dispatch paths ([`FixedNetwork::run_batch_q`] /
    /// [`PackedNetwork::run_batch_q`]) on the same quantized inputs.
    pub fn run_batch_q_into(
        &self,
        xs: &[i32],
        n_samples: usize,
        scratch: &mut PlanScratch,
        out: &mut [i32],
    ) {
        assert!(!self.is_float(), "Q entry point on an f32 plan");
        assert_eq!(xs.len(), n_samples * self.num_inputs());
        assert_eq!(out.len(), n_samples * self.num_outputs());
        if n_samples == 0 {
            return;
        }
        let n_layers = self.layers.len();
        let (a, b) = scratch.halves_q(self.max_layer_width() * n_samples);
        for li in 0..n_layers {
            let last = li + 1 == n_layers;
            let (n_in, n_out) = self.layer_dims(li);
            let (src, dst) = super::batch_route(li, last, xs, a, b, out);
            self.run_layer_rows_q(
                li,
                &src[..n_in * n_samples],
                n_samples,
                0..n_out,
                &mut dst[..n_out * n_samples],
            );
        }
    }

    /// Vec-returning convenience for [`run_batch_q_into`](Self::run_batch_q_into).
    pub fn run_batch_q(&self, xs: &[i32], n_samples: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_samples * self.num_outputs()];
        let mut scratch = PlanScratch::new();
        self.run_batch_q_into(xs, n_samples, &mut scratch, &mut out);
        out
    }

    /// Compute rows `rows` of layer `li` for `n_samples` packed input
    /// rows (f32 plans). `dst` holds the range's rows contiguously,
    /// sample-major with row stride `rows.len()` — the row-split worker
    /// granularity. Row accumulation is independent, so any range
    /// reassembles the whole layer bit for bit.
    pub fn run_layer_rows_f32(
        &self,
        li: usize,
        src: &[f32],
        n_samples: usize,
        rows: Range<usize>,
        dst: &mut [f32],
    ) {
        let l = &self.layers[li];
        debug_assert!(rows.end <= l.n_out);
        debug_assert_eq!(dst.len(), (rows.end - rows.start) * n_samples);
        let arena = match &self.repr {
            Repr::F32 { arena } => arena,
            _ => panic!("f32 layer execution on a {} plan", self.repr_label()),
        };
        let w = &arena[l.w_off + rows.start * l.n_in..l.w_off + rows.end * l.n_in];
        let b = &arena[l.b_off + rows.start..l.b_off + rows.end];
        let lref = DenseLayerRef::new(l.n_in, rows.end - rows.start, w, b);
        // Concrete kernel, resolved at compile time: BlockedF32's fused
        // batched pass (the crate default the dispatch path also ends
        // up in — same accumulation order per output row, so sub-range
        // results are bit-identical to the whole-layer call).
        super::BlockedF32.matmul_act(&lref, src, n_samples, dst, l.act, l.steepness);
    }

    /// Compute rows `rows` of layer `li` (Q32 and packed plans). For
    /// packed plans `rows` must be panel-aligned (use
    /// [`partition_rows`](Self::partition_rows)). Resolves the narrow
    /// fast path itself; row-split drivers that already scanned the
    /// layer input use [`run_layer_rows_q_hinted`](Self::run_layer_rows_q_hinted).
    pub fn run_layer_rows_q(
        &self,
        li: usize,
        src: &[i32],
        n_samples: usize,
        rows: Range<usize>,
        dst: &mut [i32],
    ) {
        let narrow = self.narrow_ok(li, src);
        self.run_layer_rows_q_hinted(li, src, n_samples, (rows, narrow), dst);
    }

    /// [`run_layer_rows_q`](Self::run_layer_rows_q) with the layer's
    /// narrow-path verdict hoisted by the caller: `job` is the row
    /// range plus the result of [`narrow_ok`](Self::narrow_ok) for this
    /// layer's input, so N row jobs share one input scan. The hint only
    /// selects between two bit-identical kernels — a wrong `false`
    /// costs speed, never correctness; `true` must come from
    /// `narrow_ok` (the narrow kernel assumes products fit i32).
    pub fn run_layer_rows_q_hinted(
        &self,
        li: usize,
        src: &[i32],
        n_samples: usize,
        job: (Range<usize>, bool),
        dst: &mut [i32],
    ) {
        let (rows, narrow) = job;
        let l = &self.layers[li];
        debug_assert!(rows.end <= l.n_out);
        debug_assert_eq!(dst.len(), (rows.end - rows.start) * n_samples);
        match &self.repr {
            Repr::Q32 { arena, dec } => {
                let w = &arena[l.w_off + rows.start * l.n_in..l.w_off + rows.end * l.n_in];
                let b = &arena[l.b_off + rows.start..l.b_off + rows.end];
                let lref = DenseLayerRef::new(l.n_in, rows.end - rows.start, w, b);
                if narrow {
                    matmul_act_q32_narrow(*dec, &lref, src, n_samples, dst, l.act);
                } else {
                    FixedQ::new(*dec).matmul_act(&lref, src, n_samples, dst, l.act, 1.0);
                }
            }
            Repr::Packed {
                words,
                biases,
                dec,
                width,
            } => {
                debug_assert_eq!(rows.start % ROWS_PER_PANEL, 0, "packed split must be panel-aligned");
                let lref = PackedLayerRef::from_raw(
                    *width,
                    l.n_in,
                    l.n_out,
                    l.words_per_row,
                    &words[l.w_off..l.w_off + l.w_len],
                    &biases[l.b_off..l.b_off + l.n_out],
                );
                let p0 = rows.start / ROWS_PER_PANEL;
                let p1 = rows.end.div_ceil(ROWS_PER_PANEL);
                // The hoisted verdict: equivalent to the kernels'
                // internal `all_fast` scan (|x| <= FAST_LIMIT - 1 ⟺
                // |x| < FAST_LIMIT), resolved once per layer.
                match width {
                    PackedWidth::Q7 => PackedQ7::new(*dec).matmul_act_panels_hinted(
                        &lref,
                        src,
                        n_samples,
                        (p0..p1, narrow),
                        dst,
                        l.act,
                    ),
                    PackedWidth::Q15 => PackedQ15::new(*dec).matmul_act_panels_hinted(
                        &lref,
                        src,
                        n_samples,
                        (p0..p1, narrow),
                        dst,
                        l.act,
                    ),
                }
            }
            Repr::F32 { .. } => panic!("Q layer execution on an f32 plan"),
        }
    }
}

/// The compile-time-selected narrow Q32 kernel: per-product multiply +
/// arithmetic shift in 32-bit arithmetic (vectorizes twice as wide as
/// the generic i64 `qmul`), i64 accumulate, one saturation per output —
/// bit-exact vs [`FixedQ`] whenever the caller's input scan cleared the
/// layer's compile-time `narrow_x_bound`. Same 4-sample blocking as
/// `FixedQ::matmul_impl`, same fused epilogue.
fn matmul_act_q32_narrow(
    dec: u32,
    layer: &DenseLayerRef<i32>,
    xs: &[i32],
    n_samples: usize,
    out: &mut [i32],
    act: Activation,
) {
    let n_in = layer.n_in;
    let n_out = layer.n_out;
    debug_assert_eq!(xs.len(), n_in * n_samples);
    debug_assert_eq!(out.len(), n_out * n_samples);
    let mut s0 = 0;
    while s0 < n_samples {
        let sb = (n_samples - s0).min(4);
        for o in 0..n_out {
            let row = &layer.weights[o * n_in..(o + 1) * n_in];
            let mut acc = [layer.biases[o] as i64; 4];
            for (i, &w) in row.iter().enumerate() {
                for (si, a) in acc.iter_mut().enumerate().take(sb) {
                    *a += ((w * xs[(s0 + si) * n_in + i]) >> dec) as i64;
                }
            }
            for (si, a) in acc.iter().enumerate().take(sb) {
                out[(s0 + si) * n_out + o] = super::epilogue_q(act, dec, sat_i32(*a) as i32);
            }
        }
        s0 += sb;
    }
}

impl PlanSource for Network {
    fn compile_exec_plan(&self) -> ExecPlan {
        let total: usize = self.layers.iter().map(|l| l.weights.len() + l.biases.len()).sum();
        let mut arena = Vec::with_capacity(total);
        let mut layers = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            let w_off = arena.len();
            arena.extend_from_slice(&l.weights);
            let b_off = arena.len();
            arena.extend_from_slice(&l.biases);
            layers.push(PlanLayer {
                n_in: l.n_in,
                n_out: l.n_out,
                w_off,
                w_len: l.weights.len(),
                b_off,
                act: l.activation,
                steepness: l.steepness,
                narrow_x_bound: 0,
                words_per_row: 0,
            });
        }
        ExecPlan {
            repr: Repr::F32 { arena },
            layers,
            sizes: self.layer_sizes(),
            simd: super::simd::selected_level(),
        }
    }
}

impl PlanSource for FixedNetwork {
    fn compile_exec_plan(&self) -> ExecPlan {
        let total: usize = self.layers.iter().map(|l| l.weights.len() + l.biases.len()).sum();
        let mut arena = Vec::with_capacity(total);
        let mut layers = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            // Compile-time kernel selection fact: the largest weight
            // magnitude bounds the input range under which every
            // product fits i32 (|w·x| <= wmax · bound <= i32::MAX).
            let wmax = l.weights.iter().map(|w| w.unsigned_abs()).max().unwrap_or(0);
            let narrow_x_bound = if wmax == 0 {
                u32::MAX
            } else {
                i32::MAX as u32 / wmax
            };
            let w_off = arena.len();
            arena.extend_from_slice(&l.weights);
            let b_off = arena.len();
            arena.extend_from_slice(&l.biases);
            layers.push(PlanLayer {
                n_in: l.n_in,
                n_out: l.n_out,
                w_off,
                w_len: l.weights.len(),
                b_off,
                act: l.activation,
                steepness: 1.0,
                narrow_x_bound,
                words_per_row: 0,
            });
        }
        ExecPlan {
            repr: Repr::Q32 {
                arena,
                dec: self.decimal_point,
            },
            layers,
            sizes: self.layer_sizes(),
            simd: super::simd::selected_level(),
        }
    }
}

impl PlanSource for PackedNetwork {
    fn compile_exec_plan(&self) -> ExecPlan {
        let total_w: usize = self.layers.iter().map(|l| l.panels.words.len()).sum();
        let total_b: usize = self.layers.iter().map(|l| l.biases.len()).sum();
        let mut words = Vec::with_capacity(total_w);
        let mut biases = Vec::with_capacity(total_b);
        let mut layers = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            let w_off = words.len();
            words.extend_from_slice(&l.panels.words);
            let b_off = biases.len();
            biases.extend_from_slice(&l.biases);
            layers.push(PlanLayer {
                n_in: l.panels.n_in,
                n_out: l.panels.n_out,
                w_off,
                w_len: l.panels.words.len(),
                b_off,
                act: l.activation,
                steepness: 1.0,
                // Packing guarantees narrow weights, so the fast-path
                // condition is the width's input bound alone.
                narrow_x_bound: self.width.fast_input_bound(),
                words_per_row: l.panels.words_per_row,
            });
        }
        ExecPlan {
            repr: Repr::Packed {
                words,
                biases,
                dec: self.decimal_point,
                width: self.width,
            },
            layers,
            sizes: self.layer_sizes(),
            simd: super::simd::selected_level(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::from_float_packed;
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn split_rows_covers_exactly_once_and_max_is_ceil() {
        for n in [0usize, 1, 2, 3, 7, 8, 24, 100] {
            for w in [1usize, 2, 3, 7, 8, 16] {
                let ranges = split_rows(n, w);
                let mut next = 0;
                for &(start, len) in &ranges {
                    assert_eq!(start, next);
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, n);
                if n > 0 {
                    // The schedule's fullest range IS the cost model's
                    // ceil(n / cores) — one shared partition.
                    assert_eq!(rows_per_core_max(n, w), n.div_ceil(w));
                }
            }
        }
        assert_eq!(rows_per_core_max(0, 4), 0);
    }

    #[test]
    fn block_aligned_split_covers_and_bills_the_fullest_core() {
        for n in [1usize, 3, 4, 5, 8, 11, 13, 16, 24, 40] {
            for block in [1usize, 4] {
                for w in 1..=8usize {
                    let ranges = split_row_blocks(n, block, w);
                    let mut next = 0;
                    for &(r0, r1) in &ranges {
                        assert_eq!(r0, next);
                        assert_eq!(r0 % block, 0, "n={n} block={block} w={w}");
                        assert!(r1 > r0);
                        next = r1;
                    }
                    assert_eq!(next, n);
                    let max = ranges.iter().map(|&(r0, r1)| r1 - r0).max().unwrap();
                    assert_eq!(rows_per_core_block_max(n, block, w), max);
                    if block == 1 {
                        assert_eq!(max, rows_per_core_max(n, w));
                    }
                }
            }
        }
        // The reviewer's case: 16 packed rows on 8 cores = 4 panels on
        // 8 cores -> the fullest core owns one whole panel (4 rows),
        // not ceil(16/8) = 2.
        assert_eq!(rows_per_core_block_max(16, 4, 8), 4);
        assert_eq!(rows_per_core_max(16, 8), 2);
    }

    #[test]
    fn f32_plan_bit_identical_to_dispatch() {
        let n = net(&[5, 9, 4, 3], 11);
        let plan = ExecPlan::compile(&n);
        assert_eq!(plan.layer_sizes(), n.layer_sizes());
        assert_eq!(plan.repr_label(), "f32");
        assert!(plan.is_float());
        let mut rng = Rng::new(3);
        let samples = 7;
        let xs: Vec<f32> = (0..samples * 5).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        assert_eq!(plan.run_batch_f32(&xs, samples), n.run_batch(&xs, samples));
        // Single-sample entry agrees with Network::run.
        assert_eq!(plan.run(&xs[..5]), n.run(&xs[..5]));
    }

    #[test]
    fn q32_plan_narrow_and_wide_paths_bit_exact() {
        let n = net(&[6, 8, 3], 21);
        let mut rng = Rng::new(5);
        let samples = 6;
        let xs: Vec<f32> = (0..samples * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        // Default (overflow-analysis) decimal point: deep fractional
        // bits make raw products exceed i32 — the wide path runs, and
        // it is the bit-exactness reference by construction.
        let fixed = FixedNetwork::from_float(&n, 1.0).unwrap();
        let plan = ExecPlan::compile(&fixed);
        assert_eq!(plan.repr_label(), "q32");
        assert_eq!(plan.decimal_point(), Some(fixed.decimal_point));
        let xq = fixed.quantize_input(&xs);
        assert_eq!(plan.run_batch_q(&xq, samples), fixed.run_batch_q(&xq, samples));

        // A shallow decimal point keeps weights and inputs small enough
        // that the compile-time bound clears: the narrow 32-bit kernel
        // runs and must still match FixedQ bit for bit.
        let shallow = FixedNetwork::from_float_with_dec(&n, 6);
        let plan_s = ExecPlan::compile(&shallow);
        let xq_s = shallow.quantize_input(&xs);
        assert!(plan_s.narrow_ok(0, &xq_s), "dec 6 inputs should clear the narrow bound");
        assert_eq!(plan_s.run_batch_q(&xq_s, samples), shallow.run_batch_q(&xq_s, samples));

        // Near-overflow inputs force the wide path; still bit-exact.
        let huge: Vec<i32> = (0..6)
            .map(|i| if i % 2 == 0 { i32::MAX - i as i32 } else { i32::MIN + 1 + i as i32 })
            .collect();
        assert!(!plan_s.narrow_ok(0, &huge));
        assert_eq!(plan_s.run_batch_q(&huge, 1), shallow.run_batch_q(&huge, 1));
    }

    #[test]
    fn packed_plans_bit_exact_vs_dispatch() {
        let n = net(&[7, 10, 5], 9);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (_, packed) = from_float_packed(&n, 1.0, width).unwrap();
            let plan = ExecPlan::compile(&packed);
            assert_eq!(plan.repr_label(), width.label());
            let mut rng = Rng::new(2);
            let samples = 5;
            let xs: Vec<f32> = (0..samples * 7).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let xq = packed.quantize_input(&xs);
            assert_eq!(
                plan.run_batch_q(&xq, samples),
                packed.run_batch_q(&xq, samples),
                "{width:?}"
            );
            assert_eq!(plan.param_bytes(), packed.param_bytes(), "{width:?}");
        }
    }

    #[test]
    fn row_ranges_reassemble_layers_bit_exactly() {
        let n = net(&[9, 11, 6], 31);
        let fixed = FixedNetwork::from_float(&n, 1.0).unwrap();
        let plan = ExecPlan::compile(&fixed);
        let mut rng = Rng::new(7);
        let samples = 4;
        let xs: Vec<f32> = (0..samples * 9).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let xq = fixed.quantize_input(&xs);
        // Whole layer 0 via one call vs stitched from ragged ranges.
        let (n_in, n_out) = plan.layer_dims(0);
        let src = &xq[..samples * n_in];
        let mut whole = vec![0i32; n_out * samples];
        plan.run_layer_rows_q(0, src, samples, 0..n_out, &mut whole);
        for workers in 1..=8 {
            let mut stitched = vec![0i32; n_out * samples];
            for (r0, r1) in plan.partition_rows(0, workers) {
                let rr = r1 - r0;
                let mut part = vec![0i32; rr * samples];
                plan.run_layer_rows_q(0, src, samples, r0..r1, &mut part);
                for s in 0..samples {
                    stitched[s * n_out + r0..s * n_out + r1]
                        .copy_from_slice(&part[s * rr..(s + 1) * rr]);
                }
            }
            assert_eq!(stitched, whole, "workers={workers}");
        }
    }

    #[test]
    fn partition_rows_is_panel_aligned_for_packed() {
        let n = net(&[5, 11, 3], 17);
        let (_, packed) = from_float_packed(&n, 1.0, PackedWidth::Q7).unwrap();
        let plan = ExecPlan::compile(&packed);
        for workers in 1..=8 {
            let ranges = plan.partition_rows(0, workers);
            let mut next = 0;
            for &(r0, r1) in &ranges {
                assert_eq!(r0, next);
                assert_eq!(r0 % ROWS_PER_PANEL, 0, "workers={workers}");
                assert!(r1 > r0);
                next = r1;
            }
            assert_eq!(next, 11);
        }
        // A single-panel layer never splits below one panel.
        assert_eq!(plan.partition_rows(1, 8).len(), 1);
    }

    #[test]
    fn arena_is_contiguous_in_traversal_order() {
        let n = net(&[4, 6, 5, 2], 1);
        let fixed = FixedNetwork::from_float(&n, 1.0).unwrap();
        let plan = ExecPlan::compile(&fixed);
        let mut expect_off = 0;
        for li in 0..plan.num_layers() {
            let l = &plan.layers[li];
            assert_eq!(l.w_off, expect_off);
            assert_eq!(l.b_off, l.w_off + l.w_len);
            expect_off = l.b_off + l.n_out;
        }
        assert_eq!(plan.param_bytes(), 4 * expect_off);
    }

    #[test]
    fn plan_scratch_is_one_flat_buffer() {
        let mut s = PlanScratch::new();
        let (a, b) = s.halves_q(16);
        a[0] = 1;
        b[15] = 2;
        assert_eq!(a.len(), 16);
        assert_eq!(b.len(), 16);
        // Both halves come from one allocation: growing for a smaller
        // request is a no-op.
        let cap = s.q.capacity();
        let _ = s.halves_q(8);
        assert_eq!(s.q.capacity(), cap);
    }
}
