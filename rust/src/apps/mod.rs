//! Application showcases (Sec. VI): topology registry mirroring
//! `python/compile/topologies.py` plus the end-to-end pipeline
//! train → (quantize) → plan → simulate used by Table II and the
//! examples. [`biglittle`] models the Sec. IV-B dual-domain scenario,
//! [`energy`] the InfiniWolf energy-autonomy budget (Sec. III-C).

pub mod biglittle;
pub mod energy;
pub mod paper;

use anyhow::Result;

use crate::datasets;
use crate::deploy::{self, DeploymentPlan, NetShape};
use crate::fann::train::{accuracy, rprop::Rprop, rprop::RpropConfig};
use crate::fann::{Activation, FixedNetwork, Network, TrainData};
use crate::simulator::{self, CostOptions, Executable, SimReport};
use crate::targets::{DataType, Target};
use crate::util::rng::Rng;

/// Topology + training metadata of one registered application
/// (mirrors `python/compile/topologies.py`; parity pinned by tests).
#[derive(Debug, Clone)]
pub struct AppSpec {
    /// CLI name (`gesture`, `fall`, `activity`).
    pub name: &'static str,
    /// Human-readable title (paper Sec. VI).
    pub title: &'static str,
    /// Layer sizes `[in, hidden..., out]`.
    pub sizes: &'static [usize],
    /// Paper-reported accuracy for the showcase (fraction).
    pub paper_accuracy: f32,
    /// iRPROP- epoch budget.
    pub max_epochs: usize,
    /// Early-stop MSE threshold.
    pub desired_error: f32,
}

/// Application A — hand-gesture recognition (Colli-Alfaro et al. [47]).
pub const GESTURE: AppSpec = AppSpec {
    name: "gesture",
    title: "Hand gesture recognition (app A)",
    sizes: &[76, 300, 200, 100, 10],
    paper_accuracy: 0.8558,
    max_epochs: 80,
    desired_error: 0.005,
};

/// Application B — fall detection for elderly people (Howcroft et al. [48]).
pub const FALL: AppSpec = AppSpec {
    name: "fall",
    title: "Fall detection (app B)",
    sizes: &[117, 20, 2],
    paper_accuracy: 0.84,
    max_epochs: 200,
    desired_error: 0.01,
};

/// Application C — human activity classification (Gaikwad et al. [46]).
pub const ACTIVITY: AppSpec = AppSpec {
    name: "activity",
    title: "Human activity classification (app C)",
    sizes: &[7, 6, 5],
    paper_accuracy: 0.946,
    max_epochs: 300,
    desired_error: 0.01,
};

/// The example profiling network of Sec. V-A.
pub const EXAMPLE: AppSpec = AppSpec {
    name: "example",
    title: "Sec. V-A profiling network",
    sizes: &[5, 100, 100, 3],
    paper_accuracy: 0.0,
    max_epochs: 0,
    desired_error: 0.0,
};

/// The registered Sec. VI showcases, in Table II order.
pub const ALL_APPS: [&AppSpec; 3] = [&GESTURE, &FALL, &ACTIVITY];

impl AppSpec {
    /// Synthesize this app's dataset (deterministic per seed).
    pub fn dataset(&self, seed: u64) -> TrainData {
        match self.name {
            "gesture" => datasets::gesture(seed),
            "fall" => datasets::fall(seed),
            "activity" => datasets::activity(seed),
            other => panic!("no dataset for app {other:?}"),
        }
    }

    /// Shape-only view for the deployment planner.
    pub fn shape(&self) -> NetShape {
        NetShape::new(self.sizes)
    }

    /// Multiply-accumulates per classification.
    pub fn macs(&self) -> usize {
        self.shape().macs()
    }
}

/// A trained, quantized, deployable application.
pub struct TrainedApp {
    /// The showcase recipe this app was trained from.
    pub spec: &'static AppSpec,
    /// The trained float network.
    pub net: Network,
    /// Quantized form for FPU-less targets.
    pub fixed: FixedNetwork,
    /// Accuracy on the training split.
    pub train_accuracy: f32,
    /// Accuracy on the held-out split.
    pub test_accuracy: f32,
    /// Per-epoch MSE of the training run.
    pub mse_curve: Vec<f32>,
}

/// Train an application showcase with iRPROP− on its synthetic dataset
/// (80/20 split), then quantize. Deterministic per seed.
pub fn train_app(spec: &'static AppSpec, seed: u64) -> Result<TrainedApp> {
    let mut data = spec.dataset(seed);
    data.normalize_inputs();
    let (train, test) = data.split(0.8);

    let mut rng = Rng::new(seed ^ 0xAB);
    let mut net = Network::new(spec.sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);

    let mut trainer = Rprop::new(&net, RpropConfig::default());
    let mse_curve = trainer.train_until(&mut net, &train, spec.max_epochs, spec.desired_error);

    let train_accuracy = accuracy(&net, &train);
    let test_accuracy = accuracy(&net, &test);
    let fixed = FixedNetwork::from_float(&net, 1.0)?;

    Ok(TrainedApp {
        spec,
        net,
        fixed,
        train_accuracy,
        test_accuracy,
        mse_curve,
    })
}

/// Deployment plan + executable for `app` on `target`, following the
/// paper's convention: float path on FPU targets, fixed elsewhere.
/// Shared by [`run_on_target`] and [`classify_stream`] so the dtype
/// selection can never diverge between the two.
pub fn plan_for_target<'a>(
    app: &'a TrainedApp,
    target: Target,
) -> Result<(DeploymentPlan, Executable<'a>)> {
    let dtype = if target.supports_float() {
        DataType::Float32
    } else {
        DataType::Fixed
    };
    let plan = deploy::plan(&app.spec.shape(), target, dtype)?;
    let exe = match dtype {
        DataType::Float32 => Executable::Float(&app.net),
        DataType::Fixed => Executable::Fixed(&app.fixed),
    };
    Ok((plan, exe))
}

/// One Table II cell: deploy `app` on `target` and simulate one
/// classification.
pub fn run_on_target(app: &TrainedApp, target: Target, input: &[f32]) -> Result<(DeploymentPlan, SimReport)> {
    let (plan, exe) = plan_for_target(app, target)?;
    let report = simulator::simulate(&plan, &exe, input, CostOptions::default())?;
    Ok((plan, report))
}

/// Classify a stream of `n_samples` packed sensor windows on `target`
/// under ONE deployment: one plan, one (modeled) cluster activation paid
/// for the whole stream, batched kernel execution for the numerics —
/// the paper's continuous-classification operating mode, as opposed to
/// looping [`run_on_target`] per window. Returns per-window argmax
/// predictions plus the batch report.
pub fn classify_stream(
    app: &TrainedApp,
    target: Target,
    inputs: &[f32],
    n_samples: usize,
) -> Result<(Vec<usize>, simulator::BatchSimReport)> {
    let mut scratch = simulator::ExecScratch::new();
    classify_stream_with(app, target, inputs, n_samples, &mut scratch)
}

/// [`classify_stream`] with a caller-owned [`simulator::ExecScratch`]:
/// a long-running classification service calls this per window batch
/// with one persistent arena, so the steady state allocates only the
/// per-batch report buffers. (For hosting many models behind
/// request-level adaptive micro-batching — rather than pre-batched
/// windows of one app — see [`crate::service::InferenceService`] and
/// the `service load` harness.)
pub fn classify_stream_with(
    app: &TrainedApp,
    target: Target,
    inputs: &[f32],
    n_samples: usize,
    scratch: &mut simulator::ExecScratch,
) -> Result<(Vec<usize>, simulator::BatchSimReport)> {
    let (plan, exe) = plan_for_target(app, target)?;
    let n_out = exe.num_outputs();
    let report =
        simulator::simulate_batch_with(&plan, &exe, inputs, n_samples, CostOptions::default(), scratch)?;
    let preds = report.outputs.chunks(n_out).map(crate::util::argmax).collect();
    Ok((preds, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_python_topologies() {
        // Mirrors python/compile/topologies.py (pinned by the manifest
        // parity integration test as well).
        assert_eq!(GESTURE.macs(), 103_800);
        assert_eq!(FALL.sizes, &[117, 20, 2]);
        assert_eq!(ACTIVITY.sizes, &[7, 6, 5]);
        assert_eq!(EXAMPLE.sizes, &[5, 100, 100, 3]);
    }

    #[test]
    fn activity_trains_to_paper_accuracy_band() {
        let app = train_app(&ACTIVITY, 7).unwrap();
        assert!(
            app.test_accuracy > 0.88,
            "test accuracy {} (paper: 94.6%)",
            app.test_accuracy
        );
        // MSE decreased over training.
        assert!(app.mse_curve.last().unwrap() < app.mse_curve.first().unwrap());
    }

    #[test]
    fn fall_trains_to_paper_accuracy_band() {
        let app = train_app(&FALL, 7).unwrap();
        assert!(
            (0.70..=1.0).contains(&app.test_accuracy),
            "test accuracy {} (paper: 84%)",
            app.test_accuracy
        );
    }

    #[test]
    fn classify_stream_matches_per_window_runs() {
        let app = train_app(&ACTIVITY, 3).unwrap();
        let data = ACTIVITY.dataset(3);
        let n = 12;
        let mut xs = Vec::with_capacity(n * 7);
        for i in 0..n {
            xs.extend_from_slice(data.input(i));
        }
        for target in [Target::WolfFc, Target::WolfCluster { cores: 8 }] {
            let (preds, report) = classify_stream(&app, target, &xs, n).unwrap();
            assert_eq!(preds.len(), n);
            for i in 0..n {
                let (_, r) = run_on_target(&app, target, data.input(i)).unwrap();
                assert_eq!(
                    preds[i],
                    crate::util::argmax(&r.outputs),
                    "target {:?} window {i}",
                    target
                );
            }
            // One activation for the stream beats n single end-to-end runs.
            assert!(report.total_seconds < n as f64 * report.per_sample.e2e_seconds + 1e-12);
        }
    }

    #[test]
    fn run_on_target_uses_fixed_on_fpu_less() {
        let app = train_app(&ACTIVITY, 3).unwrap();
        let x = vec![0.1f32; 7];
        let (plan, _) = run_on_target(&app, Target::WolfFc, &x).unwrap();
        assert_eq!(plan.dtype, DataType::Fixed);
        let (plan, _) = run_on_target(&app, Target::WolfCluster { cores: 8 }, &x).unwrap();
        assert_eq!(plan.dtype, DataType::Float32);
    }
}
