//! The paper-reproduction application pipelines: the three
//! wearable-bracelet case studies behind the paper's headline claims
//! (few-µs latency, few-mW power, the octa-core-vs-M4 speedup and
//! energy-reduction numbers), each run end to end —
//! train (iRPROP−) → quantize → pack → plan → emit → emulate.
//!
//! This module owns the *host* half of the pipeline: synthesizing the
//! dataset ([`crate::datasets::wearable`]), training the float MLP,
//! choosing the deployed representation (packed q7 where the weights
//! fit and accuracy holds, widening to q15/q32 otherwise) and measuring
//! float/quantized accuracy. The *target* half — per-MCU emission,
//! emulation and the assembled `PAPER_RESULTS.json` — lives in
//! [`crate::bench::paper`], which `paper reproduce` drives.

use anyhow::{Context, Result};

use crate::codegen::NetRepr;
use crate::datasets::wearable;
use crate::fann::train::{accuracy, rprop::Rprop, rprop::RpropConfig};
use crate::fann::{
    from_float_packed, Activation, FixedNetwork, Network, PackedNetwork, TrainData,
};
use crate::kernels::PackedWidth;
use crate::util::rng::Rng;

/// Inputs are min/max-normalized to `[-1, 1]` before training, so the
/// fixed-point overflow analysis bounds them by 1.0 — the same constant
/// the emit pipeline passes to `codegen::emit_float`.
pub const PAPER_MAX_ABS_INPUT: f32 = 1.0;

/// Topology + training recipe of one paper-reproduction case study.
///
/// Deliberately separate from [`crate::apps::AppSpec`] (the Sec. VI
/// showcases): this registry drives a different pipeline — quick/full
/// dataset sizing, accuracy-guarded representation selection and the
/// `paper reproduce` sweep — whose knobs (`epochs(quick)`,
/// `accuracy_floor` as a reported expectation rather than a paper
/// quote) do not fit the showcase type. Shared behavior stays shared:
/// both delegate prediction to [`crate::util::predict_class`] and
/// shape math to the same layer-size convention.
#[derive(Debug, Clone)]
pub struct PaperAppSpec {
    /// CLI name (`emg`, `ecg`, `eeg`).
    pub name: &'static str,
    /// Human-readable title used in reports.
    pub title: &'static str,
    /// Layer sizes `[in, hidden..., out]`.
    pub sizes: &'static [usize],
    /// iRPROP− epoch budget of the full (non-quick) pipeline.
    pub max_epochs: usize,
    /// Early-stop MSE threshold.
    pub desired_error: f32,
    /// Test accuracy the full pipeline is expected to reach (reported,
    /// not enforced — `PaperPipeline::meets_floor` records the outcome).
    pub accuracy_floor: f32,
}

/// Case study A — 8-channel surface-EMG hand-gesture classification
/// (the bracelet's 192-100-4 MLP).
pub const EMG: PaperAppSpec = PaperAppSpec {
    name: "emg",
    title: "EMG hand-gesture classification (8ch)",
    sizes: &[192, 100, 4],
    max_epochs: 60,
    desired_error: 0.005,
    accuracy_floor: 0.85,
};

/// Case study B — single-lead ECG heartbeat/arrhythmia detection.
pub const ECG: PaperAppSpec = PaperAppSpec {
    name: "ecg",
    title: "ECG heartbeat/arrhythmia detection",
    sizes: &[64, 32, 3],
    max_epochs: 80,
    desired_error: 0.005,
    accuracy_floor: 0.9,
};

/// Case study C — EEG/BMI-style binary movement-intention detector.
pub const EEG: PaperAppSpec = PaperAppSpec {
    name: "eeg",
    title: "EEG/BMI movement-intention detection",
    sizes: &[16, 20, 1],
    max_epochs: 80,
    desired_error: 0.01,
    accuracy_floor: 0.8,
};

/// The three case studies `paper reproduce` runs, in report order.
pub const PAPER_APPS: [&PaperAppSpec; 3] = [&EMG, &ECG, &EEG];

/// Look a paper app up by CLI name.
pub fn paper_app_by_name(name: &str) -> Result<&'static PaperAppSpec> {
    PAPER_APPS
        .iter()
        .find(|a| a.name == name)
        .copied()
        .with_context(|| format!("unknown paper app {name:?} (known: emg, ecg, eeg)"))
}

impl PaperAppSpec {
    /// Synthesize this app's dataset. `quick` shrinks the per-class
    /// sample count for CI smoke runs; topology and generator shape
    /// are unchanged, so modeled latency/memory/energy depend only on
    /// the representation `choose_repr` lands on (recorded as `repr`
    /// in the results) — at the same representation, quick and full
    /// runs model identically and only the achieved accuracy differs.
    pub fn dataset(&self, seed: u64, quick: bool) -> TrainData {
        match (self.name, quick) {
            ("emg", false) => wearable::emg(seed),
            ("emg", true) => wearable::emg_sized(seed, 40),
            ("ecg", false) => wearable::ecg(seed),
            ("ecg", true) => wearable::ecg_sized(seed, 60),
            ("eeg", false) => wearable::eeg(seed),
            ("eeg", true) => wearable::eeg_sized(seed, 80),
            (other, _) => panic!("no dataset for paper app {other:?}"),
        }
    }

    /// Epoch budget (`quick` caps it for smoke runs).
    pub fn epochs(&self, quick: bool) -> usize {
        if quick {
            self.max_epochs.min(15)
        } else {
            self.max_epochs
        }
    }

    /// Multiply-accumulates per classification.
    pub fn macs(&self) -> usize {
        self.sizes.windows(2).map(|w| w[0] * w[1]).sum()
    }
}

/// The host half of one reproduced case study: the trained float
/// network, its quantized/packed deployment forms at the chosen
/// representation, accuracy on the held-out split, and the test set the
/// target sweep probes with.
pub struct PaperPipeline {
    /// The case-study recipe this pipeline ran.
    pub spec: &'static PaperAppSpec,
    /// The trained float network.
    pub net: Network,
    /// Wide Q(dec) form at the *deployed* decimal point (the packed
    /// representation's reference; bit-exact vs `packed`).
    pub fixed: FixedNetwork,
    /// Panel-packed form when `repr` is q7/q15 (`None` for q32).
    pub packed: Option<PackedNetwork>,
    /// The representation the target sweep deploys (q7 preferred).
    pub repr: NetRepr,
    /// Q-format decimal point of the deployed representation.
    pub decimal_point: u32,
    /// Float-path accuracy on the training split.
    pub train_accuracy: f32,
    /// Float-path accuracy on the held-out split.
    pub test_accuracy: f32,
    /// Accuracy of the deployed (quantized) representation on the
    /// held-out split — the number the paper quotes per case study.
    pub quantized_test_accuracy: f32,
    /// Whether `quantized_test_accuracy` reached the spec's floor.
    pub meets_floor: bool,
    /// Per-epoch MSE curve of the iRPROP− run.
    pub mse_curve: Vec<f32>,
    /// Held-out split (normalized), used as emulation probes.
    pub test: TrainData,
}

/// Classification accuracy of a quantized network over a dataset
/// (the shared [`crate::util::predict_class`] rule).
pub fn fixed_accuracy(fixed: &FixedNetwork, data: &TrainData) -> f32 {
    let mut correct = 0usize;
    for i in 0..data.len() {
        if crate::util::predict_class(&fixed.run(data.input(i))) == data.label(i) {
            correct += 1;
        }
    }
    correct as f32 / data.len() as f32
}

/// Pick the deployed representation: the narrowest packed width whose
/// quantized accuracy stays within 5 points of the float path (q7, then
/// q15), falling back to wide q32. Returns the chosen representation
/// with its fixed/packed forms and the quantized held-out accuracy.
fn choose_repr(
    net: &Network,
    test: &TrainData,
    float_test_accuracy: f32,
) -> Result<(NetRepr, FixedNetwork, Option<PackedNetwork>, f32)> {
    for (repr, width) in [(NetRepr::Q7, PackedWidth::Q7), (NetRepr::Q15, PackedWidth::Q15)] {
        if let Ok((fixed, packed)) = from_float_packed(net, PAPER_MAX_ABS_INPUT, width) {
            let acc = fixed_accuracy(&fixed, test);
            if acc >= float_test_accuracy - 0.05 {
                return Ok((repr, fixed, Some(packed), acc));
            }
        }
    }
    let fixed = FixedNetwork::from_float(net, PAPER_MAX_ABS_INPUT)?;
    let acc = fixed_accuracy(&fixed, test);
    Ok((NetRepr::Q32, fixed, None, acc))
}

/// Run the host half of one case study: synthesize → normalize → split
/// 80/20 → train with iRPROP− → quantize at a packable decimal point →
/// pack. Deterministic per `(spec, seed, quick)`.
pub fn train_paper_app(
    spec: &'static PaperAppSpec,
    seed: u64,
    quick: bool,
) -> Result<PaperPipeline> {
    let mut data = spec.dataset(seed, quick);
    data.normalize_inputs();
    let (train, test) = data.split(0.8);

    let mut rng = Rng::new(seed ^ 0xA99);
    let mut net = Network::new(spec.sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);

    let mut trainer = Rprop::new(&net, RpropConfig::default());
    let mse_curve = trainer.train_until(&mut net, &train, spec.epochs(quick), spec.desired_error);

    let train_accuracy = accuracy(&net, &train);
    let test_accuracy = accuracy(&net, &test);
    let (repr, fixed, packed, quantized_test_accuracy) =
        choose_repr(&net, &test, test_accuracy)?;

    Ok(PaperPipeline {
        spec,
        decimal_point: fixed.decimal_point,
        net,
        fixed,
        packed,
        repr,
        train_accuracy,
        test_accuracy,
        quantized_test_accuracy,
        meets_floor: quantized_test_accuracy >= spec.accuracy_floor,
        mse_curve,
        test,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_shapes_match_issue() {
        assert_eq!(EMG.sizes, &[192, 100, 4]);
        assert_eq!(EMG.macs(), 192 * 100 + 100 * 4);
        assert_eq!(ECG.sizes.first(), Some(&wearable::ECG_WINDOW));
        assert_eq!(EEG.sizes.last(), Some(&1));
        assert!(paper_app_by_name("ecg").is_ok());
        assert!(paper_app_by_name("gait").is_err());
    }

    #[test]
    fn quick_pipeline_is_deterministic() {
        let a = train_paper_app(&EEG, 11, true).unwrap();
        let b = train_paper_app(&EEG, 11, true).unwrap();
        assert_eq!(a.mse_curve, b.mse_curve);
        assert_eq!(a.test_accuracy, b.test_accuracy);
        assert_eq!(a.repr.label(), b.repr.label());
        for (la, lb) in a.fixed.layers.iter().zip(&b.fixed.layers) {
            assert_eq!(la.weights, lb.weights);
        }
    }

    #[test]
    fn eeg_quick_trains_above_chance() {
        let p = train_paper_app(&EEG, 7, true).unwrap();
        assert!(
            p.test_accuracy > 0.6,
            "EEG quick test accuracy {} is at chance",
            p.test_accuracy
        );
        // Training reduced the MSE.
        assert!(p.mse_curve.last().unwrap() < p.mse_curve.first().unwrap());
    }

    #[test]
    fn packed_form_is_bit_exact_vs_fixed_reference() {
        let p = train_paper_app(&ECG, 7, true).unwrap();
        if let Some(packed) = &p.packed {
            for i in 0..8.min(p.test.len()) {
                let xq = p.fixed.quantize_input(p.test.input(i));
                assert_eq!(p.fixed.run_q(&xq), packed.run_q(&xq), "sample {i}");
            }
        }
    }
}
