//! The big-little deployment scenario of Sec. IV-B: "a small network is
//! used to detect the onset and, once the onset is detected, a deeper
//! network is used for classification [44]. The FC continuously reads
//! the sensory data and executes the onset detection algorithm, while
//! the cluster domain is activated once the onset is detected."
//!
//! The framework stores the little network in the FC's private L2 and
//! streams the big network into cluster L1 on demand — this module
//! models the full duty cycle and its energy, the configuration the
//! paper argues meets "the two main requirements in the IoT domain:
//! low power and low latency".

use anyhow::{ensure, Result};

use crate::deploy::{self, NetShape};
use crate::fann::{FixedNetwork, Network};
use crate::simulator::{self, CostOptions, Executable};
use crate::targets::{power, DataType, Region, Target};

/// A deployed big-little pair.
pub struct BigLittle<'a> {
    /// Little onset detector (fixed point, runs on the FC).
    pub little: &'a FixedNetwork,
    /// Big classifier (float, runs on the cluster).
    pub big: &'a Network,
    /// Deployment of the always-on little network.
    pub little_plan: deploy::DeploymentPlan,
    /// Deployment of the wake-up big network.
    pub big_plan: deploy::DeploymentPlan,
}

/// Energy/latency report of one duty cycle window.
#[derive(Debug, Clone)]
pub struct DutyCycleReport {
    /// Windows screened by the little network.
    pub windows: u64,
    /// Windows that triggered the big classifier.
    pub onsets: u64,
    /// Energy of the little tier over the window, in uJ.
    pub little_energy_uj: f64,
    /// Energy of the big tier over the window, in uJ.
    pub big_energy_uj: f64,
    /// Cluster activation overhead energy (paid once per onset burst).
    pub overhead_energy_uj: f64,
    /// Total dual-domain energy over the window, in uJ.
    pub total_energy_uj: f64,
    /// Energy had every window gone straight to the big classifier.
    pub always_big_energy_uj: f64,
}

impl DutyCycleReport {
    /// Energy saving of the big-little split vs always-on classification.
    pub fn saving(&self) -> f64 {
        1.0 - self.total_energy_uj / self.always_big_energy_uj
    }
}

impl<'a> BigLittle<'a> {
    /// Plan both deployments: little on the FC (must fit private L2 for
    /// the always-on path), big on the 8-core cluster.
    pub fn deploy(little: &'a FixedNetwork, big: &'a Network) -> Result<Self> {
        let little_plan = deploy::plan(&NetShape::from(little), Target::WolfFc, DataType::Fixed)?;
        ensure!(
            little_plan.region == Region::PrivateL2,
            "little network must fit the FC private L2 for always-on screening \
             (got {})",
            little_plan.region.name()
        );
        let big_plan = deploy::plan(
            &NetShape::from(big),
            Target::WolfCluster { cores: 8 },
            DataType::Float32,
        )?;
        ensure!(big_plan.fits(), "big network does not fit the cluster path");
        Ok(Self {
            little,
            big,
            little_plan,
            big_plan,
        })
    }

    /// Screen one window on the little network; returns (onset?, outputs).
    /// Onset = output 0 above `threshold`.
    pub fn screen(&self, window: &[f32], threshold: f32) -> Result<(bool, Vec<f32>)> {
        let r = simulator::simulate(
            &self.little_plan,
            &Executable::Fixed(self.little),
            window,
            CostOptions::default(),
        )?;
        Ok((r.outputs[0] >= threshold, r.outputs))
    }

    /// Classify one window on the big network (cluster).
    pub fn classify(&self, window: &[f32]) -> Result<Vec<f32>> {
        let r = simulator::simulate(
            &self.big_plan,
            &Executable::Float(self.big),
            window,
            CostOptions::default(),
        )?;
        Ok(r.outputs)
    }

    /// Model a monitoring period of `windows` sensor windows with an
    /// onset rate of `onset_rate` (fraction of windows that trigger the
    /// big classifier). Onsets are assumed isolated (one cluster
    /// activation each — worst case for the split).
    pub fn duty_cycle(&self, windows: u64, onset_rate: f64, probe: &[f32]) -> Result<DutyCycleReport> {
        let little = simulator::simulate(
            &self.little_plan,
            &Executable::Fixed(self.little),
            probe,
            CostOptions::default(),
        )?;
        // Any valid big-network input works for timing (numerics are
        // input-independent); reuse or pad the probe.
        let big_input = vec![0.1f32; self.big.num_inputs()];
        let big = simulator::simulate(
            &self.big_plan,
            &Executable::Float(self.big),
            &big_input,
            CostOptions::default(),
        )?;

        let onsets = (windows as f64 * onset_rate).round() as u64;
        let little_energy = little.energy_uj * windows as f64;
        let big_energy = big.energy_uj * onsets as f64;
        let overhead = power::energy_uj(
            self.big_plan.target.fixed_overhead_seconds(),
            self.big_plan.target.fixed_overhead_mw(),
        ) * onsets as f64;
        let always_big = (big.energy_uj + power::energy_uj(
            self.big_plan.target.fixed_overhead_seconds(),
            self.big_plan.target.fixed_overhead_mw(),
        )) * windows as f64;

        Ok(DutyCycleReport {
            windows,
            onsets,
            little_energy_uj: little_energy,
            big_energy_uj: big_energy,
            overhead_energy_uj: overhead,
            total_energy_uj: little_energy + big_energy + overhead,
            always_big_energy_uj: always_big,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::Activation;
    use crate::util::rng::Rng;

    fn nets() -> (FixedNetwork, Network) {
        let mut rng = Rng::new(31);
        // Little: 7-6-1 onset detector.
        let mut little_f =
            Network::new(&[7, 6, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        little_f.randomize(&mut rng, None);
        let little = FixedNetwork::from_float(&little_f, 1.0).unwrap();
        // Big: application-A-sized classifier.
        let mut big = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        big.randomize(&mut rng, None);
        (little, big)
    }

    #[test]
    fn deploys_little_on_fc_big_on_cluster() {
        let (little, big) = nets();
        let bl = BigLittle::deploy(&little, &big).unwrap();
        assert_eq!(bl.little_plan.region, Region::PrivateL2);
        assert_eq!(bl.big_plan.target, Target::WolfCluster { cores: 8 });
    }

    #[test]
    fn rare_onsets_save_energy() {
        let (little, big) = nets();
        let bl = BigLittle::deploy(&little, &big).unwrap();
        let probe = vec![0.1f32; 7];
        // 1% onset rate over 10k windows: big-little must win big.
        let r = bl.duty_cycle(10_000, 0.01, &probe).unwrap();
        assert!(r.saving() > 0.8, "saving {}", r.saving());
        assert_eq!(r.onsets, 100);
    }

    #[test]
    fn onset_rate_one_is_worse_than_always_big() {
        // At 100% onset rate the split pays the little net on top of
        // every big classification: no saving (slightly negative).
        let (little, big) = nets();
        let bl = BigLittle::deploy(&little, &big).unwrap();
        let probe = vec![0.1f32; 7];
        let r = bl.duty_cycle(100, 1.0, &probe).unwrap();
        assert!(r.saving() <= 0.0);
    }

    #[test]
    fn screening_and_classification_run() {
        let (little, big) = nets();
        let bl = BigLittle::deploy(&little, &big).unwrap();
        let (onset, outs) = bl.screen(&[0.2; 7], 0.5).unwrap();
        assert_eq!(outs.len(), 1);
        let _ = onset;
        let c = bl.classify(&vec![0.1; 76]).unwrap();
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn oversized_little_net_rejected() {
        let mut rng = Rng::new(32);
        // 200x300 fixed net exceeds 64 kB private L2.
        let mut big_little_f =
            Network::new(&[200, 300, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        big_little_f.randomize(&mut rng, None);
        let too_big = FixedNetwork::from_float(&big_little_f, 1.0).unwrap();
        let (_, big) = nets();
        assert!(BigLittle::deploy(&too_big, &big).is_err());
    }
}
