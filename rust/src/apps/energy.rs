//! Energy-autonomy analysis for the InfiniWolf wearable (Sec. III-C):
//! the dual-source harvester (solar + TEG) collects ≈ 21.44 J/day in the
//! paper's worst-case indoor scenario; "the energy acquired needs to
//! balance the energy consumed during the classification and the power
//! consumption for the sleep mode".
//!
//! This module answers the design question the paper poses: *how many
//! classifications per day can each deployment sustain on harvested
//! energy alone?*

use crate::simulator::SimReport;
use crate::targets::{power, Target};

/// Paper's worst-case daily harvest (6 h challenging indoor conditions).
pub const HARVEST_J_PER_DAY: f64 = 21.44;

const SECONDS_PER_DAY: f64 = 86_400.0;

/// Sustainable classification budget of one deployment.
#[derive(Debug, Clone)]
pub struct AutonomyReport {
    /// Daily sleep-mode energy in J (always spent).
    pub sleep_j: f64,
    /// Energy of one classification in J (incl. amortized cluster
    /// overhead at the given burst size).
    pub per_classification_j: f64,
    /// Classifications/day sustainable from the harvest budget.
    pub classifications_per_day: f64,
    /// Equivalent classification rate in Hz.
    pub rate_hz: f64,
}

/// Compute the autonomy budget for a simulated deployment.
///
/// `burst` is the number of classifications per cluster activation
/// (1 = worst case; large = continuous operation), `sleep_mw` the
/// platform's sleep power.
pub fn autonomy(
    report: &SimReport,
    target: Target,
    burst: u64,
    sleep_mw: f64,
    harvest_j_per_day: f64,
) -> AutonomyReport {
    let sleep_j = sleep_mw * 1e-3 * SECONDS_PER_DAY;
    let per_class_j = report.amortized_energy_uj(target, burst) * 1e-6;
    let available = (harvest_j_per_day - sleep_j).max(0.0);
    let per_day = if per_class_j > 0.0 {
        available / per_class_j
    } else {
        0.0
    };
    AutonomyReport {
        sleep_j,
        per_classification_j: per_class_j,
        classifications_per_day: per_day,
        rate_hz: per_day / SECONDS_PER_DAY,
    }
}

/// Default sleep power of the InfiniWolf platform (both SoCs in deep
/// sleep with RTC + fuel gauge alive).
pub fn platform_sleep_mw(target: Target) -> f64 {
    match target {
        Target::CortexM4(_) | Target::CortexM0(_) => power::NRF52832_M4.sleep_mw,
        Target::CortexM7(_) => power::STM32F769_M7.sleep_mw,
        Target::WolfFc | Target::WolfCluster { .. } => power::WOLF_FC.sleep_mw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{self, NetShape};
    use crate::fann::{Activation, Network};
    use crate::simulator::{self, CostOptions, Executable};
    use crate::targets::{Chip, DataType};
    use crate::util::rng::Rng;

    fn app_a_report(target: Target) -> (SimReport, Target) {
        let mut rng = Rng::new(61);
        let mut net = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        net.randomize(&mut rng, None);
        let plan = deploy::plan(&NetShape::from(&net), target, DataType::Float32).unwrap();
        let x = vec![0.1f32; 76];
        (
            simulator::simulate(&plan, &Executable::Float(&net), &x, CostOptions::default())
                .unwrap(),
            target,
        )
    }

    #[test]
    fn harvest_sustains_continuous_wolf_but_fewer_on_m4() {
        let (m4, t_m4) = app_a_report(Target::CortexM4(Chip::Nrf52832));
        let (wolf, t_wolf) = app_a_report(Target::WolfCluster { cores: 8 });
        let a_m4 = autonomy(&m4, t_m4, 1, platform_sleep_mw(t_m4), HARVEST_J_PER_DAY);
        let a_wolf = autonomy(&wolf, t_wolf, 100, platform_sleep_mw(t_wolf), HARVEST_J_PER_DAY);
        // Both sustain >0; the Wolf cluster sustains strictly more.
        assert!(a_m4.classifications_per_day > 10_000.0);
        assert!(a_wolf.classifications_per_day > a_m4.classifications_per_day);
        // Paper's design point: ~0.5-5 Hz continuous classification is
        // within the harvested budget on the parallel implementation.
        assert!(a_wolf.rate_hz > 1.0, "rate {}", a_wolf.rate_hz);
    }

    #[test]
    fn sleep_power_is_charged_against_harvest() {
        let (wolf, t) = app_a_report(Target::WolfCluster { cores: 8 });
        let lo = autonomy(&wolf, t, 100, 0.001, HARVEST_J_PER_DAY);
        let hi = autonomy(&wolf, t, 100, 0.1, HARVEST_J_PER_DAY);
        assert!(hi.sleep_j > lo.sleep_j);
        assert!(hi.classifications_per_day < lo.classifications_per_day);
    }

    #[test]
    fn burst_amortization_increases_budget() {
        let (wolf, t) = app_a_report(Target::WolfCluster { cores: 8 });
        let single = autonomy(&wolf, t, 1, 0.01, HARVEST_J_PER_DAY);
        let burst = autonomy(&wolf, t, 1000, 0.01, HARVEST_J_PER_DAY);
        assert!(burst.classifications_per_day > single.classifications_per_day * 1.2);
    }

    #[test]
    fn zero_harvest_means_zero_budget() {
        let (wolf, t) = app_a_report(Target::WolfCluster { cores: 8 });
        let a = autonomy(&wolf, t, 1, 1.0, 0.0);
        assert_eq!(a.classifications_per_day, 0.0);
    }
}
