//! Deterministic xorshift64* PRNG.
//!
//! Used for weight init, synthetic dataset generation and property tests.
//! Deterministic across platforms so that every experiment in
//! EXPERIMENTS.md is exactly reproducible from its seed.

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double mantissa.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// N(mu, sigma) as f32.
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (stable derivation, used to give each
    /// dataset/class its own stream).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
