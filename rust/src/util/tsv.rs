//! Parser for the parity-vector TSV files emitted by `python/compile/aot.py`.
//!
//! Format (one record per line, tab-separated):
//!
//! ```text
//! case<TAB><topology-name>
//! dec<TAB><decimal-point>              (fixed-point file only)
//! acts<TAB><hidden-act><TAB><output-act>
//! w0<TAB><rows>x<cols><TAB><v v v ...>
//! b0<TAB><len><TAB><v v v ...>
//! ...
//! x<TAB><batch>x<in><TAB>...
//! out<TAB><batch>x<out><TAB>...
//! ```

use anyhow::{bail, Context, Result};

/// One named tensor: shape (1-D or 2-D) + flat values.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Tensor shape (1-D or 2-D).
    pub shape: Vec<usize>,
    /// Flat values, row-major.
    pub values: Vec<f64>,
}

impl Tensor {
    /// Element count (product of the shape).
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Values narrowed to f32.
    pub fn as_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32).collect()
    }

    /// Values truncated to i32.
    pub fn as_i32(&self) -> Vec<i32> {
        self.values.iter().map(|&v| v as i32).collect()
    }

    /// Values truncated to i64.
    pub fn as_i64(&self) -> Vec<i64> {
        self.values.iter().map(|&v| v as i64).collect()
    }
}

/// One parity case: a topology's tensors keyed by tag.
#[derive(Debug, Clone, Default)]
pub struct ParityCase {
    /// Topology name of the case.
    pub name: String,
    /// Decimal point for fixed-point cases.
    pub dec: Option<u32>,
    /// Hidden activation name.
    pub hidden_act: String,
    /// Output activation name.
    pub output_act: String,
    /// Named tensors, in file order.
    pub tensors: Vec<(String, Tensor)>,
}

impl ParityCase {
    /// The tensor tagged `tag`, if present.
    pub fn tensor(&self, tag: &str) -> Option<&Tensor> {
        self.tensors
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, v)| v)
    }

    /// Number of (w_i, b_i) layer pairs present.
    pub fn num_layers(&self) -> usize {
        self.tensors
            .iter()
            .filter(|(t, _)| t.starts_with('w'))
            .count()
    }
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad shape dim"))
        .collect()
}

/// Parse a full parity TSV file into its cases.
pub fn parse_parity(text: &str) -> Result<Vec<ParityCase>> {
    let mut cases: Vec<ParityCase> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        match parts[0] {
            "case" => {
                if parts.len() != 2 {
                    bail!("line {}: malformed case record", lineno + 1);
                }
                cases.push(ParityCase {
                    name: parts[1].to_string(),
                    ..Default::default()
                });
            }
            "dec" => {
                let case = cases.last_mut().context("dec before case")?;
                case.dec = Some(parts[1].parse()?);
            }
            "acts" => {
                let case = cases.last_mut().context("acts before case")?;
                if parts.len() != 3 {
                    bail!("line {}: malformed acts record", lineno + 1);
                }
                case.hidden_act = parts[1].to_string();
                case.output_act = parts[2].to_string();
            }
            tag => {
                let case = cases.last_mut().context("tensor before case")?;
                if parts.len() != 3 {
                    bail!("line {}: malformed tensor record", lineno + 1);
                }
                let shape = parse_shape(parts[1])?;
                let values: Vec<f64> = parts[2]
                    .split(' ')
                    .map(|v| v.parse::<f64>().context("bad value"))
                    .collect::<Result<_>>()?;
                let n: usize = shape.iter().product();
                if values.len() != n {
                    bail!(
                        "line {}: tensor {tag} shape {:?} wants {n} values, got {}",
                        lineno + 1,
                        shape,
                        values.len()
                    );
                }
                case.tensors.push((tag.to_string(), Tensor { shape, values }));
            }
        }
    }
    Ok(cases)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "case\txor\nacts\ttanh\tsigmoid\nw0\t2x3\t1 2 3 4 5 6\nb0\t3\t0.5 0.5 0.5\nx\t1x2\t1 0\nout\t1x3\t0.1 0.2 0.3\n";

    #[test]
    fn parses_sample() {
        let cases = parse_parity(SAMPLE).unwrap();
        assert_eq!(cases.len(), 1);
        let c = &cases[0];
        assert_eq!(c.name, "xor");
        assert_eq!(c.hidden_act, "tanh");
        assert_eq!(c.num_layers(), 1);
        let w = c.tensor("w0").unwrap();
        assert_eq!(w.shape, vec![2, 3]);
        assert_eq!(w.values[5], 6.0);
    }

    #[test]
    fn rejects_bad_count() {
        let bad = "case\tt\nw0\t2x2\t1 2 3\n";
        assert!(parse_parity(bad).is_err());
    }

    #[test]
    fn dec_record_parsed() {
        let s = "case\tt\ndec\t12\nacts\ttanh\tsigmoid\n";
        let cases = parse_parity(s).unwrap();
        assert_eq!(cases[0].dec, Some(12));
    }
}
