//! Minimal JSON value tree + serializer (the offline crate set has no
//! `serde`), used by the `bench json` perf-tracking harness. Output is
//! deterministic: object keys keep insertion order, floats render with
//! `{}` (shortest round-trip representation), non-finite floats render
//! as `null` (JSON has no NaN/Inf).

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// Integers stay exact (no float round-trip).
    Int(i64),
    /// Floating-point number (non-finite renders as `null`).
    Num(f64),
    /// String value.
    Str(String),
    /// Array of values.
    Arr(Vec<Json>),
    /// Object as insertion-ordered `(key, value)` pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object builder for insertion-ordered keys.
    pub fn obj() -> ObjBuilder {
        ObjBuilder(Vec::new())
    }

    /// Serialize with 2-space indentation — the form committed as a CI
    /// artifact, so diffs between runs stay readable.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, s: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Int(v) => {
                let _ = write!(s, "{v}");
            }
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(s, "{v}");
                } else {
                    s.push_str("null");
                }
            }
            Json::Str(v) => write_escaped(s, v),
            Json::Arr(items) => write_seq(s, indent, depth, '[', ']', items.len(), |s, i| {
                items[i].write(s, indent, depth + 1)
            }),
            Json::Obj(pairs) => write_seq(s, indent, depth, '{', '}', pairs.len(), |s, i| {
                write_escaped(s, &pairs[i].0);
                s.push(':');
                if indent.is_some() {
                    s.push(' ');
                }
                pairs[i].1.write(s, indent, depth + 1);
            }),
        }
    }
}

/// Compact (whitespace-free) serialization via `Display`/`to_string`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

fn write_seq(
    s: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize),
) {
    s.push(open);
    for i in 0..len {
        if i > 0 {
            s.push(',');
        }
        if let Some(w) = indent {
            s.push('\n');
            s.push_str(&" ".repeat(w * (depth + 1)));
        }
        item(s, i);
    }
    if len > 0 {
        if let Some(w) = indent {
            s.push('\n');
            s.push_str(&" ".repeat(w * depth));
        }
    }
    s.push(close);
}

fn write_escaped(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(s, "\\u{:04x}", c as u32);
            }
            c => s.push(c),
        }
    }
    s.push('"');
}

/// Insertion-ordered object builder: `Json::obj().field("k", 1).build()`.
pub struct ObjBuilder(Vec<(String, Json)>);

impl ObjBuilder {
    /// Append one key/value pair (chainable).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Self {
        self.0.push((key.to_string(), value.into()));
        self
    }

    /// Finish the object.
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj()
            .field("name", "packed_q7")
            .field("n", 3usize)
            .field("x", 1.5f64)
            .field("ok", true)
            .build();
        assert_eq!(
            j.to_string(),
            r#"{"name":"packed_q7","n":3,"x":1.5,"ok":true}"#
        );
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::Arr(vec![Json::Int(1), Json::obj().field("a", Json::Null).build()]);
        assert_eq!(j.to_string(), r#"[1,{"a":null}]"#);
    }

    #[test]
    fn escapes_and_nonfinite() {
        let j = Json::obj()
            .field("s", "a\"b\\c\nd")
            .field("nan", f64::NAN)
            .build();
        assert_eq!(j.to_string(), r#"{"s":"a\"b\\c\nd","nan":null}"#);
    }

    #[test]
    fn pretty_is_indented_and_reparseable_shape() {
        let j = Json::obj()
            .field("rows", Json::Arr(vec![Json::Int(1), Json::Int(2)]))
            .build();
        let p = j.to_pretty();
        assert!(p.contains("\n  \"rows\": [\n    1,\n    2\n  ]\n}"));
        assert!(p.ends_with("}\n"));
    }

    #[test]
    fn whole_floats_render_as_valid_json() {
        // `{}` on 2.0 prints "2" — integral, still valid JSON.
        assert_eq!(Json::Num(2.0).to_string(), "2");
        assert_eq!(Json::Int(-7).to_string(), "-7");
    }
}
