//! Minimal property-based testing driver (the offline crate set has no
//! `proptest`/`quickcheck`).
//!
//! A property is a closure from a seeded [`Rng`] to `Result<(), String>`.
//! The driver runs it for `cases` derived seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```
//! use fann_on_mcu::util::proptest::check;
//! check("addition commutes", 256, |rng| {
//!     let a = rng.range_f32(-1e3, 1e3);
//!     let b = rng.range_f32(-1e3, 1e3);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Base seed for derived case seeds; changing it reshuffles all property
/// test inputs (it is deliberately fixed for reproducibility).
pub const BASE_SEED: u64 = 0xFA99_05EC_0DE5_16ED;

/// Run `prop` for `cases` deterministic cases; panic with the failing seed
/// and message on the first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = BASE_SEED ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 32, |rng| {
            let x = rng.uniform();
            ensure((0.0..1.0).contains(&x), "out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn check_reports_failures() {
        check("failing", 8, |rng| {
            ensure(rng.uniform() < 0.0, "always fails")
        });
    }
}
