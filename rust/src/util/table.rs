//! ASCII table / series printer used by the bench harness so every bench
//! binary emits the same paper-shaped rows (no `criterion` offline).

/// Simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (chainable).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a cycle count with thousands separators (readability of the
/// Fig. 8/11 dumps).
pub fn fmt_cycles(c: u64) -> String {
    let s = c.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

/// Format seconds with an auto-scaled unit (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

/// Format joules with an auto-scaled unit (nJ/µJ/mJ/J).
pub fn fmt_energy(j: f64) -> String {
    if j < 1e-6 {
        format!("{:.2} nJ", j * 1e9)
    } else if j < 1e-3 {
        format!("{:.3} µJ", j * 1e6)
    } else if j < 1.0 {
        format!("{:.3} mJ", j * 1e3)
    } else {
        format!("{j:.3} J")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bbbb"]);
        t.row(vec!["1", "2"]).row(vec!["333", "4"]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a "));
        assert!(lines[2].starts_with("1 "));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn cycle_formatting() {
        assert_eq!(fmt_cycles(1_234_567), "1,234,567");
        assert_eq!(fmt_cycles(999), "999");
    }

    #[test]
    fn time_units() {
        assert_eq!(fmt_time(0.0176), "17.600 ms");
        assert!(fmt_time(2.5e-7).ends_with("ns"));
        assert!(fmt_time(3.2).ends_with("s"));
    }

    #[test]
    fn energy_units() {
        assert!(fmt_energy(183.74e-6).contains("µJ"));
        assert!(fmt_energy(0.05).contains("mJ"));
    }
}
