//! Small shared utilities: PRNG, property-test driver, TSV parsing, table
//! printing, JSON emission. Hand-rolled because the offline crate set has
//! no `rand`, `proptest`, `criterion` or `serde`.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod tsv;

/// Relative/absolute closeness check used across numeric tests.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(&x, &y)| (x - y).abs() <= atol + rtol * y.abs().max(x.abs()))
}

/// Maximum absolute difference between two slices (∞-norm distance).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// The one classification rule of the toolkit: argmax for multi-output
/// networks, 0.5 threshold for single-sigmoid-output binary detectors.
/// Shared by the float/quantized accuracy metrics and the
/// paper-reproduction parity checks so the rule cannot diverge.
pub fn predict_class(outputs: &[f32]) -> usize {
    if outputs.len() == 1 {
        usize::from(outputs[0] >= 0.5)
    } else {
        argmax(outputs)
    }
}

/// Index of the maximum element (classification argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_basic() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }
}
