//! Seeded, deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a pure description of *which* faults fire *when*:
//! every decision is a deterministic function of (plan, model,
//! sequence number), so a chaos run is exactly reproducible from its
//! seed — the same executions panic, the same batches get latency
//! spikes, the same requests carry poisoned inputs, and the dispatcher
//! dies at the same loop iterations. Nothing here touches a clock or
//! an RNG stream at decision time; randomness is a hash of the seed.
//!
//! The plan is consulted from three places:
//!
//! * [`InferenceService`](super::InferenceService) execution — per
//!   model, per execution attempt: [`FaultPlan::should_panic`] (the
//!   injected kernel panic that panic isolation must contain) and
//!   [`FaultPlan::spike_for`] (an injected slow batch).
//! * The dispatcher loop — per iteration:
//!   [`FaultPlan::should_kill_dispatcher`] panics the dispatcher
//!   *outside* any batch scope, exercising the watchdog respawn path
//!   without ever holding un-replied requests. In a sharded service the
//!   kills target the shard hosting [`FaultPlan::panic_model`] (shard 0
//!   when no panic model is set), so each listed iteration still kills
//!   exactly one dispatcher and the other shards' watchdog counters
//!   stay untouched.
//! * The chaos load generator — per request:
//!   [`FaultPlan::poison_input`] decides which submitted samples carry
//!   a NaN, which the submit-time input validation must reject.

use std::time::Duration;

/// A deterministic seeded schedule of injected faults. See the
/// [module docs](self) for where each knob is consulted.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed for the probabilistic faults (spikes, poisoned inputs).
    pub seed: u64,
    /// The model whose executions fail during the panic window.
    pub panic_model: String,
    /// Injected-panic window over `panic_model`'s execution-attempt
    /// sequence numbers: attempts in `[panic_from, panic_until)`
    /// panic. Probe executions advance the sequence too, so a
    /// quarantined model's failed probes walk it toward the window's
    /// end — and recovery.
    pub panic_from: u64,
    /// Exclusive end of the panic window.
    pub panic_until: u64,
    /// Probability that a batch execution gets an injected latency
    /// spike. Applies to every model unless
    /// [`spike_model`](Self::spike_model) narrows it.
    pub spike_prob: f64,
    /// Duration of one injected latency spike.
    pub spike: Duration,
    /// When non-empty, only this model's batches are eligible for
    /// injected spikes — the targeted "one hot, slow model" used by the
    /// head-of-line scenario. Empty (the default) spikes any model.
    pub spike_model: String,
    /// Probability that a chaos load-generator request carries a
    /// NaN-poisoned input (only meaningful for f32 models — Q models
    /// quantize at submit).
    pub nan_prob: f64,
    /// Dispatcher loop iterations at which an injected panic kills the
    /// dispatcher (the watchdog must fail pending requests and
    /// respawn it). Iteration numbers are global across respawns, so
    /// each listed iteration kills at most once.
    pub kill_at_iters: Vec<u64>,
}

impl Default for FaultPlan {
    /// A plan that injects nothing (empty panic window, zero
    /// probabilities, no kills) — useful as a base for `..` updates.
    fn default() -> Self {
        Self {
            seed: 0,
            panic_model: String::new(),
            panic_from: 0,
            panic_until: 0,
            spike_prob: 0.0,
            spike: Duration::ZERO,
            spike_model: String::new(),
            nan_prob: 0.0,
            kill_at_iters: Vec::new(),
        }
    }
}

/// splitmix64 finalizer — a cheap, well-mixed hash for fault decisions
/// (also reused by the load harness's shed-retry jitter, so backed-off
/// clients never share a jitter stream).
pub(super) fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over the model id, so per-model fault streams differ (also
/// the static model → shard hash in [`super::ShardPolicy`]).
pub(super) fn model_tag(model: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in model.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Map a hash to `[0, 1)` for probability thresholds.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// Whether `model`'s execution attempt number `seq` must panic.
    pub fn should_panic(&self, model: &str, seq: u64) -> bool {
        model == self.panic_model && seq >= self.panic_from && seq < self.panic_until
    }

    /// The injected latency spike for `model`'s execution attempt
    /// `seq`, if the seeded coin says so (and `model` matches
    /// [`spike_model`](Self::spike_model) when one is set).
    pub fn spike_for(&self, model: &str, seq: u64) -> Option<Duration> {
        if self.spike_prob <= 0.0 || self.spike.is_zero() {
            return None;
        }
        if !self.spike_model.is_empty() && model != self.spike_model {
            return None;
        }
        let h = mix(self.seed ^ model_tag(model) ^ seq.wrapping_mul(0x2545_F491_4F6C_DD1D));
        (unit(h) < self.spike_prob).then_some(self.spike)
    }

    /// Whether dispatcher loop iteration `iter` must panic (outside
    /// any batch scope — no request is ever held across this panic).
    pub fn should_kill_dispatcher(&self, iter: u64) -> bool {
        self.kill_at_iters.contains(&iter)
    }

    /// Whether the chaos load generator poisons client `client`'s
    /// request number `req` with a NaN input.
    pub fn poison_input(&self, client: u64, req: u64) -> bool {
        if self.nan_prob <= 0.0 {
            return false;
        }
        let h = mix(self.seed ^ client.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ req.rotate_left(17));
        unit(h) < self.nan_prob
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            seed: 42,
            panic_model: "emg-q7".to_string(),
            panic_from: 10,
            panic_until: 20,
            spike_prob: 0.25,
            spike: Duration::from_micros(100),
            nan_prob: 0.1,
            kill_at_iters: vec![3, 7],
            ..FaultPlan::default()
        }
    }

    #[test]
    fn spike_model_filter_narrows_spikes_to_one_model() {
        let p = FaultPlan {
            spike_prob: 1.0,
            spike: Duration::from_micros(100),
            spike_model: "hot".to_string(),
            ..FaultPlan::default()
        };
        assert!((0..32).all(|s| p.spike_for("hot", s).is_some()));
        assert!((0..32).all(|s| p.spike_for("cold", s).is_none()));
        // Empty filter keeps the old any-model behavior.
        let p = FaultPlan { spike_model: String::new(), ..p };
        assert!(p.spike_for("cold", 0).is_some());
    }

    #[test]
    fn panic_window_is_half_open_and_model_scoped() {
        let p = plan();
        assert!(!p.should_panic("emg-q7", 9));
        assert!(p.should_panic("emg-q7", 10));
        assert!(p.should_panic("emg-q7", 19));
        assert!(!p.should_panic("emg-q7", 20));
        assert!(!p.should_panic("ecg-q32", 15));
    }

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        let p = plan();
        let spikes: Vec<bool> = (0..64).map(|s| p.spike_for("m", s).is_some()).collect();
        assert_eq!(
            spikes,
            (0..64).map(|s| p.spike_for("m", s).is_some()).collect::<Vec<_>>(),
            "same plan, same decisions"
        );
        assert!(spikes.iter().any(|&b| b), "spike_prob 0.25 over 64 attempts fires");
        assert!(!spikes.iter().all(|&b| b), "...but not always");
        let reseeded = FaultPlan { seed: 43, ..plan() };
        let other: Vec<bool> = (0..64).map(|s| reseeded.spike_for("m", s).is_some()).collect();
        assert_ne!(spikes, other, "different seed, different stream");

        let poisons: Vec<bool> = (0..256).map(|r| p.poison_input(5, r)).collect();
        assert!(poisons.iter().any(|&b| b));
        assert!(!poisons.iter().all(|&b| b));
    }

    #[test]
    fn default_plan_injects_nothing() {
        let p = FaultPlan::default();
        assert!(!p.should_panic("", 0));
        assert!(p.spike_for("m", 0).is_none());
        assert!(!p.should_kill_dispatcher(0));
        assert!(!p.poison_input(0, 0));
        let p = plan();
        assert!(p.should_kill_dispatcher(3) && p.should_kill_dispatcher(7));
        assert!(!p.should_kill_dispatcher(4));
    }
}
