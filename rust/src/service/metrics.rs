//! Per-model / per-tenant service counters and the log-bucketed
//! latency histogram behind the p50/p99 fields of `BENCH_service.json`.
//!
//! Everything here is plain data guarded by the host's one metrics
//! mutex — no atomics to reason about, and a [`MetricsSnapshot`] is a
//! straight clone, so a snapshot is always internally consistent.
//! `BTreeMap`s keep iteration (and therefore every report and JSON
//! artifact) deterministically ordered.

use std::collections::BTreeMap;

use super::queue::FlushReason;

/// Latency histogram over geometric (~25% growth) microsecond buckets,
/// 1 µs up to > 60 s. Percentiles come back as the matched bucket's
/// upper bound, so a reported p99 is within one bucket (≤ 25%) of the
/// exact order statistic — plenty for a throughput harness, at O(1)
/// record cost and a fixed small footprint per model.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Inclusive upper bound of each bucket, strictly increasing.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram with the standard bucket ladder.
    pub fn new() -> Self {
        let mut bounds = Vec::with_capacity(96);
        let mut b: u64 = 1;
        while b < 60_000_000 {
            bounds.push(b);
            // ≥ +1 guarantees strict growth at the small end, ~+25%
            // beyond it.
            b = (b + b / 4).max(b + 1);
        }
        bounds.push(u64::MAX);
        let counts = vec![0; bounds.len()];
        Self { bounds, counts, total: 0 }
    }

    /// Record one latency observation in microseconds.
    pub fn record(&mut self, us: u64) {
        let idx = self.bounds.partition_point(|&b| b < us);
        self.counts[idx.min(self.counts.len() - 1)] += 1;
        self.total += 1;
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// The `p`-quantile (`0.0 < p ≤ 1.0`) as the upper bound of the
    /// bucket holding that order statistic; `0` when empty.
    ///
    /// Nearest-rank semantics: the target order statistic is
    /// `ceil(p * total)`, clamped into `1..=total` — so `p99` of 100
    /// samples is the 99th smallest, and `percentile(1.0)` is the
    /// maximum.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        // `p * total` can land a hair *above* the exact integer rank
        // (0.99 × 100 = 99.000000000000002 in f64), and a bare `ceil`
        // then overshoots by a whole rank — p99 of 100 samples became
        // the maximum. Shave one part in 10^12 before ceiling so
        // near-integer products round to the intended rank while
        // genuinely fractional ones still ceil up.
        let raw = p * self.total as f64;
        let target = ((raw * (1.0 - 1e-12)).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.bounds[i];
            }
        }
        // Invariant: the bucket ladder is built non-empty at
        // construction and never shrinks.
        *self.bounds.last().expect("non-empty ladder")
    }

    /// Median latency (µs).
    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    /// 99th-percentile latency (µs).
    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    /// Fold another histogram into this one (same standard ladder) —
    /// used to aggregate per-model latency into the service headline.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        debug_assert_eq!(self.bounds.len(), other.bounds.len());
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
    }
}

/// Counters for one model id.
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    /// Requests accepted into the queue (excludes shed).
    pub requests: u64,
    /// Requests executed successfully and replied to.
    pub completed: u64,
    /// Requests rejected because the bounded queue was at capacity.
    pub shed: u64,
    /// Requests answered [`super::InferError::ExecFailed`] — their
    /// batch panicked during execution (caught at the batch boundary).
    pub failed: u64,
    /// Requests answered [`super::InferError::Timeout`] — stale past
    /// the [`super::BatchPolicy::request_budget`] when their batch was
    /// taken.
    pub timeouts: u64,
    /// Requests answered [`super::InferError::Aborted`] — failed
    /// without execution across a dispatcher restart or teardown.
    pub aborted: u64,
    /// Batch executions that panicked (each fails a whole batch; the
    /// per-request count is [`failed`](Self::failed)).
    pub exec_failures: u64,
    /// Submits fast-rejected because the model was quarantined.
    pub rejected_quarantined: u64,
    /// Times the circuit breaker tripped this model into quarantine
    /// (including a failed half-open probe re-tripping it).
    pub quarantine_trips: u64,
    /// Half-open probe requests admitted after a quarantine cooldown.
    pub quarantine_probes: u64,
    /// Times the model recovered (a successful execution closed the
    /// breaker from quarantine/half-open).
    pub quarantine_recoveries: u64,
    /// Coalesced batches executed.
    pub batches: u64,
    /// Samples executed across those batches (= `completed`).
    pub batched_samples: u64,
    /// Batches released by the size trigger.
    pub size_flushes: u64,
    /// Batches released by the deadline trigger (partial batches).
    pub deadline_flushes: u64,
    /// Batches released by explicit drain (shutdown / manual flush).
    pub drain_flushes: u64,
    /// Batches of size 1 (requests that rode alone).
    pub solo_batches: u64,
    /// Largest coalesced batch executed.
    pub max_batch_seen: usize,
    /// Queue depth after the most recent queue transition.
    pub queue_depth: usize,
    /// Highest queue depth observed.
    pub peak_queue_depth: usize,
    /// Request latency (enqueue → reply) distribution.
    pub latency: LatencyHistogram,
}

impl ModelMetrics {
    /// Mean coalesced batch size — the micro-batching win (`1.0` means
    /// no coalescing happened). `0` batches yields `0.0`.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_samples as f64 / self.batches as f64
        }
    }

    /// Fraction of completed requests that shared their batch with at
    /// least one other request (a batch of size 1 contributes exactly
    /// one unbatched sample). `0.0` until something completes.
    pub fn batched_ratio(&self) -> f64 {
        if self.batched_samples == 0 {
            0.0
        } else {
            1.0 - self.solo_batches as f64 / self.batched_samples as f64
        }
    }

    pub(crate) fn note_flush(&mut self, reason: FlushReason, batch_size: usize) {
        self.batches += 1;
        self.batched_samples += batch_size as u64;
        self.completed += batch_size as u64;
        if batch_size == 1 {
            self.solo_batches += 1;
        }
        self.max_batch_seen = self.max_batch_seen.max(batch_size);
        match reason {
            FlushReason::Size => self.size_flushes += 1,
            FlushReason::Deadline => self.deadline_flushes += 1,
            FlushReason::Drain => self.drain_flushes += 1,
        }
    }

    pub(crate) fn note_depth(&mut self, depth: usize) {
        self.queue_depth = depth;
        self.peak_queue_depth = self.peak_queue_depth.max(depth);
    }

    /// Fold in a push-time peak observed by the queue itself
    /// ([`super::MicroBatchQueue::peak_depth`]). The gauge samples
    /// depth at submit/execute transitions, which can miss a peak that
    /// rises and drains between two samples — the queue's own counter
    /// cannot.
    pub(crate) fn note_peak(&mut self, peak: usize) {
        self.peak_queue_depth = self.peak_queue_depth.max(peak);
    }
}

/// Counters for one tenant (client) id, across all models.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantCounters {
    /// Requests accepted from this tenant.
    pub requests: u64,
    /// Requests executed successfully and replied to.
    pub completed: u64,
    /// Requests shed back to this tenant.
    pub shed: u64,
    /// Accepted requests answered with a terminal [`super::InferError`]
    /// (exec failure, timeout, or abort).
    pub failed: u64,
}

/// Per-dispatcher-shard counters, derived at snapshot time: model
/// counters rolled up by the model → shard assignment, plus the
/// shard's own watchdog/heartbeat atomics. One row per shard, in shard
/// order, even for shards currently serving no models.
#[derive(Debug, Clone, Default)]
pub struct ShardMetrics {
    /// The shard index (`0..shards`).
    pub shard: usize,
    /// Model ids assigned to this shard (sorted — BTreeMap order).
    pub models: Vec<String>,
    /// Requests accepted across this shard's models.
    pub requests: u64,
    /// Requests completed across this shard's models.
    pub completed: u64,
    /// Requests shed across this shard's models.
    pub shed: u64,
    /// Terminal-error replies (exec failures + timeouts + aborts)
    /// across this shard's models.
    pub failed: u64,
    /// Coalesced batches executed on this shard.
    pub batches: u64,
    /// Samples executed across those batches.
    pub batched_samples: u64,
    /// Times this shard's watchdog respawned its dead dispatcher.
    pub restarts: u64,
    /// This shard's dispatcher loop iterations.
    pub heartbeats: u64,
}

impl ShardMetrics {
    /// Mean coalesced batch size on this shard (`0.0` with no batches).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_samples as f64 / self.batches as f64
        }
    }
}

/// Socket-level counters from the wire front-end
/// ([`super::wire::WireServer`]). Kept as plain data here — the server
/// owns the live atomics and folds a consistent copy into the
/// snapshot it hands back ([`super::wire::WireServer::shutdown_all`]);
/// services running without a wire front-end report all zeros.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireCounters {
    /// Connections accepted (UDS + TCP).
    pub connections_opened: u64,
    /// Connections fully torn down (reader, forwarder, and writer all
    /// exited). Equals `connections_opened` once the server is idle or
    /// shut down.
    pub connections_closed: u64,
    /// Well-formed request frames decoded.
    pub frames_rx: u64,
    /// Response frames written to peers.
    pub frames_tx: u64,
    /// Frames rejected at the codec layer (bad magic/version/kind/
    /// dtype/tag, payload mismatch, oversized length prefix).
    pub bad_frames: u64,
    /// Bytes read off sockets (length prefixes + bodies).
    pub bytes_rx: u64,
    /// Bytes written to sockets.
    pub bytes_tx: u64,
}

/// A consistent copy of every counter the service keeps, taken under
/// the one metrics lock. Doubles as the service's internal store.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Per-model counters, keyed by model id.
    pub models: BTreeMap<String, ModelMetrics>,
    /// Per-tenant counters, keyed by tenant id.
    pub tenants: BTreeMap<u64, TenantCounters>,
    /// Per-shard rollups (one row per dispatcher shard, in shard
    /// order), filled at snapshot time from the model rows and each
    /// shard's own atomics. Empty only inside the internal store —
    /// [`super::InferenceService::metrics`] always populates it.
    pub shards: Vec<ShardMetrics>,
    /// Models removed by TTL idle eviction
    /// ([`super::InferenceService::evict_idle`]).
    pub models_evicted: u64,
    /// Times a watchdog respawned a dead dispatcher, summed across
    /// shards (started mode).
    pub watchdog_restarts: u64,
    /// Dispatcher loop iterations observed, summed across shards — the
    /// heartbeat the watchdog layer surfaces (monotonically increasing
    /// while dispatchers are alive; manual-mode services never beat).
    pub dispatcher_heartbeats: u64,
    /// Socket-level counters when a [`super::wire::WireServer`] fronts
    /// this service (all zeros otherwise — the in-process `submit`
    /// path never touches a socket).
    pub wire: WireCounters,
}

impl MetricsSnapshot {
    /// Requests accepted across all models.
    pub fn total_requests(&self) -> u64 {
        self.models.values().map(|m| m.requests).sum()
    }

    /// Requests executed successfully and replied to across all models.
    pub fn total_completed(&self) -> u64 {
        self.models.values().map(|m| m.completed).sum()
    }

    /// Requests shed across all models.
    pub fn total_shed(&self) -> u64 {
        self.models.values().map(|m| m.shed).sum()
    }

    /// Accepted requests answered with a terminal error across all
    /// models (exec failures + timeouts + aborts). Together with
    /// [`total_completed`](Self::total_completed) this accounts for
    /// every terminal reply: `requests = completed + failed + still
    /// queued`.
    pub fn total_failed(&self) -> u64 {
        self.models.values().map(|m| m.failed + m.timeouts + m.aborted).sum()
    }

    /// Quarantine trips across all models.
    pub fn total_quarantine_trips(&self) -> u64 {
        self.models.values().map(|m| m.quarantine_trips).sum()
    }

    /// Quarantine recoveries across all models.
    pub fn total_quarantine_recoveries(&self) -> u64 {
        self.models.values().map(|m| m.quarantine_recoveries).sum()
    }

    /// Mean coalesced batch size across all models.
    pub fn mean_batch(&self) -> f64 {
        let batches: u64 = self.models.values().map(|m| m.batches).sum();
        let samples: u64 = self.models.values().map(|m| m.batched_samples).sum();
        if batches == 0 {
            0.0
        } else {
            samples as f64 / batches as f64
        }
    }

    /// All models' latency histograms folded into one.
    pub fn merged_latency(&self) -> LatencyHistogram {
        let mut h = LatencyHistogram::new();
        for m in self.models.values() {
            h.merge(&m.latency);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_bracket_the_order_statistic() {
        let mut h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(us);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        let p99 = h.p99();
        // Bucket bounds grow ≤ 25%, so the reported quantile sits in
        // [exact, exact * 1.25].
        assert!((500..=625).contains(&p50), "p50 {p50}");
        assert!((990..=1238).contains(&p99), "p99 {p99}");
        assert!(p99 >= p50);
    }

    #[test]
    fn histogram_handles_extremes_and_empty() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile(0.99), 0);
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.p99() >= 60_000_000);
        assert_eq!(h.p50(), 1); // the 0-µs sample lands in the first bucket
    }

    #[test]
    fn percentile_uses_nearest_rank_for_tiny_totals() {
        // total = 1: every quantile is that one sample's bucket.
        let mut h = LatencyHistogram::new();
        h.record(7);
        for p in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile(p), 7, "p={p}");
        }

        // total = 2: p50 is the *first* order statistic
        // (ceil(0.5 × 2) = 1), p99 and p100 the second.
        let mut h = LatencyHistogram::new();
        h.record(1);
        h.record(10_000_000);
        assert_eq!(h.p50(), 1);
        assert!(h.p99() >= 10_000_000);
        assert!(h.percentile(1.0) >= 10_000_000);
    }

    #[test]
    fn p99_of_100_samples_is_the_99th_not_the_100th() {
        // 99 fast samples and one huge outlier. Nearest rank says p99
        // is the 99th smallest — fast. The old code computed
        // ceil(0.99 × 100) on a float product a hair above 99, landed
        // on rank 100, and reported the outlier.
        let mut h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(10);
        }
        h.record(10_000_000);
        assert_eq!(h.count(), 100);
        assert!(h.p99() <= 12, "p99 {} must be the fast bucket", h.p99());
        assert!(h.percentile(1.0) >= 10_000_000, "max still sees the outlier");
    }

    #[test]
    fn note_peak_raises_the_peak_without_touching_the_gauge() {
        let mut m = ModelMetrics::default();
        m.note_depth(3);
        m.note_peak(9); // push-time peak the gauge sampling missed
        assert_eq!(m.queue_depth, 3);
        assert_eq!(m.peak_queue_depth, 9);
        m.note_peak(4); // never lowers
        assert_eq!(m.peak_queue_depth, 9);
    }

    #[test]
    fn shard_rollup_mean_batch_handles_empty_shards() {
        let mut s = ShardMetrics { shard: 2, ..ShardMetrics::default() };
        assert_eq!(s.mean_batch(), 0.0);
        s.batches = 4;
        s.batched_samples = 10;
        assert!((s.mean_batch() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record(100);
            b.record(10_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert_eq!(a.p50(), a.percentile(0.5));
        assert!(a.p99() >= 10_000);
    }

    #[test]
    fn model_metrics_flush_accounting() {
        let mut m = ModelMetrics::default();
        m.note_flush(FlushReason::Size, 8);
        m.note_flush(FlushReason::Deadline, 3);
        m.note_flush(FlushReason::Drain, 1);
        assert_eq!(m.batches, 3);
        assert_eq!(m.batched_samples, 12);
        assert_eq!(m.completed, 12);
        assert_eq!(m.max_batch_seen, 8);
        assert_eq!((m.size_flushes, m.deadline_flushes, m.drain_flushes), (1, 1, 1));
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
        assert!(m.batched_ratio() > 0.0);
    }

    #[test]
    fn failure_counters_aggregate_into_total_failed() {
        let mut s = MetricsSnapshot::default();
        let a = s.models.entry("a".into()).or_default();
        a.requests = 10;
        a.failed = 3;
        a.timeouts = 2;
        a.exec_failures = 1;
        a.quarantine_trips = 1;
        let b = s.models.entry("b".into()).or_default();
        b.aborted = 4;
        b.quarantine_recoveries = 1;
        assert_eq!(s.total_failed(), 9);
        assert_eq!(s.total_quarantine_trips(), 1);
        assert_eq!(s.total_quarantine_recoveries(), 1);
        assert_eq!(s.total_completed(), 0);
    }

    #[test]
    fn snapshot_aggregates_across_models() {
        let mut s = MetricsSnapshot::default();
        let a = s.models.entry("a".into()).or_default();
        a.requests = 10;
        a.note_flush(FlushReason::Size, 10);
        a.latency.record(50);
        let b = s.models.entry("b".into()).or_default();
        b.requests = 4;
        b.shed = 2;
        b.note_flush(FlushReason::Deadline, 4);
        b.latency.record(5000);
        assert_eq!(s.total_requests(), 14);
        assert_eq!(s.total_completed(), 14);
        assert_eq!(s.total_shed(), 2);
        assert!((s.mean_batch() - 7.0).abs() < 1e-9);
        assert_eq!(s.merged_latency().count(), 2);
    }
}
