//! Socket front-end for the inference service: a [`WireServer`] that
//! accepts Unix-domain-socket and TCP connections speaking the
//! length-prefixed frame protocol of [`super::frame`], and a blocking
//! [`WireClient`] used by the load/chaos harnesses and the integration
//! tests.
//!
//! The server adds *no* scheduling of its own — every decoded request
//! is handed to [`InferenceService::submit`], so batching, sharding,
//! quarantine, and watchdog semantics are inherited from the host
//! layer, not reimplemented. Per connection there are three threads:
//!
//! * **reader** — owns the socket's read half; reads one
//!   length-prefixed frame at a time (partial reads are fine — a
//!   byte-at-a-time peer still parses), enforces the frame-size cap
//!   from the 4-byte prefix *before* buffering a body, enforces the
//!   per-connection in-flight window, and submits. Synchronous
//!   rejections ([`SubmitError`]) become immediate `Shed` /
//!   `Quarantined` / `BadFrame` response frames.
//! * **forwarder** — drains the connection's reply channel from the
//!   service, maps service tickets back to wire request ids, and
//!   encodes terminal response frames.
//! * **writer** — the only thread that writes the socket; serializes
//!   all response frames through one bounded channel so a stalled peer
//!   (write backpressure) blocks the pipeline into the socket's send
//!   buffer instead of growing server memory, until the write deadline
//!   closes the connection.
//!
//! Lock and lifecycle invariants (pinned by `rust/tests/wire.rs`):
//!
//! * The ticket→request-id map's mutex is held *across* submit+insert,
//!   so the forwarder can never observe a ticket before its mapping —
//!   the service never takes wire locks, so no cycle exists.
//! * Every accepted request id gets **at most one** terminal frame:
//!   the mapping is removed on first reply, and the host guarantees
//!   exactly one terminal [`Reply`] per ticket.
//! * A malformed frame gets a `BadFrame` response, then the connection
//!   stops reading — but already-submitted requests still receive
//!   their terminal frames before the socket closes.
//! * [`WireServer::shutdown`] stops accepting, half-closes every
//!   connection's read side, then fails all pending requests so every
//!   in-flight request is answered `Aborted` before the sockets close.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::frame::{
    self, FrameError, RequestFrame, ResponseBody, ResponseFrame, DEFAULT_MAX_FRAME, LEN_PREFIX,
};
use super::host::{lock_recover, InferenceService, Reply};
use super::metrics::{MetricsSnapshot, WireCounters};
use super::{InferError, SubmitError};

/// Per-connection limits and deadlines for a [`WireServer`].
#[derive(Debug, Clone)]
pub struct WireConfig {
    /// Maximum frame body size a peer may declare; a larger length
    /// prefix (e.g. `u32::MAX`) is rejected from the prefix alone,
    /// without allocating.
    pub max_frame: usize,
    /// Maximum requests a single connection may have in flight
    /// (submitted, no terminal reply yet); further requests are
    /// answered `Shed` without entering the service.
    pub max_in_flight: usize,
    /// Read deadline: a connection idle (mid-frame or between frames)
    /// longer than this is closed. `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Write deadline: a peer that stops reading responses for this
    /// long has its connection closed (bounding server memory).
    pub write_timeout: Option<Duration>,
}

impl Default for WireConfig {
    fn default() -> Self {
        Self {
            max_frame: DEFAULT_MAX_FRAME,
            max_in_flight: 256,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(10)),
        }
    }
}

impl WireConfig {
    /// The config with its invariants enforced (`max_in_flight ≥ 1`,
    /// `max_frame` large enough for any header + tag).
    pub fn normalized(&self) -> Self {
        Self {
            max_frame: self.max_frame.max(frame::REQUEST_HEADER + frame::MAX_TAG),
            max_in_flight: self.max_in_flight.max(1),
            read_timeout: self.read_timeout,
            write_timeout: self.write_timeout,
        }
    }
}

/// A wire-layer failure: transport IO or frame decoding.
#[derive(Debug)]
pub enum WireError {
    /// The underlying socket failed (includes deadline expiry).
    Io(io::Error),
    /// The peer sent bytes that do not decode as a frame.
    Frame(FrameError),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io error: {e}"),
            WireError::Frame(e) => write!(f, "wire frame error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

impl From<FrameError> for WireError {
    fn from(e: FrameError) -> Self {
        WireError::Frame(e)
    }
}

/// A process-unique Unix-socket path under the system temp directory —
/// pid plus a monotonic counter, so parallel tests and harness runs
/// never collide.
pub fn temp_uds_path(tag: &str) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("fann-wire-{}-{tag}-{n}.sock", std::process::id()))
}

/// One accepted transport, UDS or TCP, behind a uniform blocking
/// `Read`/`Write` face.
enum WireStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl WireStream {
    fn try_clone(&self) -> io::Result<WireStream> {
        Ok(match self {
            WireStream::Tcp(s) => WireStream::Tcp(s.try_clone()?),
            WireStream::Uds(s) => WireStream::Uds(s.try_clone()?),
        })
    }

    fn shutdown(&self, how: Shutdown) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.shutdown(how),
            WireStream::Uds(s) => s.shutdown(how),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_read_timeout(t),
            WireStream::Uds(s) => s.set_read_timeout(t),
        }
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_write_timeout(t),
            WireStream::Uds(s) => s.set_write_timeout(t),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_nonblocking(nb),
            WireStream::Uds(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.read(buf),
            WireStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.write(buf),
            WireStream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.flush(),
            WireStream::Uds(s) => s.flush(),
        }
    }
}

enum WireListener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

impl WireListener {
    fn accept(&self) -> io::Result<WireStream> {
        Ok(match self {
            WireListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                WireStream::Tcp(s)
            }
            WireListener::Uds(l) => {
                let (s, _) = l.accept()?;
                WireStream::Uds(s)
            }
        })
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            WireListener::Tcp(l) => l.set_nonblocking(nb),
            WireListener::Uds(l) => l.set_nonblocking(nb),
        }
    }
}

/// Atomic wire counters, snapshotted into
/// [`WireCounters`] for `MetricsSnapshot::wire`.
#[derive(Default)]
struct WireStats {
    connections_opened: AtomicU64,
    connections_closed: AtomicU64,
    frames_rx: AtomicU64,
    frames_tx: AtomicU64,
    bad_frames: AtomicU64,
    bytes_rx: AtomicU64,
    bytes_tx: AtomicU64,
}

impl WireStats {
    fn snapshot(&self) -> WireCounters {
        WireCounters {
            connections_opened: self.connections_opened.load(Ordering::Relaxed),
            connections_closed: self.connections_closed.load(Ordering::Relaxed),
            frames_rx: self.frames_rx.load(Ordering::Relaxed),
            frames_tx: self.frames_tx.load(Ordering::Relaxed),
            bad_frames: self.bad_frames.load(Ordering::Relaxed),
            bytes_rx: self.bytes_rx.load(Ordering::Relaxed),
            bytes_tx: self.bytes_tx.load(Ordering::Relaxed),
        }
    }
}

/// Ticket → wire request id for one connection's in-flight requests.
type Pending = Arc<Mutex<HashMap<u64, u64>>>;

struct ConnTable {
    next_id: u64,
    /// A shutdown handle (socket clone) per live connection.
    live: HashMap<u64, WireStream>,
    /// Join handles for every connection thread ever spawned.
    joins: Vec<JoinHandle<()>>,
}

struct Shared {
    cfg: WireConfig,
    stop: AtomicBool,
    stats: WireStats,
    conns: Mutex<ConnTable>,
}

/// The socket front-end: accept loops for any number of UDS/TCP
/// listeners, three threads per connection, and the wire counters.
///
/// Unix-domain sockets are first-class (the load and chaos harnesses
/// run over UDS); TCP shares every code path above the transport.
pub struct WireServer {
    shared: Arc<Shared>,
    svc: Arc<InferenceService>,
    accept_handles: Vec<JoinHandle<()>>,
    uds_paths: Vec<PathBuf>,
}

impl WireServer {
    /// A server front-ending `svc` with no listeners yet — add them
    /// with [`listen_uds`](Self::listen_uds) /
    /// [`listen_tcp`](Self::listen_tcp).
    pub fn start(svc: Arc<InferenceService>, cfg: &WireConfig) -> Self {
        Self {
            shared: Arc::new(Shared {
                cfg: cfg.normalized(),
                stop: AtomicBool::new(false),
                stats: WireStats::default(),
                conns: Mutex::new(ConnTable {
                    next_id: 0,
                    live: HashMap::new(),
                    joins: Vec::new(),
                }),
            }),
            svc,
            accept_handles: Vec::new(),
            uds_paths: Vec::new(),
        }
    }

    /// Bind and serve a Unix-domain socket at `path` (an existing
    /// socket file there is unlinked first; the file is unlinked again
    /// at shutdown).
    pub fn listen_uds(&mut self, path: &Path) -> io::Result<()> {
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)?;
        self.uds_paths.push(path.to_path_buf());
        self.spawn_accept(WireListener::Uds(listener));
        Ok(())
    }

    /// Bind and serve a TCP listener; returns the bound address (so
    /// `127.0.0.1:0` callers learn their ephemeral port).
    pub fn listen_tcp<A: ToSocketAddrs>(&mut self, addr: A) -> io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let bound = listener.local_addr()?;
        self.spawn_accept(WireListener::Tcp(listener));
        Ok(bound)
    }

    fn spawn_accept(&mut self, listener: WireListener) {
        // Non-blocking accept so the loop can observe the stop flag.
        let _ = listener.set_nonblocking(true);
        let shared = Arc::clone(&self.shared);
        let svc = Arc::clone(&self.svc);
        let idx = self.accept_handles.len();
        let handle = std::thread::Builder::new()
            .name(format!("wire-accept-{idx}"))
            .spawn(move || accept_loop(&shared, &svc, &listener))
            .expect("spawn wire accept thread");
        self.accept_handles.push(handle);
    }

    /// The service this server fronts.
    pub fn service(&self) -> &Arc<InferenceService> {
        &self.svc
    }

    /// A consistent copy of the wire counters.
    pub fn counters(&self) -> WireCounters {
        self.shared.stats.snapshot()
    }

    /// Live (accepted, not yet fully closed) connections right now.
    pub fn live_connections(&self) -> usize {
        lock_recover(&self.shared.conns).live.len()
    }

    /// Stop accepting, half-close every connection's read side, answer
    /// every in-flight request `Aborted`, and join all wire threads.
    /// Returns the service handle (still running) and the final wire
    /// counters.
    pub fn shutdown(mut self) -> (Arc<InferenceService>, WireCounters) {
        self.stop_wire();
        let counters = self.shared.stats.snapshot();
        (Arc::clone(&self.svc), counters)
    }

    /// [`shutdown`](Self::shutdown), then shut the service itself down
    /// and return its final snapshot with the wire counters folded in.
    ///
    /// # Panics
    /// If other `Arc` clones of the service are still held — the
    /// service teardown needs sole ownership.
    pub fn shutdown_all(mut self) -> MetricsSnapshot {
        self.stop_wire();
        let counters = self.shared.stats.snapshot();
        let WireServer { svc, .. } = self;
        let svc = match Arc::try_unwrap(svc) {
            Ok(svc) => svc,
            Err(_) => panic!("wire shutdown_all needs sole ownership of the service Arc"),
        };
        let mut snap = svc.shutdown();
        snap.wire = counters;
        snap
    }

    fn stop_wire(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for h in self.accept_handles.drain(..) {
            let _ = h.join();
        }
        {
            let table = lock_recover(&self.shared.conns);
            for stream in table.live.values() {
                // Readers unblock with EOF; writers keep draining so
                // in-flight requests still get their terminal frames.
                let _ = stream.shutdown(Shutdown::Read);
            }
        }
        // Everything still queued is answered `Aborted` now; the
        // forwarders turn those replies into frames before the writers
        // wind down.
        self.svc.fail_pending("wire server shutdown");
        loop {
            let joins = {
                let mut table = lock_recover(&self.shared.conns);
                std::mem::take(&mut table.joins)
            };
            if joins.is_empty() {
                break;
            }
            for j in joins {
                let _ = j.join();
            }
        }
        for p in &self.uds_paths {
            let _ = std::fs::remove_file(p);
        }
        self.uds_paths.clear();
    }
}

fn accept_loop(shared: &Arc<Shared>, svc: &Arc<InferenceService>, listener: &WireListener) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => spawn_connection(shared, svc, stream),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

fn spawn_connection(shared: &Arc<Shared>, svc: &Arc<InferenceService>, stream: WireStream) {
    // Accepted sockets must be blocking regardless of what they
    // inherited from the non-blocking listener.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(shared.cfg.read_timeout);
    let _ = stream.set_write_timeout(shared.cfg.write_timeout);
    let (read_half, shutdown_handle) = match (stream.try_clone(), stream.try_clone()) {
        (Ok(r), Ok(s)) => (r, s),
        _ => return, // clone failed: drop the connection before it counts
    };
    let write_half = stream;
    shared.stats.connections_opened.fetch_add(1, Ordering::Relaxed);

    let conn_id = {
        let mut table = lock_recover(&shared.conns);
        let id = table.next_id;
        table.next_id += 1;
        table.live.insert(id, shutdown_handle);
        id
    };

    let pending: Pending = Arc::new(Mutex::new(HashMap::new()));
    // Bounded: a peer that stops reading can only queue this many
    // frames server-side before the pipeline stalls into the socket
    // buffer and, past the write deadline, the connection dies.
    let (event_tx, event_rx) = mpsc::sync_channel::<ResponseFrame>(shared.cfg.max_in_flight + 32);
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();

    let mut joins = Vec::with_capacity(3);
    {
        let shared = Arc::clone(shared);
        let svc = Arc::clone(svc);
        let pending = Arc::clone(&pending);
        let event_tx = event_tx.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("wire-read-{conn_id}"))
                .spawn(move || reader_loop(&shared, &svc, read_half, &event_tx, &reply_tx, &pending))
                .expect("spawn wire reader"),
        );
    }
    {
        let pending = Arc::clone(&pending);
        joins.push(
            std::thread::Builder::new()
                .name(format!("wire-fwd-{conn_id}"))
                .spawn(move || forwarder_loop(&reply_rx, &event_tx, &pending))
                .expect("spawn wire forwarder"),
        );
    }
    {
        let shared = Arc::clone(shared);
        joins.push(
            std::thread::Builder::new()
                .name(format!("wire-write-{conn_id}"))
                .spawn(move || writer_loop(&shared, conn_id, write_half, &event_rx))
                .expect("spawn wire writer"),
        );
    }
    lock_recover(&shared.conns).joins.extend(joins);
}

/// Best-effort request-id recovery from a body that failed to decode:
/// the id field sits at a fixed offset, so echo it when enough bytes
/// exist; otherwise answer on id 0.
fn salvage_id(body: &[u8]) -> u64 {
    if body.len() >= 16 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&body[8..16]);
        u64::from_le_bytes(b)
    } else {
        0
    }
}

fn reject_body(err: &SubmitError) -> ResponseBody {
    match err {
        SubmitError::QueueFull { .. } => ResponseBody::Shed { detail: err.to_string() },
        SubmitError::Quarantined { .. } => ResponseBody::Quarantined { detail: err.to_string() },
        // Unknown model / wrong width / non-finite input: the frame
        // parsed, but the request itself is unusable.
        _ => ResponseBody::BadFrame { detail: err.to_string() },
    }
}

fn reply_frame(wire_id: u64, reply: Reply) -> ResponseFrame {
    let body = match reply.outcome {
        Ok(output) => ResponseBody::Ok {
            output,
            latency_us: reply.latency_us,
            batch: reply.batch_size as u64,
        },
        Err(InferError::Timeout { waited_us, budget_us }) => {
            ResponseBody::Timeout { waited_us, budget_us }
        }
        Err(InferError::ExecFailed { detail }) => ResponseBody::ExecFailed { detail },
        Err(InferError::Aborted { detail }) => ResponseBody::Aborted { detail },
    };
    ResponseFrame { id: wire_id, body }
}

fn reader_loop(
    shared: &Arc<Shared>,
    svc: &Arc<InferenceService>,
    mut read: WireStream,
    events: &SyncSender<ResponseFrame>,
    reply_tx: &Sender<Reply>,
    pending: &Pending,
) {
    let stats = &shared.stats;
    let mut prefix = [0u8; LEN_PREFIX];
    let mut body: Vec<u8> = Vec::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // EOF, peer reset, or the read deadline: the connection is
        // done reading. Already-submitted requests still complete.
        if read.read_exact(&mut prefix).is_err() {
            return;
        }
        stats.bytes_rx.fetch_add(LEN_PREFIX as u64, Ordering::Relaxed);
        let declared = u32::from_le_bytes(prefix) as u64;
        if declared as usize > shared.cfg.max_frame {
            // Rejected from the prefix alone — a `u32::MAX` declarer
            // costs four bytes of reading and zero allocation.
            stats.bad_frames.fetch_add(1, Ordering::Relaxed);
            let err = FrameError::Oversized { declared, limit: shared.cfg.max_frame };
            let _ = events.send(ResponseFrame {
                id: 0,
                body: ResponseBody::BadFrame { detail: err.to_string() },
            });
            return;
        }
        body.resize(declared as usize, 0);
        if read.read_exact(&mut body).is_err() {
            return;
        }
        stats.bytes_rx.fetch_add(declared, Ordering::Relaxed);
        let req = match frame::decode_request(&body) {
            Ok(req) => req,
            Err(e) => {
                // Malformed body: answer BadFrame, then stop reading —
                // stream framing integrity is gone.
                stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                let _ = events.send(ResponseFrame {
                    id: salvage_id(&body),
                    body: ResponseBody::BadFrame { detail: e.to_string() },
                });
                return;
            }
        };
        stats.frames_rx.fetch_add(1, Ordering::Relaxed);
        let reject = {
            // Held across submit+insert so a reply can never race
            // ahead of its ticket mapping.
            let mut map = lock_recover(pending);
            if map.len() >= shared.cfg.max_in_flight {
                Some(ResponseBody::Shed {
                    detail: format!(
                        "connection in-flight limit ({}) reached",
                        shared.cfg.max_in_flight
                    ),
                })
            } else {
                match svc.submit(&req.model, req.tenant, &req.input, reply_tx) {
                    Ok(ticket) => {
                        map.insert(ticket, req.id);
                        None
                    }
                    Err(e) => Some(reject_body(&e)),
                }
            }
        };
        if let Some(body) = reject {
            if events.send(ResponseFrame { id: req.id, body }).is_err() {
                return; // writer is gone
            }
        }
    }
}

fn forwarder_loop(reply_rx: &Receiver<Reply>, events: &SyncSender<ResponseFrame>, pending: &Pending) {
    // Ends when every sender is gone: the reader dropped its handle
    // and the service delivered (and so dropped) every per-request
    // sender — i.e. all in-flight requests reached a terminal reply.
    for reply in reply_rx.iter() {
        let wire_id = lock_recover(pending).remove(&reply.ticket);
        // A ticket without a mapping would be a second terminal reply
        // for the same request; dropping it preserves the at-most-one
        // frame per request id guarantee.
        let Some(wire_id) = wire_id else { continue };
        if events.send(reply_frame(wire_id, reply)).is_err() {
            return; // writer is gone; the host tolerates dropped receivers
        }
    }
}

fn writer_loop(
    shared: &Arc<Shared>,
    conn_id: u64,
    mut write: WireStream,
    events: &Receiver<ResponseFrame>,
) {
    let stats = &shared.stats;
    let mut buf: Vec<u8> = Vec::new();
    // Ends when reader + forwarder have both dropped their senders —
    // every terminal frame for this connection has been offered.
    for frame_out in events.iter() {
        buf.clear();
        frame::encode_response(&frame_out, &mut buf);
        if write.write_all(&buf).is_err() {
            // Peer gone or write deadline expired: unblock the reader
            // too and stop. Undelivered frames are dropped with the
            // channel.
            let _ = write.shutdown(Shutdown::Both);
            break;
        }
        stats.frames_tx.fetch_add(1, Ordering::Relaxed);
        stats.bytes_tx.fetch_add(buf.len() as u64, Ordering::Relaxed);
    }
    stats.connections_closed.fetch_add(1, Ordering::Relaxed);
    lock_recover(&shared.conns).live.remove(&conn_id);
}

/// A blocking client for the wire protocol — one connection, explicit
/// [`send`](Self::send)/[`recv`](Self::recv) so callers control
/// pipelining. Used by the harnesses' `--wire` modes and the tests.
pub struct WireClient {
    stream: WireStream,
    max_frame: usize,
    buf: Vec<u8>,
}

impl WireClient {
    /// Connect to a server's Unix-domain socket.
    pub fn connect_uds(path: &Path) -> io::Result<Self> {
        Ok(Self::wrap(WireStream::Uds(UnixStream::connect(path)?)))
    }

    /// Connect to a server's TCP address.
    pub fn connect_tcp<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        let s = TcpStream::connect(addr)?;
        let _ = s.set_nodelay(true);
        Ok(Self::wrap(WireStream::Tcp(s)))
    }

    fn wrap(stream: WireStream) -> Self {
        Self { stream, max_frame: DEFAULT_MAX_FRAME, buf: Vec::new() }
    }

    /// Set this client's read/write deadlines.
    pub fn set_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> io::Result<()> {
        self.stream.set_read_timeout(read)?;
        self.stream.set_write_timeout(write)
    }

    /// Largest response body this client will accept (defaults to
    /// [`DEFAULT_MAX_FRAME`]).
    pub fn set_max_frame(&mut self, max: usize) {
        self.max_frame = max;
    }

    /// Encode and send one request frame.
    pub fn send(&mut self, req: &RequestFrame) -> Result<(), WireError> {
        self.buf.clear();
        frame::encode_request(req, &mut self.buf);
        self.stream.write_all(&self.buf)?;
        Ok(())
    }

    /// Read one response frame (blocking, honoring the read deadline).
    pub fn recv(&mut self) -> Result<ResponseFrame, WireError> {
        let mut prefix = [0u8; LEN_PREFIX];
        self.stream.read_exact(&mut prefix)?;
        let declared = u32::from_le_bytes(prefix) as u64;
        if declared as usize > self.max_frame {
            return Err(WireError::Frame(FrameError::Oversized {
                declared,
                limit: self.max_frame,
            }));
        }
        self.buf.resize(declared as usize, 0);
        self.stream.read_exact(&mut self.buf)?;
        Ok(frame::decode_response(&self.buf)?)
    }

    /// Lockstep convenience: send one request and wait for one
    /// response.
    pub fn call(&mut self, req: &RequestFrame) -> Result<ResponseFrame, WireError> {
        self.send(req)?;
        self.recv()
    }

    /// Half-close the write side (the server reader sees EOF; pending
    /// responses can still be received).
    pub fn finish_sending(&self) -> io::Result<()> {
        self.stream.shutdown(Shutdown::Write)
    }
}
