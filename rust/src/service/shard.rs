//! Model → dispatcher-shard assignment.
//!
//! A sharded [`InferenceService`](super::InferenceService) runs N
//! independent dispatcher shards, each with its own queue set, wake
//! condvar, execution engine and watchdog — the serving-layer analogue
//! of the paper's per-core work partitioning on the octa-core cluster.
//! [`ShardPolicy`] decides which shard serves which model:
//!
//! * **Static hash** (the default): FNV-1a over the model id, modulo
//!   the shard count — deterministic, registration-order independent,
//!   and stable across restarts.
//! * **Explicit pinning**: [`super::ModelRegistry::pin_shard`] overrides
//!   the hash for chosen models (e.g. to isolate a known-hot model on
//!   its own shard, the head-of-line scenario's setup).
//!
//! A model always maps to exactly one shard, so its execution-attempt
//! sequence (the [`super::FaultPlan`] key) and its queue FIFO order are
//! exactly what they were in the single-dispatcher service.

use super::faults::model_tag;

/// Upper bound on dispatcher shards — far above any sensible
/// configuration (each shard is two OS threads in started mode).
pub const MAX_SHARDS: usize = 64;

/// How models are distributed across dispatcher shards. See the
/// [module docs](self).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPolicy {
    /// Number of dispatcher shards. Normalized into `1..=`
    /// [`MAX_SHARDS`] at service construction.
    pub shards: usize,
}

impl Default for ShardPolicy {
    fn default() -> Self {
        Self::single()
    }
}

impl ShardPolicy {
    /// The unsharded policy (one dispatcher — the pre-sharding
    /// service, byte for byte).
    pub fn single() -> Self {
        Self { shards: 1 }
    }

    /// A policy with `shards` dispatcher shards.
    pub fn new(shards: usize) -> Self {
        Self { shards }
    }

    /// The policy with its invariants enforced (`1 ≤ shards ≤`
    /// [`MAX_SHARDS`]), applied once at service construction.
    pub fn normalized(&self) -> Self {
        Self { shards: self.shards.clamp(1, MAX_SHARDS) }
    }

    /// The shard serving `model`: the explicit pin when one is set
    /// (wrapped into range), else the static FNV-1a hash of the id.
    /// Pure — same inputs, same shard, on every host and every run.
    pub fn shard_of(&self, model: &str, pinned: Option<usize>) -> usize {
        let n = self.shards.max(1);
        match pinned {
            Some(p) => p % n,
            None => (model_tag(model) % n as u64) as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_policy_maps_everything_to_shard_zero() {
        let p = ShardPolicy::single();
        for id in ["emg-q7", "ecg-q32", "eeg-f32", ""] {
            assert_eq!(p.shard_of(id, None), 0);
            assert_eq!(p.shard_of(id, Some(7)), 0);
        }
    }

    #[test]
    fn hash_assignment_is_deterministic_and_in_range() {
        let p = ShardPolicy::new(4);
        for id in ["emg-q7", "ecg-q32", "eeg-f32", "a", "b", "zz"] {
            let s = p.shard_of(id, None);
            assert!(s < 4);
            assert_eq!(s, p.shard_of(id, None), "stable for {id}");
        }
        // FNV spreads: the three load models don't all collide.
        let shards: Vec<usize> = ["emg-q7", "ecg-q32", "eeg-f32"]
            .iter()
            .map(|id| p.shard_of(id, None))
            .collect();
        assert!(shards.iter().any(|&s| s != shards[0]), "{shards:?}");
    }

    #[test]
    fn pin_overrides_hash_and_wraps_into_range() {
        let p = ShardPolicy::new(3);
        assert_eq!(p.shard_of("m", Some(2)), 2);
        assert_eq!(p.shard_of("m", Some(5)), 2);
        assert_ne!(p.shard_of("m", Some(1)), p.shard_of("m", Some(2)));
    }

    #[test]
    fn normalization_clamps_to_valid_shard_counts() {
        assert_eq!(ShardPolicy::new(0).normalized().shards, 1);
        assert_eq!(ShardPolicy::new(4).normalized().shards, 4);
        assert_eq!(ShardPolicy::new(10_000).normalized().shards, MAX_SHARDS);
        assert_eq!(ShardPolicy::default(), ShardPolicy::single());
    }
}
