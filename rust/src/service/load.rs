//! The synthetic service load harness behind `service load`.
//!
//! Replays a fleet of simulated wearable clients against a running
//! [`InferenceService`] and measures what the micro-batcher buys. Three
//! models cover every plan family end to end:
//!
//! * `emg-q7` — the paper's 192-100-4 EMG gesture MLP as a **packed
//!   Q7** plan;
//! * `ecg-q32` — the 64-32-3 ECG arrhythmia MLP as a **Q32** plan;
//! * `eeg-f32` — the 16-20-1 EEG/BMI MLP as an **f32** plan.
//!
//! Clients are assigned round-robin across the models; each replays a
//! deterministic (per-seed) sequence of samples drawn from the
//! [`crate::datasets::wearable`] signal generators. Every reply is
//! checked **bit-exact** against a precomputed per-sample reference
//! (`run()` errors on any mismatch), and the same request multiset is
//! also executed as a serial per-request loop — quantize + one
//! single-sample plan run per request, the no-batching server a
//! micro-batcher replaces — to time `speedup_service_vs_serial` on the
//! same machine. The resulting [`LoadReport`] serializes to
//! `BENCH_service.json`, whose `ratchet_*`/`speedup_*` fields CI gates
//! via `scripts/bench_diff.py` (see the README "Serving" section for
//! the field dictionary).

use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::datasets::wearable;
use crate::fann::{from_float_packed, Activation, FixedNetwork, Network, TrainData};
use crate::kernels::{ExecPlan, PackedWidth, PlanScratch};
use crate::quantize::quantize;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::faults::FaultPlan;
use super::frame::{RequestFrame, ResponseBody};
use super::host::{InferenceService, Output};
use super::metrics::{LatencyHistogram, MetricsSnapshot, ShardMetrics, WireCounters};
use super::registry::ModelRegistry;
use super::shard::ShardPolicy;
use super::wire::{temp_uds_path, WireClient, WireConfig, WireError, WireServer};
use super::{BatchPolicy, SubmitError};

/// Load-harness configuration. `Default` is the full CI run (125k
/// requests ≥ the 100k acceptance floor); [`LoadOptions::quick`] is the
/// smoke-test size.
#[derive(Debug, Clone)]
pub struct LoadOptions {
    /// Simulated wearable clients (each is one tenant id).
    pub clients: usize,
    /// Requests each client submits.
    pub requests_per_client: usize,
    /// Seed for model weights, input pools and the request schedule.
    pub seed: u64,
    /// Submitter threads the clients are sharded across.
    pub submitters: usize,
    /// Dispatcher shards the service runs
    /// ([`ShardPolicy::new`]`(shards)`); `1` is the single-dispatcher
    /// service.
    pub shards: usize,
    /// Drive the run over a real Unix-domain socket: a
    /// [`WireServer`] fronts the service and every client speaks the
    /// length-prefixed frame protocol instead of calling `submit()`
    /// in-process. Same request schedule, same bit-exact checks.
    pub wire: bool,
    /// Scheduler policy for the run.
    pub policy: BatchPolicy,
}

impl Default for LoadOptions {
    fn default() -> Self {
        Self {
            clients: 25_000,
            requests_per_client: 5,
            seed: 7,
            submitters: 4,
            shards: 1,
            wire: false,
            policy: BatchPolicy {
                max_batch: 32,
                max_delay: Duration::from_millis(1),
                queue_capacity: 4096,
                ..BatchPolicy::default()
            },
        }
    }
}

impl LoadOptions {
    /// The smoke-test size (~6k requests): same code path, CI-cheap.
    pub fn quick() -> Self {
        Self {
            clients: 2_000,
            requests_per_client: 3,
            ..Self::default()
        }
    }

    /// Total requests this configuration replays.
    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }
}

/// Per-model results of a load run (counters from the service metrics
/// plus the model's identity).
#[derive(Debug, Clone)]
pub struct ModelLoadRow {
    /// Registry id (`emg-q7`, `ecg-q32`, `eeg-f32`).
    pub model: String,
    /// Plan representation label (`f32`/`q32`/`q7`).
    pub repr: &'static str,
    /// Layer sizes.
    pub topology: Vec<usize>,
    /// Requests accepted for this model.
    pub requests: u64,
    /// Requests completed (== accepted at the end of a run).
    pub completed: u64,
    /// Shed submits (each is one rejected attempt; clients retry with
    /// capped jittered backoff).
    pub shed: u64,
    /// Requests whose client exhausted its shed-retry budget and gave
    /// up — never accepted, never replied to. `0` in a healthy run.
    pub gave_up: u64,
    /// Coalesced batches executed.
    pub batches: u64,
    /// Mean coalesced batch size.
    pub mean_batch: f64,
    /// Size- / deadline- / drain-triggered flush counts.
    pub flushes: (u64, u64, u64),
    /// Largest batch executed.
    pub max_batch_seen: usize,
    /// Peak queue depth observed.
    pub peak_queue_depth: usize,
    /// Median request latency (µs, enqueue → reply).
    pub p50_us: u64,
    /// 99th-percentile request latency (µs).
    pub p99_us: u64,
}

/// Everything a load run measured — the in-memory form of
/// `BENCH_service.json`.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The configuration that produced this report.
    pub options: LoadOptions,
    /// Requests replayed (clients × requests_per_client).
    pub total_requests: usize,
    /// Wall time of the service phase (first submit → last reply).
    pub wall_seconds: f64,
    /// Service throughput: `total_requests / wall_seconds`.
    pub samples_per_sec: f64,
    /// Wall time of the serial per-request reference loop.
    pub serial_seconds: f64,
    /// Serial throughput: `total_requests / serial_seconds`.
    pub serial_samples_per_sec: f64,
    /// `serial_seconds / wall_seconds` — what coalescing (plus
    /// pipelining submit work onto client threads) buys end to end.
    pub speedup_service_vs_serial: f64,
    /// Mean coalesced batch size across all models — the ratchet field
    /// CI floors (a regression here means the scheduler stopped
    /// coalescing).
    pub mean_batch: f64,
    /// Median request latency (µs) across all models.
    pub p50_us: u64,
    /// 99th-percentile request latency (µs) across all models.
    pub p99_us: u64,
    /// Requests shed (and retried by their client) across the run.
    pub shed_total: u64,
    /// Submit retries performed by clients after sheds.
    pub retries_total: u64,
    /// Requests abandoned after exhausting the shed-retry budget
    /// (`MAX_SHED_RETRIES` attempts with capped jittered exponential
    /// backoff). The bit-exact and completion invariants then hold over
    /// `total_requests - gave_up_total` accepted requests.
    pub gave_up_total: u64,
    /// Distinct tenant ids the service saw.
    pub tenants: usize,
    /// Every reply matched the serial per-request reference bit for
    /// bit. `run()` errors instead of returning a report when false.
    pub bit_exact: bool,
    /// Per-model rows.
    pub rows: Vec<ModelLoadRow>,
    /// Per-shard rollups from the final metrics snapshot (one row per
    /// dispatcher shard, in shard order).
    pub shard_rows: Vec<ShardMetrics>,
    /// The hot+cold head-of-line probe (see [`HeadOfLineReport`]).
    pub head_of_line: HeadOfLineReport,
    /// Wire counters from the [`WireServer`] when the run went over a
    /// socket (`None` for in-process runs).
    pub wire: Option<WireCounters>,
    /// Connection resets the wire clients survived (reconnect +
    /// retry). `0` for in-process runs and for any healthy wire run;
    /// when non-zero, the service-side `completed == accepted` check
    /// is skipped (a reset can duplicate an execution whose first
    /// reply died with its socket) and the client-side ledger
    /// (`answered + gave_up == issued`) is the accounting gate.
    pub wire_resets: u64,
}

/// Result of the head-of-line decoupling probe: one *hot* model whose
/// every batch is slowed by an injected latency spike floods the
/// service while a *cold* model submits sparse, fast requests. On one
/// shard the cold model's p99 inherits the hot model's backlog
/// (oldest-head-first scheduling keeps picking the flooded queue); with
/// the two models pinned to different shards the cold p99 decouples —
/// the number CI asserts at `--shards 4`.
#[derive(Debug, Clone)]
pub struct HeadOfLineReport {
    /// The flooded, spike-slowed model (`emg-q7`).
    pub hot_model: String,
    /// The sparse, fast model pinned away from it (`eeg-f32`).
    pub cold_model: String,
    /// Injected spike added to every hot batch (µs).
    pub spike_us: u64,
    /// Shard count of the sharded pass (the single pass is always 1).
    pub shards: usize,
    /// Hot-model p99 with everything on one shard (µs).
    pub hot_p99_us_single: u64,
    /// Cold-model p99 with everything on one shard (µs) — inflated by
    /// the hot backlog.
    pub cold_p99_us_single: u64,
    /// Hot-model p99 with the models on separate shards (µs).
    pub hot_p99_us_sharded: u64,
    /// Cold-model p99 with the models on separate shards (µs) —
    /// decoupled from the hot backlog when `shards > 1`.
    pub cold_p99_us_sharded: u64,
}

/// One load-harness model: a compiled plan plus its deterministic input
/// pool and the precomputed per-sample reference outputs. Shared with
/// the [`super::chaos`] harness (`pub(super)`), which replays the same
/// models under an injected [`super::FaultPlan`].
pub(super) struct LoadModel {
    pub(super) id: &'static str,
    pub(super) repr: &'static str,
    pub(super) sizes: Vec<usize>,
    pub(super) plan: ExecPlan,
    pub(super) n_in: usize,
    pub(super) n_out: usize,
    /// Input pool, `pool_samples × n_in`, already normalized to [-1, 1].
    pub(super) pool_f: Vec<f32>,
    /// The pool quantized at the plan's decimal point (empty for f32
    /// plans) — identical values to what submit-time quantization
    /// produces, since both call [`quantize`] at the same dec.
    pub(super) pool_q: Vec<i32>,
    pub(super) pool_samples: usize,
    /// Reference outputs per pool sample (float plans).
    pub(super) expected_f: Vec<f32>,
    /// Reference outputs per pool sample (Q plans).
    pub(super) expected_q: Vec<i32>,
}

fn flatten_inputs(data: &TrainData) -> Vec<f32> {
    let mut xs = Vec::with_capacity(data.len() * data.input(0).len());
    for i in 0..data.len() {
        xs.extend_from_slice(data.input(i));
    }
    xs
}

fn randomized_net(sizes: &[usize], rng: &mut Rng) -> Result<Network> {
    let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(rng, None);
    Ok(net)
}

/// Build the three load models (one per plan family) with seeded
/// weights and seeded wearable input pools. Weights are random — the
/// harness measures scheduling and kernels, not accuracy — but inputs
/// come from the paper's signal generators so request content has the
/// real workloads' shape and dynamic range.
pub(super) fn build_models(seed: u64, pool_per_class: usize) -> Result<Vec<LoadModel>> {
    let mut rng = Rng::new(seed ^ 0x5E21_1CE0);
    let mut models = Vec::with_capacity(3);

    // emg-q7: 192-100-4 as a packed Q7 plan.
    {
        let sizes = vec![wearable::EMG_CHANNELS * wearable::EMG_WINDOW, 100, wearable::EMG_CLASSES];
        let mut r = rng.fork(1);
        let net = randomized_net(&sizes, &mut r)?;
        let (_, packed) =
            from_float_packed(&net, 1.0, PackedWidth::Q7).context("packing emg-q7")?;
        let plan = ExecPlan::compile(&packed);
        let mut data = wearable::emg_sized(seed ^ 0xA, pool_per_class);
        data.normalize_inputs();
        models.push(finish_model("emg-q7", "q7", sizes, plan, &data)?);
    }
    // ecg-q32: 64-32-3 as a wide Q32 plan.
    {
        let sizes = vec![wearable::ECG_WINDOW, 32, wearable::ECG_CLASSES];
        let mut r = rng.fork(2);
        let net = randomized_net(&sizes, &mut r)?;
        let fixed = FixedNetwork::from_float(&net, 1.0).context("quantizing ecg-q32")?;
        let plan = ExecPlan::compile(&fixed);
        let mut data = wearable::ecg_sized(seed ^ 0xB, pool_per_class);
        data.normalize_inputs();
        models.push(finish_model("ecg-q32", "q32", sizes, plan, &data)?);
    }
    // eeg-f32: 16-20-1 as a float plan.
    {
        let sizes = vec![wearable::EEG_CHANNELS * wearable::EEG_BANDS, 20, 1];
        let mut r = rng.fork(3);
        let net = randomized_net(&sizes, &mut r)?;
        let plan = ExecPlan::compile(&net);
        let mut data = wearable::eeg_sized(seed ^ 0xC, pool_per_class);
        data.normalize_inputs();
        models.push(finish_model("eeg-f32", "f32", sizes, plan, &data)?);
    }
    Ok(models)
}

fn finish_model(
    id: &'static str,
    repr: &'static str,
    sizes: Vec<usize>,
    plan: ExecPlan,
    data: &TrainData,
) -> Result<LoadModel> {
    let n_in = plan.num_inputs();
    let n_out = plan.num_outputs();
    ensure!(data.input(0).len() == n_in, "{id}: pool width != plan inputs");
    let pool_f = flatten_inputs(data);
    let pool_samples = data.len();
    let (pool_q, expected_f, expected_q) = if plan.is_float() {
        let expected = plan.run_batch_f32(&pool_f, pool_samples);
        (Vec::new(), expected, Vec::new())
    } else {
        // Invariant: the non-float branch implies a Q plan, and every
        // Q plan is compiled with a decimal point.
        let dec = plan.decimal_point().expect("Q plan has a decimal point");
        let pool_q: Vec<i32> = pool_f.iter().map(|&v| quantize(v, dec)).collect();
        let expected = plan.run_batch_q(&pool_q, pool_samples);
        (pool_q, Vec::new(), expected)
    };
    Ok(LoadModel {
        id,
        repr,
        sizes,
        plan,
        n_in,
        n_out,
        pool_f,
        pool_q,
        pool_samples,
        expected_f,
        expected_q,
    })
}

/// Build the three-model wearable registry the harnesses replay —
/// `emg-q7` (packed Q7), `ecg-q32` (Q32), `eeg-f32` (f32) — for a
/// standalone wire server (`service serve`): the same seeded compiled
/// plans behind a default circuit breaker, plus
/// `(id, input_width, output_width)` rows for the startup banner.
pub fn demo_registry(seed: u64) -> Result<(Arc<ModelRegistry>, Vec<(String, usize, usize)>)> {
    let models = build_models(seed, 4)?;
    let registry = Arc::new(ModelRegistry::new());
    let mut rows = Vec::with_capacity(models.len());
    for m in &models {
        registry.register_plan(m.id, m.plan.clone())?;
        rows.push((m.id.to_string(), m.n_in, m.n_out));
    }
    Ok((registry, rows))
}

/// The deterministic request schedule: which pool sample client `c`'s
/// `r`-th request submits (a Weyl-style mix so neighboring clients
/// don't walk the pool in lockstep).
pub(super) fn pool_index(c: usize, r: usize, pool_samples: usize) -> usize {
    c.wrapping_mul(2_654_435_761)
        .wrapping_add(r.wrapping_mul(40_503))
        % pool_samples
}

/// Time the serial per-request reference: one quantize (for Q models)
/// plus one single-sample plan run per request, reusing one scratch and
/// output buffer — an honest no-batching server loop, not a strawman
/// with per-call allocation.
fn run_serial_reference(models: &[LoadModel], opts: &LoadOptions) -> f64 {
    let mut scratch = PlanScratch::new();
    let max_out = models.iter().map(|m| m.n_out).max().unwrap_or(1);
    let max_in = models.iter().map(|m| m.n_in).max().unwrap_or(1);
    let mut out_f = vec![0.0f32; max_out];
    let mut out_q = vec![0i32; max_out];
    let mut in_q = vec![0i32; max_in];
    let mut ck = 0u64;
    let t0 = Instant::now();
    for c in 0..opts.clients {
        let m = &models[c % models.len()];
        for r in 0..opts.requests_per_client {
            let pi = pool_index(c, r, m.pool_samples);
            let x = &m.pool_f[pi * m.n_in..(pi + 1) * m.n_in];
            if m.plan.is_float() {
                m.plan.run_batch_f32_into(x, 1, &mut scratch, &mut out_f[..m.n_out]);
                ck = ck.wrapping_add(crate::bench::batch::checksum_f32(&out_f[..m.n_out]));
            } else {
                // Invariant: non-float ⇒ Q plan ⇒ decimal point set.
                let dec = m.plan.decimal_point().expect("Q plan");
                for (dst, &v) in in_q[..m.n_in].iter_mut().zip(x) {
                    *dst = quantize(v, dec);
                }
                m.plan.run_batch_q_into(&in_q[..m.n_in], 1, &mut scratch, &mut out_q[..m.n_out]);
                ck = ck.wrapping_add(crate::bench::batch::checksum_i32(&out_q[..m.n_out]));
            }
        }
    }
    std::hint::black_box(ck);
    t0.elapsed().as_secs_f64()
}

/// How many times a client retries one shed request before giving up.
/// With the capped exponential backoff below this is tens of
/// milliseconds of closed-loop backpressure per request — far beyond
/// what a correctly bounded queue needs to clear a batch, so a give-up
/// means the service is genuinely wedged, not merely busy.
pub(super) const MAX_SHED_RETRIES: u32 = 50;

/// Backoff before shed-retry `attempt`: capped exponential (100 µs
/// doubling to 1.6 ms) plus a deterministic jitter so submitter
/// threads don't re-collide on the queue bound in lockstep. The jitter
/// hash runs the splitmix64 finalizer ([`super::faults::mix`]) over
/// *both* the client salt and the attempt number — the earlier
/// single-multiply hash left adjacent clients' jitter correlated
/// within an attempt, so a burst of sheds retried as the same
/// thundering herd it backed off from.
pub(super) fn shed_backoff(attempt: u32, salt: u64) -> Duration {
    let base = 100u64 << attempt.min(4);
    let h = super::faults::mix(salt.rotate_left(32) ^ u64::from(attempt));
    Duration::from_micros(base + h % (base / 2 + 1))
}

/// What one submitter thread observed.
#[derive(Debug, Default)]
struct SubmitterStats {
    /// Replies whose output diverged from the per-sample reference (or
    /// arrived as an error — impossible in a fault-free run).
    mismatches: u64,
    /// Shed-retry submit attempts.
    retries: u64,
    /// Requests abandoned after [`MAX_SHED_RETRIES`], per model index.
    gave_up: Vec<u64>,
    /// Accepted requests whose reply never arrived (the terminal-reply
    /// invariant is broken if this is ever non-zero).
    lost: u64,
    /// Terminal replies received (successful or not). Together with
    /// `gave_up` and `lost` this closes the client-side ledger:
    /// `answered + gave_up + lost == issued` — a check that cannot be
    /// satisfied by the service-side counters alone, so dropped wire
    /// requests can never pass silently.
    answered: u64,
    /// Wire mode only: connection resets survived by reconnecting and
    /// retrying the in-flight request.
    resets: u64,
}

/// One submitter thread's work: submit every request of its client
/// range (retrying sheds with capped jittered backoff — closed-loop
/// backpressure that cannot spin forever), then receive exactly one
/// reply per accepted request and count bit-exact mismatches against
/// the precomputed reference.
fn submitter(
    svc: &InferenceService,
    models: &[LoadModel],
    clients: Range<usize>,
    requests_per_client: usize,
) -> SubmitterStats {
    let (tx, rx) = mpsc::channel();
    let mut expect: HashMap<u64, (usize, usize)> =
        HashMap::with_capacity(clients.len() * requests_per_client);
    let mut stats = SubmitterStats {
        gave_up: vec![0; models.len()],
        ..SubmitterStats::default()
    };
    for c in clients {
        let mi = c % models.len();
        let m = &models[mi];
        for r in 0..requests_per_client {
            let pi = pool_index(c, r, m.pool_samples);
            let input = &m.pool_f[pi * m.n_in..(pi + 1) * m.n_in];
            let mut attempt = 0u32;
            loop {
                match svc.submit(m.id, c as u64, input, &tx) {
                    Ok(ticket) => {
                        expect.insert(ticket, (mi, pi));
                        break;
                    }
                    Err(SubmitError::QueueFull { .. }) => {
                        // Shed: back off and retry — the client keeps
                        // its request, the queue keeps its bound — but
                        // only MAX_SHED_RETRIES times, so a wedged
                        // service turns into a counted give-up instead
                        // of a submitter spinning forever.
                        if attempt >= MAX_SHED_RETRIES {
                            stats.gave_up[mi] += 1;
                            break;
                        }
                        stats.retries += 1;
                        std::thread::sleep(shed_backoff(attempt, c as u64));
                        attempt += 1;
                    }
                    Err(e) => panic!("load submit failed: {e}"),
                }
            }
        }
    }
    let expected_replies = expect.len();
    let mut received = 0usize;
    while received < expected_replies {
        // Bounded wait: a reply that never comes must surface as a
        // counted lost reply, not a hung harness.
        let Ok(reply) = rx.recv_timeout(Duration::from_secs(120)) else {
            break;
        };
        received += 1;
        let (mi, pi) = expect[&reply.ticket];
        let m = &models[mi];
        let ok = match reply.output() {
            Some(Output::F32(v)) => v[..] == m.expected_f[pi * m.n_out..(pi + 1) * m.n_out],
            Some(Output::Q(v)) => v[..] == m.expected_q[pi * m.n_out..(pi + 1) * m.n_out],
            // A fault-free run must never answer an accepted request
            // with an error.
            None => false,
        };
        if !ok {
            stats.mismatches += 1;
        }
    }
    stats.lost += (expected_replies - received) as u64;
    stats.answered = received as u64;
    stats
}

/// Connect to the harness's Unix socket, with a couple of short
/// retries to ride out accept-queue races at run start. `None` means
/// the server is genuinely unreachable. Shared with the chaos
/// harness's wire mode.
pub(super) fn connect_with_retry(path: &Path) -> Option<WireClient> {
    for _ in 0..3 {
        if let Ok(client) = WireClient::connect_uds(path) {
            let _ = client
                .set_timeouts(Some(Duration::from_secs(120)), Some(Duration::from_secs(30)));
            return Some(client);
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    None
}

/// The wire-mode submitter: the same client range and request
/// schedule as [`submitter`], but every request travels the socket as
/// a length-prefixed frame and every reply comes back as a response
/// frame. Lockstep per request (send one, wait for its reply), with
/// the same capped jittered backoff on `Shed` — plus reconnect-and-
/// retry on connection resets, counted in `resets` so the run can
/// refuse to trust service-side counters that a reset may have
/// inflated.
fn wire_submitter(
    path: &Path,
    models: &[LoadModel],
    clients: Range<usize>,
    requests_per_client: usize,
) -> SubmitterStats {
    let mut stats = SubmitterStats {
        gave_up: vec![0; models.len()],
        ..SubmitterStats::default()
    };
    let mut conn: Option<WireClient> = None;
    'clients: for c in clients {
        let mi = c % models.len();
        let m = &models[mi];
        for r in 0..requests_per_client {
            let pi = pool_index(c, r, m.pool_samples);
            let req = RequestFrame {
                // Unique per client: requests_per_client is far below
                // 2^20, so client and request index cannot collide.
                id: ((c as u64) << 20) | r as u64,
                tenant: c as u64,
                model: m.id.to_string(),
                input: m.pool_f[pi * m.n_in..(pi + 1) * m.n_in].to_vec(),
            };
            let mut attempt = 0u32;
            loop {
                if conn.is_none() {
                    match connect_with_retry(path) {
                        Some(client) => conn = Some(client),
                        None => {
                            // Server unreachable: every request this
                            // client still owes (including this one) is
                            // a counted give-up, never a silent drop.
                            stats.gave_up[mi] += (requests_per_client - r) as u64;
                            continue 'clients;
                        }
                    }
                }
                let client = conn.as_mut().expect("connection just ensured");
                match client.call(&req) {
                    Ok(resp) if resp.id == req.id => match resp.body {
                        ResponseBody::Ok { output, .. } => {
                            stats.answered += 1;
                            let ok = match &output {
                                Output::F32(v) => {
                                    v[..] == m.expected_f[pi * m.n_out..(pi + 1) * m.n_out]
                                }
                                Output::Q(v) => {
                                    v[..] == m.expected_q[pi * m.n_out..(pi + 1) * m.n_out]
                                }
                            };
                            if !ok {
                                stats.mismatches += 1;
                            }
                            break;
                        }
                        ResponseBody::Shed { .. } | ResponseBody::Quarantined { .. } => {
                            if attempt >= MAX_SHED_RETRIES {
                                stats.gave_up[mi] += 1;
                                break;
                            }
                            stats.retries += 1;
                            std::thread::sleep(shed_backoff(attempt, c as u64));
                            attempt += 1;
                        }
                        ResponseBody::Timeout { .. }
                        | ResponseBody::ExecFailed { .. }
                        | ResponseBody::Aborted { .. } => {
                            // Terminal, but not the bit-exact answer a
                            // fault-free run owes — counted as answered
                            // (the ledger closes) and as a mismatch
                            // (the run fails loudly).
                            stats.answered += 1;
                            stats.mismatches += 1;
                            break;
                        }
                        ResponseBody::BadFrame { detail } => {
                            panic!("load wire request rejected as bad frame: {detail}")
                        }
                    },
                    Ok(_) => {
                        // A reply for an id we are not waiting on would
                        // break the lockstep protocol — treat the
                        // stream as desynced: count it against
                        // exactness and retry on a fresh connection.
                        stats.mismatches += 1;
                        conn = None;
                        stats.resets += 1;
                        if attempt >= MAX_SHED_RETRIES {
                            stats.gave_up[mi] += 1;
                            break;
                        }
                        attempt += 1;
                    }
                    Err(WireError::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        // The reply never arrived inside the client
                        // budget: a lost request, the invariant the
                        // run gates on.
                        stats.lost += 1;
                        break;
                    }
                    Err(_) => {
                        // Connection reset mid-request: the service may
                        // or may not have executed it (its reply died
                        // with the socket). Reconnect and retry —
                        // counted, so accounting never double-trusts
                        // the service's completed counter.
                        conn = None;
                        stats.resets += 1;
                        if attempt >= MAX_SHED_RETRIES {
                            stats.gave_up[mi] += 1;
                            break;
                        }
                        stats.retries += 1;
                        std::thread::sleep(shed_backoff(attempt, c as u64));
                        attempt += 1;
                    }
                }
            }
        }
    }
    stats
}

fn rows_from_snapshot(
    models: &[LoadModel],
    snap: &MetricsSnapshot,
    gave_up: &[u64],
) -> Vec<ModelLoadRow> {
    models
        .iter()
        .enumerate()
        .map(|(mi, m)| {
            let mm = snap.models.get(m.id).cloned().unwrap_or_default();
            ModelLoadRow {
                model: m.id.to_string(),
                repr: m.repr,
                topology: m.sizes.clone(),
                requests: mm.requests,
                completed: mm.completed,
                shed: mm.shed,
                gave_up: gave_up.get(mi).copied().unwrap_or(0),
                batches: mm.batches,
                mean_batch: mm.mean_batch(),
                flushes: (mm.size_flushes, mm.deadline_flushes, mm.drain_flushes),
                max_batch_seen: mm.max_batch_seen,
                peak_queue_depth: mm.peak_queue_depth,
                p50_us: mm.latency.p50(),
                p99_us: mm.latency.p99(),
            }
        })
        .collect()
}

/// One pass of the head-of-line probe at `shards` shards: pin the hot
/// model to shard 0 and the cold model to the last shard, flood the
/// hot model under a 100%-probability injected spike, probe the cold
/// model sparsely, and return `(hot_p99_us, cold_p99_us)` from the
/// replies' own latency stamps.
fn head_of_line_pass(
    hot: &LoadModel,
    cold: &LoadModel,
    shards: usize,
    spike: Duration,
    seed: u64,
) -> Result<(u64, u64)> {
    const HOT_REQUESTS: usize = 240;
    const COLD_REQUESTS: usize = 30;
    const COLD_GAP: Duration = Duration::from_millis(2);
    let registry = Arc::new(ModelRegistry::new());
    registry.register_plan(hot.id, hot.plan.clone())?;
    registry.register_plan(cold.id, cold.plan.clone())?;
    registry.pin_shard(hot.id, 0);
    registry.pin_shard(cold.id, shards.saturating_sub(1));
    let faults = FaultPlan {
        seed,
        spike_prob: 1.0,
        spike,
        spike_model: hot.id.to_string(),
        ..FaultPlan::default()
    };
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_micros(200),
        queue_capacity: 4096,
        ..BatchPolicy::default()
    };
    let svc =
        InferenceService::start_sharded(registry, &policy, &ShardPolicy::new(shards), Some(faults));

    fn probe(
        svc: &InferenceService,
        model: &LoadModel,
        tenant: u64,
        requests: usize,
        gap: Option<Duration>,
    ) -> LatencyHistogram {
        let (tx, rx) = mpsc::channel();
        let mut accepted = 0usize;
        for r in 0..requests {
            let pi = pool_index(tenant as usize, r, model.pool_samples);
            let input = &model.pool_f[pi * model.n_in..(pi + 1) * model.n_in];
            loop {
                match svc.submit(model.id, tenant, input, &tx) {
                    Ok(_) => {
                        accepted += 1;
                        break;
                    }
                    Err(SubmitError::QueueFull { .. }) => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) => panic!("head-of-line submit failed: {e}"),
                }
            }
            if let Some(g) = gap {
                std::thread::sleep(g);
            }
        }
        let mut hist = LatencyHistogram::new();
        for _ in 0..accepted {
            // Bounded wait — a missing reply surfaces as a short count,
            // which the caller rejects.
            let Ok(reply) = rx.recv_timeout(Duration::from_secs(120)) else {
                break;
            };
            hist.record(reply.latency_us);
        }
        hist
    }

    let (hot_lat, cold_lat) = std::thread::scope(|s| {
        let hot_h = s.spawn(|| probe(&svc, hot, 1, HOT_REQUESTS, None));
        let cold_h = s.spawn(|| probe(&svc, cold, 2, COLD_REQUESTS, Some(COLD_GAP)));
        (hot_h.join().expect("hot prober"), cold_h.join().expect("cold prober"))
    });
    svc.shutdown();
    ensure!(
        hot_lat.count() == HOT_REQUESTS as u64 && cold_lat.count() == COLD_REQUESTS as u64,
        "head-of-line probe lost replies (hot {}/{HOT_REQUESTS}, cold {}/{COLD_REQUESTS})",
        hot_lat.count(),
        cold_lat.count()
    );
    Ok((hot_lat.p99(), cold_lat.p99()))
}

/// The full head-of-line probe: the same hot+cold workload once on a
/// single shard and once on `shards` shards. Real-time (the spike is a
/// wall-clock sleep), so the p99s are measurements, not simulations.
fn run_head_of_line(models: &[LoadModel], shards: usize) -> Result<HeadOfLineReport> {
    // Hot: the packed-Q7 EMG model (the largest). Cold: the small f32
    // EEG model — disjoint plan families, so the decoupling shows up
    // across representations too.
    let hot = &models[0];
    let cold = models.iter().find(|m| m.plan.is_float()).unwrap_or(&models[models.len() - 1]);
    let spike = Duration::from_millis(5);
    let (hot_single, cold_single) = head_of_line_pass(hot, cold, 1, spike, 0x401D)?;
    let (hot_sharded, cold_sharded) = head_of_line_pass(hot, cold, shards, spike, 0x401D)?;
    Ok(HeadOfLineReport {
        hot_model: hot.id.to_string(),
        cold_model: cold.id.to_string(),
        spike_us: spike.as_micros() as u64,
        shards: ShardPolicy::new(shards).normalized().shards,
        hot_p99_us_single: hot_single,
        cold_p99_us_single: cold_single,
        hot_p99_us_sharded: hot_sharded,
        cold_p99_us_sharded: cold_sharded,
    })
}

/// Run the load harness: build the three models, time the serial
/// per-request reference, replay the full request schedule through a
/// started [`InferenceService`] (sharded per
/// [`LoadOptions::shards`]), verify every reply bit-exact, run the
/// head-of-line probe, and assemble the [`LoadReport`]. Errors if any
/// reply mismatches or any accepted request goes unanswered.
pub fn run(opts: &LoadOptions) -> Result<LoadReport> {
    ensure!(opts.clients > 0 && opts.requests_per_client > 0, "empty load configuration");
    let total = opts.total_requests();
    let models = build_models(opts.seed, 40)?;

    let serial_seconds = run_serial_reference(&models, opts);

    let registry = Arc::new(ModelRegistry::new());
    for m in &models {
        registry.register_plan(m.id, m.plan.clone())?;
    }
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &opts.policy,
        &ShardPolicy::new(opts.shards),
        None,
    ));

    let mut wire_path: Option<PathBuf> = None;
    let wire_server = if opts.wire {
        let cfg = WireConfig {
            // Generous deadlines: harness clients are cooperative, and
            // the reply-wait bound lives client-side.
            read_timeout: Some(Duration::from_secs(150)),
            write_timeout: Some(Duration::from_secs(30)),
            ..WireConfig::default()
        };
        let mut server = WireServer::start(Arc::clone(&svc), &cfg);
        let path = temp_uds_path("load");
        server.listen_uds(&path).context("binding load-harness UDS")?;
        wire_path = Some(path);
        Some(server)
    } else {
        None
    };

    let submitters = opts.submitters.clamp(1, opts.clients);
    let t0 = Instant::now();
    let per_thread: Vec<SubmitterStats> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(submitters);
        let base = opts.clients / submitters;
        let extra = opts.clients % submitters;
        let mut start = 0usize;
        for i in 0..submitters {
            let len = base + usize::from(i < extra);
            let range = start..start + len;
            start += len;
            let svc_ref: &InferenceService = &svc;
            let models_ref = &models;
            let rpc = opts.requests_per_client;
            let path_ref = wire_path.as_deref();
            handles.push(s.spawn(move || match path_ref {
                Some(p) => wire_submitter(p, models_ref, range, rpc),
                None => submitter(svc_ref, models_ref, range, rpc),
            }));
        }
        handles
            .into_iter()
            // A panicking submitter is a harness bug (its asserts hold
            // the bit-exactness gate); propagating the panic is the
            // correct failure mode, not something to recover from.
            .map(|h| h.join().expect("submitter thread"))
            .collect()
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    // Wire teardown first (it half-closes connections and aborts
    // anything still in flight), then the service; shutdown() joins
    // the dispatchers, so the snapshot accounts for every batch.
    let wire_counters = wire_server.map(|server| {
        let (svc_back, counters) = server.shutdown();
        drop(svc_back);
        counters
    });
    let Ok(svc) = Arc::try_unwrap(svc) else {
        anyhow::bail!("service Arc still shared after wire shutdown")
    };
    let mut snap = svc.shutdown();
    if let Some(c) = wire_counters {
        snap.wire = c;
    }

    let mismatches: u64 = per_thread.iter().map(|s| s.mismatches).sum();
    let retries_total: u64 = per_thread.iter().map(|s| s.retries).sum();
    let lost_total: u64 = per_thread.iter().map(|s| s.lost).sum();
    let answered_total: u64 = per_thread.iter().map(|s| s.answered).sum();
    let resets_total: u64 = per_thread.iter().map(|s| s.resets).sum();
    let mut gave_up_by_model = vec![0u64; models.len()];
    for s in &per_thread {
        for (dst, g) in gave_up_by_model.iter_mut().zip(&s.gave_up) {
            *dst += g;
        }
    }
    let gave_up_total: u64 = gave_up_by_model.iter().sum();
    let accepted = total as u64 - gave_up_total;
    ensure!(
        mismatches == 0,
        "{mismatches} of {accepted} coalesced replies diverged from serial per-request execution"
    );
    ensure!(lost_total == 0, "{lost_total} accepted requests never received a reply");
    // The client-side ledger must close on its own numbers: every
    // issued request was answered or became a counted give-up. The
    // service-side counters cannot vouch for this — a wire request
    // dropped between socket and submit would leave them consistent —
    // so the accounting gate lives on the client's side of the socket.
    ensure!(
        answered_total + gave_up_total == total as u64,
        "client ledger does not close: answered {answered_total} + gave_up {gave_up_total} != issued {total}"
    );
    if resets_total == 0 {
        ensure!(
            snap.total_completed() == accepted,
            "completed {} != accepted {accepted}",
            snap.total_completed()
        );
    }

    // Per-shard accounting must reconcile with the aggregate — the
    // same invariant the chaos harness gates, checked here too.
    ensure!(
        snap.shards.iter().map(|s| s.completed).sum::<u64>() == snap.total_completed(),
        "per-shard completed rows do not sum to the aggregate"
    );

    let head_of_line = run_head_of_line(&models, opts.shards)?;

    let latency = snap.merged_latency();
    Ok(LoadReport {
        options: opts.clone(),
        total_requests: total,
        wall_seconds,
        samples_per_sec: accepted as f64 / wall_seconds,
        serial_seconds,
        serial_samples_per_sec: total as f64 / serial_seconds,
        speedup_service_vs_serial: serial_seconds / wall_seconds,
        mean_batch: snap.mean_batch(),
        p50_us: latency.p50(),
        p99_us: latency.p99(),
        shed_total: snap.total_shed(),
        retries_total,
        gave_up_total,
        tenants: snap.tenants.len(),
        bit_exact: true,
        rows: rows_from_snapshot(&models, &snap, &gave_up_by_model),
        wire: opts.wire.then_some(snap.wire),
        wire_resets: resets_total,
        shard_rows: snap.shards,
        head_of_line,
    })
}

/// Serialize the wire-counter block of a BENCH document — shared by
/// the load and chaos artifacts (`wire` objects in both). Always
/// present so asserts can key on `enabled` instead of probing for
/// missing fields.
pub(super) fn wire_json(wire: Option<&WireCounters>, resets: u64) -> Json {
    let c = wire.copied().unwrap_or_default();
    Json::obj()
        .field("enabled", wire.is_some())
        .field("connections_opened", Json::Int(c.connections_opened as i64))
        .field("connections_closed", Json::Int(c.connections_closed as i64))
        .field("frames_rx", Json::Int(c.frames_rx as i64))
        .field("frames_tx", Json::Int(c.frames_tx as i64))
        .field("bad_frames", Json::Int(c.bad_frames as i64))
        .field("bytes_rx", Json::Int(c.bytes_rx as i64))
        .field("bytes_tx", Json::Int(c.bytes_tx as i64))
        .field("resets", Json::Int(resets as i64))
        .build()
}

/// Serialize per-shard rollup rows — shared by the load and chaos
/// artifacts (`shards` arrays in both BENCH documents).
pub(super) fn shard_rows_json(rows: &[ShardMetrics]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|s| {
                Json::obj()
                    .field("shard", s.shard)
                    .field(
                        "models",
                        Json::Arr(
                            s.models
                                .iter()
                                .map(|m| Json::Str(m.clone()))
                                .collect::<Vec<_>>(),
                        ),
                    )
                    .field("requests", Json::Int(s.requests as i64))
                    .field("completed", Json::Int(s.completed as i64))
                    .field("shed", Json::Int(s.shed as i64))
                    .field("failed", Json::Int(s.failed as i64))
                    .field("batches", Json::Int(s.batches as i64))
                    .field("mean_batch", s.mean_batch())
                    .field("restarts", Json::Int(s.restarts as i64))
                    .field("heartbeats", Json::Int(s.heartbeats as i64))
                    .build()
            })
            .collect::<Vec<_>>(),
    )
}

impl LoadReport {
    /// Serialize as the `BENCH_service.json` document (see the README
    /// "Serving" section for the field dictionary).
    pub fn to_json(&self) -> Json {
        let policy = &self.options.policy;
        Json::obj()
            .field("schema", "fann-on-mcu/bench-service/v1")
            .field("seed", Json::Int(self.options.seed as i64))
            .field("clients", self.options.clients)
            .field("requests_per_client", self.options.requests_per_client)
            .field("total_requests", self.total_requests)
            .field(
                "policy",
                Json::obj()
                    .field("max_batch", policy.max_batch)
                    .field("max_delay_us", policy.max_delay.as_micros() as usize)
                    .field("queue_capacity", policy.queue_capacity)
                    .field("exec_workers", policy.exec_workers)
                    .field("submitters", self.options.submitters)
                    .field("adaptive_delay", policy.adaptive_delay)
                    .build(),
            )
            .field("shards", self.options.shards.max(1))
            .field("wall_seconds", self.wall_seconds)
            .field("samples_per_sec", self.samples_per_sec)
            .field("serial_seconds", self.serial_seconds)
            .field("serial_samples_per_sec", self.serial_samples_per_sec)
            .field("speedup_service_vs_serial", self.speedup_service_vs_serial)
            .field("ratchet_mean_batch", self.mean_batch)
            .field("p50_us", Json::Int(self.p50_us as i64))
            .field("p99_us", Json::Int(self.p99_us as i64))
            .field("shed_total", Json::Int(self.shed_total as i64))
            .field("retries_total", Json::Int(self.retries_total as i64))
            .field("gave_up_total", Json::Int(self.gave_up_total as i64))
            .field("tenants", self.tenants)
            .field("bit_exact", self.bit_exact)
            .field("wire", wire_json(self.wire.as_ref(), self.wire_resets))
            .field(
                "models",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj()
                                .field("model", r.model.as_str())
                                .field("repr", r.repr)
                                .field(
                                    "topology",
                                    Json::Arr(
                                        r.topology
                                            .iter()
                                            .map(|&s| Json::Int(s as i64))
                                            .collect::<Vec<_>>(),
                                    ),
                                )
                                .field("requests", Json::Int(r.requests as i64))
                                .field("completed", Json::Int(r.completed as i64))
                                .field("shed", Json::Int(r.shed as i64))
                                .field("gave_up", Json::Int(r.gave_up as i64))
                                .field("batches", Json::Int(r.batches as i64))
                                .field("mean_batch", r.mean_batch)
                                .field("size_flushes", Json::Int(r.flushes.0 as i64))
                                .field("deadline_flushes", Json::Int(r.flushes.1 as i64))
                                .field("drain_flushes", Json::Int(r.flushes.2 as i64))
                                .field("max_batch_seen", r.max_batch_seen)
                                .field("peak_queue_depth", r.peak_queue_depth)
                                .field("p50_us", Json::Int(r.p50_us as i64))
                                .field("p99_us", Json::Int(r.p99_us as i64))
                                .build()
                        })
                        .collect::<Vec<_>>(),
                ),
            )
            .field("shards_detail", shard_rows_json(&self.shard_rows))
            .field(
                "head_of_line",
                Json::obj()
                    .field("hot_model", self.head_of_line.hot_model.as_str())
                    .field("cold_model", self.head_of_line.cold_model.as_str())
                    .field("spike_us", Json::Int(self.head_of_line.spike_us as i64))
                    .field("shards", self.head_of_line.shards)
                    .field(
                        "hot_p99_us_single",
                        Json::Int(self.head_of_line.hot_p99_us_single as i64),
                    )
                    .field(
                        "cold_p99_us_single",
                        Json::Int(self.head_of_line.cold_p99_us_single as i64),
                    )
                    .field(
                        "hot_p99_us_sharded",
                        Json::Int(self.head_of_line.hot_p99_us_sharded as i64),
                    )
                    .field(
                        "cold_p99_us_sharded",
                        Json::Int(self.head_of_line.cold_p99_us_sharded as i64),
                    )
                    .build(),
            )
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_load_run_is_bit_exact_and_complete() {
        let opts = LoadOptions {
            clients: 12,
            requests_per_client: 2,
            seed: 3,
            submitters: 2,
            shards: 2,
            wire: false,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_micros(500),
                queue_capacity: 64,
                ..BatchPolicy::default()
            },
        };
        let report = run(&opts).unwrap();
        assert_eq!(report.total_requests, 24);
        assert!(report.bit_exact);
        assert_eq!(report.gave_up_total, 0);
        assert!(report.samples_per_sec > 0.0);
        assert!(report.p99_us >= report.p50_us);
        assert_eq!(report.rows.len(), 3);
        assert_eq!(report.rows.iter().map(|r| r.completed).sum::<u64>(), 24);
        // Per-shard rows: one per shard, reconciling with the total.
        assert_eq!(report.shard_rows.len(), 2);
        assert_eq!(report.shard_rows.iter().map(|s| s.completed).sum::<u64>(), 24);
        // The head-of-line probe ran both passes and measured real
        // latencies.
        assert!(report.head_of_line.cold_p99_us_single > 0);
        assert!(report.head_of_line.cold_p99_us_sharded > 0);
        assert_eq!(report.head_of_line.shards, 2);
        let json = report.to_json().to_pretty();
        for field in [
            "\"schema\"",
            "\"samples_per_sec\"",
            "\"p50_us\"",
            "\"p99_us\"",
            "\"ratchet_mean_batch\"",
            "\"speedup_service_vs_serial\"",
            "\"bit_exact\"",
            "\"gave_up_total\"",
            "\"shards\"",
            "\"shards_detail\"",
            "\"head_of_line\"",
            "\"cold_p99_us_sharded\"",
        ] {
            assert!(json.contains(field), "missing {field} in {json}");
        }
    }

    #[test]
    fn tiny_wire_load_run_is_bit_exact_with_reconciled_counters() {
        let opts = LoadOptions {
            clients: 9,
            requests_per_client: 2,
            seed: 5,
            submitters: 3,
            shards: 2,
            wire: true,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_micros(500),
                queue_capacity: 64,
                ..BatchPolicy::default()
            },
        };
        let report = run(&opts).unwrap();
        assert!(report.bit_exact);
        assert_eq!(report.gave_up_total, 0);
        assert_eq!(report.wire_resets, 0);
        let wire = report.wire.expect("wire counters present in a --wire run");
        // Every connection fully torn down, every request's frame
        // counted: rx ≥ issued (sheds retry), one terminal tx per
        // request, zero codec-level rejects from cooperative clients.
        assert_eq!(wire.connections_opened, wire.connections_closed);
        assert!(wire.connections_opened >= 1);
        assert!(wire.frames_rx >= 18, "frames_rx {}", wire.frames_rx);
        assert!(wire.frames_tx >= 18, "frames_tx {}", wire.frames_tx);
        assert_eq!(wire.bad_frames, 0);
        assert!(wire.bytes_rx > 0 && wire.bytes_tx > 0);
        let json = report.to_json().to_pretty();
        for field in ["\"wire\"", "\"frames_rx\"", "\"bad_frames\"", "\"resets\""] {
            assert!(json.contains(field), "missing {field} in {json}");
        }
    }

    #[test]
    fn wire_submitter_ledger_closes_across_resets_and_server_loss() {
        // The satellite invariant: when wire retries hit connection
        // resets (here: the server shuts down mid-run and its socket
        // file disappears), answered + gave_up must still equal the
        // requests issued — no silent drops.
        let models = build_models(11, 6).unwrap();
        let registry = Arc::new(ModelRegistry::new());
        for m in &models {
            registry.register_plan(m.id, m.plan.clone()).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_micros(300),
            queue_capacity: 64,
            ..BatchPolicy::default()
        };
        let svc = Arc::new(InferenceService::start_sharded(
            registry,
            &policy,
            &ShardPolicy::new(1),
            None,
        ));
        let mut server = WireServer::start(Arc::clone(&svc), &WireConfig::default());
        let path = temp_uds_path("load-reset");
        server.listen_uds(&path).unwrap();

        let requests_per_client = 80;
        let clients = 0..3usize;
        let issued = (clients.len() * requests_per_client) as u64;
        let stats = std::thread::scope(|s| {
            let path_ref = path.as_path();
            let models_ref = &models;
            let worker =
                s.spawn(move || wire_submitter(path_ref, models_ref, clients, requests_per_client));
            // Kill the wire front-end mid-run: in-flight requests are
            // answered `Aborted`, open sockets reset, and the socket
            // file is unlinked so reconnects fail.
            std::thread::sleep(Duration::from_millis(30));
            let (svc_back, _) = server.shutdown();
            drop(svc_back);
            worker.join().expect("wire submitter thread")
        });
        let gave_up: u64 = stats.gave_up.iter().sum();
        assert_eq!(
            stats.answered + gave_up + stats.lost,
            issued,
            "ledger must close: answered {} + gave_up {gave_up} + lost {} != issued {issued}",
            stats.answered,
            stats.lost
        );
        assert_eq!(stats.lost, 0, "a reset must become a retry or give-up, never a lost reply");
        assert!(gave_up > 0, "the mid-run shutdown must strand some requests as give-ups");
        let svc = Arc::try_unwrap(svc).ok().unwrap();
        svc.shutdown();
    }

    #[test]
    fn shed_backoff_is_capped_and_jittered() {
        // Exponential up to the cap...
        assert!(shed_backoff(0, 1) < shed_backoff(4, 1) || shed_backoff(4, 1).as_micros() >= 1600);
        for attempt in 0..60 {
            let d = shed_backoff(attempt, 9).as_micros() as u64;
            let base = 100u64 << attempt.min(4);
            assert!((base..=base + base / 2).contains(&d), "attempt {attempt}: {d}");
        }
        // ...and deterministic per (attempt, salt).
        assert_eq!(shed_backoff(3, 5), shed_backoff(3, 5));
    }

    #[test]
    fn shed_backoff_jitter_spreads_clients_and_attempts() {
        use std::collections::HashSet;
        // Within one attempt, adjacent client ids must land on many
        // distinct jitter values — a shed burst must not retry as the
        // same thundering herd it backed off from.
        let per_client: HashSet<u64> =
            (0..64).map(|c| shed_backoff(2, c).as_micros() as u64).collect();
        assert!(per_client.len() >= 16, "only {} distinct jitters", per_client.len());
        // Across attempts at the capped base, one client's jitter keeps
        // moving too (the attempt number is mixed in, not shifted out).
        let per_attempt: HashSet<u64> =
            (4..36).map(|a| shed_backoff(a, 7).as_micros() as u64).collect();
        assert!(per_attempt.len() >= 8, "only {} distinct jitters", per_attempt.len());
        // And two adjacent clients never walk identical jitter
        // sequences.
        let a: Vec<u64> = (4..24).map(|at| shed_backoff(at, 10).as_micros() as u64).collect();
        let b: Vec<u64> = (4..24).map(|at| shed_backoff(at, 11).as_micros() as u64).collect();
        let same = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        assert!(same <= 4, "{same}/20 positions collide");
    }

    #[test]
    fn pool_index_stays_in_bounds_and_varies() {
        let idx: Vec<usize> = (0..8).map(|r| pool_index(5, r, 17)).collect();
        assert!(idx.iter().all(|&i| i < 17));
        assert!(idx.windows(2).any(|w| w[0] != w[1]));
    }
}
