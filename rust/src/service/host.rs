//! The long-running inference host: submit → coalesce → execute →
//! reply.
//!
//! Clients call [`InferenceService::submit`] with one sample and a
//! reply channel; the service validates and (for Q-format models)
//! quantizes the input up front, enqueues it on the model's bounded
//! [`MicroBatchQueue`], and a single dispatcher coalesces each queue
//! into one `run_batch_*_into` call — the same zero-allocation compiled
//! path the throughput harness drives — then scatters the outputs back
//! to each client's channel. One persistent [`ExecEngine`] (plan
//! scratch + gather/output buffers) is reused for every batch, so the
//! execute path allocates nothing in steady state beyond each reply's
//! output vector.
//!
//! Two operating modes share all of that machinery:
//!
//! * **Started** ([`InferenceService::start`]): a dispatcher thread
//!   sleeps until the nearest queue deadline (or a submit wakeup) and
//!   flushes whatever is ready. [`shutdown`](InferenceService::shutdown)
//!   — or dropping the service — drains every queue before the thread
//!   exits, so accepted requests always get a reply.
//! * **Manual** ([`InferenceService::new`]): no thread; tests pump the
//!   scheduler explicitly with [`pump_at`](InferenceService::pump_at) /
//!   [`drain`](InferenceService::drain), making deadline-flush and
//!   backpressure behavior fully deterministic (no sleeps, no races).
//!
//! Batched execution is bit-identical per sample to single-sample runs
//! (the batch-consistency invariant the kernel tests pin), so the
//! micro-batcher can never change a client's answer — only its latency.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::bench::batch;
use crate::kernels::PlanScratch;
use crate::quantize::quantize;

use super::metrics::MetricsSnapshot;
use super::queue::{Batch, FlushReason, MicroBatchQueue};
use super::registry::ModelRegistry;
use super::{BatchPolicy, SubmitError};

/// One model output in the model's native representation: `F32` for
/// float plans, `Q` (fixed-point at the plan's decimal point) for
/// q32/q7/q15 plans — exactly what the underlying kernel produced, so
/// bit-exactness against a serial reference is checkable without any
/// float round-trip.
#[derive(Debug, Clone, PartialEq)]
pub enum Output {
    /// Float-plan outputs.
    F32(Vec<f32>),
    /// Q-format plan outputs (interpret at the plan's decimal point).
    Q(Vec<i32>),
}

/// What a client receives on its reply channel for one accepted
/// request.
#[derive(Debug, Clone, PartialEq)]
pub struct Reply {
    /// The ticket [`InferenceService::submit`] returned for this
    /// request.
    pub ticket: u64,
    /// The model outputs for the submitted sample.
    pub output: Output,
    /// Enqueue → reply latency in microseconds (includes queueing and
    /// execution).
    pub latency_us: u64,
    /// Size of the coalesced batch this request rode in.
    pub batch_size: usize,
}

/// A validated request waiting in a model queue. Q-format inputs are
/// quantized at submit time, so a coalesced batch and a per-request
/// serial run see *identical* integer inputs — the bit-exactness
/// guarantee needs no float re-quantization anywhere downstream.
struct Pending {
    ticket: u64,
    tenant: u64,
    input: PendingInput,
    reply: mpsc::Sender<Reply>,
}

enum PendingInput {
    F32(Vec<f32>),
    Q(Vec<i32>),
}

/// All model queues, guarded by one mutex (submits touch one queue for
/// a few pushes; the dispatcher holds it only to pick/take a batch —
/// execution happens outside the lock).
struct SchedState {
    queues: BTreeMap<String, MicroBatchQueue<Pending>>,
}

impl SchedState {
    /// Take the ready batch whose head request is oldest (cross-model
    /// FIFO fairness). Returns the model id, the batch and the queue's
    /// remaining depth.
    fn take_ready(&mut self, now: Instant) -> Option<(String, Batch<Pending>, usize)> {
        let mut best_id: Option<&String> = None;
        let mut best_head: Option<Instant> = None;
        for (id, q) in &self.queues {
            if q.ready(now).is_none() {
                continue;
            }
            let Some(head) = q.head_enqueued() else {
                continue;
            };
            let better = match best_head {
                None => true,
                Some(t) => head < t,
            };
            if better {
                best_id = Some(id);
                best_head = Some(head);
            }
        }
        let id = best_id?.clone();
        let q = self.queues.get_mut(&id).expect("picked id exists");
        let b = q.take(now).expect("picked queue is ready");
        let depth = q.len();
        Some((id, b, depth))
    }

    /// Take any non-empty queue's next batch unconditionally (drain).
    fn take_any(&mut self) -> Option<(String, Batch<Pending>, usize)> {
        for (id, q) in self.queues.iter_mut() {
            if let Some(b) = q.drain_batch() {
                let id = id.clone();
                let depth = q.len();
                return Some((id, b, depth));
            }
        }
        None
    }

    /// The earliest deadline across all queues — what the dispatcher
    /// sleeps until when nothing is ready yet.
    fn next_deadline(&self) -> Option<Instant> {
        self.queues.values().filter_map(|q| q.next_deadline()).min()
    }
}

/// Persistent per-dispatcher execution state: the plan scratch plus
/// grow-only gather/output buffers, reused across every batch of every
/// model — the execute path's zero-steady-state-allocation guarantee.
struct ExecEngine {
    scratch: PlanScratch,
    in_f: Vec<f32>,
    in_q: Vec<i32>,
    out_f: Vec<f32>,
    out_q: Vec<i32>,
}

impl ExecEngine {
    fn new() -> Self {
        Self {
            scratch: PlanScratch::new(),
            in_f: Vec::new(),
            in_q: Vec::new(),
            out_f: Vec::new(),
            out_q: Vec::new(),
        }
    }
}

struct Inner {
    registry: Arc<ModelRegistry>,
    policy: BatchPolicy,
    state: Mutex<SchedState>,
    wake: Condvar,
    metrics: Mutex<MetricsSnapshot>,
    engine: Mutex<ExecEngine>,
    next_ticket: AtomicU64,
    shutdown: AtomicBool,
}

impl Inner {
    /// Execute one coalesced batch and reply to every request in it.
    /// Called with no lock held; takes `engine`, then (after release)
    /// `metrics` — never `state`, so it cannot deadlock with submitters.
    fn execute_batch(&self, model_id: &str, batch_of: Batch<Pending>, depth_after: usize) {
        let Some(model) = self.registry.get(model_id) else {
            // Unreachable today (models are never deregistered), but a
            // dropped batch must not hang clients silently: with no
            // reply possible, dropping the senders closes the channels.
            return;
        };
        let plan = model.plan();
        let n = batch_of.items.len();
        if n == 0 {
            return;
        }
        let n_in = plan.num_inputs();
        let n_out = plan.num_outputs();
        let workers = self.policy.exec_workers;

        let mut guard = self.engine.lock().expect("engine lock");
        let engine = &mut *guard;
        let done_at;
        if plan.is_float() {
            grow(&mut engine.in_f, n * n_in, 0.0);
            grow(&mut engine.out_f, n * n_out, 0.0);
            for (i, (p, _)) in batch_of.items.iter().enumerate() {
                let PendingInput::F32(v) = &p.input else {
                    unreachable!("f32 plan queued a Q input");
                };
                engine.in_f[i * n_in..(i + 1) * n_in].copy_from_slice(v);
            }
            let xs = &engine.in_f[..n * n_in];
            let out = &mut engine.out_f[..n * n_out];
            if workers > 1 {
                // The dispatcher is a plain thread (never a pool
                // worker), so the row-split driver's no-nesting rule
                // holds by construction.
                batch::run_plan_rowsplit_into(plan, xs, n, workers, out);
            } else {
                plan.run_batch_f32_into(xs, n, &mut engine.scratch, out);
            }
            done_at = Instant::now();
            for (i, (p, enq)) in batch_of.items.iter().enumerate() {
                let out = engine.out_f[i * n_out..(i + 1) * n_out].to_vec();
                send_reply(p, enq, done_at, Output::F32(out), n);
            }
        } else {
            grow(&mut engine.in_q, n * n_in, 0);
            grow(&mut engine.out_q, n * n_out, 0);
            for (i, (p, _)) in batch_of.items.iter().enumerate() {
                let PendingInput::Q(v) = &p.input else {
                    unreachable!("Q plan queued an f32 input");
                };
                engine.in_q[i * n_in..(i + 1) * n_in].copy_from_slice(v);
            }
            let xs = &engine.in_q[..n * n_in];
            let out = &mut engine.out_q[..n * n_out];
            if workers > 1 {
                batch::run_plan_q_rowsplit_into(plan, xs, n, workers, out);
            } else {
                plan.run_batch_q_into(xs, n, &mut engine.scratch, out);
            }
            done_at = Instant::now();
            for (i, (p, enq)) in batch_of.items.iter().enumerate() {
                let out = engine.out_q[i * n_out..(i + 1) * n_out].to_vec();
                send_reply(p, enq, done_at, Output::Q(out), n);
            }
        }
        drop(guard);

        let mut metrics = self.metrics.lock().expect("metrics lock");
        {
            let m = metrics.models.entry(model_id.to_string()).or_default();
            m.note_flush(batch_of.reason, n);
            m.note_depth(depth_after);
            for (_, enq) in &batch_of.items {
                m.latency.record(done_at.duration_since(*enq).as_micros() as u64);
            }
        }
        for (p, _) in &batch_of.items {
            metrics.tenants.entry(p.tenant).or_default().completed += 1;
        }
    }
}

fn grow<T: Clone>(buf: &mut Vec<T>, need: usize, fill: T) {
    if buf.len() < need {
        buf.resize(need, fill);
    }
}

fn send_reply(p: &Pending, enqueued: &Instant, done_at: Instant, output: Output, batch_size: usize) {
    // A gone client (dropped receiver) is not an error; the work was
    // already shared with the rest of the batch.
    let _ = p.reply.send(Reply {
        ticket: p.ticket,
        output,
        latency_us: done_at.duration_since(*enqueued).as_micros() as u64,
        batch_size,
    });
}

/// The multi-tenant inference host. See the [module docs](super::host)
/// for the dataflow; [`ModelRegistry`] for registration;
/// [`BatchPolicy`] for the flush/shed knobs.
pub struct InferenceService {
    inner: Arc<Inner>,
    dispatcher: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// A manual-mode service (no dispatcher thread): flush decisions
    /// run only when [`pump`](Self::pump) / [`pump_at`](Self::pump_at)
    /// / [`drain`](Self::drain) are called. The deterministic harness
    /// the scheduler tests drive.
    pub fn new(registry: Arc<ModelRegistry>, policy: &BatchPolicy) -> Self {
        let inner = Arc::new(Inner {
            registry,
            policy: policy.normalized(),
            state: Mutex::new(SchedState { queues: BTreeMap::new() }),
            wake: Condvar::new(),
            metrics: Mutex::new(MetricsSnapshot::default()),
            engine: Mutex::new(ExecEngine::new()),
            next_ticket: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
        });
        Self { inner, dispatcher: None }
    }

    /// A started service: spawns the dispatcher thread that sleeps
    /// until the nearest queue deadline (or a submit wakeup) and
    /// flushes whatever is ready.
    pub fn start(registry: Arc<ModelRegistry>, policy: &BatchPolicy) -> Self {
        let mut svc = Self::new(registry, policy);
        let inner = Arc::clone(&svc.inner);
        let handle = std::thread::Builder::new()
            .name("svc-dispatch".to_string())
            .spawn(move || dispatcher_loop(&inner))
            .expect("spawn dispatcher");
        svc.dispatcher = Some(handle);
        svc
    }

    /// Submit one sample for `model` on behalf of `tenant`. On success
    /// the request is queued and the returned ticket will eventually
    /// arrive on `reply` (batched with others when traffic allows).
    /// Rejections ([`SubmitError`]) are synchronous and leave no trace.
    pub fn submit(
        &self,
        model: &str,
        tenant: u64,
        input: &[f32],
        reply: &mpsc::Sender<Reply>,
    ) -> Result<u64, SubmitError> {
        let Some(m) = self.inner.registry.get(model) else {
            return Err(SubmitError::UnknownModel(model.to_string()));
        };
        let plan = m.plan();
        if input.len() != plan.num_inputs() {
            return Err(SubmitError::BadInputWidth {
                expected: plan.num_inputs(),
                got: input.len(),
            });
        }
        let pending_input = if plan.is_float() {
            PendingInput::F32(input.to_vec())
        } else {
            let dec = plan.decimal_point().expect("Q plan has a decimal point");
            PendingInput::Q(input.iter().map(|&v| quantize(v, dec)).collect())
        };
        let ticket = self.inner.next_ticket.fetch_add(1, Ordering::Relaxed);
        let pending = Pending {
            ticket,
            tenant,
            input: pending_input,
            reply: reply.clone(),
        };
        let now = Instant::now();
        let pushed = {
            let mut st = self.inner.state.lock().expect("state lock");
            let q = st
                .queues
                .entry(model.to_string())
                .or_insert_with(|| MicroBatchQueue::new(&self.inner.policy));
            q.push(pending, now).map_err(|_| q.capacity())
        };
        match pushed {
            Ok(depth) => {
                self.inner.wake.notify_all();
                let mut metrics = self.inner.metrics.lock().expect("metrics lock");
                let mm = metrics.models.entry(model.to_string()).or_default();
                mm.requests += 1;
                mm.note_depth(depth);
                metrics.tenants.entry(tenant).or_default().requests += 1;
                Ok(ticket)
            }
            Err(capacity) => {
                let mut metrics = self.inner.metrics.lock().expect("metrics lock");
                metrics.models.entry(model.to_string()).or_default().shed += 1;
                metrics.tenants.entry(tenant).or_default().shed += 1;
                Err(SubmitError::QueueFull { capacity })
            }
        }
    }

    /// Manual pump at the real clock — [`pump_at`](Self::pump_at) with
    /// `Instant::now()`.
    pub fn pump(&self) -> usize {
        self.pump_at(Instant::now())
    }

    /// Execute every batch whose size or deadline trigger has fired as
    /// of `now`; returns how many batches ran. Passing a future instant
    /// makes deadline flushes happen deterministically in tests —
    /// without sleeping. Safe to call alongside a running dispatcher
    /// (both just take ready batches under the lock).
    pub fn pump_at(&self, now: Instant) -> usize {
        let mut ran = 0;
        loop {
            let taken = self.inner.state.lock().expect("state lock").take_ready(now);
            match taken {
                Some((id, b, depth)) => {
                    self.inner.execute_batch(&id, b, depth);
                    ran += 1;
                }
                None => return ran,
            }
        }
    }

    /// Flush *everything* still queued, ready or not (partial batches
    /// execute with [`FlushReason::Drain`]); returns how many batches
    /// ran. Used at shutdown and by tests.
    pub fn drain(&self) -> usize {
        let mut ran = 0;
        loop {
            let taken = self.inner.state.lock().expect("state lock").take_any();
            match taken {
                Some((id, b, depth)) => {
                    self.inner.execute_batch(&id, b, depth);
                    ran += 1;
                }
                None => return ran,
            }
        }
    }

    /// A consistent snapshot of every per-model / per-tenant counter.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.metrics.lock().expect("metrics lock").clone()
    }

    /// The registry this service serves from.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.inner.registry
    }

    /// Stop the service: the dispatcher (if any) drains every queue and
    /// exits; in manual mode the queues are drained inline. Every
    /// accepted request has been replied to when this returns. Returns
    /// the final metrics snapshot — unlike [`metrics`](Self::metrics)
    /// mid-run, it is guaranteed to account for every batch (replies are
    /// sent before counters are bumped, so a mid-run snapshot can trail
    /// the last reply by one batch).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.finish();
        self.inner.metrics.lock().expect("metrics lock").clone()
    }

    fn finish(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.wake.notify_all();
        match self.dispatcher.take() {
            Some(h) => {
                let _ = h.join();
            }
            None => {
                self.drain();
            }
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.finish();
    }
}

/// The dispatcher: wait for a trigger, take the oldest ready batch,
/// execute it outside the lock, repeat. On shutdown, drain every queue
/// (partial batches run with [`FlushReason::Drain`]) before exiting.
fn dispatcher_loop(inner: &Inner) {
    loop {
        let taken = {
            let mut st = inner.state.lock().expect("state lock");
            loop {
                let now = Instant::now();
                if let Some(t) = st.take_ready(now) {
                    break Some(t);
                }
                if inner.shutdown.load(Ordering::Acquire) {
                    break st.take_any();
                }
                // Sleep until the nearest deadline can fire (floored so
                // an imminent deadline never busy-spins), or idle-tick
                // when every queue is empty. Submits notify the condvar,
                // so light traffic still gets sub-delay wakeups.
                let wait = match st.next_deadline() {
                    Some(d) => d
                        .saturating_duration_since(now)
                        .max(Duration::from_micros(50)),
                    None => Duration::from_millis(20),
                };
                let (guard, _) = inner
                    .wake
                    .wait_timeout(st, wait)
                    .expect("state lock poisoned");
                st = guard;
            }
        };
        match taken {
            Some((id, b, depth)) => inner.execute_batch(&id, b, depth),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, Network};
    use crate::util::rng::Rng;

    fn registry_with(sizes: &[usize], id: &str) -> Arc<ModelRegistry> {
        let mut rng = Rng::new(11);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        let reg = Arc::new(ModelRegistry::new());
        reg.register(id, &n).unwrap();
        reg
    }

    #[test]
    fn manual_pump_respects_size_trigger() {
        let reg = registry_with(&[3, 4, 2], "m");
        let policy = BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        svc.submit("m", 1, &[0.1, 0.2, 0.3], &tx).unwrap();
        // One waiting request, huge deadline: nothing is ready.
        assert_eq!(svc.pump(), 0);
        svc.submit("m", 2, &[0.4, 0.5, 0.6], &tx).unwrap();
        // Size trigger: one batch of two.
        assert_eq!(svc.pump(), 1);
        let a = rx.recv().unwrap();
        let b = rx.recv().unwrap();
        assert_eq!(a.batch_size, 2);
        assert_eq!(b.batch_size, 2);
        assert!(a.ticket != b.ticket);
        let m = svc.metrics();
        assert_eq!(m.models["m"].size_flushes, 1);
        assert_eq!(m.models["m"].completed, 2);
        assert_eq!(m.tenants[&1].completed, 1);
    }

    #[test]
    fn submit_validates_model_and_width() {
        let reg = registry_with(&[3, 4, 2], "m");
        let svc = InferenceService::new(reg, &BatchPolicy::default());
        let (tx, _rx) = mpsc::channel();
        assert_eq!(
            svc.submit("nope", 0, &[0.0; 3], &tx),
            Err(SubmitError::UnknownModel("nope".to_string()))
        );
        assert_eq!(
            svc.submit("m", 0, &[0.0; 5], &tx),
            Err(SubmitError::BadInputWidth { expected: 3, got: 5 })
        );
        // Rejections leave no trace in the accepted-request counters.
        assert_eq!(svc.metrics().total_requests(), 0);
    }

    #[test]
    fn shutdown_in_manual_mode_drains_pending_requests() {
        let reg = registry_with(&[2, 3, 1], "m");
        let policy = BatchPolicy {
            max_batch: 100,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        for _ in 0..3 {
            svc.submit("m", 7, &[0.5, -0.5], &tx).unwrap();
        }
        let snap = svc.shutdown();
        let replies: Vec<Reply> = rx.try_iter().collect();
        assert_eq!(replies.len(), 3);
        assert!(replies.iter().all(|r| r.batch_size == 3));
        assert_eq!(snap.total_completed(), 3);
        assert_eq!(snap.models["m"].drain_flushes, 1);
    }
}
