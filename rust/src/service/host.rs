//! The long-running inference host: submit → coalesce → execute →
//! reply, built to survive faults.
//!
//! Clients call [`InferenceService::submit`] with one sample and a
//! reply channel; the service validates (width, NaN/inf on the f32
//! path) and (for Q-format models) quantizes the input up front,
//! consults the model's circuit breaker, enqueues it on the model's
//! bounded [`MicroBatchQueue`], and a dispatcher coalesces each queue
//! into one `run_batch_*_into` call — the same zero-allocation compiled
//! path the throughput harness drives — then scatters the outputs back
//! to each client's channel. One persistent [`ExecEngine`] (plan
//! scratch + gather/output buffers) is reused for every batch, so the
//! execute path allocates nothing in steady state beyond each reply's
//! output vector.
//!
//! **The terminal-reply invariant.** Every accepted request gets
//! exactly one terminal [`Reply`] — a successful [`Output`], or a typed
//! [`InferError`] (`ExecFailed` when its batch panicked, `Timeout` when
//! it went stale past [`BatchPolicy::request_budget`], `Aborted` when a
//! dispatcher restart failed it before execution). Batch execution runs
//! under `catch_unwind`, so a panicking kernel fails only its own
//! batch; the started-mode dispatcher runs under a watchdog supervisor
//! that fails (never leaks) pending requests and respawns the
//! dispatcher when it dies. `rust/tests/prop_service_faults.rs` pins
//! the invariant under randomized fault schedules.
//!
//! Two operating modes share all of that machinery:
//!
//! * **Started** ([`InferenceService::start`]): a watchdog thread
//!   supervises the dispatcher thread, which sleeps until the nearest
//!   queue deadline (or a submit wakeup) and flushes whatever is
//!   ready. [`shutdown`](InferenceService::shutdown) — or dropping the
//!   service — drains every queue before the threads exit, so accepted
//!   requests always get a reply.
//! * **Manual** ([`InferenceService::new`]): no threads; tests pump the
//!   scheduler explicitly with [`pump_at`](InferenceService::pump_at) /
//!   [`drain`](InferenceService::drain) and submit with an explicit
//!   clock via [`submit_at`](InferenceService::submit_at), making every
//!   flush, timeout, and quarantine decision fully deterministic (no
//!   sleeps, no races).
//!
//! Batched execution is bit-identical per sample to single-sample runs
//! (the batch-consistency invariant the kernel tests pin), so the
//! micro-batcher can never change a client's answer — only its latency
//! or, under faults, whether a typed error arrives instead.
//!
//! **Sharding.** The service runs [`ShardPolicy::shards`] independent
//! dispatcher shards. Each shard owns its own queue set (scheduler
//! state + wake condvar), its own [`ExecEngine`], and — in started
//! mode — its own watchdog/dispatcher thread pair, so a panicking or
//! slow model only ever stalls the shard it lives on. Models are
//! assigned to shards by [`ShardPolicy::shard_of`] (static FNV hash,
//! overridable per model via [`ModelRegistry::pin_shard`]); a model
//! always maps to exactly one shard, so its queue FIFO order and
//! execution-attempt sequence (the [`FaultPlan`] key) are exactly what
//! they were in the single-dispatcher service. Every invariant above
//! holds per shard and in aggregate — a watchdog that respawns shard
//! 2's dispatcher fails (never leaks) only shard 2's pending requests,
//! and injected dispatcher kills target only the shard hosting the
//! fault plan's panic model.
//!
//! Lock order is strictly `state` → `engine` → `metrics` within a
//! shard (the breaker's health lock nests inside none of them), never
//! the reverse, and no code path holds two shards' scheduler or engine
//! locks at once, so submitters, dispatchers, and watchdogs cannot
//! deadlock.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::bench::batch;
use crate::kernels::{ExecPlan, PlanScratch};
use crate::quantize::quantize;

use super::faults::FaultPlan;
use super::metrics::{MetricsSnapshot, ShardMetrics};
use super::queue::{Batch, MicroBatchQueue};
use super::registry::{Admission, BreakerEvent, ModelRegistry};
use super::shard::ShardPolicy;
use super::{BatchPolicy, InferError, SubmitError};

/// Lock a mutex, recovering from poison: the protected structures here
/// (queues, metrics, grow-only engine buffers) are valid after any
/// panic — every writer either completes a whole update or leaves data
/// that the next batch overwrites — so a poisoned lock must not
/// cascade a dead batch into a dead service.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One model output in the model's native representation: `F32` for
/// float plans, `Q` (fixed-point at the plan's decimal point) for
/// q32/q7/q15 plans — exactly what the underlying kernel produced, so
/// bit-exactness against a serial reference is checkable without any
/// float round-trip.
#[derive(Debug, Clone, PartialEq)]
pub enum Output {
    /// Float-plan outputs.
    F32(Vec<f32>),
    /// Q-format plan outputs (interpret at the plan's decimal point).
    Q(Vec<i32>),
}

/// The one terminal message a client receives on its reply channel for
/// each accepted request.
#[derive(Debug, Clone, PartialEq)]
pub struct Reply {
    /// The ticket [`InferenceService::submit`] returned for this
    /// request.
    pub ticket: u64,
    /// The model outputs for the submitted sample, or the typed reason
    /// the request failed. Exactly one such reply arrives per accepted
    /// request — success, exec failure, timeout, or abort.
    pub outcome: Result<Output, InferError>,
    /// Enqueue → reply latency in microseconds (includes queueing and,
    /// for executed requests, execution).
    pub latency_us: u64,
    /// Size of the coalesced batch this request rode in; `0` when the
    /// request never executed (timeout or abort).
    pub batch_size: usize,
}

impl Reply {
    /// Whether this reply carries a successful output.
    pub fn is_ok(&self) -> bool {
        self.outcome.is_ok()
    }

    /// The successful output, if any.
    pub fn output(&self) -> Option<&Output> {
        self.outcome.as_ref().ok()
    }
}

/// A validated request waiting in a model queue. Q-format inputs are
/// quantized at submit time, so a coalesced batch and a per-request
/// serial run see *identical* integer inputs — the bit-exactness
/// guarantee needs no float re-quantization anywhere downstream.
struct Pending {
    ticket: u64,
    tenant: u64,
    input: PendingInput,
    reply: mpsc::Sender<Reply>,
    /// This request is the model's half-open quarantine probe; if it
    /// dies without executing, its probe slot must be released.
    is_probe: bool,
}

enum PendingInput {
    F32(Vec<f32>),
    Q(Vec<i32>),
}

/// All model queues, guarded by one mutex (submits touch one queue for
/// a few pushes; the dispatcher holds it only to pick/take a batch —
/// execution happens outside the lock).
struct SchedState {
    queues: BTreeMap<String, MicroBatchQueue<Pending>>,
}

impl SchedState {
    /// Take the ready batch whose head request is oldest (cross-model
    /// FIFO fairness); equal head-enqueue instants tie-break on the
    /// *model id* — an explicit, deterministic total order, so two
    /// models whose heads arrived on the same clock tick are always
    /// served in the same order regardless of map internals or
    /// insertion history. Returns the model id, the batch and the
    /// queue's remaining depth.
    fn take_ready(&mut self, now: Instant) -> Option<(String, Batch<Pending>, usize)> {
        let mut best: Option<(Instant, &String)> = None;
        for (id, q) in &self.queues {
            if q.ready(now).is_none() {
                continue;
            }
            let Some(head) = q.head_enqueued() else {
                continue;
            };
            let better = match best {
                None => true,
                Some((t, bid)) => (head, id.as_str()) < (t, bid.as_str()),
            };
            if better {
                best = Some((head, id));
            }
        }
        let id = best?.1.clone();
        // Invariant: `id` was produced by the loop above from this very
        // map, and a queue that reported ready stays ready until
        // mutated — both lookups are locally provable.
        let q = self.queues.get_mut(&id).expect("picked id exists");
        let b = q.take(now).expect("picked queue is ready");
        let depth = q.len();
        Some((id, b, depth))
    }

    /// Take any non-empty queue's next batch unconditionally (drain).
    fn take_any(&mut self) -> Option<(String, Batch<Pending>, usize)> {
        for (id, q) in self.queues.iter_mut() {
            if let Some(b) = q.drain_batch() {
                let id = id.clone();
                let depth = q.len();
                return Some((id, b, depth));
            }
        }
        None
    }

    /// The earliest deadline across all queues — what the dispatcher
    /// sleeps until when nothing is ready yet.
    fn next_deadline(&self) -> Option<Instant> {
        self.queues.values().filter_map(|q| q.next_deadline()).min()
    }
}

/// Persistent per-dispatcher execution state: the plan scratch plus
/// grow-only gather/output buffers, reused across every batch of every
/// model — the execute path's zero-steady-state-allocation guarantee.
/// Also carries the per-model execution-attempt counters that key the
/// deterministic [`FaultPlan`] decisions.
struct ExecEngine {
    scratch: PlanScratch,
    in_f: Vec<f32>,
    in_q: Vec<i32>,
    out_f: Vec<f32>,
    out_q: Vec<i32>,
    exec_seq: BTreeMap<String, u64>,
}

impl ExecEngine {
    fn new() -> Self {
        Self {
            scratch: PlanScratch::new(),
            in_f: Vec::new(),
            in_q: Vec::new(),
            out_f: Vec::new(),
            out_q: Vec::new(),
            exec_seq: BTreeMap::new(),
        }
    }
}

/// One dispatcher shard: its own queue set and wake trigger, its own
/// execution engine, and its own heartbeat/restart counters. Started
/// mode runs one watchdog/dispatcher thread pair per shard; a panic on
/// one shard never touches another's state.
struct Shard {
    state: Mutex<SchedState>,
    wake: Condvar,
    engine: Mutex<ExecEngine>,
    /// This shard's dispatcher loop iterations, monotone across
    /// respawns — the heartbeat the watchdog surfaces and (on the
    /// kill-target shard) the key for injected dispatcher kills.
    dispatch_iters: AtomicU64,
    /// Times this shard's watchdog respawned its dead dispatcher.
    restarts: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Self {
            state: Mutex::new(SchedState { queues: BTreeMap::new() }),
            wake: Condvar::new(),
            engine: Mutex::new(ExecEngine::new()),
            dispatch_iters: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
        }
    }
}

struct Inner {
    registry: Arc<ModelRegistry>,
    policy: BatchPolicy,
    shard_policy: ShardPolicy,
    faults: Option<FaultPlan>,
    shards: Vec<Shard>,
    metrics: Mutex<MetricsSnapshot>,
    next_ticket: AtomicU64,
    shutdown: AtomicBool,
}

impl Inner {
    /// The shard serving `model`: the registry pin when one is set,
    /// else the shard policy's static hash.
    fn shard_of(&self, model: &str) -> usize {
        self.shard_policy
            .shard_of(model, self.registry.pinned_shard(model))
    }

    /// The shard injected dispatcher kills target: the one hosting the
    /// fault plan's `panic_model` (shard 0 when no panic model is set),
    /// so each `kill_at_iters` entry still kills exactly one dispatcher
    /// and every other shard's watchdog counters stay untouched.
    fn kill_shard(&self) -> usize {
        match &self.faults {
            Some(f) if !f.panic_model.is_empty() => self.shard_of(&f.panic_model),
            _ => 0,
        }
    }

    /// Execute one coalesced batch on `shard` and send exactly one
    /// terminal reply to every request in it: stale requests get
    /// `Timeout`, a caught execution panic fails the remainder with
    /// `ExecFailed`, success replies carry outputs. `now` is the
    /// scheduling clock the batch was taken at — timeout and breaker
    /// decisions use it, so manual mode stays on one virtual timeline.
    /// Called with no lock held; takes the shard's `engine`, then
    /// (after release) `metrics` — never any `state`, so it cannot
    /// deadlock with submitters.
    fn execute_batch(
        &self,
        shard: usize,
        model_id: &str,
        batch_of: Batch<Pending>,
        depth_after: usize,
        now: Instant,
    ) {
        let reason = batch_of.reason;
        let Some(model) = self.registry.get(model_id) else {
            // Unreachable today (models are never deregistered), but a
            // dropped batch must not hang clients silently: every
            // request still gets its terminal reply.
            self.abort_items(model_id, batch_of.items, &format!("model {model_id:?} missing"));
            return;
        };
        let plan = model.plan();

        // Stale requests answered Timeout instead of executed.
        let budget = self.policy.request_budget;
        let (live, expired) = batch_of.split_expired(budget, now);
        let budget_us = budget.unwrap_or(Duration::ZERO).as_micros() as u64;
        for (p, enq) in &expired {
            if p.is_probe {
                self.registry.release_probe(model_id);
            }
            let waited = now.duration_since(*enq).as_micros() as u64;
            send_reply(p, Err(InferError::Timeout { waited_us: waited, budget_us }), waited, 0);
        }

        let n = live.len();
        let workers = self.policy.exec_workers;
        let mut exec_error: Option<InferError> = None;
        let mut outputs: Vec<Output> = Vec::new();
        let mut done_at = now;
        if n > 0 {
            let mut guard = lock_recover(&self.shards[shard].engine);
            let engine = &mut *guard;
            let seq = {
                let s = engine.exec_seq.entry(model_id.to_string()).or_insert(0);
                let cur = *s;
                *s += 1;
                cur
            };
            if let Some(spike) = self.faults.as_ref().and_then(|f| f.spike_for(model_id, seq)) {
                std::thread::sleep(spike);
            }
            let inject = self
                .faults
                .as_ref()
                .is_some_and(|f| f.should_panic(model_id, seq));
            // Panic isolation: a panicking kernel (or injected fault)
            // fails only this batch. The engine guard outlives the
            // catch, so the engine mutex is never poisoned by a caught
            // panic; its grow-only buffers are overwritten by the next
            // batch regardless of where this one stopped.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if inject {
                    panic!("injected exec fault (model {model_id}, exec #{seq})");
                }
                run_batch_kernels(engine, plan, &live, workers)
            }));
            done_at = Instant::now();
            match run {
                Ok(outs) => outputs = outs,
                Err(payload) => {
                    exec_error = Some(InferError::ExecFailed {
                        detail: panic_detail(payload.as_ref()),
                    });
                }
            }
        }

        // One breaker observation per execution attempt, on the same
        // clock the batch was scheduled at.
        let event = if n > 0 {
            self.registry.note_exec(model_id, exec_error.is_none(), now)
        } else {
            BreakerEvent::None
        };

        match &exec_error {
            None => {
                for ((p, enq), out) in live.iter().zip(outputs) {
                    let latency = done_at.duration_since(*enq).as_micros() as u64;
                    send_reply(p, Ok(out), latency, n);
                }
            }
            Some(err) => {
                for (p, enq) in &live {
                    let latency = done_at.duration_since(*enq).as_micros() as u64;
                    send_reply(p, Err(err.clone()), latency, n);
                }
            }
        }

        let mut metrics = lock_recover(&self.metrics);
        {
            let m = metrics.models.entry(model_id.to_string()).or_default();
            m.note_depth(depth_after);
            m.timeouts += expired.len() as u64;
            match &exec_error {
                None if n > 0 => {
                    m.note_flush(reason, n);
                    for (_, enq) in &live {
                        m.latency.record(done_at.duration_since(*enq).as_micros() as u64);
                    }
                }
                Some(_) => {
                    m.exec_failures += 1;
                    m.failed += n as u64;
                }
                None => {}
            }
            match event {
                BreakerEvent::Tripped => m.quarantine_trips += 1,
                BreakerEvent::Recovered => m.quarantine_recoveries += 1,
                BreakerEvent::None => {}
            }
        }
        for (p, _) in &expired {
            metrics.tenants.entry(p.tenant).or_default().failed += 1;
        }
        for (p, _) in &live {
            let t = metrics.tenants.entry(p.tenant).or_default();
            if exec_error.is_none() {
                t.completed += 1;
            } else {
                t.failed += 1;
            }
        }
    }

    /// Reply `Aborted` to a set of requests that will never execute,
    /// releasing any probe slot among them and keeping the counters
    /// consistent.
    fn abort_items(&self, model_id: &str, items: Vec<(Pending, Instant)>, detail: &str) {
        if items.is_empty() {
            return;
        }
        let now = Instant::now();
        for (p, enq) in &items {
            if p.is_probe {
                self.registry.release_probe(model_id);
            }
            let waited = now.duration_since(*enq).as_micros() as u64;
            send_reply(p, Err(InferError::Aborted { detail: detail.to_string() }), waited, 0);
        }
        let mut metrics = lock_recover(&self.metrics);
        metrics
            .models
            .entry(model_id.to_string())
            .or_default()
            .aborted += items.len() as u64;
        for (p, _) in &items {
            metrics.tenants.entry(p.tenant).or_default().failed += 1;
        }
    }

    /// Drain one shard's queues and fail all its still-pending requests
    /// with [`InferError::Aborted`] — the watchdog's pending-request
    /// policy across *that shard's* dispatcher restart. Other shards'
    /// queues are untouched: a hot shard's crash never aborts a cold
    /// shard's requests. Returns how many were failed.
    fn fail_shard_pending(&self, shard: usize, detail: &str) -> usize {
        let mut per_model: Vec<(String, Vec<(Pending, Instant)>)> = Vec::new();
        {
            let mut st = lock_recover(&self.shards[shard].state);
            for (id, q) in st.queues.iter_mut() {
                let mut items = Vec::new();
                while let Some(b) = q.drain_batch() {
                    items.extend(b.items);
                }
                if !items.is_empty() {
                    per_model.push((id.clone(), items));
                }
            }
        }
        let mut count = 0;
        for (id, items) in per_model {
            count += items.len();
            self.abort_items(&id, items, detail);
        }
        count
    }

    /// [`fail_shard_pending`](Self::fail_shard_pending) across every
    /// shard — service-wide teardown.
    fn fail_all_pending(&self, detail: &str) -> usize {
        (0..self.shards.len())
            .map(|s| self.fail_shard_pending(s, detail))
            .sum()
    }

    fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = lock_recover(&self.metrics).clone();
        snap.watchdog_restarts = self
            .shards
            .iter()
            .map(|s| s.restarts.load(Ordering::Relaxed))
            .sum();
        snap.dispatcher_heartbeats = self
            .shards
            .iter()
            .map(|s| s.dispatch_iters.load(Ordering::Relaxed))
            .sum();
        // Per-shard rollups: model rows grouped by the (pure, stable)
        // model → shard assignment, plus each shard's own atomics. One
        // row per shard even when it currently serves no models.
        snap.shards = (0..self.shards.len())
            .map(|idx| ShardMetrics {
                shard: idx,
                restarts: self.shards[idx].restarts.load(Ordering::Relaxed),
                heartbeats: self.shards[idx].dispatch_iters.load(Ordering::Relaxed),
                ..ShardMetrics::default()
            })
            .collect();
        for (id, m) in &snap.models {
            let row = &mut snap.shards[self.shard_of(id).min(self.shards.len() - 1)];
            row.models.push(id.clone());
            row.requests += m.requests;
            row.completed += m.completed;
            row.shed += m.shed;
            row.failed += m.failed + m.timeouts + m.aborted;
            row.batches += m.batches;
            row.batched_samples += m.batched_samples;
        }
        snap
    }
}

/// Gather the live requests' inputs, run the plan (serial or
/// row-split), and scatter per-request outputs. Runs inside the
/// panic-isolation boundary; everything it touches in `engine` is
/// overwritten by the next batch, so a mid-run panic leaves no
/// poisoned state behind.
fn run_batch_kernels(
    engine: &mut ExecEngine,
    plan: &ExecPlan,
    live: &[(Pending, Instant)],
    workers: usize,
) -> Vec<Output> {
    let n = live.len();
    let n_in = plan.num_inputs();
    let n_out = plan.num_outputs();
    if plan.is_float() {
        grow(&mut engine.in_f, n * n_in, 0.0);
        grow(&mut engine.out_f, n * n_out, 0.0);
        for (i, (p, _)) in live.iter().enumerate() {
            let PendingInput::F32(v) = &p.input else {
                unreachable!("f32 plan queued a Q input");
            };
            engine.in_f[i * n_in..(i + 1) * n_in].copy_from_slice(v);
        }
        let xs = &engine.in_f[..n * n_in];
        let out = &mut engine.out_f[..n * n_out];
        if workers > 1 {
            // The dispatcher is a plain thread (never a pool worker),
            // so the row-split driver's no-nesting rule holds by
            // construction.
            batch::run_plan_rowsplit_into(plan, xs, n, workers, out);
        } else {
            plan.run_batch_f32_into(xs, n, &mut engine.scratch, out);
        }
        (0..n)
            .map(|i| Output::F32(engine.out_f[i * n_out..(i + 1) * n_out].to_vec()))
            .collect()
    } else {
        grow(&mut engine.in_q, n * n_in, 0);
        grow(&mut engine.out_q, n * n_out, 0);
        for (i, (p, _)) in live.iter().enumerate() {
            let PendingInput::Q(v) = &p.input else {
                unreachable!("Q plan queued an f32 input");
            };
            engine.in_q[i * n_in..(i + 1) * n_in].copy_from_slice(v);
        }
        let xs = &engine.in_q[..n * n_in];
        let out = &mut engine.out_q[..n * n_out];
        if workers > 1 {
            batch::run_plan_q_rowsplit_into(plan, xs, n, workers, out);
        } else {
            plan.run_batch_q_into(xs, n, &mut engine.scratch, out);
        }
        (0..n)
            .map(|i| Output::Q(engine.out_q[i * n_out..(i + 1) * n_out].to_vec()))
            .collect()
    }
}

fn grow<T: Clone>(buf: &mut Vec<T>, need: usize, fill: T) {
    if buf.len() < need {
        buf.resize(need, fill);
    }
}

/// Extract a human-readable detail from a caught panic payload.
fn panic_detail(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn send_reply(p: &Pending, outcome: Result<Output, InferError>, latency_us: u64, batch_size: usize) {
    // A gone client (dropped receiver) is not an error; the work was
    // already shared with the rest of the batch.
    let _ = p.reply.send(Reply {
        ticket: p.ticket,
        outcome,
        latency_us,
        batch_size,
    });
}

/// The multi-tenant inference host. See the [module docs](super::host)
/// for the dataflow and fault-tolerance contract; [`ModelRegistry`] for
/// registration and quarantine; [`BatchPolicy`] for the flush/shed/
/// budget knobs.
pub struct InferenceService {
    inner: Arc<Inner>,
    supervisors: Vec<JoinHandle<()>>,
}

impl InferenceService {
    /// A manual-mode service (no threads): flush decisions run only
    /// when [`pump`](Self::pump) / [`pump_at`](Self::pump_at) /
    /// [`drain`](Self::drain) are called. The deterministic harness
    /// the scheduler and fault tests drive. Single-shard; see
    /// [`new_sharded`](Self::new_sharded) for the sharded form.
    pub fn new(registry: Arc<ModelRegistry>, policy: &BatchPolicy) -> Self {
        Self::new_with_faults(registry, policy, None)
    }

    /// Manual mode with an injected [`FaultPlan`] (chaos testing).
    pub fn new_with_faults(
        registry: Arc<ModelRegistry>,
        policy: &BatchPolicy,
        faults: Option<FaultPlan>,
    ) -> Self {
        Self::new_sharded(registry, policy, &ShardPolicy::single(), faults)
    }

    /// A manual-mode service with an explicit [`ShardPolicy`]: each
    /// shard owns its own queue set and execution engine, and
    /// [`pump_at`](Self::pump_at) / [`drain`](Self::drain) sweep every
    /// shard — so virtual-clock tests can drive a sharded service with
    /// zero threads.
    pub fn new_sharded(
        registry: Arc<ModelRegistry>,
        policy: &BatchPolicy,
        shard_policy: &ShardPolicy,
        faults: Option<FaultPlan>,
    ) -> Self {
        let shard_policy = shard_policy.normalized();
        let shards = (0..shard_policy.shards).map(|_| Shard::new()).collect();
        let inner = Arc::new(Inner {
            registry,
            policy: policy.normalized(),
            shard_policy,
            faults,
            shards,
            metrics: Mutex::new(MetricsSnapshot::default()),
            next_ticket: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
        });
        Self { inner, supervisors: Vec::new() }
    }

    /// A started service: spawns one watchdog supervisor per shard,
    /// each running that shard's dispatcher thread (sleeping until the
    /// nearest queue deadline or a submit wakeup, flushing whatever is
    /// ready) and respawning it — failing, never leaking, that shard's
    /// pending requests — if it dies. Single-shard; see
    /// [`start_sharded`](Self::start_sharded).
    pub fn start(registry: Arc<ModelRegistry>, policy: &BatchPolicy) -> Self {
        Self::start_with_faults(registry, policy, None)
    }

    /// Started mode with an injected [`FaultPlan`] (chaos testing).
    pub fn start_with_faults(
        registry: Arc<ModelRegistry>,
        policy: &BatchPolicy,
        faults: Option<FaultPlan>,
    ) -> Self {
        Self::start_sharded(registry, policy, &ShardPolicy::single(), faults)
    }

    /// Started mode with an explicit [`ShardPolicy`]: one
    /// watchdog/dispatcher thread pair per shard, each supervising only
    /// its own shard's queues.
    pub fn start_sharded(
        registry: Arc<ModelRegistry>,
        policy: &BatchPolicy,
        shard_policy: &ShardPolicy,
        faults: Option<FaultPlan>,
    ) -> Self {
        let mut svc = Self::new_sharded(registry, policy, shard_policy, faults);
        for idx in 0..svc.inner.shards.len() {
            let inner = Arc::clone(&svc.inner);
            let handle = std::thread::Builder::new()
                .name(format!("svc-watchdog-{idx}"))
                .spawn(move || supervisor_loop(&inner, idx))
                // Invariant: no request has been accepted yet (the
                // service is still being constructed), so failing to
                // start here leaks nothing — propagating the spawn
                // error is correct.
                .expect("spawn watchdog supervisor at service start");
            svc.supervisors.push(handle);
        }
        svc
    }

    /// How many dispatcher shards this service runs.
    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    /// The shard serving `model` (pin-aware) — what
    /// [`ShardMetrics`] rows and the load/chaos harnesses key on.
    pub fn shard_of(&self, model: &str) -> usize {
        self.inner.shard_of(model)
    }

    /// Submit one sample for `model` on behalf of `tenant` at the real
    /// clock — [`submit_at`](Self::submit_at) with `Instant::now()`.
    pub fn submit(
        &self,
        model: &str,
        tenant: u64,
        input: &[f32],
        reply: &mpsc::Sender<Reply>,
    ) -> Result<u64, SubmitError> {
        self.submit_at(model, tenant, input, reply, Instant::now())
    }

    /// Submit one sample at an explicit clock `now` (quarantine
    /// cooldowns and queue deadlines are measured on it, so manual-mode
    /// tests can drive the whole admit/flush/timeout timeline
    /// virtually). On success the request is queued and the returned
    /// ticket will eventually arrive on `reply` as exactly one terminal
    /// [`Reply`]. Rejections ([`SubmitError`]) are synchronous and
    /// leave nothing queued.
    pub fn submit_at(
        &self,
        model: &str,
        tenant: u64,
        input: &[f32],
        reply: &mpsc::Sender<Reply>,
        now: Instant,
    ) -> Result<u64, SubmitError> {
        let Some(m) = self.inner.registry.get(model) else {
            return Err(SubmitError::UnknownModel(model.to_string()));
        };
        let plan = m.plan();
        if input.len() != plan.num_inputs() {
            return Err(SubmitError::BadInputWidth {
                expected: plan.num_inputs(),
                got: input.len(),
            });
        }
        let pending_input = if plan.is_float() {
            // NaN/inf would poison every sample coalesced into the same
            // kernel call; Q plans are immune (quantize saturates).
            if let Some(index) = input.iter().position(|v| !v.is_finite()) {
                return Err(SubmitError::BadInput { index });
            }
            PendingInput::F32(input.to_vec())
        } else {
            // Invariant: `!plan.is_float()` implies a Q plan, and every
            // Q plan is compiled with a decimal point.
            let dec = plan.decimal_point().expect("Q plan has a decimal point");
            PendingInput::Q(input.iter().map(|&v| quantize(v, dec)).collect())
        };

        // Circuit breaker: quarantined models fast-reject; the first
        // submit after the cooldown becomes the half-open probe.
        let admission = self.inner.registry.admit(model, now);
        if admission == Admission::Reject {
            let mut metrics = lock_recover(&self.inner.metrics);
            metrics
                .models
                .entry(model.to_string())
                .or_default()
                .rejected_quarantined += 1;
            return Err(SubmitError::Quarantined { model: model.to_string() });
        }
        let is_probe = admission == Admission::Probe;

        let ticket = self.inner.next_ticket.fetch_add(1, Ordering::Relaxed);
        let pending = Pending {
            ticket,
            tenant,
            input: pending_input,
            reply: reply.clone(),
            is_probe,
        };
        let shard = &self.inner.shards[self.inner.shard_of(model)];
        let pushed = {
            let mut st = lock_recover(&shard.state);
            let q = st
                .queues
                .entry(model.to_string())
                .or_insert_with(|| MicroBatchQueue::new(&self.inner.policy));
            // Capture the queue's own push-time peak under the same
            // lock as the push: the metrics gauge samples depth at
            // transitions and can miss a spike that rises and drains
            // between samples — this counter cannot.
            q.push(pending, now)
                .map(|depth| (depth, q.peak_depth()))
                .map_err(|_| q.capacity())
        };
        match pushed {
            Ok((depth, peak)) => {
                shard.wake.notify_all();
                self.inner.registry.touch(model, now);
                let mut metrics = lock_recover(&self.inner.metrics);
                let mm = metrics.models.entry(model.to_string()).or_default();
                mm.requests += 1;
                mm.note_depth(depth);
                mm.note_peak(peak);
                if is_probe {
                    mm.quarantine_probes += 1;
                }
                metrics.tenants.entry(tenant).or_default().requests += 1;
                Ok(ticket)
            }
            Err(capacity) => {
                // The shed probe never executes — release its slot so
                // the next submit can probe instead.
                if is_probe {
                    self.inner.registry.release_probe(model);
                }
                let mut metrics = lock_recover(&self.inner.metrics);
                metrics.models.entry(model.to_string()).or_default().shed += 1;
                metrics.tenants.entry(tenant).or_default().shed += 1;
                Err(SubmitError::QueueFull { capacity })
            }
        }
    }

    /// Manual pump at the real clock — [`pump_at`](Self::pump_at) with
    /// `Instant::now()`.
    pub fn pump(&self) -> usize {
        self.pump_at(Instant::now())
    }

    /// Execute every batch whose size or deadline trigger has fired as
    /// of `now`, sweeping every shard; returns how many batches ran.
    /// Passing a future instant makes deadline flushes (and
    /// request-budget timeouts) happen deterministically in tests —
    /// without sleeping. Safe to call alongside running dispatchers
    /// (both just take ready batches under each shard's lock).
    pub fn pump_at(&self, now: Instant) -> usize {
        let mut ran = 0;
        for idx in 0..self.inner.shards.len() {
            loop {
                let taken = lock_recover(&self.inner.shards[idx].state).take_ready(now);
                match taken {
                    Some((id, b, depth)) => {
                        self.inner.execute_batch(idx, &id, b, depth, now);
                        ran += 1;
                    }
                    None => break,
                }
            }
        }
        ran
    }

    /// Flush *everything* still queued, ready or not (partial batches
    /// execute with [`FlushReason::Drain`](super::FlushReason::Drain)),
    /// sweeping every shard; returns how many batches ran. Used at
    /// shutdown and by tests.
    pub fn drain(&self) -> usize {
        let mut ran = 0;
        for idx in 0..self.inner.shards.len() {
            loop {
                let taken = lock_recover(&self.inner.shards[idx].state).take_any();
                match taken {
                    Some((id, b, depth)) => {
                        self.inner.execute_batch(idx, &id, b, depth, Instant::now());
                        ran += 1;
                    }
                    None => break,
                }
            }
        }
        ran
    }

    /// TTL idle eviction: remove every registered model whose last
    /// accepted submit (or registration) is at least `ttl` before
    /// `now` *and* whose queue is empty — a model with requests still
    /// waiting is never evicted, so the terminal-reply invariant is
    /// untouched. Evicted models drop their plan, breaker state and
    /// shard pin; their historical metrics rows remain. Returns the
    /// evicted ids. Time-parametric like the rest of the scheduler, so
    /// tests drive it on a virtual clock; callers run it as a periodic
    /// maintenance sweep.
    pub fn evict_idle(&self, ttl: Duration, now: Instant) -> Vec<String> {
        let mut evicted = Vec::new();
        for id in self.inner.registry.idle_candidates(ttl, now) {
            let shard = &self.inner.shards[self.inner.shard_of(&id)];
            let removed_queue = {
                let mut st = lock_recover(&shard.state);
                match st.queues.get(&id) {
                    Some(q) if !q.is_empty() => continue, // live work — keep
                    Some(_) => {
                        st.queues.remove(&id);
                        true
                    }
                    None => true,
                }
            };
            if removed_queue && self.inner.registry.remove(&id) {
                evicted.push(id);
            }
        }
        if !evicted.is_empty() {
            let mut metrics = lock_recover(&self.inner.metrics);
            metrics.models_evicted += evicted.len() as u64;
        }
        evicted
    }

    /// Fail every still-queued request with [`InferError::Aborted`]
    /// (each gets its terminal reply; nothing executes, nothing leaks)
    /// and return how many were failed. This is the watchdog's policy
    /// across a dispatcher restart, exposed for tests and for
    /// operational teardown-without-drain.
    pub fn fail_pending(&self, detail: &str) -> usize {
        self.inner.fail_all_pending(detail)
    }

    /// A consistent snapshot of every per-model / per-tenant counter,
    /// including the watchdog's restart and heartbeat counts.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.inner.snapshot()
    }

    /// The registry this service serves from.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.inner.registry
    }

    /// Stop the service: the dispatcher (if any) drains every queue and
    /// exits; in manual mode the queues are drained inline. Every
    /// accepted request has received its terminal reply when this
    /// returns. Returns the final metrics snapshot — unlike
    /// [`metrics`](Self::metrics) mid-run, it is guaranteed to account
    /// for every batch (replies are sent before counters are bumped, so
    /// a mid-run snapshot can trail the last reply by one batch).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.finish();
        self.inner.snapshot()
    }

    fn finish(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        for shard in &self.inner.shards {
            shard.wake.notify_all();
        }
        if self.supervisors.is_empty() {
            self.drain();
        } else {
            for h in self.supervisors.drain(..) {
                let _ = h.join();
            }
            // Belt and braces: if a dispatcher died during shutdown,
            // its supervisor already failed that shard's pending set; a
            // clean exit leaves nothing queued. Either way this is a
            // no-op unless something slipped in between.
            self.inner.fail_all_pending("service shut down");
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.finish();
    }
}

/// One shard's watchdog: run that shard's dispatcher, and when it dies
/// (a panic that escaped batch isolation — e.g. an injected dispatcher
/// kill), fail *that shard's* pending requests with their terminal
/// `Aborted` replies and respawn it. Other shards never notice. A
/// clean dispatcher exit means shutdown completed.
fn supervisor_loop(inner: &Arc<Inner>, shard: usize) {
    loop {
        let worker = Arc::clone(inner);
        let handle = match std::thread::Builder::new()
            .name(format!("svc-dispatch-{shard}"))
            .spawn(move || dispatcher_loop(&worker, shard))
        {
            Ok(h) => h,
            Err(_) => {
                // OS refused a thread: nothing can execute on this
                // shard anymore, so fail its pending instead of leaking
                // and stop supervising it.
                inner.fail_shard_pending(shard, "dispatcher spawn failed");
                return;
            }
        };
        if handle.join().is_ok() {
            // Clean exit: the dispatcher drained everything at
            // shutdown.
            return;
        }
        inner.shards[shard].restarts.fetch_add(1, Ordering::Relaxed);
        inner.fail_shard_pending(shard, "dispatcher restarted after panic");
        if inner.shutdown.load(Ordering::Acquire) {
            return;
        }
    }
}

/// One shard's dispatcher: wait for a trigger, take the oldest ready
/// batch among *this shard's* queues, execute it outside the lock,
/// repeat. On shutdown, drain this shard's queues (partial batches run
/// with `FlushReason::Drain`) before exiting. Each loop iteration
/// bumps the shard's heartbeat/iteration counter — the watchdog's
/// liveness signal and, on the kill-target shard only (see
/// [`Inner::kill_shard`]), the [`FaultPlan`] kill key.
fn dispatcher_loop(inner: &Inner, shard: usize) {
    let me = &inner.shards[shard];
    let kill_here = inner.faults.is_some() && inner.kill_shard() == shard;
    loop {
        let iter = me.dispatch_iters.fetch_add(1, Ordering::Relaxed);
        if kill_here {
            // Invariant: `kill_here` implies `faults` is Some.
            let f = inner.faults.as_ref().expect("kill target has a fault plan");
            if f.should_kill_dispatcher(iter) {
                // Injected outside any batch scope: no request is held
                // here, so the watchdog can fail this shard's pending
                // and respawn without a single reply being lost.
                panic!("injected dispatcher kill (shard {shard}, iteration {iter})");
            }
        }
        let taken = {
            let mut st = lock_recover(&me.state);
            loop {
                let now = Instant::now();
                if let Some(t) = st.take_ready(now) {
                    break Some((t, now));
                }
                if inner.shutdown.load(Ordering::Acquire) {
                    break st.take_any().map(|t| (t, now));
                }
                // Sleep until the nearest deadline can fire (floored so
                // an imminent deadline never busy-spins), or idle-tick
                // when every queue is empty. Submits notify the condvar,
                // so light traffic still gets sub-delay wakeups.
                let wait = match st.next_deadline() {
                    Some(d) => d
                        .saturating_duration_since(now)
                        .max(Duration::from_micros(50)),
                    None => Duration::from_millis(20),
                };
                let (guard, _) = me
                    .wake
                    .wait_timeout(st, wait)
                    .unwrap_or_else(PoisonError::into_inner);
                st = guard;
            }
        };
        match taken {
            Some(((id, b, depth), now)) => inner.execute_batch(shard, &id, b, depth, now),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, Network};
    use crate::util::rng::Rng;

    fn registry_with(sizes: &[usize], id: &str) -> Arc<ModelRegistry> {
        let mut rng = Rng::new(11);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        let reg = Arc::new(ModelRegistry::new());
        reg.register(id, &n).unwrap();
        reg
    }

    fn plan_for(sizes: &[usize], seed: u64) -> ExecPlan {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        ExecPlan::compile(&n)
    }

    #[test]
    fn equal_head_instants_tie_break_on_model_id() {
        // Two models whose queue heads were enqueued at the *same*
        // instant: cross-model fairness must break the tie on model id
        // ("a" before "z"), independent of submission order.
        let reg = Arc::new(ModelRegistry::new());
        reg.register_plan("a", plan_for(&[2, 3, 1], 1)).unwrap();
        reg.register_plan("z", plan_for(&[2, 3, 1], 2)).unwrap();
        let policy = BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        // "z" is submitted first — and must still execute second.
        let tz = svc.submit_at("z", 0, &[0.1, 0.2], &tx, t0).unwrap();
        let ta = svc.submit_at("a", 0, &[0.3, 0.4], &tx, t0).unwrap();
        assert_eq!(svc.pump_at(t0), 2);
        let first = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let second = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.ticket, ta, "equal heads: smallest model id serves first");
        assert_eq!(second.ticket, tz);
    }

    #[test]
    fn sharded_manual_service_routes_pins_and_rolls_up_per_shard() {
        let reg = Arc::new(ModelRegistry::new());
        reg.register_plan("a", plan_for(&[2, 3, 1], 3)).unwrap();
        reg.register_plan("b", plan_for(&[2, 3, 1], 4)).unwrap();
        reg.pin_shard("a", 0);
        reg.pin_shard("b", 1);
        let policy = BatchPolicy {
            max_batch: 1,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new_sharded(reg, &policy, &ShardPolicy::new(2), None);
        assert_eq!(svc.shard_count(), 2);
        assert_eq!((svc.shard_of("a"), svc.shard_of("b")), (0, 1));
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        svc.submit_at("a", 1, &[0.1, 0.2], &tx, t0).unwrap();
        svc.submit_at("b", 2, &[0.3, 0.4], &tx, t0).unwrap();
        svc.submit_at("b", 2, &[0.5, 0.6], &tx, t0).unwrap();
        assert_eq!(svc.pump_at(t0), 3, "pump sweeps every shard");
        for _ in 0..3 {
            assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        }
        let m = svc.metrics();
        assert_eq!(m.shards.len(), 2);
        assert_eq!(m.shards[0].models, vec!["a".to_string()]);
        assert_eq!(m.shards[1].models, vec!["b".to_string()]);
        assert_eq!((m.shards[0].completed, m.shards[1].completed), (1, 2));
        // Per-shard rows reconcile with the aggregate counters.
        assert_eq!(
            m.shards.iter().map(|s| s.requests).sum::<u64>(),
            m.total_requests()
        );
        assert_eq!(
            m.shards.iter().map(|s| s.completed).sum::<u64>(),
            m.total_completed()
        );
    }

    #[test]
    fn idle_models_evict_on_ttl_but_never_with_queued_work() {
        let reg = Arc::new(ModelRegistry::new());
        let t0 = Instant::now();
        reg.register_plan_at("idle", plan_for(&[2, 3, 1], 5), t0).unwrap();
        reg.register_plan_at("busy", plan_for(&[2, 3, 1], 6), t0).unwrap();
        let policy = BatchPolicy {
            max_batch: 100,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let ttl = Duration::from_secs(3);
        let (tx, rx) = mpsc::channel();
        svc.submit_at("idle", 0, &[0.1, 0.2], &tx, t0).unwrap();
        let t1 = t0 + Duration::from_secs(2);
        svc.submit_at("busy", 0, &[0.3, 0.4], &tx, t1).unwrap();
        // "idle" is past its TTL relative to a far-future now, but has
        // a queued request — never evicted while work is waiting.
        assert!(svc.evict_idle(ttl, t0 + Duration::from_secs(10)).is_empty());
        svc.drain();
        assert_eq!(rx.try_iter().count(), 2);
        // t2: "idle" last active t0 (3.5s ago ≥ ttl) → evicted;
        // "busy" last active t1 (1.5s ago < ttl) → kept.
        let t2 = t0 + Duration::from_millis(3500);
        assert_eq!(svc.evict_idle(ttl, t2), vec!["idle".to_string()]);
        assert_eq!(
            svc.submit_at("idle", 0, &[0.1, 0.2], &tx, t2),
            Err(SubmitError::UnknownModel("idle".to_string())),
            "an evicted model is gone"
        );
        assert!(svc.submit_at("busy", 0, &[0.3, 0.4], &tx, t2).is_ok());
        let m = svc.metrics();
        assert_eq!(m.models_evicted, 1);
        assert!(m.models.contains_key("idle"), "historical metrics row remains");
        svc.drain();
    }

    #[test]
    fn manual_pump_respects_size_trigger() {
        let reg = registry_with(&[3, 4, 2], "m");
        let policy = BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        svc.submit("m", 1, &[0.1, 0.2, 0.3], &tx).unwrap();
        // One waiting request, huge deadline: nothing is ready.
        assert_eq!(svc.pump(), 0);
        svc.submit("m", 2, &[0.4, 0.5, 0.6], &tx).unwrap();
        // Size trigger: one batch of two.
        assert_eq!(svc.pump(), 1);
        let a = rx.recv().unwrap();
        let b = rx.recv().unwrap();
        assert!(a.is_ok() && b.is_ok());
        assert_eq!(a.batch_size, 2);
        assert_eq!(b.batch_size, 2);
        assert!(a.ticket != b.ticket);
        let m = svc.metrics();
        assert_eq!(m.models["m"].size_flushes, 1);
        assert_eq!(m.models["m"].completed, 2);
        assert_eq!(m.tenants[&1].completed, 1);
    }

    #[test]
    fn submit_validates_model_width_and_finiteness() {
        let reg = registry_with(&[3, 4, 2], "m");
        let svc = InferenceService::new(reg, &BatchPolicy::default());
        let (tx, _rx) = mpsc::channel();
        assert_eq!(
            svc.submit("nope", 0, &[0.0; 3], &tx),
            Err(SubmitError::UnknownModel("nope".to_string()))
        );
        assert_eq!(
            svc.submit("m", 0, &[0.0; 5], &tx),
            Err(SubmitError::BadInputWidth { expected: 3, got: 5 })
        );
        assert_eq!(
            svc.submit("m", 0, &[0.0, f32::NAN, 0.0], &tx),
            Err(SubmitError::BadInput { index: 1 })
        );
        assert_eq!(
            svc.submit("m", 0, &[f32::INFINITY, 0.0, 0.0], &tx),
            Err(SubmitError::BadInput { index: 0 })
        );
        // Rejections leave no trace in the accepted-request counters.
        assert_eq!(svc.metrics().total_requests(), 0);
    }

    #[test]
    fn shutdown_in_manual_mode_drains_pending_requests() {
        let reg = registry_with(&[2, 3, 1], "m");
        let policy = BatchPolicy {
            max_batch: 100,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        for _ in 0..3 {
            svc.submit("m", 7, &[0.5, -0.5], &tx).unwrap();
        }
        let snap = svc.shutdown();
        let replies: Vec<Reply> = rx.try_iter().collect();
        assert_eq!(replies.len(), 3);
        assert!(replies.iter().all(|r| r.is_ok() && r.batch_size == 3));
        assert_eq!(snap.total_completed(), 3);
        assert_eq!(snap.models["m"].drain_flushes, 1);
    }

    #[test]
    fn fail_pending_aborts_queued_requests_with_terminal_replies() {
        let reg = registry_with(&[2, 3, 1], "m");
        let policy = BatchPolicy {
            max_batch: 100,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        for t in 0..4u64 {
            svc.submit("m", t, &[0.5, -0.5], &tx).unwrap();
        }
        assert_eq!(svc.fail_pending("test abort"), 4);
        let replies: Vec<Reply> = rx.try_iter().collect();
        assert_eq!(replies.len(), 4);
        for r in &replies {
            assert_eq!(
                r.outcome,
                Err(InferError::Aborted { detail: "test abort".to_string() })
            );
            assert_eq!(r.batch_size, 0);
        }
        let m = svc.metrics();
        assert_eq!(m.models["m"].aborted, 4);
        assert_eq!(m.total_failed(), 4);
        assert_eq!(m.total_completed(), 0);
        // Nothing left: a second call is a no-op.
        assert_eq!(svc.fail_pending("again"), 0);
    }

    #[test]
    fn stale_requests_time_out_instead_of_executing() {
        let reg = registry_with(&[2, 3, 1], "m");
        let policy = BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_secs(3600),
            request_budget: Some(Duration::from_millis(10)),
            ..BatchPolicy::default()
        };
        let svc = InferenceService::new(reg, &policy);
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        // First request goes stale (submitted at t0, second arrives
        // 20ms later on the virtual clock → size trigger fires at a
        // `now` where the first has blown its 10ms budget).
        svc.submit_at("m", 1, &[0.5, -0.5], &tx, t0).unwrap();
        let t1 = t0 + Duration::from_millis(20);
        svc.submit_at("m", 2, &[0.25, 0.75], &tx, t1).unwrap();
        assert_eq!(svc.pump_at(t1), 1);
        let mut ok = 0;
        let mut timed_out = 0;
        for _ in 0..2 {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match r.outcome {
                Ok(_) => {
                    ok += 1;
                    assert_eq!(r.batch_size, 1, "only the live request executed");
                }
                Err(InferError::Timeout { waited_us, budget_us }) => {
                    timed_out += 1;
                    assert_eq!(budget_us, 10_000);
                    assert!(waited_us >= 10_000, "waited {waited_us}");
                    assert_eq!(r.batch_size, 0);
                }
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert_eq!((ok, timed_out), (1, 1));
        let m = svc.metrics();
        assert_eq!(m.models["m"].timeouts, 1);
        assert_eq!(m.models["m"].completed, 1);
    }

    #[test]
    fn injected_exec_panic_fails_only_that_batch() {
        let reg = registry_with(&[2, 3, 1], "m");
        let policy = BatchPolicy {
            max_batch: 2,
            max_delay: Duration::from_secs(3600),
            ..BatchPolicy::default()
        };
        // Exec attempt #0 panics; #1 onward succeed.
        let faults = FaultPlan {
            panic_model: "m".to_string(),
            panic_from: 0,
            panic_until: 1,
            ..FaultPlan::default()
        };
        let svc = InferenceService::new_with_faults(reg, &policy, Some(faults));
        let (tx, rx) = mpsc::channel();
        for t in 0..2u64 {
            svc.submit("m", t, &[0.5, -0.5], &tx).unwrap();
        }
        assert_eq!(svc.pump(), 1);
        for _ in 0..2 {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match r.outcome {
                Err(InferError::ExecFailed { detail }) => {
                    assert!(detail.contains("injected exec fault"), "{detail}");
                }
                other => panic!("expected ExecFailed, got {other:?}"),
            }
        }
        // The next batch executes normally — the panic was contained.
        for t in 0..2u64 {
            svc.submit("m", 10 + t, &[0.5, -0.5], &tx).unwrap();
        }
        assert_eq!(svc.pump(), 1);
        for _ in 0..2 {
            assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
        }
        let m = svc.metrics();
        assert_eq!(m.models["m"].exec_failures, 1);
        assert_eq!(m.models["m"].failed, 2);
        assert_eq!(m.models["m"].completed, 2);
    }
}
