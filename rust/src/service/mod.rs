//! Multi-tenant inference host — the request-level layer above the
//! compiled [`crate::kernels::ExecPlan`] / [`crate::bench::batch`]
//! execution stack.
//!
//! The paper's end game (§VII) is continuous real-time classification
//! for *fleets* of wearable devices. The per-inference kernels are only
//! half of that story: sustained node throughput comes from how
//! per-client single-sample requests are coalesced onto the batched
//! zero-allocation execution path. This module provides that layer:
//!
//! * [`ModelRegistry`] — many compiled [`crate::kernels::ExecPlan`]s
//!   keyed by model id, shared immutably across threads.
//! * [`MicroBatchQueue`] — the pure adaptive micro-batching core: a
//!   bounded FIFO per model that flushes on batch-size *or* deadline,
//!   whichever comes first, and sheds (rejects) arrivals when full.
//!   Time is a parameter, so every flush decision is unit-testable
//!   without sleeping.
//! * [`InferenceService`] — the host: clients [`submit`] single
//!   samples; a dispatcher coalesces each model's queue into one
//!   `run_batch_*_into` call on a persistent [`crate::kernels::PlanScratch`]
//!   (zero steady-state allocation on the execute path) and scatters
//!   the outputs back to per-client reply channels. Batched execution
//!   is bit-identical per sample to single-sample runs (pinned by
//!   `rust/tests/batch_consistency.rs`), so coalescing never changes
//!   any client's answer — `rust/tests/service.rs` re-pins this end to
//!   end across f32/q32/packed plans.
//! * [`ShardPolicy`] — the service's parallelism axis: models are
//!   assigned (static FNV hash, or explicit
//!   [`ModelRegistry::pin_shard`] pins) to N dispatcher *shards*, each
//!   owning its own queue set, wake trigger, execution engine, and
//!   watchdog, so a panicking or slow model only ever stalls its own
//!   shard — the serving-layer analogue of the paper's per-core work
//!   partitioning on the octa-core cluster. Every invariant below
//!   holds per shard and in aggregate.
//! * [`MetricsSnapshot`] — per-model and per-tenant counters (requests,
//!   completed, shed, batches, flush causes, queue depth) plus a
//!   log-bucketed latency histogram with p50/p99 accessors.
//! * [`load`] — the synthetic load harness behind the `service load`
//!   CLI: replays tens of thousands of simulated wearable clients from
//!   the seeded [`crate::datasets::wearable`] generators, asserts every
//!   coalesced output bit-exact against serial per-request execution,
//!   and writes `BENCH_service.json` for the CI ratchet.
//!
//! The layer is built to *survive* faults, not just schedule around
//! load — the paper's deployment target is an always-on wearable where
//! a wedged pipeline is a dead device:
//!
//! * **Panic isolation** — each batch executes under `catch_unwind`;
//!   a panicking kernel fails only that batch's requests with a typed
//!   [`InferError::ExecFailed`] reply. Every accepted request gets
//!   exactly one terminal reply — success, timeout, or error — never
//!   silence.
//! * **Model quarantine** — [`ModelRegistry`] runs a per-model circuit
//!   breaker ([`BreakerPolicy`]): consecutive execution failures trip
//!   the model into a `Quarantined` state that fast-rejects at submit,
//!   then a half-open probe after a cooldown decides recovery.
//! * **Watchdog supervision** — started services run the dispatcher
//!   under a supervisor that detects dispatcher death, fails (never
//!   leaks) pending requests, and respawns the dispatcher.
//! * **Deadline budgets** — [`BatchPolicy::request_budget`] answers
//!   stale queued requests [`InferError::Timeout`] instead of
//!   executing them.
//! * **Fault injection** — a seeded deterministic [`FaultPlan`]
//!   (exec panics, latency spikes, dispatcher kills, poisoned inputs)
//!   drives the [`chaos`] harness behind the `service chaos` CLI,
//!   which writes `BENCH_chaos.json` and is asserted in CI.
//!
//! Beyond in-process channels, the service also speaks real sockets:
//!
//! * **Wire front-end** — [`frame`] defines a length-prefixed binary
//!   protocol (magic/version/request-id/model-tag/tenant/dtype/payload
//!   with typed response frames), and [`wire::WireServer`] accepts
//!   Unix-domain-socket and TCP connections, feeding every decoded
//!   request through the same [`submit`] path so batching, sharding,
//!   and breaker semantics are inherited unchanged. Per-connection
//!   frame-size/in-flight limits and read/write deadlines bound what
//!   an adversarial peer can cost; wire counters land in
//!   [`MetricsSnapshot::wire`], and `service {serve,load,chaos}`
//!   expose it on the CLI (`--wire` drives the harnesses over UDS).
//!
//! [`submit`]: InferenceService::submit

pub mod chaos;
pub mod faults;
pub mod frame;
pub mod host;
pub mod load;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod shard;
pub mod wire;

pub use faults::FaultPlan;
pub use frame::{FrameError, RequestFrame, ResponseBody, ResponseFrame, WireDtype};
pub use host::{InferenceService, Output, Reply};
pub use metrics::{
    LatencyHistogram, MetricsSnapshot, ModelMetrics, ShardMetrics, TenantCounters, WireCounters,
};
pub use queue::{AdmissionController, Batch, FlushReason, MicroBatchQueue};
pub use registry::{Admission, BreakerEvent, BreakerPolicy, HealthState, ModelRegistry, ServiceModel};
pub use shard::{ShardPolicy, MAX_SHARDS};
pub use wire::{WireClient, WireConfig, WireError, WireServer};

use std::time::Duration;

/// Adaptive micro-batching policy: when a model's queue flushes, how
/// much it may hold, and how a coalesced batch executes.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are waiting (the size
    /// trigger). Clamped to ≥ 1.
    pub max_batch: usize,
    /// Flush when the *oldest* waiting request has been queued this
    /// long (the deadline trigger) — bounds worst-case added latency
    /// when traffic is light.
    pub max_delay: Duration,
    /// Bounded-queue capacity per model; arrivals beyond it are shed
    /// (rejected with [`SubmitError::QueueFull`]) instead of growing
    /// the queue without bound. Clamped to ≥ `max_batch`.
    pub queue_capacity: usize,
    /// Worker threads for executing one coalesced batch through the
    /// neuron-parallel row-split driver
    /// ([`crate::bench::batch::run_plan_rowsplit_into`]); `0` or `1`
    /// keeps the serial plan path (best for small models, where the
    /// per-layer barrier costs more than it buys).
    pub exec_workers: usize,
    /// Per-request deadline budget: a request that has already waited
    /// longer than this when its batch is taken for execution is
    /// answered [`InferError::Timeout`] instead of executed — a stale
    /// answer to a real-time classification request is worthless, and
    /// skipping it sheds load exactly when the service is furthest
    /// behind. `None` (the default) never times requests out.
    pub request_budget: Option<Duration>,
    /// Run an [`AdmissionController`] per queue: an EWMA over observed
    /// inter-arrival gaps auto-tunes the deadline trigger down to
    /// roughly the time a size flush needs at the current rate, clamped
    /// to [`max_delay`](Self::max_delay) as the upper bound. Off by
    /// default (the deadline window stays the static `max_delay`).
    pub adaptive_delay: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
            queue_capacity: 1024,
            exec_workers: 1,
            request_budget: None,
            adaptive_delay: false,
        }
    }
}

impl BatchPolicy {
    /// The policy with its invariants enforced (`max_batch ≥ 1`,
    /// `queue_capacity ≥ max_batch`) — applied once at queue/service
    /// construction so the scheduler core never re-checks.
    pub fn normalized(&self) -> Self {
        let max_batch = self.max_batch.max(1);
        Self {
            max_batch,
            max_delay: self.max_delay,
            queue_capacity: self.queue_capacity.max(max_batch),
            exec_workers: self.exec_workers,
            request_budget: self.request_budget,
            adaptive_delay: self.adaptive_delay,
        }
    }
}

/// Why [`InferenceService::submit`] rejected a request. Rejections are
/// synchronous and deterministic: nothing was enqueued, no ticket was
/// issued, and the caller decides whether to retry (backpressure) or
/// drop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// No model with this id in the registry.
    UnknownModel(String),
    /// Input length does not match the model's input layer.
    BadInputWidth {
        /// The model's expected input width.
        expected: usize,
        /// The submitted sample's length.
        got: usize,
    },
    /// The model's bounded queue is at capacity — the request was shed.
    QueueFull {
        /// The capacity the queue was at when the request was shed.
        capacity: usize,
    },
    /// A non-finite (NaN/inf) value in an f32-plan input. Q-family
    /// plans quantize (and so saturate) at submit time; the f32 path
    /// would propagate the poison through every sample coalesced into
    /// the same batch's kernel call, so it is rejected up front —
    /// mirroring the NaN/inf hardening in [`crate::fann::io`].
    BadInput {
        /// Index of the first non-finite element in the submitted
        /// sample.
        index: usize,
    },
    /// The model is quarantined: its circuit breaker tripped after
    /// consecutive execution failures and the cooldown has not elapsed
    /// (or a half-open probe is already in flight). Fast-rejected at
    /// submit so a broken model cannot consume queue space or
    /// execution time. See [`BreakerPolicy`].
    Quarantined {
        /// The quarantined model's id.
        model: String,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(id) => write!(f, "unknown model {id:?}"),
            SubmitError::BadInputWidth { expected, got } => {
                write!(f, "bad input width: expected {expected}, got {got}")
            }
            SubmitError::QueueFull { capacity } => {
                write!(f, "queue full (capacity {capacity}): request shed")
            }
            SubmitError::BadInput { index } => {
                write!(f, "non-finite input value at index {index} (NaN/inf rejected)")
            }
            SubmitError::Quarantined { model } => {
                write!(f, "model {model:?} is quarantined (circuit breaker open)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an *accepted* request failed — the error side of a terminal
/// [`Reply`]. Every accepted request gets exactly one terminal reply:
/// a successful [`Output`] or one of these. (Rejected submits never
/// enter the queue and are reported synchronously via [`SubmitError`].)
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// The batch this request rode in panicked during execution (a
    /// kernel bug or an injected fault). The panic was caught at the
    /// batch boundary; only this batch's requests fail.
    ExecFailed {
        /// The caught panic payload (or a placeholder for non-string
        /// payloads).
        detail: String,
    },
    /// The request waited longer than the configured
    /// [`BatchPolicy::request_budget`] before its batch was taken, so
    /// it was answered instead of executed stale.
    Timeout {
        /// How long the request had waited when it was timed out (µs).
        waited_us: u64,
        /// The configured budget (µs).
        budget_us: u64,
    },
    /// The request was failed without execution — the dispatcher died
    /// and the watchdog failed all pending requests before respawning
    /// it, or the service was torn down abnormally.
    Aborted {
        /// Human-readable cause (e.g. `"dispatcher restarted"`).
        detail: String,
    },
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::ExecFailed { detail } => write!(f, "batch execution failed: {detail}"),
            InferError::Timeout { waited_us, budget_us } => {
                write!(f, "request timed out (waited {waited_us} us, budget {budget_us} us)")
            }
            InferError::Aborted { detail } => write!(f, "request aborted: {detail}"),
        }
    }
}

impl std::error::Error for InferError {}
