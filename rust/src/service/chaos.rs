//! The seeded chaos harness behind `service chaos`.
//!
//! Replays the same three wearable models as the [`super::load`]
//! harness — `emg-q7` (packed Q7), `ecg-q32` (Q32), `eeg-f32` (f32) —
//! through a *started* [`InferenceService`], but with a deterministic
//! [`FaultPlan`] injected: a window of `emg-q7` executions panics (so
//! the circuit breaker must trip, probe, and recover), random batches
//! get latency spikes, a fraction of `eeg-f32` requests carry
//! NaN-poisoned inputs (which submit-time validation must reject), and
//! the dispatcher is killed at chosen loop iterations (which the
//! watchdog must survive by failing pending requests and respawning).
//!
//! The harness then audits the fault-tolerance contract end to end:
//!
//! * **Exactly one terminal reply per accepted request** — no lost
//!   replies, no duplicates, every reply a success or a typed
//!   [`InferError`](super::InferError).
//! * **Quarantine round-trip** — the breaker tripped (> 0 trips),
//!   admitted probes, and recovered (> 0 recoveries) once the panic
//!   window passed.
//! * **Watchdog supervision** — every injected dispatcher kill was
//!   survived (restarts ≥ 1 when kills are planned) and the run still
//!   completed.
//! * **Bit-exactness under chaos** — every *successful* reply still
//!   matches the precomputed serial per-sample reference bit for bit:
//!   faults may fail requests, but they may never corrupt an answer.
//!
//! With `--wire` ([`ChaosOptions::wire`]) the same schedule rides the
//! socket front-end instead of in-process channels: submitters become
//! real Unix-domain-socket clients of a [`WireServer`], every injected
//! fault must round-trip the frame protocol as a typed response frame,
//! and NaN poisoning must come back as `BadFrame` rejections — proving
//! the fault-tolerance contract holds across the wire boundary too.
//!
//! [`ChaosReport::to_json`] serializes the audit as
//! `BENCH_chaos.json` (schema `fann-on-mcu/bench-chaos/v1`; field
//! dictionary in the README "Fault tolerance" section), and
//! [`ChaosReport::check`] turns any violated invariant into an error —
//! the CLI writes the artifact first, then fails loudly, and CI
//! re-asserts the invariants from the JSON.

use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

use super::faults::FaultPlan;
use super::frame::{RequestFrame, ResponseBody};
use super::host::{InferenceService, Output};
use super::load::{
    build_models, connect_with_retry, pool_index, shard_rows_json, shed_backoff, wire_json,
    LoadModel, MAX_SHED_RETRIES,
};
use super::metrics::{MetricsSnapshot, ShardMetrics, WireCounters};
use super::registry::{BreakerPolicy, ModelRegistry};
use super::shard::ShardPolicy;
use super::wire::{temp_uds_path, WireClient, WireConfig, WireError, WireServer};
use super::{BatchPolicy, InferError, SubmitError};

/// How many times a client retries one quarantine-rejected request
/// before giving up. Deliberately generous: retries are what deliver
/// half-open probes through consecutive cooldowns, so the budget must
/// outlast the panic window's worth of probe → fail → cooldown rounds.
pub const MAX_QUARANTINE_RETRIES: u32 = 800;

/// Backoff before quarantine-retry `attempt`: a flat 300–600 µs
/// jittered wait — long enough for cooldowns to elapse between
/// attempts, short enough that probes flow promptly after one does.
fn quarantine_backoff(attempt: u32, salt: u64) -> Duration {
    let h = (salt.rotate_left(13) ^ u64::from(attempt))
        .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Duration::from_micros(300 + (h >> 48) % 300)
}

/// Chaos-harness configuration. `Default` is the full CI run;
/// [`ChaosOptions::quick`] is the smoke-test size.
#[derive(Debug, Clone)]
pub struct ChaosOptions {
    /// Simulated wearable clients (each is one tenant id).
    pub clients: usize,
    /// Requests each client attempts.
    pub requests_per_client: usize,
    /// Seed for model weights, input pools and the request schedule
    /// (also the default [`FaultPlan`] seed).
    pub seed: u64,
    /// Submitter threads the clients are sharded across.
    pub submitters: usize,
    /// Dispatcher shards the service runs; injected dispatcher kills
    /// target only the shard hosting the fault plan's panic model.
    pub shards: usize,
    /// Drive the run over the wire front-end (`service chaos --wire`):
    /// submitters become real Unix-domain-socket clients of a
    /// [`WireServer`], so every injected fault must round-trip the
    /// frame protocol — quarantine/abort/exec-failure as typed
    /// response frames, NaN poisoning as `BadFrame` rejections — with
    /// every invariant below intact across the socket boundary.
    pub wire: bool,
    /// Scheduler policy for the run (includes the request budget that
    /// produces `Timeout` replies under pressure).
    pub policy: BatchPolicy,
    /// Circuit-breaker policy for the run's registry.
    pub breaker: BreakerPolicy,
    /// The injected fault schedule.
    pub plan: FaultPlan,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        let seed = 11;
        Self {
            clients: 10_000,
            requests_per_client: 4,
            seed,
            submitters: 4,
            shards: 1,
            wire: false,
            policy: BatchPolicy {
                max_batch: 32,
                max_delay: Duration::from_millis(1),
                queue_capacity: 4096,
                request_budget: Some(Duration::from_millis(500)),
                ..BatchPolicy::default()
            },
            breaker: BreakerPolicy {
                failure_threshold: 5,
                cooldown: Duration::from_millis(5),
            },
            plan: FaultPlan {
                seed,
                panic_model: "emg-q7".to_string(),
                panic_from: 20,
                panic_until: 60,
                spike_prob: 0.005,
                spike: Duration::from_millis(2),
                nan_prob: 0.03,
                kill_at_iters: vec![0, 64],
                ..FaultPlan::default()
            },
        }
    }
}

impl ChaosOptions {
    /// The smoke-test size: same fault families, CI-cheap.
    pub fn quick() -> Self {
        let seed = 11;
        Self {
            clients: 1_500,
            requests_per_client: 2,
            policy: BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_micros(200),
                queue_capacity: 512,
                request_budget: Some(Duration::from_millis(500)),
                ..BatchPolicy::default()
            },
            breaker: BreakerPolicy {
                failure_threshold: 3,
                cooldown: Duration::from_millis(3),
            },
            plan: FaultPlan {
                seed,
                panic_model: "emg-q7".to_string(),
                panic_from: 10,
                panic_until: 25,
                spike_prob: 0.002,
                spike: Duration::from_millis(1),
                nan_prob: 0.02,
                kill_at_iters: vec![0],
                ..FaultPlan::default()
            },
            ..Self::default()
        }
    }

    /// Requests the schedule attempts (accepted + rejected).
    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }
}

/// What one chaos submitter thread observed.
#[derive(Debug, Default)]
struct ChaosStats {
    accepted: u64,
    replies_ok: u64,
    replies_exec_failed: u64,
    replies_timeout: u64,
    replies_aborted: u64,
    rejected_bad_input: u64,
    shed_gave_up: u64,
    quarantined_gave_up: u64,
    quarantined_rejects: u64,
    shed_retries: u64,
    lost_replies: u64,
    duplicate_replies: u64,
    mismatches: u64,
    resets: u64,
}

impl ChaosStats {
    fn absorb(&mut self, o: &ChaosStats) {
        self.accepted += o.accepted;
        self.replies_ok += o.replies_ok;
        self.replies_exec_failed += o.replies_exec_failed;
        self.replies_timeout += o.replies_timeout;
        self.replies_aborted += o.replies_aborted;
        self.rejected_bad_input += o.rejected_bad_input;
        self.shed_gave_up += o.shed_gave_up;
        self.quarantined_gave_up += o.quarantined_gave_up;
        self.quarantined_rejects += o.quarantined_rejects;
        self.shed_retries += o.shed_retries;
        self.lost_replies += o.lost_replies;
        self.duplicate_replies += o.duplicate_replies;
        self.mismatches += o.mismatches;
        self.resets += o.resets;
    }
}

/// One chaos submitter: submit its client range under the fault plan
/// (poisoning the planned requests, retrying sheds and quarantine
/// rejects within bounded budgets), then collect exactly one terminal
/// reply per accepted ticket, classifying and bit-checking each.
fn chaos_submitter(
    svc: &InferenceService,
    models: &[LoadModel],
    plan: &FaultPlan,
    clients: Range<usize>,
    requests_per_client: usize,
) -> ChaosStats {
    let (tx, rx) = mpsc::channel();
    let mut expect: HashMap<u64, (usize, usize)> = HashMap::new();
    let mut stats = ChaosStats::default();
    let mut poisoned: Vec<f32> = Vec::new();
    for c in clients {
        let mi = c % models.len();
        let m = &models[mi];
        for r in 0..requests_per_client {
            let pi = pool_index(c, r, m.pool_samples);
            let input = &m.pool_f[pi * m.n_in..(pi + 1) * m.n_in];
            if m.plan.is_float() && plan.poison_input(c as u64, r as u64) {
                // A poisoned request: submit-time validation must
                // synchronously reject it, leaving nothing queued.
                poisoned.clear();
                poisoned.extend_from_slice(input);
                poisoned[pi % m.n_in] = f32::NAN;
                match svc.submit(m.id, c as u64, &poisoned, &tx) {
                    Err(SubmitError::BadInput { .. }) => stats.rejected_bad_input += 1,
                    // Anything else means validation regressed; the
                    // mismatch count fails the run's bit_exact gate.
                    other => {
                        stats.mismatches += 1;
                        if let Ok(ticket) = other {
                            expect.insert(ticket, (mi, pi));
                            stats.accepted += 1;
                        }
                    }
                }
                continue;
            }
            let mut shed_attempts = 0u32;
            let mut quar_attempts = 0u32;
            loop {
                match svc.submit(m.id, c as u64, input, &tx) {
                    Ok(ticket) => {
                        expect.insert(ticket, (mi, pi));
                        stats.accepted += 1;
                        break;
                    }
                    Err(SubmitError::QueueFull { .. }) => {
                        if shed_attempts >= MAX_SHED_RETRIES {
                            stats.shed_gave_up += 1;
                            break;
                        }
                        stats.shed_retries += 1;
                        std::thread::sleep(shed_backoff(shed_attempts, c as u64));
                        shed_attempts += 1;
                    }
                    Err(SubmitError::Quarantined { .. }) => {
                        stats.quarantined_rejects += 1;
                        if quar_attempts >= MAX_QUARANTINE_RETRIES {
                            stats.quarantined_gave_up += 1;
                            break;
                        }
                        std::thread::sleep(quarantine_backoff(quar_attempts, c as u64));
                        quar_attempts += 1;
                    }
                    Err(e) => panic!("chaos submit failed unexpectedly: {e}"),
                }
            }
        }
    }
    // Exactly one terminal reply per accepted ticket: removing from
    // `expect` detects duplicates, what's left at the end is lost.
    while !expect.is_empty() {
        let Ok(reply) = rx.recv_timeout(Duration::from_secs(120)) else {
            break;
        };
        let Some((mi, pi)) = expect.remove(&reply.ticket) else {
            stats.duplicate_replies += 1;
            continue;
        };
        let m = &models[mi];
        match &reply.outcome {
            Ok(out) => {
                stats.replies_ok += 1;
                let ok = match out {
                    Output::F32(v) => v[..] == m.expected_f[pi * m.n_out..(pi + 1) * m.n_out],
                    Output::Q(v) => v[..] == m.expected_q[pi * m.n_out..(pi + 1) * m.n_out],
                };
                if !ok {
                    stats.mismatches += 1;
                }
            }
            Err(InferError::ExecFailed { .. }) => stats.replies_exec_failed += 1,
            Err(InferError::Timeout { .. }) => stats.replies_timeout += 1,
            Err(InferError::Aborted { .. }) => stats.replies_aborted += 1,
        }
    }
    stats.lost_replies += expect.len() as u64;
    stats
}

/// The wire-mode chaos submitter: the same schedule, poison
/// expectations, and retry budgets as [`chaos_submitter`], but every
/// request travels the harness's Unix socket as a length-prefixed
/// frame, lockstep (send one, wait for its terminal frame). The
/// in-process expect-map becomes the lockstep id check: a frame for
/// an id we are not waiting on is a protocol desync, counted as a
/// mismatch and a reset. Poisoned submits must come back as
/// `BadFrame` rejections — submit-time NaN validation now runs on the
/// far side of the socket. Connection resets reconnect-and-retry
/// within the shed budget, counted in `resets` so the report can
/// refuse to trust service-side counters a reset may have inflated.
fn wire_chaos_submitter(
    path: &Path,
    models: &[LoadModel],
    plan: &FaultPlan,
    clients: Range<usize>,
    requests_per_client: usize,
) -> ChaosStats {
    let mut stats = ChaosStats::default();
    let mut conn: Option<WireClient> = None;
    let mut poisoned: Vec<f32> = Vec::new();
    'clients: for c in clients {
        let mi = c % models.len();
        let m = &models[mi];
        for r in 0..requests_per_client {
            let pi = pool_index(c, r, m.pool_samples);
            let input = &m.pool_f[pi * m.n_in..(pi + 1) * m.n_in];
            let poison = m.plan.is_float() && plan.poison_input(c as u64, r as u64);
            let payload: Vec<f32> = if poison {
                poisoned.clear();
                poisoned.extend_from_slice(input);
                poisoned[pi % m.n_in] = f32::NAN;
                poisoned.clone()
            } else {
                input.to_vec()
            };
            let req = RequestFrame {
                // Unique per client: requests_per_client is far below
                // 2^20, so client and request index cannot collide.
                id: ((c as u64) << 20) | r as u64,
                tenant: c as u64,
                model: m.id.to_string(),
                input: payload,
            };
            let mut shed_attempts = 0u32;
            let mut quar_attempts = 0u32;
            loop {
                if conn.is_none() {
                    match connect_with_retry(path) {
                        Some(client) => conn = Some(client),
                        None => {
                            // Server unreachable: everything this client
                            // still owes is a counted give-up, never a
                            // silent drop.
                            stats.shed_gave_up += (requests_per_client - r) as u64;
                            continue 'clients;
                        }
                    }
                }
                let client = conn.as_mut().expect("connection just ensured");
                match client.call(&req) {
                    Ok(resp) if resp.id == req.id => {
                        if poison {
                            // Submit-time validation lives on the server
                            // side of the socket now; the only correct
                            // answer to a poisoned frame is `BadFrame`
                            // (the frame decodes — NaN is representable
                            // on the wire by design — but submit must
                            // reject it).
                            match resp.body {
                                ResponseBody::BadFrame { .. } => stats.rejected_bad_input += 1,
                                // Validation regressed: the mismatch
                                // fails the bit_exact gate; a terminal
                                // body is still classified so the
                                // accounting ledger closes.
                                other => {
                                    stats.mismatches += 1;
                                    match other {
                                        ResponseBody::Ok { .. } => {
                                            stats.accepted += 1;
                                            stats.replies_ok += 1;
                                        }
                                        ResponseBody::Timeout { .. } => {
                                            stats.accepted += 1;
                                            stats.replies_timeout += 1;
                                        }
                                        ResponseBody::ExecFailed { .. } => {
                                            stats.accepted += 1;
                                            stats.replies_exec_failed += 1;
                                        }
                                        ResponseBody::Aborted { .. } => {
                                            stats.accepted += 1;
                                            stats.replies_aborted += 1;
                                        }
                                        _ => {}
                                    }
                                }
                            }
                            break;
                        }
                        match resp.body {
                            ResponseBody::Ok { ref output, .. } => {
                                stats.accepted += 1;
                                stats.replies_ok += 1;
                                let ok = match output {
                                    Output::F32(v) => {
                                        v[..] == m.expected_f[pi * m.n_out..(pi + 1) * m.n_out]
                                    }
                                    Output::Q(v) => {
                                        v[..] == m.expected_q[pi * m.n_out..(pi + 1) * m.n_out]
                                    }
                                };
                                if !ok {
                                    stats.mismatches += 1;
                                }
                                break;
                            }
                            ResponseBody::Shed { .. } => {
                                if shed_attempts >= MAX_SHED_RETRIES {
                                    stats.shed_gave_up += 1;
                                    break;
                                }
                                stats.shed_retries += 1;
                                std::thread::sleep(shed_backoff(shed_attempts, c as u64));
                                shed_attempts += 1;
                            }
                            ResponseBody::Quarantined { .. } => {
                                stats.quarantined_rejects += 1;
                                if quar_attempts >= MAX_QUARANTINE_RETRIES {
                                    stats.quarantined_gave_up += 1;
                                    break;
                                }
                                std::thread::sleep(quarantine_backoff(quar_attempts, c as u64));
                                quar_attempts += 1;
                            }
                            ResponseBody::Timeout { .. } => {
                                stats.accepted += 1;
                                stats.replies_timeout += 1;
                                break;
                            }
                            ResponseBody::ExecFailed { .. } => {
                                stats.accepted += 1;
                                stats.replies_exec_failed += 1;
                                break;
                            }
                            ResponseBody::Aborted { .. } => {
                                stats.accepted += 1;
                                stats.replies_aborted += 1;
                                break;
                            }
                            ResponseBody::BadFrame { detail } => {
                                panic!("well-formed chaos request rejected as bad frame: {detail}")
                            }
                        }
                    }
                    Ok(_) => {
                        // A frame for an id we are not waiting on breaks
                        // the lockstep protocol — treat the stream as
                        // desynced: count it and resync on a fresh
                        // connection.
                        stats.mismatches += 1;
                        conn = None;
                        stats.resets += 1;
                        if shed_attempts >= MAX_SHED_RETRIES {
                            stats.shed_gave_up += 1;
                            break;
                        }
                        shed_attempts += 1;
                    }
                    Err(WireError::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        // The terminal frame never arrived inside the
                        // client budget — the lost-reply invariant this
                        // harness exists to catch.
                        stats.lost_replies += 1;
                        break;
                    }
                    Err(_) => {
                        // Reset mid-request: the service may or may not
                        // have executed it (its reply died with the
                        // socket). Reconnect and retry, counted, so the
                        // report never double-trusts service counters a
                        // reset may have inflated.
                        conn = None;
                        stats.resets += 1;
                        if shed_attempts >= MAX_SHED_RETRIES {
                            stats.shed_gave_up += 1;
                            break;
                        }
                        stats.shed_retries += 1;
                        std::thread::sleep(shed_backoff(shed_attempts, c as u64));
                        shed_attempts += 1;
                    }
                }
            }
        }
    }
    stats
}

/// Everything a chaos run measured — the in-memory form of
/// `BENCH_chaos.json`.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// The configuration (including the fault plan) that produced this
    /// report.
    pub options: ChaosOptions,
    /// Requests the schedule attempted.
    pub total_requests: usize,
    /// Requests accepted into queues (got a ticket).
    pub accepted: u64,
    /// Accepted requests answered with a successful output.
    pub replies_ok: u64,
    /// Accepted requests answered `ExecFailed` (their batch panicked).
    pub replies_exec_failed: u64,
    /// Accepted requests answered `Timeout` (stale past the budget).
    pub replies_timeout: u64,
    /// Accepted requests answered `Aborted` (dispatcher restart).
    pub replies_aborted: u64,
    /// Poisoned submits rejected by NaN/inf validation.
    pub rejected_bad_input: u64,
    /// Requests abandoned after the shed-retry budget.
    pub shed_gave_up: u64,
    /// Requests abandoned after the quarantine-retry budget.
    pub quarantined_gave_up: u64,
    /// Individual quarantine fast-rejections observed (each retried).
    pub quarantined_rejects: u64,
    /// Accepted requests that never received a terminal reply — the
    /// invariant violation this harness exists to catch; must be 0.
    pub lost_replies: u64,
    /// Tickets that received more than one reply; must be 0.
    pub duplicate_replies: u64,
    /// Successful replies whose output diverged from the per-sample
    /// reference (plus poisoned submits that were wrongly accepted);
    /// must be 0.
    pub mismatches: u64,
    /// Circuit-breaker trips across all models.
    pub quarantine_trips: u64,
    /// Half-open probes admitted across all models.
    pub quarantine_probes: u64,
    /// Breaker recoveries across all models.
    pub quarantine_recoveries: u64,
    /// Times the watchdog respawned a dead dispatcher.
    pub watchdog_restarts: u64,
    /// Dispatcher loop iterations observed (liveness heartbeat).
    pub dispatcher_heartbeats: u64,
    /// Batch executions that panicked (caught at the batch boundary).
    pub exec_failures: u64,
    /// Median latency (µs) of successful replies, all models.
    pub p50_us: u64,
    /// 99th-percentile latency (µs) of successful replies, all models.
    pub p99_us: u64,
    /// p99 (µs) of successful replies on the fault-injected model.
    pub p99_us_faulted_model: u64,
    /// p99 (µs) of successful replies on the healthy models.
    pub p99_us_healthy_models: u64,
    /// Wall time of the chaos phase (first submit → last reply).
    pub wall_seconds: f64,
    /// `lost_replies == 0 && duplicate_replies == 0` and the service's
    /// own counters agree: `completed + failed == accepted`.
    pub accounting_ok: bool,
    /// `mismatches == 0`: no fault corrupted any delivered answer.
    pub bit_exact_ok: bool,
    /// Per-shard counter rows from the service snapshot.
    pub shard_rows: Vec<ShardMetrics>,
    /// The per-shard rows sum back to the aggregate counters: completed,
    /// failed, watchdog restarts, and dispatcher heartbeats all
    /// reconcile shard-by-shard.
    pub shard_accounting_ok: bool,
    /// Wire counters from the harness's socket front-end (`Some` only
    /// for `--wire` runs).
    pub wire: Option<WireCounters>,
    /// Connection resets wire submitters survived by reconnecting.
    /// When nonzero, the service-side `completed + failed == accepted`
    /// clause of `accounting_ok` is waived: a reset can double-execute
    /// a request whose first reply died with its socket. The
    /// lost/duplicate clauses always apply.
    pub wire_resets: u64,
}

/// Run the chaos harness: build the load models, start a service with
/// the injected [`FaultPlan`], replay the schedule, and audit the
/// fault-tolerance contract. Errors only on setup failure — invariant
/// violations land in the report so the caller can serialize it first,
/// then fail via [`ChaosReport::check`].
pub fn run(opts: &ChaosOptions) -> Result<ChaosReport> {
    ensure!(opts.clients > 0 && opts.requests_per_client > 0, "empty chaos configuration");
    let models = build_models(opts.seed, 40)?;
    let registry = Arc::new(ModelRegistry::with_breaker(opts.breaker.clone()));
    for m in &models {
        registry.register_plan(m.id, m.plan.clone())?;
    }
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &opts.policy,
        &ShardPolicy::new(opts.shards),
        Some(opts.plan.clone()),
    ));

    let mut wire_path: Option<PathBuf> = None;
    let wire_server = if opts.wire {
        let cfg = WireConfig {
            // Generous deadlines: harness clients are cooperative, and
            // the reply-wait bound lives client-side.
            read_timeout: Some(Duration::from_secs(150)),
            write_timeout: Some(Duration::from_secs(30)),
            ..WireConfig::default()
        };
        let mut server = WireServer::start(Arc::clone(&svc), &cfg);
        let path = temp_uds_path("chaos");
        server.listen_uds(&path).context("binding chaos-harness UDS")?;
        wire_path = Some(path);
        Some(server)
    } else {
        None
    };

    let submitters = opts.submitters.clamp(1, opts.clients);
    let t0 = Instant::now();
    let per_thread: Vec<ChaosStats> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(submitters);
        let base = opts.clients / submitters;
        let extra = opts.clients % submitters;
        let mut start = 0usize;
        for i in 0..submitters {
            let len = base + usize::from(i < extra);
            let range = start..start + len;
            start += len;
            let svc_ref: &InferenceService = &svc;
            let models_ref = &models;
            let plan_ref = &opts.plan;
            let rpc = opts.requests_per_client;
            let path_ref = wire_path.as_deref();
            handles.push(s.spawn(move || match path_ref {
                Some(p) => wire_chaos_submitter(p, models_ref, plan_ref, range, rpc),
                None => chaos_submitter(svc_ref, models_ref, plan_ref, range, rpc),
            }));
        }
        handles
            .into_iter()
            // A panicking submitter is a harness bug, not an injected
            // fault (faults live inside the service); propagate it.
            .map(|h| h.join().expect("chaos submitter thread"))
            .collect()
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    // Wire teardown first (it half-closes connections and aborts
    // anything still in flight), then the service; shutdown() joins
    // the dispatchers, so the snapshot accounts for every batch.
    let wire_counters = wire_server.map(|server| {
        let (svc_back, counters) = server.shutdown();
        drop(svc_back);
        counters
    });
    let Ok(svc) = Arc::try_unwrap(svc) else {
        anyhow::bail!("service Arc still shared after wire shutdown")
    };
    let mut snap = svc.shutdown();
    if let Some(c) = wire_counters {
        snap.wire = c;
    }

    let mut stats = ChaosStats::default();
    for s in &per_thread {
        stats.absorb(s);
    }
    Ok(assemble_report(opts, stats, &snap, &models, wall_seconds))
}

fn assemble_report(
    opts: &ChaosOptions,
    stats: ChaosStats,
    snap: &MetricsSnapshot,
    models: &[LoadModel],
    wall_seconds: f64,
) -> ChaosReport {
    let merged = snap.merged_latency();
    let faulted = &opts.plan.panic_model;
    let p99_faulted = snap
        .models
        .get(faulted)
        .map(|m| m.latency.p99())
        .unwrap_or(0);
    let mut healthy = crate::service::metrics::LatencyHistogram::new();
    for m in models {
        if m.id != faulted {
            if let Some(mm) = snap.models.get(m.id) {
                healthy.merge(&mm.latency);
            }
        }
    }
    let exec_failures: u64 = snap.models.values().map(|m| m.exec_failures).sum();
    let probes: u64 = snap.models.values().map(|m| m.quarantine_probes).sum();
    // A wire reset can double-execute a request whose first reply died
    // with its socket, so the service-counter clause only binds on
    // reset-free runs; lost/duplicate always bind.
    let counters_reconcile = snap.total_completed() + snap.total_failed() == stats.accepted;
    let accounting_ok = stats.lost_replies == 0
        && stats.duplicate_replies == 0
        && (counters_reconcile || stats.resets > 0);
    let shard_completed: u64 = snap.shards.iter().map(|s| s.completed).sum();
    let shard_failed: u64 = snap.shards.iter().map(|s| s.failed).sum();
    let shard_restarts: u64 = snap.shards.iter().map(|s| s.restarts).sum();
    let shard_heartbeats: u64 = snap.shards.iter().map(|s| s.heartbeats).sum();
    let shard_accounting_ok = !snap.shards.is_empty()
        && shard_completed == snap.total_completed()
        && shard_failed == snap.total_failed()
        && shard_restarts == snap.watchdog_restarts
        && shard_heartbeats == snap.dispatcher_heartbeats;
    ChaosReport {
        options: opts.clone(),
        total_requests: opts.total_requests(),
        accepted: stats.accepted,
        replies_ok: stats.replies_ok,
        replies_exec_failed: stats.replies_exec_failed,
        replies_timeout: stats.replies_timeout,
        replies_aborted: stats.replies_aborted,
        rejected_bad_input: stats.rejected_bad_input,
        shed_gave_up: stats.shed_gave_up,
        quarantined_gave_up: stats.quarantined_gave_up,
        quarantined_rejects: stats.quarantined_rejects,
        lost_replies: stats.lost_replies,
        duplicate_replies: stats.duplicate_replies,
        mismatches: stats.mismatches,
        quarantine_trips: snap.total_quarantine_trips(),
        quarantine_probes: probes,
        quarantine_recoveries: snap.total_quarantine_recoveries(),
        watchdog_restarts: snap.watchdog_restarts,
        dispatcher_heartbeats: snap.dispatcher_heartbeats,
        exec_failures,
        p50_us: merged.p50(),
        p99_us: merged.p99(),
        p99_us_faulted_model: p99_faulted,
        p99_us_healthy_models: healthy.p99(),
        wall_seconds,
        accounting_ok,
        bit_exact_ok: stats.mismatches == 0,
        shard_rows: snap.shards.clone(),
        shard_accounting_ok,
        wire: opts.wire.then_some(snap.wire),
        wire_resets: stats.resets,
    }
}

impl ChaosReport {
    /// Error on the first violated fault-tolerance invariant. Called by
    /// the CLI *after* the report has been written, so a red run still
    /// leaves the full `BENCH_chaos.json` behind for diagnosis.
    pub fn check(&self) -> Result<()> {
        ensure!(
            self.accounting_ok,
            "reply accounting broken: {} lost, {} duplicate replies \
             (accepted {}, terminal {})",
            self.lost_replies,
            self.duplicate_replies,
            self.accepted,
            self.replies_ok + self.replies_exec_failed + self.replies_timeout + self.replies_aborted,
        );
        ensure!(
            self.bit_exact_ok,
            "{} successful replies diverged from the serial reference under faults",
            self.mismatches
        );
        ensure!(
            self.shard_accounting_ok,
            "per-shard counters do not reconcile with the aggregate \
             ({} shard rows)",
            self.shard_rows.len()
        );
        let plan = &self.options.plan;
        if plan.panic_until > plan.panic_from && !plan.panic_model.is_empty() {
            ensure!(self.exec_failures > 0, "panic window injected but no execution failed");
            ensure!(self.quarantine_trips > 0, "execution failures never tripped the breaker");
            ensure!(
                self.quarantine_recoveries > 0,
                "the breaker tripped but never recovered ({} trips, {} probes)",
                self.quarantine_trips,
                self.quarantine_probes
            );
        }
        if !plan.kill_at_iters.is_empty() {
            ensure!(
                self.watchdog_restarts > 0,
                "dispatcher kills injected but the watchdog never restarted it"
            );
        }
        if plan.nan_prob > 0.0 {
            ensure!(
                self.rejected_bad_input > 0,
                "poisoned inputs injected but none was rejected at submit"
            );
        }
        Ok(())
    }

    /// Serialize as the `BENCH_chaos.json` document (schema
    /// `fann-on-mcu/bench-chaos/v1`; field dictionary in the README
    /// "Fault tolerance" section).
    pub fn to_json(&self) -> Json {
        let o = &self.options;
        let p = &o.policy;
        let plan = &o.plan;
        Json::obj()
            .field("schema", "fann-on-mcu/bench-chaos/v1")
            .field("seed", Json::Int(o.seed as i64))
            .field("clients", o.clients)
            .field("requests_per_client", o.requests_per_client)
            .field("total_requests", self.total_requests)
            .field(
                "policy",
                Json::obj()
                    .field("max_batch", p.max_batch)
                    .field("max_delay_us", p.max_delay.as_micros() as usize)
                    .field("queue_capacity", p.queue_capacity)
                    .field("exec_workers", p.exec_workers)
                    .field(
                        "request_budget_us",
                        Json::Int(p.request_budget.unwrap_or(Duration::ZERO).as_micros() as i64),
                    )
                    .field("submitters", o.submitters)
                    .build(),
            )
            .field("shards", o.shards.max(1))
            .field("shards_detail", shard_rows_json(&self.shard_rows))
            .field(
                "breaker",
                Json::obj()
                    .field("failure_threshold", Json::Int(i64::from(o.breaker.failure_threshold)))
                    .field("cooldown_us", Json::Int(o.breaker.cooldown.as_micros() as i64))
                    .build(),
            )
            .field(
                "fault_plan",
                Json::obj()
                    .field("panic_model", plan.panic_model.as_str())
                    .field("panic_from", Json::Int(plan.panic_from as i64))
                    .field("panic_until", Json::Int(plan.panic_until as i64))
                    .field("spike_prob", plan.spike_prob)
                    .field("spike_us", Json::Int(plan.spike.as_micros() as i64))
                    .field("spike_model", plan.spike_model.as_str())
                    .field("nan_prob", plan.nan_prob)
                    .field(
                        "kill_at_iters",
                        Json::Arr(
                            plan.kill_at_iters
                                .iter()
                                .map(|&i| Json::Int(i as i64))
                                .collect::<Vec<_>>(),
                        ),
                    )
                    .build(),
            )
            .field("accepted", Json::Int(self.accepted as i64))
            .field(
                "replies",
                Json::obj()
                    .field("ok", Json::Int(self.replies_ok as i64))
                    .field("exec_failed", Json::Int(self.replies_exec_failed as i64))
                    .field("timeout", Json::Int(self.replies_timeout as i64))
                    .field("aborted", Json::Int(self.replies_aborted as i64))
                    .build(),
            )
            .field(
                "rejects",
                Json::obj()
                    .field("bad_input", Json::Int(self.rejected_bad_input as i64))
                    .field("shed_gave_up", Json::Int(self.shed_gave_up as i64))
                    .field("quarantined_gave_up", Json::Int(self.quarantined_gave_up as i64))
                    .field("quarantined_rejects", Json::Int(self.quarantined_rejects as i64))
                    .build(),
            )
            .field("lost_replies", Json::Int(self.lost_replies as i64))
            .field("duplicate_replies", Json::Int(self.duplicate_replies as i64))
            .field("mismatches", Json::Int(self.mismatches as i64))
            .field(
                "quarantine",
                Json::obj()
                    .field("trips", Json::Int(self.quarantine_trips as i64))
                    .field("probes", Json::Int(self.quarantine_probes as i64))
                    .field("recoveries", Json::Int(self.quarantine_recoveries as i64))
                    .build(),
            )
            .field("watchdog_restarts", Json::Int(self.watchdog_restarts as i64))
            .field("dispatcher_heartbeats", Json::Int(self.dispatcher_heartbeats as i64))
            .field("exec_failures", Json::Int(self.exec_failures as i64))
            .field("p50_us", Json::Int(self.p50_us as i64))
            .field("p99_us", Json::Int(self.p99_us as i64))
            .field("p99_us_faulted_model", Json::Int(self.p99_us_faulted_model as i64))
            .field("p99_us_healthy_models", Json::Int(self.p99_us_healthy_models as i64))
            .field("wall_seconds", self.wall_seconds)
            .field("wire", wire_json(self.wire.as_ref(), self.wire_resets))
            .field("accounting_ok", self.accounting_ok)
            .field("shard_accounting_ok", self.shard_accounting_ok)
            .field("bit_exact_ok", self.bit_exact_ok)
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A micro chaos run exercising every fault family end to end:
    /// panic window → trip → probes → recovery, a dispatcher kill at
    /// iteration 0 → watchdog restart, and NaN poisoning → submit
    /// rejection — all deterministic from the seed.
    #[test]
    fn micro_chaos_run_holds_every_invariant() {
        let opts = ChaosOptions {
            clients: 90,
            requests_per_client: 2,
            seed: 11,
            submitters: 2,
            shards: 2,
            wire: false,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_micros(200),
                queue_capacity: 128,
                request_budget: Some(Duration::from_secs(5)),
                ..BatchPolicy::default()
            },
            breaker: BreakerPolicy {
                failure_threshold: 2,
                cooldown: Duration::from_millis(1),
            },
            plan: FaultPlan {
                seed: 11,
                panic_model: "emg-q7".to_string(),
                panic_from: 2,
                panic_until: 4,
                nan_prob: 0.2,
                kill_at_iters: vec![0],
                ..FaultPlan::default()
            },
        };
        let report = run(&opts).unwrap();
        // The harness's own schedule knows exactly how many requests
        // were poisoned; validation must have rejected each one.
        let models = build_models(opts.seed, 40).unwrap();
        let expected_poisoned: u64 = (0..opts.clients)
            .filter(|c| models[c % models.len()].plan.is_float())
            .map(|c| {
                (0..opts.requests_per_client)
                    .filter(|&r| opts.plan.poison_input(c as u64, r as u64))
                    .count() as u64
            })
            .sum();
        assert_eq!(report.rejected_bad_input, expected_poisoned);
        assert!(expected_poisoned > 0, "seed 11 poisons at least one request");
        assert_eq!(report.lost_replies, 0);
        assert_eq!(report.duplicate_replies, 0);
        assert_eq!(report.mismatches, 0);
        assert!(report.quarantine_trips > 0);
        assert!(report.quarantine_recoveries > 0);
        assert!(report.watchdog_restarts >= 1);
        assert!(report.accounting_ok && report.bit_exact_ok);
        // Two dispatcher shards, and every per-shard counter sums back
        // to the aggregate even with kills landing on the faulted
        // model's shard only.
        assert_eq!(report.shard_rows.len(), 2);
        assert!(report.shard_accounting_ok);
        let restarts: u64 = report.shard_rows.iter().map(|s| s.restarts).sum();
        assert_eq!(restarts, report.watchdog_restarts);
        report.check().unwrap();
        let json = report.to_json().to_pretty();
        for field in [
            "\"schema\"",
            "\"fault_plan\"",
            "\"lost_replies\"",
            "\"duplicate_replies\"",
            "\"quarantine\"",
            "\"watchdog_restarts\"",
            "\"accounting_ok\"",
            "\"bit_exact_ok\"",
            "\"shards\"",
            "\"shards_detail\"",
            "\"shard_accounting_ok\"",
        ] {
            assert!(json.contains(field), "missing {field} in {json}");
        }
    }

    /// The same micro chaos families driven over the wire front-end:
    /// submitters are real UDS clients, so NaN poisoning must come
    /// back as `BadFrame` frames, quarantine/abort/exec-failure as
    /// typed response frames — and every invariant (including the
    /// deterministic poisoned-request count) must survive the socket
    /// boundary, with the wire counters reconciling on top.
    #[test]
    fn micro_wire_chaos_run_holds_every_invariant() {
        let opts = ChaosOptions {
            clients: 90,
            requests_per_client: 2,
            seed: 11,
            submitters: 2,
            shards: 2,
            wire: true,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_micros(200),
                queue_capacity: 128,
                request_budget: Some(Duration::from_secs(5)),
                ..BatchPolicy::default()
            },
            breaker: BreakerPolicy {
                failure_threshold: 2,
                cooldown: Duration::from_millis(1),
            },
            plan: FaultPlan {
                seed: 11,
                panic_model: "emg-q7".to_string(),
                panic_from: 2,
                panic_until: 4,
                nan_prob: 0.2,
                kill_at_iters: vec![0],
                ..FaultPlan::default()
            },
        };
        let report = run(&opts).unwrap();
        report.check().unwrap();
        // Cooperative clients over a local UDS: nothing should have
        // reset, so the deterministic poison schedule must match
        // exactly, just as it does in-process.
        assert_eq!(report.wire_resets, 0, "cooperative wire run reset a connection");
        let models = build_models(opts.seed, 40).unwrap();
        let expected_poisoned: u64 = (0..opts.clients)
            .filter(|c| models[c % models.len()].plan.is_float())
            .map(|c| {
                (0..opts.requests_per_client)
                    .filter(|&r| opts.plan.poison_input(c as u64, r as u64))
                    .count() as u64
            })
            .sum();
        assert!(expected_poisoned > 0, "seed 11 poisons at least one request");
        assert_eq!(report.rejected_bad_input, expected_poisoned);
        assert_eq!(report.lost_replies, 0);
        assert_eq!(report.duplicate_replies, 0);
        assert_eq!(report.mismatches, 0);
        assert!(report.quarantine_trips > 0);
        assert!(report.quarantine_recoveries > 0);
        assert!(report.watchdog_restarts >= 1);
        let w = report.wire.expect("wire run reports counters");
        assert_eq!(w.connections_opened, w.connections_closed, "connection leak");
        assert!(w.connections_opened >= opts.submitters as u64);
        // Poisoned frames decode fine (NaN is representable on the
        // wire by design) and are rejected at submit — they are not
        // protocol violations, so the bad_frames counter stays 0.
        assert_eq!(w.bad_frames, 0);
        assert!(w.frames_rx >= report.accepted + report.rejected_bad_input);
        assert!(w.frames_tx > 0 && w.bytes_rx > 0 && w.bytes_tx > 0);
        let json = report.to_json().to_pretty();
        for field in ["\"wire\"", "\"frames_rx\"", "\"bad_frames\"", "\"resets\""] {
            assert!(json.contains(field), "missing {field} in {json}");
        }
    }

    #[test]
    fn quarantine_backoff_stays_in_band() {
        for attempt in 0..32 {
            let d = quarantine_backoff(attempt, 7).as_micros() as u64;
            assert!((300..600).contains(&d), "attempt {attempt}: {d}");
        }
    }
}
