//! The model registry: many compiled [`ExecPlan`]s keyed by model id,
//! plus each model's health state machine (the circuit breaker).
//!
//! A registered model is an immutable `Arc<ServiceModel>` — the plan's
//! arena is position-independent and read-only at inference time, so
//! one registration serves every submitter thread and the dispatcher
//! concurrently without copies. Registration is cheap enough to do at
//! startup for a whole fleet of model variants; ids are unique (a
//! second registration under the same id is an error, never a silent
//! replacement of a model that in-flight requests still reference).
//!
//! Health lives beside the plans: [`BreakerPolicy::failure_threshold`]
//! consecutive execution failures trip a model from `Closed` to
//! `Open` (quarantined — submits fast-reject), the configured cooldown
//! later a single half-open probe is admitted, and its outcome decides
//! recovery (`Closed`) or another quarantine round. Every transition
//! is time-parametric — `now` is an argument — so the whole state
//! machine is unit-testable without sleeping, in the same style as the
//! scheduler core.
//!
//! The registry also carries two pieces of serving-layer placement
//! state:
//!
//! * **Shard pins** — [`pin_shard`](ModelRegistry::pin_shard) overrides
//!   the [`super::ShardPolicy`] hash for chosen models, e.g. to isolate
//!   a known-hot model on a dispatcher shard of its own.
//! * **Idle-model TTL eviction** — each model's
//!   [`last_used`](ModelRegistry::last_used) instant is seeded at
//!   registration and refreshed by [`touch`](ModelRegistry::touch) on
//!   every accepted submit; [`evict_idle`](ModelRegistry::evict_idle)
//!   removes models idle past a TTL (dropping their plan, health, and
//!   pin). In-flight `Arc<ServiceModel>` handles stay valid — eviction
//!   only stops *new* lookups. Like the breaker, every decision takes
//!   `now` as an argument, so virtual-clock tests cover the lifecycle
//!   without sleeping.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::kernels::{ExecPlan, PlanSource};

/// Circuit-breaker policy shared by every model in a registry.
#[derive(Debug, Clone)]
pub struct BreakerPolicy {
    /// Consecutive execution failures that trip a model into
    /// quarantine. Clamped to ≥ 1.
    pub failure_threshold: u32,
    /// How long a tripped model stays quarantined before one half-open
    /// probe is admitted.
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self {
            failure_threshold: 3,
            cooldown: Duration::from_millis(250),
        }
    }
}

/// A model's externally visible health, for reports and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Healthy: submits are admitted normally.
    Closed,
    /// Quarantined: submits fast-reject until the cooldown elapses.
    Open,
    /// Cooldown elapsed: one probe decides recovery vs re-quarantine.
    HalfOpen,
}

/// What [`ModelRegistry::admit`] decided for one submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Healthy model — enqueue normally.
    Accept,
    /// The model was quarantined and its cooldown has elapsed: this
    /// request is the half-open probe. The caller must mark the
    /// request so a probe that never executes (shed, timed out,
    /// aborted) can be released via
    /// [`ModelRegistry::release_probe`].
    Probe,
    /// Quarantined (cooldown pending, or a probe is already in
    /// flight) — reject with [`super::SubmitError::Quarantined`].
    Reject,
}

/// What [`ModelRegistry::note_exec`] observed — the host turns these
/// into quarantine metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerEvent {
    /// No state transition.
    None,
    /// The model just entered quarantine (threshold reached, or a
    /// half-open probe failed).
    Tripped,
    /// The model just recovered (a successful execution while
    /// half-open or quarantined).
    Recovered,
}

/// Per-model breaker state. `Closed` counts consecutive failures;
/// `Open` remembers when the cooldown ends; `HalfOpen` tracks whether
/// the single probe slot is taken.
#[derive(Debug, Clone, Copy)]
enum Health {
    Closed { consecutive_failures: u32 },
    Open { until: Instant },
    HalfOpen { probe_in_flight: bool },
}

impl Default for Health {
    fn default() -> Self {
        Health::Closed { consecutive_failures: 0 }
    }
}

/// One registered model: an id plus its compiled execution plan.
#[derive(Debug)]
pub struct ServiceModel {
    id: String,
    plan: ExecPlan,
}

impl ServiceModel {
    /// The registry key.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The compiled plan requests against this model execute through.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }
}

/// Thread-safe id → [`ServiceModel`] map plus per-model circuit
/// breakers. `BTreeMap` keeps `ids()` and every report listing
/// deterministic.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ServiceModel>>>,
    breaker: BreakerPolicy,
    health: Mutex<BTreeMap<String, Health>>,
    /// Explicit model → shard pins overriding the shard-policy hash.
    pins: Mutex<BTreeMap<String, usize>>,
    /// Per-model last-activity instants (seeded at registration,
    /// refreshed by [`touch`](Self::touch)) — the TTL-eviction input.
    last_used: Mutex<BTreeMap<String, Instant>>,
}

impl ModelRegistry {
    /// An empty registry with the default [`BreakerPolicy`].
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty registry with an explicit circuit-breaker policy.
    pub fn with_breaker(breaker: BreakerPolicy) -> Self {
        Self {
            breaker: BreakerPolicy {
                failure_threshold: breaker.failure_threshold.max(1),
                cooldown: breaker.cooldown,
            },
            ..Self::default()
        }
    }

    /// The circuit-breaker policy every model in this registry runs
    /// under.
    pub fn breaker(&self) -> &BreakerPolicy {
        &self.breaker
    }

    /// Register an already-compiled plan under `id` (last-used seeded
    /// at the real clock). Errors when the id is taken.
    pub fn register_plan(&self, id: &str, plan: ExecPlan) -> Result<()> {
        self.register_plan_at(id, plan, Instant::now())
    }

    /// Register an already-compiled plan under `id`, seeding its
    /// last-used instant at an explicit `now` — the time-parametric
    /// form virtual-clock eviction tests drive. Errors when the id is
    /// taken.
    pub fn register_plan_at(&self, id: &str, plan: ExecPlan, now: Instant) -> Result<()> {
        // Registration mutates nothing but the map, so a poisoned lock
        // (a panic elsewhere while holding it) leaves a fully valid
        // map — recover instead of cascading the panic.
        let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
        if models.contains_key(id) {
            bail!("model id {id:?} already registered");
        }
        models.insert(
            id.to_string(),
            Arc::new(ServiceModel { id: id.to_string(), plan }),
        );
        drop(models);
        self.touch(id, now);
        Ok(())
    }

    /// Compile `src` (any [`PlanSource`]: float, fixed or packed
    /// network) and register it under `id`.
    pub fn register<S: PlanSource + ?Sized>(&self, id: &str, src: &S) -> Result<()> {
        self.register_plan(id, ExecPlan::compile(src))
    }

    /// Pin `id` to dispatcher shard `shard`, overriding the
    /// [`super::ShardPolicy`] hash (the shard index is wrapped into the
    /// service's shard count at lookup). Pinning an unregistered id is
    /// allowed — the pin simply waits for the registration.
    pub fn pin_shard(&self, id: &str, shard: usize) {
        let mut pins = self.pins.lock().unwrap_or_else(|e| e.into_inner());
        pins.insert(id.to_string(), shard);
    }

    /// The explicit shard pin for `id`, if one was set.
    pub fn pinned_shard(&self, id: &str) -> Option<usize> {
        let pins = self.pins.lock().unwrap_or_else(|e| e.into_inner());
        pins.get(id).copied()
    }

    /// Record activity for `id` at `now` (monotone: an older `now`
    /// never rewinds the instant). The host calls this on every
    /// accepted submit; tests drive it with a virtual clock.
    pub fn touch(&self, id: &str, now: Instant) {
        let mut used = self.last_used.lock().unwrap_or_else(|e| e.into_inner());
        let e = used.entry(id.to_string()).or_insert(now);
        if now > *e {
            *e = now;
        }
    }

    /// When `id` was registered or last touched; `None` for unknown
    /// ids.
    pub fn last_used(&self, id: &str) -> Option<Instant> {
        let used = self.last_used.lock().unwrap_or_else(|e| e.into_inner());
        used.get(id).copied()
    }

    /// Registered models whose last activity is at least `ttl` before
    /// `now` — the eviction candidates. Sorted by id (BTreeMap order).
    pub fn idle_candidates(&self, ttl: Duration, now: Instant) -> Vec<String> {
        let models = self.models.read().unwrap_or_else(|e| e.into_inner());
        let used = self.last_used.lock().unwrap_or_else(|e| e.into_inner());
        models
            .keys()
            .filter(|id| match used.get(*id) {
                Some(&t) => now.saturating_duration_since(t) >= ttl,
                // Defensive: registration always seeds last_used, so a
                // missing entry means external state drift — treat as
                // idle so it cannot pin memory forever.
                None => true,
            })
            .cloned()
            .collect()
    }

    /// Remove `id` entirely: its plan, health state, shard pin and
    /// last-used record. Returns whether a model was actually removed.
    /// In-flight `Arc<ServiceModel>` clones remain valid; only new
    /// lookups miss.
    pub fn remove(&self, id: &str) -> bool {
        let removed = {
            let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
            models.remove(id).is_some()
        };
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        health.remove(id);
        drop(health);
        let mut pins = self.pins.lock().unwrap_or_else(|e| e.into_inner());
        pins.remove(id);
        drop(pins);
        let mut used = self.last_used.lock().unwrap_or_else(|e| e.into_inner());
        used.remove(id);
        removed
    }

    /// TTL eviction sweep: [`remove`](Self::remove) every
    /// [`idle_candidate`](Self::idle_candidates) and return the evicted
    /// ids. The registry-level sweep evicts unconditionally; the host's
    /// [`super::InferenceService::evict_idle`] wrapper additionally
    /// skips models with queued requests.
    pub fn evict_idle(&self, ttl: Duration, now: Instant) -> Vec<String> {
        let candidates = self.idle_candidates(ttl, now);
        let mut evicted = Vec::with_capacity(candidates.len());
        for id in candidates {
            if self.remove(&id) {
                evicted.push(id);
            }
        }
        evicted
    }

    /// Look up a model by id.
    pub fn get(&self, id: &str) -> Option<Arc<ServiceModel>> {
        // Readers see an always-consistent map even after a writer
        // panic (the map is updated via single `insert` calls).
        self.models.read().unwrap_or_else(|e| e.into_inner()).get(id).cloned()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission decision for one submit to `id` at time `now`:
    /// healthy models accept, quarantined models reject until the
    /// cooldown elapses, and the first submit after the cooldown is
    /// admitted as the single half-open probe.
    pub fn admit(&self, id: &str, now: Instant) -> Admission {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        let h = health.entry(id.to_string()).or_default();
        match *h {
            Health::Closed { .. } => Admission::Accept,
            Health::Open { until } => {
                if now < until {
                    Admission::Reject
                } else {
                    *h = Health::HalfOpen { probe_in_flight: true };
                    Admission::Probe
                }
            }
            Health::HalfOpen { probe_in_flight: false } => {
                *h = Health::HalfOpen { probe_in_flight: true };
                Admission::Probe
            }
            Health::HalfOpen { probe_in_flight: true } => Admission::Reject,
        }
    }

    /// Record one execution outcome for `id` at time `now` and apply
    /// the breaker transition: a success closes the breaker (a
    /// [`BreakerEvent::Recovered`] if it was open/half-open); a failure
    /// counts toward [`BreakerPolicy::failure_threshold`] and trips —
    /// or re-trips a failed half-open probe — into quarantine until
    /// `now + cooldown`.
    pub fn note_exec(&self, id: &str, ok: bool, now: Instant) -> BreakerEvent {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        let h = health.entry(id.to_string()).or_default();
        if ok {
            let was_unhealthy = !matches!(*h, Health::Closed { .. });
            *h = Health::Closed { consecutive_failures: 0 };
            return if was_unhealthy { BreakerEvent::Recovered } else { BreakerEvent::None };
        }
        match *h {
            Health::Closed { consecutive_failures } => {
                let failures = consecutive_failures + 1;
                if failures >= self.breaker.failure_threshold {
                    *h = Health::Open { until: now + self.breaker.cooldown };
                    BreakerEvent::Tripped
                } else {
                    *h = Health::Closed { consecutive_failures: failures };
                    BreakerEvent::None
                }
            }
            // A failed half-open probe re-opens with a fresh cooldown.
            Health::HalfOpen { .. } => {
                *h = Health::Open { until: now + self.breaker.cooldown };
                BreakerEvent::Tripped
            }
            // Already quarantined (a pre-trip batch finished late):
            // refresh the cooldown, no new event.
            Health::Open { .. } => {
                *h = Health::Open { until: now + self.breaker.cooldown };
                BreakerEvent::None
            }
        }
    }

    /// Release the half-open probe slot for `id` without an execution
    /// outcome — the probe request was failed before it ran (timed
    /// out, or aborted by a dispatcher restart). The next admitted
    /// submit becomes the new probe, so a lost probe can never wedge a
    /// model in half-open limbo.
    pub fn release_probe(&self, id: &str) {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = health.get_mut(id) {
            if matches!(*h, Health::HalfOpen { probe_in_flight: true }) {
                *h = Health::HalfOpen { probe_in_flight: false };
            }
        }
    }

    /// The model's externally visible health right now (quarantine
    /// expiry is decided lazily at [`admit`](Self::admit) time, so an
    /// `Open` model whose cooldown has passed still reports `Open`
    /// until the next submit probes it).
    pub fn health(&self, id: &str) -> HealthState {
        let health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        match health.get(id) {
            None | Some(Health::Closed { .. }) => HealthState::Closed,
            Some(Health::Open { .. }) => HealthState::Open,
            Some(Health::HalfOpen { .. }) => HealthState::HalfOpen,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, FixedNetwork, Network};
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn registers_all_plan_sources_and_lists_sorted() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let f = net(&[4, 6, 2], 1);
        let q = FixedNetwork::from_float(&net(&[3, 5, 2], 2), 1.0).unwrap();
        reg.register("float-model", &f).unwrap();
        reg.register("fixed-model", &q).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec!["fixed-model", "float-model"]);
        let m = reg.get("float-model").unwrap();
        assert_eq!(m.id(), "float-model");
        assert!(m.plan().is_float());
        assert_eq!(m.plan().num_inputs(), 4);
        assert!(!reg.get("fixed-model").unwrap().plan().is_float());
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn breaker_trips_after_threshold_probes_and_recovers() {
        let reg = ModelRegistry::with_breaker(BreakerPolicy {
            failure_threshold: 3,
            cooldown: Duration::from_millis(10),
        });
        let t0 = Instant::now();
        // Healthy model admits freely; sub-threshold failures don't trip.
        assert_eq!(reg.admit("m", t0), Admission::Accept);
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::None);
        assert_eq!(reg.health("m"), HealthState::Closed);
        // Third consecutive failure trips quarantine.
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::Tripped);
        assert_eq!(reg.health("m"), HealthState::Open);
        // During cooldown every submit is rejected.
        assert_eq!(reg.admit("m", t0 + Duration::from_millis(5)), Admission::Reject);
        // Cooldown elapsed: exactly one probe is admitted, the rest
        // keep rejecting while it is in flight.
        let t1 = t0 + Duration::from_millis(10);
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        assert_eq!(reg.health("m"), HealthState::HalfOpen);
        assert_eq!(reg.admit("m", t1), Admission::Reject);
        // Failed probe re-trips with a fresh cooldown.
        assert_eq!(reg.note_exec("m", false, t1), BreakerEvent::Tripped);
        assert_eq!(reg.admit("m", t1 + Duration::from_millis(5)), Admission::Reject);
        // Next probe succeeds: recovered, back to normal admission.
        let t2 = t1 + Duration::from_millis(10);
        assert_eq!(reg.admit("m", t2), Admission::Probe);
        assert_eq!(reg.note_exec("m", true, t2), BreakerEvent::Recovered);
        assert_eq!(reg.health("m"), HealthState::Closed);
        assert_eq!(reg.admit("m", t2), Admission::Accept);
        // A success resets the consecutive-failure counter.
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", true, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.health("m"), HealthState::Closed);
    }

    #[test]
    fn released_probe_slot_readmits_a_new_probe() {
        let reg = ModelRegistry::with_breaker(BreakerPolicy {
            failure_threshold: 1,
            cooldown: Duration::from_millis(1),
        });
        let t0 = Instant::now();
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::Tripped);
        let t1 = t0 + Duration::from_millis(1);
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        assert_eq!(reg.admit("m", t1), Admission::Reject);
        // The probe died without executing (e.g. a dispatcher
        // restart): releasing its slot lets the next submit probe.
        reg.release_probe("m");
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        // Health of a never-seen model is Closed.
        assert_eq!(reg.health("ghost"), HealthState::Closed);
    }

    #[test]
    fn duplicate_id_is_an_error_not_a_replacement() {
        let reg = ModelRegistry::new();
        let a = net(&[2, 3, 1], 3);
        let b = net(&[9, 3, 1], 4);
        reg.register("m", &a).unwrap();
        assert!(reg.register("m", &b).is_err());
        // The original registration is untouched.
        assert_eq!(reg.get("m").unwrap().plan().num_inputs(), 2);
    }

    #[test]
    fn shard_pins_are_settable_and_cleared_by_remove() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.pinned_shard("m"), None);
        // Pinning before registration is allowed (the pin waits).
        reg.pin_shard("m", 3);
        assert_eq!(reg.pinned_shard("m"), Some(3));
        reg.pin_shard("m", 1);
        assert_eq!(reg.pinned_shard("m"), Some(1), "re-pin overwrites");
        reg.register("m", &net(&[2, 3, 1], 5)).unwrap();
        assert_eq!(reg.pinned_shard("m"), Some(1));
        assert!(reg.remove("m"));
        assert_eq!(reg.pinned_shard("m"), None, "remove clears the pin");
        assert!(!reg.remove("m"), "second remove is a no-op");
    }

    #[test]
    fn ttl_eviction_tracks_touches_on_a_virtual_clock() {
        let reg = ModelRegistry::new();
        let t0 = Instant::now();
        let ttl = Duration::from_secs(30);
        reg.register_plan_at("idle", ExecPlan::compile(&net(&[2, 3, 1], 6)), t0)
            .unwrap();
        reg.register_plan_at("busy", ExecPlan::compile(&net(&[2, 3, 1], 7)), t0)
            .unwrap();
        assert_eq!(reg.last_used("idle"), Some(t0));
        assert_eq!(reg.last_used("ghost"), None);

        // Inside the TTL nothing is a candidate.
        let t1 = t0 + Duration::from_secs(29);
        assert!(reg.idle_candidates(ttl, t1).is_empty());
        // `busy` keeps getting traffic; `idle` does not.
        reg.touch("busy", t1);
        // A stale touch never rewinds the instant.
        reg.touch("busy", t0);
        assert_eq!(reg.last_used("busy"), Some(t1));

        let t2 = t0 + Duration::from_secs(31);
        assert_eq!(reg.idle_candidates(ttl, t2), vec!["idle".to_string()]);
        assert_eq!(reg.evict_idle(ttl, t2), vec!["idle".to_string()]);
        assert!(reg.get("idle").is_none(), "evicted model is gone");
        assert!(reg.get("busy").is_some(), "recently-used model survives");
        assert_eq!(reg.last_used("idle"), None, "eviction clears last_used");
        // The sweep is idempotent.
        assert!(reg.evict_idle(ttl, t2).is_empty());
    }
}
