//! The model registry: many compiled [`ExecPlan`]s keyed by model id,
//! plus each model's health state machine (the circuit breaker).
//!
//! A registered model is an immutable `Arc<ServiceModel>` — the plan's
//! arena is position-independent and read-only at inference time, so
//! one registration serves every submitter thread and the dispatcher
//! concurrently without copies. Registration is cheap enough to do at
//! startup for a whole fleet of model variants; ids are unique (a
//! second registration under the same id is an error, never a silent
//! replacement of a model that in-flight requests still reference).
//!
//! Health lives beside the plans: [`BreakerPolicy::failure_threshold`]
//! consecutive execution failures trip a model from `Closed` to
//! `Open` (quarantined — submits fast-reject), the configured cooldown
//! later a single half-open probe is admitted, and its outcome decides
//! recovery (`Closed`) or another quarantine round. Every transition
//! is time-parametric — `now` is an argument — so the whole state
//! machine is unit-testable without sleeping, in the same style as the
//! scheduler core.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::kernels::{ExecPlan, PlanSource};

/// Circuit-breaker policy shared by every model in a registry.
#[derive(Debug, Clone)]
pub struct BreakerPolicy {
    /// Consecutive execution failures that trip a model into
    /// quarantine. Clamped to ≥ 1.
    pub failure_threshold: u32,
    /// How long a tripped model stays quarantined before one half-open
    /// probe is admitted.
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self {
            failure_threshold: 3,
            cooldown: Duration::from_millis(250),
        }
    }
}

/// A model's externally visible health, for reports and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Healthy: submits are admitted normally.
    Closed,
    /// Quarantined: submits fast-reject until the cooldown elapses.
    Open,
    /// Cooldown elapsed: one probe decides recovery vs re-quarantine.
    HalfOpen,
}

/// What [`ModelRegistry::admit`] decided for one submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Healthy model — enqueue normally.
    Accept,
    /// The model was quarantined and its cooldown has elapsed: this
    /// request is the half-open probe. The caller must mark the
    /// request so a probe that never executes (shed, timed out,
    /// aborted) can be released via
    /// [`ModelRegistry::release_probe`].
    Probe,
    /// Quarantined (cooldown pending, or a probe is already in
    /// flight) — reject with [`super::SubmitError::Quarantined`].
    Reject,
}

/// What [`ModelRegistry::note_exec`] observed — the host turns these
/// into quarantine metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerEvent {
    /// No state transition.
    None,
    /// The model just entered quarantine (threshold reached, or a
    /// half-open probe failed).
    Tripped,
    /// The model just recovered (a successful execution while
    /// half-open or quarantined).
    Recovered,
}

/// Per-model breaker state. `Closed` counts consecutive failures;
/// `Open` remembers when the cooldown ends; `HalfOpen` tracks whether
/// the single probe slot is taken.
#[derive(Debug, Clone, Copy)]
enum Health {
    Closed { consecutive_failures: u32 },
    Open { until: Instant },
    HalfOpen { probe_in_flight: bool },
}

impl Default for Health {
    fn default() -> Self {
        Health::Closed { consecutive_failures: 0 }
    }
}

/// One registered model: an id plus its compiled execution plan.
#[derive(Debug)]
pub struct ServiceModel {
    id: String,
    plan: ExecPlan,
}

impl ServiceModel {
    /// The registry key.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The compiled plan requests against this model execute through.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }
}

/// Thread-safe id → [`ServiceModel`] map plus per-model circuit
/// breakers. `BTreeMap` keeps `ids()` and every report listing
/// deterministic.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ServiceModel>>>,
    breaker: BreakerPolicy,
    health: Mutex<BTreeMap<String, Health>>,
}

impl ModelRegistry {
    /// An empty registry with the default [`BreakerPolicy`].
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty registry with an explicit circuit-breaker policy.
    pub fn with_breaker(breaker: BreakerPolicy) -> Self {
        Self {
            breaker: BreakerPolicy {
                failure_threshold: breaker.failure_threshold.max(1),
                cooldown: breaker.cooldown,
            },
            ..Self::default()
        }
    }

    /// The circuit-breaker policy every model in this registry runs
    /// under.
    pub fn breaker(&self) -> &BreakerPolicy {
        &self.breaker
    }

    /// Register an already-compiled plan under `id`. Errors when the id
    /// is taken.
    pub fn register_plan(&self, id: &str, plan: ExecPlan) -> Result<()> {
        // Registration mutates nothing but the map, so a poisoned lock
        // (a panic elsewhere while holding it) leaves a fully valid
        // map — recover instead of cascading the panic.
        let mut models = self.models.write().unwrap_or_else(|e| e.into_inner());
        if models.contains_key(id) {
            bail!("model id {id:?} already registered");
        }
        models.insert(
            id.to_string(),
            Arc::new(ServiceModel { id: id.to_string(), plan }),
        );
        Ok(())
    }

    /// Compile `src` (any [`PlanSource`]: float, fixed or packed
    /// network) and register it under `id`.
    pub fn register<S: PlanSource + ?Sized>(&self, id: &str, src: &S) -> Result<()> {
        self.register_plan(id, ExecPlan::compile(src))
    }

    /// Look up a model by id.
    pub fn get(&self, id: &str) -> Option<Arc<ServiceModel>> {
        // Readers see an always-consistent map even after a writer
        // panic (the map is updated via single `insert` calls).
        self.models.read().unwrap_or_else(|e| e.into_inner()).get(id).cloned()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.models
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission decision for one submit to `id` at time `now`:
    /// healthy models accept, quarantined models reject until the
    /// cooldown elapses, and the first submit after the cooldown is
    /// admitted as the single half-open probe.
    pub fn admit(&self, id: &str, now: Instant) -> Admission {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        let h = health.entry(id.to_string()).or_default();
        match *h {
            Health::Closed { .. } => Admission::Accept,
            Health::Open { until } => {
                if now < until {
                    Admission::Reject
                } else {
                    *h = Health::HalfOpen { probe_in_flight: true };
                    Admission::Probe
                }
            }
            Health::HalfOpen { probe_in_flight: false } => {
                *h = Health::HalfOpen { probe_in_flight: true };
                Admission::Probe
            }
            Health::HalfOpen { probe_in_flight: true } => Admission::Reject,
        }
    }

    /// Record one execution outcome for `id` at time `now` and apply
    /// the breaker transition: a success closes the breaker (a
    /// [`BreakerEvent::Recovered`] if it was open/half-open); a failure
    /// counts toward [`BreakerPolicy::failure_threshold`] and trips —
    /// or re-trips a failed half-open probe — into quarantine until
    /// `now + cooldown`.
    pub fn note_exec(&self, id: &str, ok: bool, now: Instant) -> BreakerEvent {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        let h = health.entry(id.to_string()).or_default();
        if ok {
            let was_unhealthy = !matches!(*h, Health::Closed { .. });
            *h = Health::Closed { consecutive_failures: 0 };
            return if was_unhealthy { BreakerEvent::Recovered } else { BreakerEvent::None };
        }
        match *h {
            Health::Closed { consecutive_failures } => {
                let failures = consecutive_failures + 1;
                if failures >= self.breaker.failure_threshold {
                    *h = Health::Open { until: now + self.breaker.cooldown };
                    BreakerEvent::Tripped
                } else {
                    *h = Health::Closed { consecutive_failures: failures };
                    BreakerEvent::None
                }
            }
            // A failed half-open probe re-opens with a fresh cooldown.
            Health::HalfOpen { .. } => {
                *h = Health::Open { until: now + self.breaker.cooldown };
                BreakerEvent::Tripped
            }
            // Already quarantined (a pre-trip batch finished late):
            // refresh the cooldown, no new event.
            Health::Open { .. } => {
                *h = Health::Open { until: now + self.breaker.cooldown };
                BreakerEvent::None
            }
        }
    }

    /// Release the half-open probe slot for `id` without an execution
    /// outcome — the probe request was failed before it ran (timed
    /// out, or aborted by a dispatcher restart). The next admitted
    /// submit becomes the new probe, so a lost probe can never wedge a
    /// model in half-open limbo.
    pub fn release_probe(&self, id: &str) {
        let mut health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = health.get_mut(id) {
            if matches!(*h, Health::HalfOpen { probe_in_flight: true }) {
                *h = Health::HalfOpen { probe_in_flight: false };
            }
        }
    }

    /// The model's externally visible health right now (quarantine
    /// expiry is decided lazily at [`admit`](Self::admit) time, so an
    /// `Open` model whose cooldown has passed still reports `Open`
    /// until the next submit probes it).
    pub fn health(&self, id: &str) -> HealthState {
        let health = self.health.lock().unwrap_or_else(|e| e.into_inner());
        match health.get(id) {
            None | Some(Health::Closed { .. }) => HealthState::Closed,
            Some(Health::Open { .. }) => HealthState::Open,
            Some(Health::HalfOpen { .. }) => HealthState::HalfOpen,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, FixedNetwork, Network};
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn registers_all_plan_sources_and_lists_sorted() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let f = net(&[4, 6, 2], 1);
        let q = FixedNetwork::from_float(&net(&[3, 5, 2], 2), 1.0).unwrap();
        reg.register("float-model", &f).unwrap();
        reg.register("fixed-model", &q).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec!["fixed-model", "float-model"]);
        let m = reg.get("float-model").unwrap();
        assert_eq!(m.id(), "float-model");
        assert!(m.plan().is_float());
        assert_eq!(m.plan().num_inputs(), 4);
        assert!(!reg.get("fixed-model").unwrap().plan().is_float());
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn breaker_trips_after_threshold_probes_and_recovers() {
        let reg = ModelRegistry::with_breaker(BreakerPolicy {
            failure_threshold: 3,
            cooldown: Duration::from_millis(10),
        });
        let t0 = Instant::now();
        // Healthy model admits freely; sub-threshold failures don't trip.
        assert_eq!(reg.admit("m", t0), Admission::Accept);
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::None);
        assert_eq!(reg.health("m"), HealthState::Closed);
        // Third consecutive failure trips quarantine.
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::Tripped);
        assert_eq!(reg.health("m"), HealthState::Open);
        // During cooldown every submit is rejected.
        assert_eq!(reg.admit("m", t0 + Duration::from_millis(5)), Admission::Reject);
        // Cooldown elapsed: exactly one probe is admitted, the rest
        // keep rejecting while it is in flight.
        let t1 = t0 + Duration::from_millis(10);
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        assert_eq!(reg.health("m"), HealthState::HalfOpen);
        assert_eq!(reg.admit("m", t1), Admission::Reject);
        // Failed probe re-trips with a fresh cooldown.
        assert_eq!(reg.note_exec("m", false, t1), BreakerEvent::Tripped);
        assert_eq!(reg.admit("m", t1 + Duration::from_millis(5)), Admission::Reject);
        // Next probe succeeds: recovered, back to normal admission.
        let t2 = t1 + Duration::from_millis(10);
        assert_eq!(reg.admit("m", t2), Admission::Probe);
        assert_eq!(reg.note_exec("m", true, t2), BreakerEvent::Recovered);
        assert_eq!(reg.health("m"), HealthState::Closed);
        assert_eq!(reg.admit("m", t2), Admission::Accept);
        // A success resets the consecutive-failure counter.
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", true, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.note_exec("m", false, t2), BreakerEvent::None);
        assert_eq!(reg.health("m"), HealthState::Closed);
    }

    #[test]
    fn released_probe_slot_readmits_a_new_probe() {
        let reg = ModelRegistry::with_breaker(BreakerPolicy {
            failure_threshold: 1,
            cooldown: Duration::from_millis(1),
        });
        let t0 = Instant::now();
        assert_eq!(reg.note_exec("m", false, t0), BreakerEvent::Tripped);
        let t1 = t0 + Duration::from_millis(1);
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        assert_eq!(reg.admit("m", t1), Admission::Reject);
        // The probe died without executing (e.g. a dispatcher
        // restart): releasing its slot lets the next submit probe.
        reg.release_probe("m");
        assert_eq!(reg.admit("m", t1), Admission::Probe);
        // Health of a never-seen model is Closed.
        assert_eq!(reg.health("ghost"), HealthState::Closed);
    }

    #[test]
    fn duplicate_id_is_an_error_not_a_replacement() {
        let reg = ModelRegistry::new();
        let a = net(&[2, 3, 1], 3);
        let b = net(&[9, 3, 1], 4);
        reg.register("m", &a).unwrap();
        assert!(reg.register("m", &b).is_err());
        // The original registration is untouched.
        assert_eq!(reg.get("m").unwrap().plan().num_inputs(), 2);
    }
}
