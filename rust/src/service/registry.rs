//! The model registry: many compiled [`ExecPlan`]s keyed by model id.
//!
//! A registered model is an immutable `Arc<ServiceModel>` — the plan's
//! arena is position-independent and read-only at inference time, so
//! one registration serves every submitter thread and the dispatcher
//! concurrently without copies. Registration is cheap enough to do at
//! startup for a whole fleet of model variants; ids are unique (a
//! second registration under the same id is an error, never a silent
//! replacement of a model that in-flight requests still reference).

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::kernels::{ExecPlan, PlanSource};

/// One registered model: an id plus its compiled execution plan.
#[derive(Debug)]
pub struct ServiceModel {
    id: String,
    plan: ExecPlan,
}

impl ServiceModel {
    /// The registry key.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The compiled plan requests against this model execute through.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }
}

/// Thread-safe id → [`ServiceModel`] map. `BTreeMap` keeps `ids()` and
/// every report listing deterministic.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: RwLock<BTreeMap<String, Arc<ServiceModel>>>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an already-compiled plan under `id`. Errors when the id
    /// is taken.
    pub fn register_plan(&self, id: &str, plan: ExecPlan) -> Result<()> {
        let mut models = self.models.write().expect("registry lock");
        if models.contains_key(id) {
            bail!("model id {id:?} already registered");
        }
        models.insert(
            id.to_string(),
            Arc::new(ServiceModel { id: id.to_string(), plan }),
        );
        Ok(())
    }

    /// Compile `src` (any [`PlanSource`]: float, fixed or packed
    /// network) and register it under `id`.
    pub fn register<S: PlanSource + ?Sized>(&self, id: &str, src: &S) -> Result<()> {
        self.register_plan(id, ExecPlan::compile(src))
    }

    /// Look up a model by id.
    pub fn get(&self, id: &str) -> Option<Arc<ServiceModel>> {
        self.models.read().expect("registry lock").get(id).cloned()
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.models.read().expect("registry lock").keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.read().expect("registry lock").len()
    }

    /// Whether no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, FixedNetwork, Network};
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn registers_all_plan_sources_and_lists_sorted() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let f = net(&[4, 6, 2], 1);
        let q = FixedNetwork::from_float(&net(&[3, 5, 2], 2), 1.0).unwrap();
        reg.register("float-model", &f).unwrap();
        reg.register("fixed-model", &q).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec!["fixed-model", "float-model"]);
        let m = reg.get("float-model").unwrap();
        assert_eq!(m.id(), "float-model");
        assert!(m.plan().is_float());
        assert_eq!(m.plan().num_inputs(), 4);
        assert!(!reg.get("fixed-model").unwrap().plan().is_float());
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn duplicate_id_is_an_error_not_a_replacement() {
        let reg = ModelRegistry::new();
        let a = net(&[2, 3, 1], 3);
        let b = net(&[9, 3, 1], 4);
        reg.register("m", &a).unwrap();
        assert!(reg.register("m", &b).is_err());
        // The original registration is untouched.
        assert_eq!(reg.get("m").unwrap().plan().num_inputs(), 2);
    }
}
