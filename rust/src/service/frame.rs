//! Length-prefixed binary frames for the wire front-end.
//!
//! This module is the *pure* half of the wire layer: byte layout,
//! encoding, and a decoding path that is total — every malformed input
//! yields a typed [`FrameError`], never a panic or an over-read. The
//! socket plumbing lives in [`super::wire`]; keeping the codec free of
//! IO is what lets `rust/tests/prop_wire_frames.rs` fuzz truncations
//! and corruptions at every byte offset without opening a socket.
//!
//! # Wire layout
//!
//! Every frame on the stream is a 4-byte little-endian length prefix
//! (the byte count of the *body* that follows) and then the body. All
//! multi-byte integers are little-endian.
//!
//! Request body (header [`REQUEST_HEADER`] = 24 bytes, then tag, then
//! payload):
//!
//! | offset | width | field |
//! |-------:|------:|-------|
//! | 0      | 4     | magic `b"FNNW"` |
//! | 4      | 1     | version (= [`VERSION`]) |
//! | 5      | 1     | kind (= `0`, request) |
//! | 6      | 1     | dtype ([`WireDtype`] code; requests are f32) |
//! | 7      | 1     | tag length `T` (1 ..= [`MAX_TAG`]) |
//! | 8      | 8     | request id (client-chosen, echoed in the reply) |
//! | 16     | 8     | tenant id |
//! | 24     | `T`   | model tag (UTF-8) |
//! | 24+`T` | rest  | input payload (f32 LE; length must be a multiple of 4) |
//!
//! Response body (header [`RESPONSE_HEADER`] = 32 bytes, then payload):
//!
//! | offset | width | field |
//! |-------:|------:|-------|
//! | 0      | 4     | magic `b"FNNW"` |
//! | 4      | 1     | version |
//! | 5      | 1     | kind (1=Ok 2=Shed 3=Quarantined 4=Timeout 5=ExecFailed 6=Aborted 7=BadFrame) |
//! | 6      | 1     | dtype (Ok only: 0=f32, 1=q32 outputs) |
//! | 7      | 1     | reserved (0) |
//! | 8      | 8     | request id (echo) |
//! | 16     | 8     | `a` — Ok: latency µs; Timeout: waited µs; else 0 |
//! | 24     | 8     | `b` — Ok: batch size; Timeout: budget µs; else 0 |
//! | 32     | rest  | Ok: outputs (f32/i32 LE); error kinds: UTF-8 detail |
//!
//! NaN/inf input values are *representable* on the wire on purpose —
//! input hygiene is the service's job ([`super::SubmitError::BadInput`]
//! at submit), and the chaos harness relies on shipping poisoned
//! samples across the socket to prove that rejection holds there too.

use super::host::Output;

/// Frame magic: the first four body bytes of every well-formed frame.
pub const MAGIC: [u8; 4] = *b"FNNW";

/// Protocol version carried in byte 4 of every body.
pub const VERSION: u8 = 1;

/// Maximum model-tag length in bytes (the tag-length field is one
/// byte, but tags are short identifiers — bound them well below 255).
pub const MAX_TAG: usize = 64;

/// Fixed request-body header size in bytes (before tag + payload).
pub const REQUEST_HEADER: usize = 24;

/// Fixed response-body header size in bytes (before payload).
pub const RESPONSE_HEADER: usize = 32;

/// Size of the length prefix preceding every body.
pub const LEN_PREFIX: usize = 4;

/// Default per-connection frame-size cap (length-prefix values above
/// this are rejected *before* any allocation): 1 MiB.
pub const DEFAULT_MAX_FRAME: usize = 1 << 20;

/// Body kind code for request frames.
pub const KIND_REQUEST: u8 = 0;

/// Element type of a frame payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireDtype {
    /// 4-byte little-endian IEEE-754 f32 elements.
    F32,
    /// 4-byte little-endian i32 elements (quantized-plan outputs).
    Q32,
}

impl WireDtype {
    /// The on-wire code for this dtype.
    pub fn code(self) -> u8 {
        match self {
            WireDtype::F32 => 0,
            WireDtype::Q32 => 1,
        }
    }

    /// Decode an on-wire dtype code.
    pub fn from_code(code: u8) -> Result<Self, FrameError> {
        match code {
            0 => Ok(WireDtype::F32),
            1 => Ok(WireDtype::Q32),
            got => Err(FrameError::BadDtype { got }),
        }
    }

    /// Payload element width in bytes.
    pub fn width(self) -> usize {
        4
    }
}

impl std::fmt::Display for WireDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireDtype::F32 => write!(f, "f32"),
            WireDtype::Q32 => write!(f, "q32"),
        }
    }
}

/// Why a frame failed to decode. Every variant is reachable from bytes
/// alone — the decoder never panics and never reads past the buffer it
/// was handed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The buffer ends before the structure it declares is complete
    /// (also the stream-reader's "need more bytes" signal).
    Truncated {
        /// Bytes the structure needed.
        needed: usize,
        /// Bytes actually present.
        got: usize,
    },
    /// The first four body bytes are not [`MAGIC`].
    BadMagic {
        /// The bytes found where the magic should be.
        got: [u8; 4],
    },
    /// The version byte is not [`VERSION`].
    BadVersion {
        /// The version byte found.
        got: u8,
    },
    /// The kind byte names no known frame kind (for the direction being
    /// decoded).
    BadKind {
        /// The kind byte found.
        got: u8,
    },
    /// The dtype byte names no known [`WireDtype`].
    BadDtype {
        /// The dtype byte found.
        got: u8,
    },
    /// The length prefix declares a body larger than the configured
    /// frame-size cap. Raised before any allocation, so a peer
    /// declaring `u32::MAX` costs the server four bytes of reading and
    /// nothing else.
    Oversized {
        /// The declared body length.
        declared: u64,
        /// The cap it exceeded.
        limit: usize,
    },
    /// The tag-length field is out of range (0 or > [`MAX_TAG`]).
    BadTag {
        /// The declared tag length.
        len: usize,
    },
    /// A text field (model tag or error detail) is not valid UTF-8.
    BadText,
    /// The payload byte count is not a whole number of elements for
    /// the declared dtype.
    PayloadMismatch {
        /// The declared payload dtype.
        dtype: WireDtype,
        /// The payload length in bytes.
        bytes: usize,
    },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            FrameError::BadMagic { got } => write!(f, "bad magic {got:02x?}"),
            FrameError::BadVersion { got } => write!(f, "unsupported version {got}"),
            FrameError::BadKind { got } => write!(f, "unknown frame kind {got}"),
            FrameError::BadDtype { got } => write!(f, "unknown dtype code {got}"),
            FrameError::Oversized { declared, limit } => {
                write!(f, "oversized frame: declared {declared} bytes, limit {limit}")
            }
            FrameError::BadTag { len } => {
                write!(f, "bad model tag length {len} (must be 1..={MAX_TAG})")
            }
            FrameError::BadText => write!(f, "text field is not valid UTF-8"),
            FrameError::PayloadMismatch { dtype, bytes } => {
                write!(f, "payload of {bytes} bytes is not whole {dtype} elements")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// A decoded inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen correlation id, echoed verbatim in the terminal
    /// response frame. Uniqueness per connection is the client's
    /// contract; the server never interprets the value.
    pub id: u64,
    /// Tenant id forwarded to [`super::InferenceService::submit`].
    pub tenant: u64,
    /// Model tag (registry id), 1..=[`MAX_TAG`] UTF-8 bytes.
    pub model: String,
    /// Input sample (may be empty; width validation is the service's).
    pub input: Vec<f32>,
}

/// The terminal outcome a response frame carries — exactly one of
/// these is sent per accepted request id (plus synchronous rejects for
/// ids that never entered the service).
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseBody {
    /// Successful inference.
    Ok {
        /// The model outputs (f32 or quantized i32, mirroring
        /// [`Output`]).
        output: Output,
        /// Enqueue→reply latency in microseconds.
        latency_us: u64,
        /// Size of the coalesced batch the request rode in.
        batch: u64,
    },
    /// Shed: the model's bounded queue (or this connection's in-flight
    /// window) was full. Retryable.
    Shed {
        /// Human-readable cause.
        detail: String,
    },
    /// The model's circuit breaker is open. Retryable after cooldown.
    Quarantined {
        /// Human-readable cause.
        detail: String,
    },
    /// The request went stale past its budget before execution.
    Timeout {
        /// How long the request had waited (µs).
        waited_us: u64,
        /// The configured budget (µs).
        budget_us: u64,
    },
    /// The batch this request rode in panicked during execution.
    ExecFailed {
        /// The caught panic payload.
        detail: String,
    },
    /// The request was failed without execution (dispatcher restart or
    /// server shutdown).
    Aborted {
        /// Human-readable cause.
        detail: String,
    },
    /// The request itself was unusable: malformed frame, unknown
    /// model, wrong input width, or non-finite f32-plan input. The
    /// connection may be closed after this per server policy.
    BadFrame {
        /// Human-readable cause.
        detail: String,
    },
}

impl ResponseBody {
    /// The on-wire kind code.
    pub fn kind(&self) -> u8 {
        match self {
            ResponseBody::Ok { .. } => 1,
            ResponseBody::Shed { .. } => 2,
            ResponseBody::Quarantined { .. } => 3,
            ResponseBody::Timeout { .. } => 4,
            ResponseBody::ExecFailed { .. } => 5,
            ResponseBody::Aborted { .. } => 6,
            ResponseBody::BadFrame { .. } => 7,
        }
    }

    /// Short lowercase name of the kind (stable, used in counters and
    /// test diagnostics).
    pub fn kind_name(&self) -> &'static str {
        match self {
            ResponseBody::Ok { .. } => "ok",
            ResponseBody::Shed { .. } => "shed",
            ResponseBody::Quarantined { .. } => "quarantined",
            ResponseBody::Timeout { .. } => "timeout",
            ResponseBody::ExecFailed { .. } => "exec_failed",
            ResponseBody::Aborted { .. } => "aborted",
            ResponseBody::BadFrame { .. } => "bad_frame",
        }
    }
}

/// A decoded response frame: the echoed request id plus its terminal
/// [`ResponseBody`].
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    /// The request id this response answers.
    pub id: u64,
    /// The outcome.
    pub body: ResponseBody,
}

/// Split one length-prefixed frame off the front of `bytes`.
///
/// Returns the frame *body* and the total bytes consumed (prefix +
/// body). [`FrameError::Truncated`] doubles as the stream reader's
/// "need more bytes" signal; [`FrameError::Oversized`] is raised from
/// the prefix alone, before the body is touched or buffered.
pub fn split_frame(bytes: &[u8], max_frame: usize) -> Result<(&[u8], usize), FrameError> {
    if bytes.len() < LEN_PREFIX {
        return Err(FrameError::Truncated { needed: LEN_PREFIX, got: bytes.len() });
    }
    let declared = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as u64;
    if declared as usize > max_frame {
        return Err(FrameError::Oversized { declared, limit: max_frame });
    }
    let body_len = declared as usize;
    let total = LEN_PREFIX + body_len;
    if bytes.len() < total {
        return Err(FrameError::Truncated { needed: total, got: bytes.len() });
    }
    Ok((&bytes[LEN_PREFIX..total], total))
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn read_u64(body: &[u8], at: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&body[at..at + 8]);
    u64::from_le_bytes(b)
}

fn finish_prefix(out: &mut Vec<u8>, body_start: usize) {
    let body_len = out.len() - body_start;
    let prefix = u32::try_from(body_len).expect("frame body exceeds u32 range");
    out[body_start - LEN_PREFIX..body_start].copy_from_slice(&prefix.to_le_bytes());
}

/// Append one full request frame (length prefix + body) to `out`.
///
/// # Panics
/// If the model tag is empty or longer than [`MAX_TAG`] — that is a
/// caller bug, not a runtime condition (tags come from the client's
/// own configuration, never from the network).
pub fn encode_request(req: &RequestFrame, out: &mut Vec<u8>) {
    assert!(
        !req.model.is_empty() && req.model.len() <= MAX_TAG,
        "model tag must be 1..={MAX_TAG} bytes, got {}",
        req.model.len()
    );
    out.extend_from_slice(&[0u8; LEN_PREFIX]);
    let body_start = out.len();
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(KIND_REQUEST);
    out.push(WireDtype::F32.code());
    out.push(req.model.len() as u8);
    push_u64(out, req.id);
    push_u64(out, req.tenant);
    out.extend_from_slice(req.model.as_bytes());
    for v in &req.input {
        out.extend_from_slice(&v.to_le_bytes());
    }
    finish_prefix(out, body_start);
}

fn check_preamble(body: &[u8], header: usize) -> Result<(), FrameError> {
    if body.len() < header {
        return Err(FrameError::Truncated { needed: header, got: body.len() });
    }
    if body[0..4] != MAGIC {
        return Err(FrameError::BadMagic { got: [body[0], body[1], body[2], body[3]] });
    }
    if body[4] != VERSION {
        return Err(FrameError::BadVersion { got: body[4] });
    }
    Ok(())
}

/// Decode a request body (the bytes after the length prefix).
pub fn decode_request(body: &[u8]) -> Result<RequestFrame, FrameError> {
    check_preamble(body, REQUEST_HEADER)?;
    if body[5] != KIND_REQUEST {
        return Err(FrameError::BadKind { got: body[5] });
    }
    let dtype = WireDtype::from_code(body[6])?;
    if dtype != WireDtype::F32 {
        // Requests carry raw f32 samples; quantization is the
        // service's (plan-specific) job.
        return Err(FrameError::BadDtype { got: body[6] });
    }
    let tag_len = body[7] as usize;
    if tag_len == 0 || tag_len > MAX_TAG {
        return Err(FrameError::BadTag { len: tag_len });
    }
    let id = read_u64(body, 8);
    let tenant = read_u64(body, 16);
    if body.len() < REQUEST_HEADER + tag_len {
        return Err(FrameError::Truncated { needed: REQUEST_HEADER + tag_len, got: body.len() });
    }
    let model = std::str::from_utf8(&body[REQUEST_HEADER..REQUEST_HEADER + tag_len])
        .map_err(|_| FrameError::BadText)?
        .to_string();
    let payload = &body[REQUEST_HEADER + tag_len..];
    if payload.len() % dtype.width() != 0 {
        return Err(FrameError::PayloadMismatch { dtype, bytes: payload.len() });
    }
    let input = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(RequestFrame { id, tenant, model, input })
}

/// Append one full response frame (length prefix + body) to `out`.
pub fn encode_response(resp: &ResponseFrame, out: &mut Vec<u8>) {
    out.extend_from_slice(&[0u8; LEN_PREFIX]);
    let body_start = out.len();
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(resp.body.kind());
    let dtype = match &resp.body {
        ResponseBody::Ok { output: Output::Q(_), .. } => WireDtype::Q32,
        _ => WireDtype::F32,
    };
    out.push(dtype.code());
    out.push(0);
    push_u64(out, resp.id);
    let (a, b) = match &resp.body {
        ResponseBody::Ok { latency_us, batch, .. } => (*latency_us, *batch),
        ResponseBody::Timeout { waited_us, budget_us } => (*waited_us, *budget_us),
        _ => (0, 0),
    };
    push_u64(out, a);
    push_u64(out, b);
    match &resp.body {
        ResponseBody::Ok { output, .. } => match output {
            Output::F32(vs) => {
                for v in vs {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Output::Q(vs) => {
                for v in vs {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        },
        ResponseBody::Timeout { .. } => {}
        ResponseBody::Shed { detail }
        | ResponseBody::Quarantined { detail }
        | ResponseBody::ExecFailed { detail }
        | ResponseBody::Aborted { detail }
        | ResponseBody::BadFrame { detail } => out.extend_from_slice(detail.as_bytes()),
    }
    finish_prefix(out, body_start);
}

/// Decode a response body (the bytes after the length prefix).
pub fn decode_response(body: &[u8]) -> Result<ResponseFrame, FrameError> {
    check_preamble(body, RESPONSE_HEADER)?;
    let kind = body[5];
    let dtype = WireDtype::from_code(body[6])?;
    let id = read_u64(body, 8);
    let a = read_u64(body, 16);
    let b = read_u64(body, 24);
    let payload = &body[RESPONSE_HEADER..];
    let detail = || -> Result<String, FrameError> {
        Ok(std::str::from_utf8(payload).map_err(|_| FrameError::BadText)?.to_string())
    };
    let body = match kind {
        1 => {
            if payload.len() % dtype.width() != 0 {
                return Err(FrameError::PayloadMismatch { dtype, bytes: payload.len() });
            }
            let output = match dtype {
                WireDtype::F32 => Output::F32(
                    payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                WireDtype::Q32 => Output::Q(
                    payload
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
            };
            ResponseBody::Ok { output, latency_us: a, batch: b }
        }
        2 => ResponseBody::Shed { detail: detail()? },
        3 => ResponseBody::Quarantined { detail: detail()? },
        4 => {
            if !payload.is_empty() {
                return Err(FrameError::PayloadMismatch { dtype, bytes: payload.len() });
            }
            ResponseBody::Timeout { waited_us: a, budget_us: b }
        }
        5 => ResponseBody::ExecFailed { detail: detail()? },
        6 => ResponseBody::Aborted { detail: detail()? },
        7 => ResponseBody::BadFrame { detail: detail()? },
        got => return Err(FrameError::BadKind { got }),
    };
    Ok(ResponseFrame { id, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &RequestFrame) -> RequestFrame {
        let mut buf = Vec::new();
        encode_request(req, &mut buf);
        let (body, consumed) = split_frame(&buf, DEFAULT_MAX_FRAME).expect("split");
        assert_eq!(consumed, buf.len());
        decode_request(body).expect("decode")
    }

    #[test]
    fn request_roundtrip_preserves_every_field_and_nan_bits() {
        let req = RequestFrame {
            id: 0xDEAD_BEEF_0042_1111,
            tenant: 7,
            model: "emg-q7".into(),
            input: vec![1.5, -0.0, f32::NAN, f32::INFINITY, 3.25e-12],
        };
        let back = roundtrip_request(&req);
        assert_eq!(back.id, req.id);
        assert_eq!(back.tenant, req.tenant);
        assert_eq!(back.model, req.model);
        let bits: Vec<u32> = req.input.iter().map(|v| v.to_bits()).collect();
        let back_bits: Vec<u32> = back.input.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, back_bits);
    }

    #[test]
    fn response_roundtrip_covers_every_kind() {
        let bodies = vec![
            ResponseBody::Ok {
                output: Output::F32(vec![0.25, -1.0]),
                latency_us: 123,
                batch: 4,
            },
            ResponseBody::Ok { output: Output::Q(vec![-5, 0, 1 << 20]), latency_us: 9, batch: 1 },
            ResponseBody::Shed { detail: "queue full".into() },
            ResponseBody::Quarantined { detail: "breaker open".into() },
            ResponseBody::Timeout { waited_us: 2000, budget_us: 1000 },
            ResponseBody::ExecFailed { detail: "kernel panic".into() },
            ResponseBody::Aborted { detail: "shutdown".into() },
            ResponseBody::BadFrame { detail: "unknown model".into() },
        ];
        for (i, body) in bodies.into_iter().enumerate() {
            let resp = ResponseFrame { id: i as u64, body };
            let mut buf = Vec::new();
            encode_response(&resp, &mut buf);
            let (b, consumed) = split_frame(&buf, DEFAULT_MAX_FRAME).expect("split");
            assert_eq!(consumed, buf.len());
            let back = decode_response(b).expect("decode");
            assert_eq!(back, resp);
        }
    }

    #[test]
    fn oversized_prefix_is_rejected_before_the_body() {
        let mut buf = u32::MAX.to_le_bytes().to_vec();
        buf.extend_from_slice(&[0; 16]);
        match split_frame(&buf, DEFAULT_MAX_FRAME) {
            Err(FrameError::Oversized { declared, limit }) => {
                assert_eq!(declared, u32::MAX as u64);
                assert_eq!(limit, DEFAULT_MAX_FRAME);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn corrupt_headers_yield_typed_errors() {
        let req = RequestFrame { id: 1, tenant: 2, model: "m".into(), input: vec![1.0] };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf);
        let (body, _) = split_frame(&buf, DEFAULT_MAX_FRAME).expect("split");
        let body = body.to_vec();

        let mut bad = body.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_request(&bad), Err(FrameError::BadMagic { .. })));

        let mut bad = body.clone();
        bad[4] = VERSION + 1;
        assert!(matches!(decode_request(&bad), Err(FrameError::BadVersion { .. })));

        let mut bad = body.clone();
        bad[6] = 9;
        assert!(matches!(decode_request(&bad), Err(FrameError::BadDtype { got: 9 })));

        let mut bad = body.clone();
        bad[7] = 0;
        assert!(matches!(decode_request(&bad), Err(FrameError::BadTag { len: 0 })));

        // Dtype/payload-length mismatch: lop one payload byte off.
        let bad = &body[..body.len() - 1];
        assert!(matches!(
            decode_request(bad),
            Err(FrameError::PayloadMismatch { dtype: WireDtype::F32, bytes: 3 })
        ));
    }

    #[test]
    fn truncation_at_every_offset_never_panics() {
        let req = RequestFrame {
            id: 42,
            tenant: 3,
            model: "ecg-q32".into(),
            input: (0..17).map(|i| i as f32 * 0.5).collect(),
        };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf);
        for cut in 0..buf.len() {
            match split_frame(&buf[..cut], DEFAULT_MAX_FRAME) {
                Err(FrameError::Truncated { .. }) => {}
                Ok((body, _)) => {
                    // A cut inside the payload can still form a shorter
                    // self-consistent prefix only if the length prefix
                    // matched — impossible here because the prefix
                    // declares the full body.
                    panic!("truncated split unexpectedly succeeded ({} bytes)", body.len());
                }
                Err(e) => panic!("unexpected error {e:?}"),
            }
        }
    }
}
