//! The pure adaptive micro-batching core: one bounded FIFO per model,
//! flushed on batch-size or deadline — whichever comes first — with
//! deterministic shedding at capacity.
//!
//! Deliberately free of threads, clocks and channels: `now` is a
//! parameter to every time-sensitive method, so each flush decision is
//! a pure function of (queue contents, policy, now) and the test suite
//! can drive deadline and backpressure behavior without sleeping. The
//! host ([`crate::service::InferenceService`]) owns the real clock and
//! the wakeups.
//!
//! With [`BatchPolicy::adaptive_delay`] enabled, each queue also runs an
//! [`AdmissionController`]: an EWMA over observed inter-arrival gaps
//! auto-tunes the deadline trigger down to roughly the time a size
//! flush needs at the current arrival rate, clamped to the configured
//! [`BatchPolicy::max_delay`] bound — so a queue whose traffic suddenly
//! stops never strands its last partial batch for the full configured
//! delay. The controller is as time-parametric as the rest of the core.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::BatchPolicy;

/// Why a batch left the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The size trigger: `max_batch` requests were waiting.
    Size,
    /// The deadline trigger: the oldest request waited `max_delay` —
    /// the batch may be partial.
    Deadline,
    /// An explicit drain (service shutdown or manual flush) — the
    /// batch may be partial and the deadline need not have passed.
    Drain,
}

impl FlushReason {
    /// Stable lower-case label (`"size"` / `"deadline"` / `"drain"`)
    /// for reports.
    pub fn label(&self) -> &'static str {
        match self {
            FlushReason::Size => "size",
            FlushReason::Deadline => "deadline",
            FlushReason::Drain => "drain",
        }
    }
}

/// One coalesced batch taken from a queue: the requests in FIFO order,
/// each with its enqueue time, plus why the flush fired.
#[derive(Debug)]
pub struct Batch<T> {
    /// `(request, enqueued_at)` in arrival order — at most
    /// `max_batch` of them.
    pub items: Vec<(T, Instant)>,
    /// The trigger that released this batch.
    pub reason: FlushReason,
}

impl<T> Batch<T> {
    /// Split into `(live, expired)` at `now` under a per-request
    /// deadline budget: requests that have already waited longer than
    /// `budget` are expired (answered `Timeout` by the host instead of
    /// executed — a stale real-time classification is worthless), the
    /// rest execute. `budget: None` expires nothing. Pure and
    /// time-parametric like the rest of the scheduler core; relative
    /// order is preserved on both sides.
    #[allow(clippy::type_complexity)]
    pub fn split_expired(
        self,
        budget: Option<Duration>,
        now: Instant,
    ) -> (Vec<(T, Instant)>, Vec<(T, Instant)>) {
        match budget {
            None => (self.items, Vec::new()),
            Some(b) => self
                .items
                .into_iter()
                .partition(|&(_, enq)| now.duration_since(enq) <= b),
        }
    }
}

/// Per-queue EWMA deadline auto-tuner. Observes inter-arrival gaps at
/// [`MicroBatchQueue::push`] time and proposes an *effective* deadline
/// of roughly `max_batch × smoothed_gap` — the time a size flush needs
/// at the current rate — clamped into `[floor, configured max_delay]`.
/// Hot queues therefore stop over-waiting when their traffic pauses,
/// while cold queues keep the full configured coalescing window. Fully
/// time-parametric: `now` is an argument, nothing reads a clock.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    /// Smoothed inter-arrival gap (µs); `None` until two arrivals.
    ewma_gap_us: Option<f64>,
    last_arrival: Option<Instant>,
    max_batch: usize,
    /// The configured [`BatchPolicy::max_delay`] — the upper clamp.
    bound: Duration,
    /// Lower clamp, so one dense burst can't tune the deadline to zero
    /// and defeat coalescing entirely.
    floor: Duration,
}

/// EWMA weight on the newest gap: heavy enough to track a rate change
/// within a handful of arrivals, light enough that one outlier gap
/// doesn't swing the deadline.
const EWMA_ALPHA: f64 = 0.2;

/// Default lower clamp on the auto-tuned deadline (µs); the configured
/// bound wins when it is smaller.
const DELAY_FLOOR_US: u64 = 50;

impl AdmissionController {
    /// A controller for one queue under `policy` (using its `max_batch`
    /// as the fill target and its `max_delay` as the upper clamp).
    pub fn new(policy: &BatchPolicy) -> Self {
        let bound = policy.max_delay;
        Self {
            ewma_gap_us: None,
            last_arrival: None,
            max_batch: policy.max_batch.max(1),
            bound,
            floor: bound.min(Duration::from_micros(DELAY_FLOOR_US)),
        }
    }

    /// Fold one arrival at `now` into the gap EWMA. Out-of-order
    /// arrivals (possible under a virtual clock) count as a zero gap.
    pub fn observe_arrival(&mut self, now: Instant) {
        if let Some(last) = self.last_arrival {
            let gap = now.saturating_duration_since(last).as_micros() as f64;
            self.ewma_gap_us = Some(match self.ewma_gap_us {
                None => gap,
                Some(prev) => EWMA_ALPHA * gap + (1.0 - EWMA_ALPHA) * prev,
            });
        }
        self.last_arrival = Some(now);
    }

    /// The auto-tuned deadline: `max_batch × smoothed gap`, clamped to
    /// `[floor, bound]`. Until two arrivals have been observed there is
    /// no rate estimate, so the configured bound applies unchanged.
    pub fn current_delay(&self) -> Duration {
        let Some(gap) = self.ewma_gap_us else {
            return self.bound;
        };
        let predicted_us = (gap * self.max_batch as f64).round() as u64;
        Duration::from_micros(predicted_us).clamp(self.floor, self.bound)
    }
}

/// A bounded per-model FIFO with size-or-deadline flushing. Generic
/// over the payload so the scheduling logic is testable with plain
/// values; the host instantiates it with its pending-request type.
#[derive(Debug)]
pub struct MicroBatchQueue<T> {
    items: VecDeque<(T, Instant)>,
    policy: BatchPolicy,
    /// Deadline auto-tuner, present iff the policy enables it.
    admission: Option<AdmissionController>,
    /// High-water mark of the depth reached at push time — recorded
    /// here, under the same lock as the push itself, so no peak between
    /// a push and the next take can be missed by later metric reads.
    peak_depth: usize,
}

impl<T> MicroBatchQueue<T> {
    /// An empty queue under `policy` (normalized on entry: `max_batch ≥
    /// 1`, `queue_capacity ≥ max_batch`).
    pub fn new(policy: &BatchPolicy) -> Self {
        let policy = policy.normalized();
        Self {
            items: VecDeque::new(),
            admission: policy.adaptive_delay.then(|| AdmissionController::new(&policy)),
            policy,
            peak_depth: 0,
        }
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether no request is waiting.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The bound beyond which arrivals are shed.
    pub fn capacity(&self) -> usize {
        self.policy.queue_capacity
    }

    /// Enqueue at time `now`. Returns the new depth, or gives the item
    /// back (`Err`) when the queue is at capacity — the deterministic
    /// shed: nothing about the queue changes on rejection. The depth
    /// reached is folded into [`peak_depth`](Self::peak_depth) here, at
    /// push time, so transient peaks between a push and the next take
    /// are never lost to metric sampling.
    pub fn push(&mut self, item: T, now: Instant) -> Result<usize, T> {
        if self.items.len() >= self.policy.queue_capacity {
            return Err(item);
        }
        self.items.push_back((item, now));
        let depth = self.items.len();
        self.peak_depth = self.peak_depth.max(depth);
        if let Some(ac) = &mut self.admission {
            ac.observe_arrival(now);
        }
        Ok(depth)
    }

    /// High-water mark of the depth ever reached at push time.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    /// The deadline window currently in force: the admission
    /// controller's auto-tuned value when [`BatchPolicy::adaptive_delay`]
    /// is on, else the configured [`BatchPolicy::max_delay`].
    pub fn effective_delay(&self) -> Duration {
        match &self.admission {
            Some(ac) => ac.current_delay(),
            None => self.policy.max_delay,
        }
    }

    /// The flush trigger that has fired at `now`, if any: `Size` once
    /// `max_batch` requests wait, else `Deadline` once the oldest
    /// request has waited the [effective delay](Self::effective_delay).
    /// `None` means keep coalescing.
    pub fn ready(&self, now: Instant) -> Option<FlushReason> {
        if self.items.len() >= self.policy.max_batch {
            return Some(FlushReason::Size);
        }
        let &(_, oldest) = self.items.front()?;
        if now.duration_since(oldest) >= self.effective_delay() {
            return Some(FlushReason::Deadline);
        }
        None
    }

    /// When the head request was enqueued (the queue's flush priority:
    /// oldest head goes first across models).
    pub fn head_enqueued(&self) -> Option<Instant> {
        self.items.front().map(|&(_, t)| t)
    }

    /// The instant at which [`ready`](Self::ready) will turn `Some`
    /// by deadline alone — what the dispatcher sleeps until when no
    /// size trigger is pending. `None` when the queue is empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        let delay = self.effective_delay();
        self.items.front().map(|&(_, t)| t + delay)
    }

    /// Take up to `max_batch` requests if a trigger has fired at `now`
    /// (`None` otherwise). FIFO order is preserved; requests beyond
    /// `max_batch` stay queued for the next flush.
    pub fn take(&mut self, now: Instant) -> Option<Batch<T>> {
        let reason = self.ready(now)?;
        Some(self.take_with_reason(reason))
    }

    /// Take up to `max_batch` requests unconditionally (shutdown /
    /// manual drain) — `None` only when empty.
    pub fn drain_batch(&mut self) -> Option<Batch<T>> {
        if self.items.is_empty() {
            return None;
        }
        Some(self.take_with_reason(FlushReason::Drain))
    }

    fn take_with_reason(&mut self, reason: FlushReason) -> Batch<T> {
        let n = self.items.len().min(self.policy.max_batch);
        Batch {
            items: self.items.drain(..n).collect(),
            reason,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn policy(max_batch: usize, delay_ms: u64, capacity: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_delay: Duration::from_millis(delay_ms),
            queue_capacity: capacity,
            ..BatchPolicy::default()
        }
    }

    #[test]
    fn size_trigger_flushes_exactly_max_batch_in_fifo_order() {
        let mut q = MicroBatchQueue::new(&policy(4, 1000, 64));
        let t0 = Instant::now();
        for i in 0..6 {
            q.push(i, t0).unwrap();
        }
        assert_eq!(q.ready(t0), Some(FlushReason::Size));
        let b = q.take(t0).unwrap();
        assert_eq!(b.reason, FlushReason::Size);
        let vals: Vec<i32> = b.items.iter().map(|&(v, _)| v).collect();
        assert_eq!(vals, vec![0, 1, 2, 3]);
        // The two stragglers stay for the next trigger.
        assert_eq!(q.len(), 2);
        assert_eq!(q.ready(t0), None);
    }

    #[test]
    fn deadline_trigger_fires_with_partial_batch() {
        let mut q = MicroBatchQueue::new(&policy(8, 2, 64));
        let t0 = Instant::now();
        q.push('a', t0).unwrap();
        q.push('b', t0 + Duration::from_micros(300)).unwrap();
        // Before the oldest request's deadline: keep coalescing.
        assert_eq!(q.ready(t0 + Duration::from_millis(1)), None);
        // At the deadline: a partial (2 of 8) batch flushes.
        let now = t0 + Duration::from_millis(2);
        assert_eq!(q.ready(now), Some(FlushReason::Deadline));
        assert_eq!(q.next_deadline(), Some(t0 + Duration::from_millis(2)));
        let b = q.take(now).unwrap();
        assert_eq!(b.reason, FlushReason::Deadline);
        assert_eq!(b.items.len(), 2);
        assert!(q.is_empty());
        assert!(q.take(now + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn sheds_deterministically_at_capacity_and_recovers() {
        let mut q = MicroBatchQueue::new(&policy(8, 1000, 3));
        let t0 = Instant::now();
        assert_eq!(q.push(1, t0), Ok(1));
        assert_eq!(q.push(2, t0), Ok(2));
        assert_eq!(q.push(3, t0), Ok(3));
        // Full: the 4th and 5th arrivals are handed back unchanged.
        assert_eq!(q.push(4, t0), Err(4));
        assert_eq!(q.push(5, t0), Err(5));
        assert_eq!(q.len(), 3);
        // Draining frees capacity again.
        let b = q.drain_batch().unwrap();
        assert_eq!(b.reason, FlushReason::Drain);
        assert_eq!(b.items.len(), 3);
        assert_eq!(q.push(6, t0), Ok(1));
    }

    #[test]
    fn normalization_keeps_capacity_at_least_max_batch() {
        let q: MicroBatchQueue<u8> = MicroBatchQueue::new(&policy(16, 1, 2));
        assert_eq!(q.capacity(), 16);
        let q: MicroBatchQueue<u8> = MicroBatchQueue::new(&BatchPolicy {
            max_batch: 0,
            ..BatchPolicy::default()
        });
        assert_eq!(q.policy.max_batch, 1);
    }

    #[test]
    fn split_expired_partitions_on_budget_and_preserves_order() {
        let mut q = MicroBatchQueue::new(&policy(8, 1000, 64));
        let t0 = Instant::now();
        q.push('a', t0).unwrap();
        q.push('b', t0 + Duration::from_millis(4)).unwrap();
        q.push('c', t0 + Duration::from_millis(9)).unwrap();
        let b = q.drain_batch().unwrap();
        // Budget 5ms at t0+10ms: 'a' waited 10ms (expired), 'b' 6ms
        // (expired), 'c' 1ms (live).
        let now = t0 + Duration::from_millis(10);
        let (live, expired) = b.split_expired(Some(Duration::from_millis(5)), now);
        assert_eq!(live.iter().map(|&(v, _)| v).collect::<Vec<_>>(), vec!['c']);
        assert_eq!(expired.iter().map(|&(v, _)| v).collect::<Vec<_>>(), vec!['a', 'b']);
        // No budget: nothing expires.
        let mut q = MicroBatchQueue::new(&policy(8, 1000, 64));
        q.push('z', t0).unwrap();
        let (live, expired) = q.drain_batch().unwrap().split_expired(None, now);
        assert_eq!(live.len(), 1);
        assert!(expired.is_empty());
    }

    #[test]
    fn drain_of_empty_queue_is_none() {
        let mut q: MicroBatchQueue<u8> = MicroBatchQueue::new(&BatchPolicy::default());
        assert!(q.drain_batch().is_none());
        assert_eq!(q.head_enqueued(), None);
        assert_eq!(q.next_deadline(), None);
    }

    #[test]
    fn peak_depth_is_recorded_at_push_time_and_survives_takes() {
        let mut q = MicroBatchQueue::new(&policy(8, 1000, 64));
        let t0 = Instant::now();
        for i in 0..5 {
            q.push(i, t0).unwrap();
        }
        assert_eq!(q.peak_depth(), 5);
        // Draining empties the queue but the push-time peak persists —
        // a metrics read after the take still sees the true high-water.
        q.drain_batch().unwrap();
        assert!(q.is_empty());
        assert_eq!(q.peak_depth(), 5);
        q.push(99, t0).unwrap();
        assert_eq!(q.peak_depth(), 5, "lower depths never lower the peak");
        // Shed pushes change nothing, including the peak.
        let mut q = MicroBatchQueue::new(&policy(8, 1000, 2));
        q.push(1, t0).unwrap();
        q.push(2, t0).unwrap();
        assert_eq!(q.push(3, t0), Err(3));
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn adaptive_delay_tracks_arrival_rate_within_clamps() {
        let bound = Duration::from_millis(10);
        let pol = BatchPolicy {
            max_batch: 8,
            max_delay: bound,
            adaptive_delay: true,
            ..BatchPolicy::default()
        };
        let mut q = MicroBatchQueue::new(&pol);
        let t0 = Instant::now();
        // No rate estimate yet: the configured bound applies.
        assert_eq!(q.effective_delay(), bound);
        q.push(0, t0).unwrap();
        assert_eq!(q.effective_delay(), bound, "one arrival is not a rate");
        // Steady 100 µs gaps → EWMA gap 100 µs → effective delay about
        // max_batch × gap = 800 µs, well under the 10 ms bound.
        for i in 1..20u64 {
            q.push(i as i32, t0 + Duration::from_micros(100 * i)).unwrap();
        }
        let d = q.effective_delay();
        assert!(d < bound, "auto-tuned {d:?} should undercut the bound");
        assert!(d >= Duration::from_micros(DELAY_FLOOR_US), "floor holds: {d:?}");
        assert!(
            (Duration::from_micros(400)..Duration::from_micros(1600)).contains(&d),
            "expected ≈800 µs, got {d:?}"
        );
        // The deadline trigger fires on the tuned window, not the bound.
        let q2 = {
            let mut q2 = MicroBatchQueue::new(&pol);
            for i in 0..7u64 {
                q2.push(i as i32, t0 + Duration::from_micros(100 * i)).unwrap();
            }
            q2
        };
        let head = t0;
        assert_eq!(q2.ready(head + Duration::from_micros(200)), None);
        assert_eq!(
            q2.ready(head + Duration::from_millis(2)),
            Some(FlushReason::Deadline),
            "tuned window (≈{:?}) fires long before the 10 ms bound",
            q2.effective_delay()
        );
        assert!(q2.next_deadline().unwrap() < head + bound);
    }

    #[test]
    fn adaptive_delay_clamps_dense_bursts_to_the_floor_and_idle_to_the_bound() {
        let pol = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_millis(5),
            adaptive_delay: true,
            ..BatchPolicy::default()
        };
        let mut ac = AdmissionController::new(&pol);
        let t0 = Instant::now();
        // Same-instant burst: gap 0 → clamp at the floor, never zero.
        for _ in 0..16 {
            ac.observe_arrival(t0);
        }
        assert_eq!(ac.current_delay(), Duration::from_micros(DELAY_FLOOR_US));
        // Huge gaps: the prediction exceeds the bound → clamp to it.
        let mut ac = AdmissionController::new(&pol);
        ac.observe_arrival(t0);
        ac.observe_arrival(t0 + Duration::from_secs(1));
        assert_eq!(ac.current_delay(), Duration::from_millis(5));
        // A bound tighter than the floor wins (clamp stays ordered).
        let tight = BatchPolicy {
            max_delay: Duration::from_micros(10),
            adaptive_delay: true,
            ..BatchPolicy::default()
        };
        let mut ac = AdmissionController::new(&tight);
        ac.observe_arrival(t0);
        ac.observe_arrival(t0);
        assert_eq!(ac.current_delay(), Duration::from_micros(10));
        // Disabled policies keep the static window.
        let q: MicroBatchQueue<u8> = MicroBatchQueue::new(&BatchPolicy::default());
        assert_eq!(q.effective_delay(), BatchPolicy::default().max_delay);
    }
}
