//! `fann-on-mcu` — the toolkit CLI.
//!
//! Commands:
//!
//! * `train`          — train an application showcase natively (iRPROP−),
//!                      save float + fixed `.net` files, report accuracy.
//! * `train-pjrt`     — train via the AOT-compiled JAX step (PJRT
//!                      runtime; needs `--features pjrt`).
//! * `deploy`         — plan placement + generate C code for a target
//!                      (legacy form; `deploy emit` supersedes it).
//! * `deploy emit`    — the emit pipeline: placement + generated C +
//!                      the machine-readable `deploy_plan.json`, from a
//!                      `.net` file or a synthesized `--topo` network,
//!                      at an explicit representation (f32/q32/q7/q15).
//! * `deploy emulate` — execute the emitted artifact in the Rust
//!                      emulator: bit-exact outputs vs the native
//!                      kernels plus the walked DMA/cycle/energy report.
//! * `run`            — simulate one classification on a target.
//! * `throughput`     — host-side batched-inference throughput: looped
//!                      single-sample vs batched kernels vs the parallel
//!                      batch driver vs compiled execution plans
//!                      (serial + row-split), float, fixed and packed.
//! * `bench json`     — the machine-readable kernel × mode throughput
//!                      sweep (incl. compiled-plan serial/row-split rows
//!                      and the fig11 row-split speedup) plus per-target
//!                      emulated cycle counts; writes
//!                      `BENCH_kernels.json` (the per-PR perf baseline
//!                      CI diffs against the committed copy).
//! * `bench smoke`    — row-split correctness gate: the compiled-plan
//!                      row-split path under 1/2/8 workers must
//!                      checksum-match the serial run for every kernel
//!                      family.
//! * `bench autotune` — time the host-SIMD kernel knobs (f32 row tile,
//!                      q7/q15 panel path — all candidates bit-exact
//!                      with each other) on this machine and install
//!                      the winners for the process.
//! * `paper reproduce` — the paper-results reproduction suite: train the
//!                      three wearable case studies (EMG / ECG / EEG),
//!                      emit + emulate each across the modeled targets
//!                      (`cortex-m4f`, `wolf-fc`, `wolf-{1,2,4,8}core`)
//!                      and write `PAPER_RESULTS.json` + `RESULTS.md`
//!                      with the per-app latency/memory/energy rows and
//!                      the wolf-8core-vs-m4 headline fields.
//! * `service load`   — the multi-tenant inference-service load harness:
//!                      replay seeded simulated wearable clients through
//!                      the adaptive micro-batching host (the
//!                      `fann_on_mcu::service` module), assert every
//!                      coalesced output bit-exact vs serial
//!                      per-request execution, and write
//!                      `BENCH_service.json` (samples/s, p50/p99 latency,
//!                      mean batch size) for the CI ratchet.
//! * `service chaos`  — the seeded fault-injection harness: replay the
//!                      same client fleet against a service with an
//!                      injected `FaultPlan` (exec panics, latency
//!                      spikes, NaN-poisoned inputs, dispatcher kills)
//!                      and audit the fault-tolerance contract (exactly
//!                      one terminal reply per accepted request,
//!                      quarantine trip → probe → recovery, watchdog
//!                      restarts, bit-exact successful replies); writes
//!                      `BENCH_chaos.json` and exits non-zero on any
//!                      violated invariant.
//! * `info`           — list applications, targets, artifact status.
//! * `help`           — this text.
//!
//! Examples:
//!
//! ```text
//! fann-on-mcu train --app fall --seed 7 --out /tmp/fall
//! fann-on-mcu deploy emit --target cortex-m4f --out /tmp/gen
//! fann-on-mcu deploy emit --net /tmp/fall.net --target wolf-8core --repr q7
//! fann-on-mcu deploy emulate --target wolf-8core --topo "76,300,200,100,10"
//! fann-on-mcu run --net /tmp/fall.net --target m4 --input "0.1,0.2,..."
//! fann-on-mcu train-pjrt --topo xor --steps 400
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use fann_on_mcu::apps::{self, AppSpec};
use fann_on_mcu::bench::batch;
use fann_on_mcu::cli::{parse_csv_f32, parse_sizes, parse_target, Args};
use fann_on_mcu::codegen::{self, EmitBundle, NetRepr, NetSource};
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::emulator;
use fann_on_mcu::fann::{io, Activation, FixedNetwork, Network};
use fann_on_mcu::runtime::ArtifactDir;
#[cfg(feature = "pjrt")]
use fann_on_mcu::runtime::{PjrtTrainer, Runtime};
use fann_on_mcu::simulator::{self, CostOptions, Executable};
use fann_on_mcu::targets::{Chip, DataType, Target};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn app_by_name(name: &str) -> Result<&'static AppSpec> {
    for app in apps::ALL_APPS {
        if app.name == name {
            return Ok(app);
        }
    }
    bail!("unknown app {name:?} (known: gesture, fall, activity)")
}

fn cmd_train(args: &Args) -> Result<()> {
    args.expect_only(&["app", "seed", "out"])?;
    let spec = app_by_name(args.get("app").context("--app required")?)?;
    let seed = args.get_u64("seed", 7)?;
    println!("training {} (topology {:?}, seed {seed})", spec.title, spec.sizes);
    let trained = apps::train_app(spec, seed)?;
    println!(
        "  epochs: {}   final MSE: {:.5}",
        trained.mse_curve.len(),
        trained.mse_curve.last().unwrap()
    );
    println!(
        "  train accuracy: {:.2}%   test accuracy: {:.2}% (paper: {:.2}%)",
        trained.train_accuracy * 100.0,
        trained.test_accuracy * 100.0,
        spec.paper_accuracy * 100.0
    );
    if let Some(out) = args.get("out") {
        std::fs::write(format!("{out}.net"), io::save_float(&trained.net))?;
        std::fs::write(format!("{out}_fixed.net"), io::save_fixed(&trained.fixed))?;
        println!("  wrote {out}.net and {out}_fixed.net");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> Result<()> {
    bail!("train-pjrt needs the PJRT runtime: rebuild with `cargo build --features pjrt` (and a real `xla` crate; see rust/Cargo.toml)")
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> Result<()> {
    args.expect_only(&["topo", "steps", "seed", "artifacts"])?;
    let name = args.get("topo").context("--topo required")?;
    let steps = args.get_usize("steps", 300)?;
    let seed = args.get_u64("seed", 7)?;
    let art = ArtifactDir::locate(args.get("artifacts").map(Path::new))?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut trainer = PjrtTrainer::new(&rt, &art, name, seed)?;

    let mut data = match name {
        "xor" => fann_on_mcu::datasets::xor(),
        "gesture" | "fall" | "activity" => {
            let mut d = app_by_name(name)?.dataset(seed);
            d.normalize_inputs();
            d
        }
        other => bail!("no dataset for topology {other:?}"),
    };
    if data.len() < trainer.manifest.train_batch {
        // tiny datasets (xor): oversample to one batch
        let orig = data.len();
        let mut i = 0;
        while data.len() < trainer.manifest.train_batch {
            let x = data.input(i % orig).to_vec();
            let y = data.target(i % orig).to_vec();
            data.push(&x, &y);
            i += 1;
        }
    }

    let mut rng = Rng::new(seed ^ 0x51);
    let curve = trainer.train(&data, steps, &mut rng)?;
    for (i, loss) in curve.iter().enumerate() {
        if i % (steps / 10).max(1) == 0 || i + 1 == curve.len() {
            println!("  step {i:>5}: loss {loss:.6}");
        }
    }
    println!("  accuracy: {:.2}%", trainer.accuracy(&data)? * 100.0);
    Ok(())
}

fn load_any_net(path: &str) -> Result<(Option<fann_on_mcu::fann::Network>, Option<FixedNetwork>)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    if text.starts_with("FANN_FLO") {
        Ok((Some(io::load_float(&text)?), None))
    } else if text.starts_with("FANN_FIX") {
        Ok((None, Some(io::load_fixed(&text)?)))
    } else {
        bail!("{path}: not a FANN .net file")
    }
}

fn cmd_deploy(args: &Args) -> Result<()> {
    args.expect_only(&["net", "target", "out", "dtype"])?;
    let target = parse_target(args.get("target").context("--target required")?)?;
    let (fnet, qnet) = load_any_net(args.get("net").context("--net required")?)?;

    let want_fixed = args.get("dtype") == Some("fixed") || !target.supports_float();
    let (shape, dtype, source): (NetShape, DataType, NetSource) = match (&fnet, &qnet, want_fixed) {
        (Some(n), _, false) => (NetShape::from(n), DataType::Float32, NetSource::Float(n)),
        (_, Some(q), _) => (NetShape::from(q), DataType::Fixed, NetSource::Fixed(q)),
        (Some(_), None, true) => {
            bail!("target needs fixed point: pass the *_fixed.net produced by `train --out`")
        }
        _ => unreachable!(),
    };

    let plan = deploy::plan(&shape, target, dtype)?;
    println!("deployment plan for {}:", target.label());
    println!("  estimated memory (Eq. 2): {} bytes", plan.est_memory_bytes);
    println!("  placement: {}", plan.region.name());
    if let Some(dma) = plan.dma {
        println!("  DMA strategy: {dma:?}");
    }
    if !plan.fits() {
        bail!("network does not fit this target");
    }
    let code = codegen::generate(&plan, source);
    match args.get("out") {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            for (name, contents) in &code.files {
                std::fs::write(Path::new(dir).join(name), contents)?;
                println!("  wrote {dir}/{name}");
            }
        }
        None => {
            println!(
                "  generated {} files ({} bytes); pass --out DIR to write them",
                code.files.len(),
                code.total_bytes()
            );
        }
    }
    Ok(())
}

/// Default synthesized topology and input bound shared by `deploy emit`
/// / `deploy emulate` and their native-parity reference — one source of
/// truth so the two can never drift apart.
const EMIT_DEFAULT_TOPO: &str = "64,64,32";
const EMIT_MAX_ABS_INPUT: f32 = 1.0;

/// The resolved network a `deploy emit` / `deploy emulate` invocation
/// operates on: a `.net` file (float or fixed) or a synthesized
/// `--topo` network (deterministic per `--seed`).
enum EmitSourceNet {
    Float(Network),
    Fixed(FixedNetwork),
}

fn resolve_emit_source(args: &Args) -> Result<EmitSourceNet> {
    if let Some(path) = args.get("net") {
        let (fnet, qnet) = load_any_net(path)?;
        Ok(match (fnet, qnet) {
            (Some(n), _) => EmitSourceNet::Float(n),
            (_, Some(q)) => EmitSourceNet::Fixed(q),
            _ => unreachable!(),
        })
    } else {
        let sizes = parse_sizes(args.get_or("topo", EMIT_DEFAULT_TOPO))?;
        let seed = args.get_u64("seed", 7)?;
        let mut rng = Rng::new(seed);
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)?;
        net.randomize(&mut rng, None);
        Ok(EmitSourceNet::Float(net))
    }
}

/// Emit the resolved source for `target` at the `--repr` choice
/// (default: f32 on FPU targets, q32 elsewhere; a fixed `.net` source
/// always deploys as q32).
fn emit_from_source(source: &EmitSourceNet, args: &Args, target: Target) -> Result<EmitBundle> {
    let default_repr = if target.supports_float() { "f32" } else { "q32" };
    match source {
        EmitSourceNet::Float(n) => {
            let repr = NetRepr::parse(args.get_or("repr", default_repr))?;
            codegen::emit_float(n, target, repr, EMIT_MAX_ABS_INPUT)
        }
        EmitSourceNet::Fixed(q) => {
            // Only an explicit conflicting --repr is an error.
            if let Some(r) = args.get("repr") {
                codegen::repr_for_fixed_source(NetRepr::parse(r)?)?;
            }
            codegen::emit_fixed(q, target)
        }
    }
}

/// The host-kernel outputs `deploy emulate` checks itself against,
/// derived from the SAME resolved source the artifact was emitted from.
fn native_reference_outputs(
    source: &EmitSourceNet,
    repr: NetRepr,
    input: &[f32],
) -> Result<Vec<f32>> {
    use fann_on_mcu::fann::from_float_packed;
    use fann_on_mcu::kernels::PackedWidth;
    Ok(match source {
        // Fixed source: the native path is the FixedNetwork itself.
        EmitSourceNet::Fixed(q) => q.run(input),
        EmitSourceNet::Float(n) => match repr {
            NetRepr::F32 => n.run(input),
            NetRepr::Q32 => FixedNetwork::from_float(n, EMIT_MAX_ABS_INPUT)?.run(input),
            NetRepr::Q7 => from_float_packed(n, EMIT_MAX_ABS_INPUT, PackedWidth::Q7)?.1.run(input),
            NetRepr::Q15 => {
                from_float_packed(n, EMIT_MAX_ABS_INPUT, PackedWidth::Q15)?.1.run(input)
            }
        },
    })
}

fn print_plan_summary(bundle: &EmitBundle) {
    let plan = &bundle.artifact.plan;
    println!("deploy plan for {} ({}):", plan.target.label(), plan.repr.label());
    println!("  estimated memory (Eq. 2): {} bytes", plan.est_memory_bytes);
    println!(
        "  parameters: {} bytes in {} placement {}",
        plan.param_bytes(),
        plan.repr.label(),
        plan.region.name()
    );
    if let Some(dma) = plan.dma {
        println!("  DMA strategy: {dma:?} (staging {} bytes of L1)", plan.staging_bytes());
    }
    if let Some(dec) = plan.decimal_point {
        println!("  decimal point: Q{dec}");
    }
    let mut t = Table::new(vec!["layer", "shape", "act", "bytes", "reads from", "dma chunks", "est cycles"]);
    for l in &plan.layers {
        t.row(vec![
            l.index.to_string(),
            format!("{}x{}", l.n_in, l.n_out),
            l.activation.name().to_string(),
            l.param_bytes.to_string(),
            l.compute_region.name().to_string(),
            l.dma.as_ref().map_or("-".to_string(), |d| d.chunks.to_string()),
            format!("{:.0}", l.est_cycles),
        ]);
    }
    t.print();
    println!(
        "  estimate: {:.0} cycles, {} / classification, {} energy",
        plan.cost.breakdown.total(),
        fmt_time(plan.cost.seconds),
        fmt_energy(plan.cost.energy_uj * 1e-6),
    );
}

/// `deploy emit` — run the emit pipeline and (optionally) write the
/// bundle, including `deploy_plan.json`, to `--out DIR`.
fn cmd_deploy_emit(args: &Args) -> Result<()> {
    args.expect_only(&["net", "topo", "seed", "target", "repr", "out"])?;
    let target = parse_target(args.get("target").context("--target required")?)?;
    let source = resolve_emit_source(args)?;
    let bundle = emit_from_source(&source, args, target)?;
    print_plan_summary(&bundle);
    match args.get("out") {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            for (name, contents) in &bundle.code.files {
                std::fs::write(Path::new(dir).join(name), contents)?;
                println!("  wrote {dir}/{name}");
            }
        }
        None => println!(
            "  generated {} files ({} bytes); pass --out DIR to write them",
            bundle.code.files.len(),
            bundle.code.total_bytes()
        ),
    }
    Ok(())
}

/// `deploy emulate` — emit, then execute the emitted artifact in the
/// Rust emulator and cross-check it bit-exactly against the native
/// kernel path for the same representation.
fn cmd_deploy_emulate(args: &Args) -> Result<()> {
    args.expect_only(&["net", "topo", "seed", "target", "repr", "input"])?;
    let target = parse_target(args.get("target").context("--target required")?)?;
    let source = resolve_emit_source(args)?;
    let bundle = emit_from_source(&source, args, target)?;
    let n_in = bundle.artifact.num_inputs();
    let input = match args.get("input") {
        Some(csv) => parse_csv_f32(csv)?,
        None => {
            let mut rng = Rng::new(args.get_u64("seed", 7)? ^ 0xE31);
            (0..n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect()
        }
    };
    let report = emulator::emulate(&bundle.artifact, &input)?;

    // Native parity: run the same resolved source through the host
    // kernel path of this representation and compare bit for bit (f32)
    // / value for value (dequantized fixed outputs round-trip the same
    // i32s).
    let native = native_reference_outputs(&source, bundle.artifact.plan.repr, &input)?;
    anyhow::ensure!(
        report.outputs == native,
        "emulated outputs diverged from the native kernel path: {:?} vs {native:?}",
        report.outputs
    );

    println!("outputs: {:?}", report.outputs);
    println!("predicted class: {}", fann_on_mcu::util::argmax(&report.outputs));
    println!("parity vs native {} kernels: OK (bit-exact)", bundle.artifact.plan.repr.label());
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec![
        "placement".to_string(),
        bundle.artifact.plan.region.name().to_string(),
    ])
    .row(vec!["cycles".to_string(), format!("{:.0}", report.cycles())])
    .row(vec!["compute time".to_string(), fmt_time(report.seconds)])
    .row(vec![
        "active power".to_string(),
        format!("{:.2} mW", report.active_mw),
    ])
    .row(vec![
        "energy/classification".to_string(),
        fmt_energy(report.energy_uj * 1e-6),
    ])
    .row(vec!["DMA transfers".to_string(), report.dma_chunks.to_string()])
    .row(vec![
        "DMA bytes".to_string(),
        report.dma_bytes.to_string(),
    ])
    .row(vec![
        "peak L1 bytes".to_string(),
        report.l1_peak_bytes.to_string(),
    ]);
    t.print();
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_only(&["net", "target", "input", "classifications"])?;
    let target = parse_target(args.get("target").context("--target required")?)?;
    let (fnet, qnet) = load_any_net(args.get("net").context("--net required")?)?;
    let input = parse_csv_f32(args.get("input").context("--input required")?)?;
    let n_class = args.get_u64("classifications", 1)?;

    let (plan, report) = match (&fnet, &qnet, target.supports_float()) {
        (Some(n), _, true) => {
            let plan = deploy::plan(&NetShape::from(n), target, DataType::Float32)?;
            let r =
                simulator::simulate(&plan, &Executable::Float(n), &input, CostOptions::default())?;
            (plan, r)
        }
        (_, Some(q), _) => {
            let plan = deploy::plan(&NetShape::from(q), target, DataType::Fixed)?;
            let r =
                simulator::simulate(&plan, &Executable::Fixed(q), &input, CostOptions::default())?;
            (plan, r)
        }
        (Some(_), None, false) => bail!("{} needs a fixed-point net", target.label()),
        _ => unreachable!(),
    };

    println!("outputs: {:?}", report.outputs);
    println!("predicted class: {}", fann_on_mcu::util::argmax(&report.outputs));
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["placement".to_string(), plan.region.name().to_string()])
        .row(vec![
            "cycles".to_string(),
            format!("{:.0}", report.breakdown.total()),
        ])
        .row(vec!["compute time".to_string(), fmt_time(report.seconds)])
        .row(vec![
            "active power".to_string(),
            format!("{:.2} mW", report.active_mw),
        ])
        .row(vec![
            "energy/classification".to_string(),
            fmt_energy(report.energy_uj * 1e-6),
        ])
        .row(vec![
            format!("amortized time ({n_class} classifications/activation)"),
            fmt_time(report.amortized_seconds(plan.target, n_class)),
        ])
        .row(vec![
            "amortized energy".to_string(),
            fmt_energy(report.amortized_energy_uj(plan.target, n_class) * 1e-6),
        ]);
    t.print();
    Ok(())
}

/// Host-side throughput comparison: the same randomized MLP executed as
/// (a) a loop of single-sample `run` calls, (b) one batched
/// kernel-dispatch `run_batch`, (c) the multi-threaded batch driver —
/// float and fixed paths. `bench::batch::measure_throughput` (shared
/// with `benches/perf_batch.rs`) asserts all modes are bit-identical,
/// then times them; only the loop structure differs, which is the
/// paper's Table I point transplanted to the host.
fn cmd_throughput(args: &Args) -> Result<()> {
    args.expect_only(&["topo", "samples", "threads", "reps", "seed"])?;
    let sizes = parse_sizes(args.get_or("topo", "64,64,64,8"))?;
    let n = args.get_usize("samples", 1024)?.max(1);
    let threads = args.get_usize("threads", 0)?;
    let reps = args.get_usize("reps", 7)?.max(1);
    let seed = args.get_u64("seed", 7)?;

    let mut rng = Rng::new(seed);
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0)?;
    let n_in = net.num_inputs();
    let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let workers = batch::effective_workers(threads);
    println!(
        "throughput: topology {:?} ({} MACs/inference), batch {n}, {workers} worker thread(s)\n",
        sizes,
        net.macs()
    );

    let rows = batch::measure_throughput(&net, &fixed, &xs, n, threads, 1, reps);
    let mut t = Table::new(vec!["path", "batch time", "samples/s", "vs loop"]);
    for row in &rows {
        t.row(vec![
            row.name.to_string(),
            fmt_time(row.seconds),
            format!("{:.0}", n as f64 / row.seconds),
            format!("{:.2}x", row.baseline_seconds / row.seconds),
        ]);
    }
    t.print();
    Ok(())
}

/// `bench <mode>` — the perf-tracking harness. `json` runs the kernel ×
/// execution-mode throughput sweep (`bench::batch::kernel_sweep`,
/// bit-parity asserted before timing) and writes `BENCH_kernels.json`,
/// giving subsequent PRs a machine-readable perf baseline; `smoke` is
/// the row-split correctness gate; `autotune` times the SIMD kernel
/// knob candidates on this host and prints the winners.
fn cmd_bench(mode: &str, args: &Args) -> Result<()> {
    match mode {
        "json" => cmd_bench_json(args),
        "smoke" => cmd_bench_smoke(args),
        "autotune" => cmd_bench_autotune(args),
        other => bail!("unknown bench mode {other:?} (known: json, smoke, autotune)"),
    }
}

/// `bench autotune` — run the full SIMD autotune grid
/// (`kernels::autotune`): time every candidate panel-path / f32-tile
/// knob value on this host (all candidates are bit-exact with each
/// other; the pass asserts it), install and report the winners.
fn cmd_bench_autotune(args: &Args) -> Result<()> {
    use fann_on_mcu::kernels::{autotune, cpu_features};

    args.expect_only(&["quick"])?;
    let quick = args.get_flag("quick")?;
    let feats = cpu_features();
    println!(
        "bench autotune: arch {}, detected SIMD level {} ({})",
        feats.arch,
        feats.detected.label(),
        if quick { "quick grid" } else { "full grid" },
    );

    let (tuning, timings) = autotune::autotune(quick);
    let mut t = Table::new(vec!["knob", "candidate", "best time", "chosen"]);
    for c in &timings {
        t.row(vec![
            c.knob.to_string(),
            c.candidate.clone(),
            fmt_time(c.seconds),
            if c.chosen { "*".to_string() } else { String::new() },
        ]);
    }
    t.print();
    println!(
        "\ninstalled: f32_rows_per_tile={} q7={} q15={}",
        tuning.f32_rows_per_tile,
        tuning.q7.label(),
        tuning.q15.label(),
    );
    Ok(())
}

/// `bench smoke` — the row-split correctness gate CI runs on every
/// push: execute the compiled-plan row-split path under 1, 2 and 8
/// workers for every kernel family on the fig11 and reference
/// topologies, and fail unless every checksum matches the serial plan
/// run exactly.
fn cmd_bench_smoke(args: &Args) -> Result<()> {
    use fann_on_mcu::bench::fig11_shape;
    use fann_on_mcu::fann::from_float_packed;
    use fann_on_mcu::kernels::{ExecPlan, PackedWidth};

    args.expect_only(&["samples", "seed"])?;
    let n = args.get_usize("samples", 96)?.max(1);
    let seed = args.get_u64("seed", 7)?;

    let topologies: [(&str, Vec<usize>); 2] = [
        ("fig11(6,8)", fig11_shape(6, 8).sizes),
        ("reference", vec![64, 64, 32]),
    ];
    let mut checked = 0usize;
    for (label, sizes) in topologies {
        let mut rng = Rng::new(seed ^ 0x50_C0DE);
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)?;
        net.randomize(&mut rng, None);
        let n_in = net.num_inputs();
        let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        // Float family.
        let plan_f = ExecPlan::compile(&net);
        let serial_ck = batch::checksum_f32(&plan_f.run_batch_f32(&xs, n));
        for workers in [1usize, 2, 8] {
            let ck = batch::checksum_f32(&batch::run_plan_rowsplit(&plan_f, &xs, n, workers));
            anyhow::ensure!(
                ck == serial_ck,
                "{label} f32: row-split checksum {ck:016x} != serial {serial_ck:016x} at {workers} workers"
            );
            checked += 1;
        }

        // Q32 + packed families.
        let fixed = FixedNetwork::from_float(&net, 1.0)?;
        let (_, packed7) = from_float_packed(&net, 1.0, PackedWidth::Q7)?;
        let (_, packed15) = from_float_packed(&net, 1.0, PackedWidth::Q15)?;
        let q_plans: [(&str, ExecPlan, Vec<i32>); 3] = [
            ("q32", ExecPlan::compile(&fixed), fixed.quantize_input(&xs)),
            ("q7", ExecPlan::compile(&packed7), packed7.quantize_input(&xs)),
            ("q15", ExecPlan::compile(&packed15), packed15.quantize_input(&xs)),
        ];
        for (family, plan, xq) in &q_plans {
            let serial_ck = batch::checksum_i32(&plan.run_batch_q(xq, n));
            for workers in [1usize, 2, 8] {
                let ck = batch::checksum_i32(&batch::run_plan_q_rowsplit(plan, xq, n, workers));
                anyhow::ensure!(
                    ck == serial_ck,
                    "{label} {family}: row-split checksum {ck:016x} != serial {serial_ck:016x} at {workers} workers"
                );
                checked += 1;
            }
        }
    }
    println!(
        "bench smoke: {checked} row-split runs (1/2/8 workers x f32/q32/q7/q15 x 2 topologies) \
         all checksum-identical to serial"
    );
    Ok(())
}

/// The compiled-plan headline measurement: the q32 [`ExecPlan`]
/// streaming `n` samples (kernels, epilogues and arena resolved once;
/// persistent flat scratch; 4-sample register tiles) against the seed's
/// execution model the plan replaces — one per-call kernel dispatch per
/// sample (`FixedNetwork::run_q` in a loop: per-call scratch routing,
/// batch-of-one kernel entry and a fresh output allocation per
/// classification). Outputs asserted bit-identical before timing.
fn bench_execplan_vs_dispatch(net: &Network, xs: &[f32], n: usize, reps: usize) -> Result<f64> {
    use fann_on_mcu::kernels::{ExecPlan, PlanScratch};

    let fixed = FixedNetwork::from_float(net, 1.0)?;
    let xq = fixed.quantize_input(xs);
    let plan = ExecPlan::compile(&fixed);
    let n_in = fixed.num_inputs();
    let n_out = fixed.num_outputs();

    let mut looped = Vec::with_capacity(n * n_out);
    for s in 0..n {
        looped.extend_from_slice(&fixed.run_q(&xq[s * n_in..(s + 1) * n_in]));
    }
    anyhow::ensure!(
        plan.run_batch_q(&xq, n) == looped,
        "exec plan diverged from the per-call dispatch loop"
    );

    let mut ck = 0u64;
    let t_dispatch = fann_on_mcu::bench::time_median(1, reps, || {
        ck = 0;
        for s in 0..n {
            ck = ck
                .wrapping_add(batch::checksum_i32(&fixed.run_q(&xq[s * n_in..(s + 1) * n_in])));
        }
        std::hint::black_box(ck);
    });
    let mut scratch = PlanScratch::new();
    let mut out = vec![0i32; n * n_out];
    let t_plan = fann_on_mcu::bench::time_median(1, reps, || {
        plan.run_batch_q_into(&xq, n, &mut scratch, &mut out);
        ck = batch::checksum_i32(&out);
        std::hint::black_box(ck);
    });
    Ok(t_dispatch / t_plan)
}

/// Measured Fig. 11 row-split comparison reported by `bench json`.
struct Fig11Rowsplit {
    sizes: Vec<usize>,
    serial_seconds: f64,
    rowsplit_seconds: f64,
    workers_requested: usize,
    speedup: f64,
    checksum: u64,
}

/// Time the q32 execution plan of the paper's Fig. 11 network family
/// (l_total = 6, d = 8 — the intra-network-parallelism benchmark)
/// serially and under the 8-worker row-split driver, asserting bit
/// parity before and checksum parity while timing.
fn bench_fig11_rowsplit(n: usize, seed: u64, reps: usize) -> Result<Fig11Rowsplit> {
    use fann_on_mcu::bench::{fig11_shape, time_median};
    use fann_on_mcu::kernels::{ExecPlan, PlanScratch};

    const WORKERS: usize = 8;
    let sizes = fig11_shape(6, 8).sizes;
    let mut rng = Rng::new(seed ^ 0xF16);
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0)?;
    let plan = ExecPlan::compile(&fixed);
    let n_in = net.num_inputs();
    let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let xq = fixed.quantize_input(&xs);

    let serial = plan.run_batch_q(&xq, n);
    anyhow::ensure!(
        serial == batch::run_plan_q_rowsplit(&plan, &xq, n, WORKERS),
        "fig11 row-split diverged from serial plan execution"
    );

    let mut scratch = PlanScratch::new();
    let mut out = vec![0i32; n * plan.num_outputs()];
    let mut ck = 0u64;
    let t_serial = time_median(1, reps, || {
        plan.run_batch_q_into(&xq, n, &mut scratch, &mut out);
        ck = batch::checksum_i32(&out);
        std::hint::black_box(ck);
    });
    let ck_serial = ck;
    // Same preallocated output buffer as the serial loop, so the timed
    // comparison measures the execution strategy, not the allocator.
    let t_rowsplit = time_median(1, reps, || {
        batch::run_plan_q_rowsplit_into(&plan, &xq, n, WORKERS, &mut out);
        ck = batch::checksum_i32(&out);
        std::hint::black_box(ck);
    });
    anyhow::ensure!(ck == ck_serial, "fig11 timed row-split checksum diverged");
    Ok(Fig11Rowsplit {
        sizes,
        serial_seconds: t_serial,
        rowsplit_seconds: t_rowsplit,
        workers_requested: WORKERS,
        speedup: t_serial / t_rowsplit,
        checksum: ck_serial,
    })
}

/// Time one packed batch run with the SIMD dispatch pinned to scalar vs
/// the ambient (runtime-detected) dispatch, returning
/// `t_scalar / t_simd`. Bit parity is asserted before timing: the SIMD
/// panel cores are bit-exact with the scalar fast/slow paths by
/// construction, so the two runs must agree word-for-word. On hosts
/// where detection lands on `Scalar` both timings measure the same code
/// and the ratio is ~1.0 — the field is still emitted so the
/// `bench_diff.py` missing-key check can never fire on a non-SIMD
/// runner.
fn bench_simd_q_speedup(
    net: &Network,
    xs: &[f32],
    n: usize,
    reps: usize,
    width: fann_on_mcu::kernels::PackedWidth,
) -> Result<f64> {
    use fann_on_mcu::bench::time_median;
    use fann_on_mcu::fann::from_float_packed;
    use fann_on_mcu::kernels::{with_forced_level, SimdLevel};

    let (_, packed) = from_float_packed(net, 1.0, width)?;
    let xq = packed.quantize_input(xs);
    let ambient = packed.run_batch_q(&xq, n);
    let forced = with_forced_level(SimdLevel::Scalar, || packed.run_batch_q(&xq, n));
    anyhow::ensure!(
        ambient == forced,
        "{} SIMD batch diverged from the forced-scalar batch",
        width.label(),
    );
    let mut ck = 0u64;
    let t_scalar = with_forced_level(SimdLevel::Scalar, || {
        time_median(1, reps, || {
            ck = batch::checksum_i32(&packed.run_batch_q(&xq, n));
            std::hint::black_box(ck);
        })
    });
    let t_simd = time_median(1, reps, || {
        ck = batch::checksum_i32(&packed.run_batch_q(&xq, n));
        std::hint::black_box(ck);
    });
    Ok(t_scalar / t_simd)
}

fn cmd_bench_json(args: &Args) -> Result<()> {
    use fann_on_mcu::util::json::Json;

    args.expect_only(&["topo", "samples", "threads", "reps", "seed", "out"])?;
    // The ISSUE's reference MLP for the packed-vs-FixedQ speedup gate.
    let sizes = parse_sizes(args.get_or("topo", "64,64,32"))?;
    let n = args.get_usize("samples", 1024)?.max(1);
    let threads = args.get_usize("threads", 0)?;
    let reps = args.get_usize("reps", 7)?.max(1);
    let seed = args.get_u64("seed", 7)?;
    let out_path = args.get_or("out", "BENCH_kernels.json");

    let mut rng = Rng::new(seed);
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)?;
    net.randomize(&mut rng, None);
    let n_in = net.num_inputs();
    let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let workers = batch::effective_workers(threads);
    println!(
        "bench json: topology {:?} ({} MACs/inference), batch {n}, {workers} worker(s), {reps} reps",
        sizes,
        net.macs()
    );

    // Install host-tuned SIMD knobs before any timed work (quick grid:
    // every candidate is bit-exact with every other, so this can only
    // change speed, never results). The chosen values ride along in the
    // JSON so a regression traced to a bad tuning is diagnosable.
    let feats = fann_on_mcu::kernels::cpu_features();
    let (tuning, autotune_timings) = fann_on_mcu::kernels::autotune::autotune(true);
    println!(
        "cpu: {} detected {} / selected {}; autotuned f32_rows_per_tile={} q7={} q15={}",
        feats.arch,
        feats.detected.label(),
        feats.selected.label(),
        tuning.f32_rows_per_tile,
        tuning.q7.label(),
        tuning.q15.label(),
    );

    let rows = batch::kernel_sweep(&net, &xs, n, threads, 1, reps);

    let mut t = Table::new(vec!["kernel", "mode", "batch time", "samples/s", "bytes/net"]);
    for r in &rows {
        t.row(vec![
            r.kernel.to_string(),
            r.mode.to_string(),
            fmt_time(r.seconds),
            format!("{:.0}", r.samples_per_sec),
            r.bytes_per_network.to_string(),
        ]);
    }
    t.print();

    let rate = |kernel: &str, mode: &str| {
        rows.iter()
            .find(|r| r.kernel == kernel && r.mode == mode)
            .map(|r| r.samples_per_sec)
            .unwrap_or(f64::NAN)
    };
    let speedup_q7 = rate("packed_q7", "serial") / rate("fixed_q", "serial");
    let speedup_q15 = rate("packed_q15", "serial") / rate("fixed_q", "serial");
    // The compiled-plan headline: the q32 exec plan streaming the whole
    // sample set (everything resolved at compile time, one flat
    // scratch) vs the seed's execution model this ISSUE replaces — one
    // per-call kernel dispatch per sample. Parity asserted inside.
    let speedup_execplan = bench_execplan_vs_dispatch(&net, &xs, n, reps)?;
    println!(
        "\nheadline: packed_q7 {speedup_q7:.2}x / packed_q15 {speedup_q15:.2}x vs fixed_q; \
         exec_plan q32 {speedup_execplan:.2}x vs per-call dispatch (single-thread)"
    );

    // Host-SIMD headline: each packed width timed with dispatch pinned
    // to scalar vs the ambient runtime-detected level (bit parity
    // asserted inside), plus the f32 SIMD kernel against the blocked
    // default from the sweep rows it already shares.
    let speedup_simd_q7 =
        bench_simd_q_speedup(&net, &xs, n, reps, fann_on_mcu::kernels::PackedWidth::Q7)?;
    let speedup_simd_q15 =
        bench_simd_q_speedup(&net, &xs, n, reps, fann_on_mcu::kernels::PackedWidth::Q15)?;
    let speedup_simd_f32 = rate("simd_f32", "serial") / rate("blocked_f32", "serial");
    println!(
        "simd ({}): packed_q7 {speedup_simd_q7:.2}x / packed_q15 {speedup_simd_q15:.2}x vs \
         forced-scalar dispatch; simd_f32 {speedup_simd_f32:.2}x vs blocked_f32 (serial)",
        feats.selected.label(),
    );

    // Intra-network parallelism on the paper's Fig. 11 family
    // (l_total = 6, d = 8): the q32 plan's row-split path under 8
    // requested workers vs its own serial run, bit-parity asserted.
    let fig11 = bench_fig11_rowsplit(n, seed, reps)?;
    println!(
        "fig11 {:?}: row-split x{} workers {:.2}x vs serial exec plan ({} -> {} samples/s)",
        fig11.sizes,
        fig11.workers_requested,
        fig11.speedup,
        (n as f64 / fig11.serial_seconds) as u64,
        (n as f64 / fig11.rowsplit_seconds) as u64,
    );

    // Per-target emulated cycle counts: emit the same network for each
    // modeled MCU and execute the artifact in the emulator, so the perf
    // baseline tracks target-side estimates alongside host throughput.
    let emu_cells: [(Target, NetRepr); 5] = [
        (Target::CortexM4(Chip::Stm32l475vg), NetRepr::Q32),
        (Target::WolfFc, NetRepr::Q32),
        (Target::WolfCluster { cores: 8 }, NetRepr::Q32),
        (Target::WolfCluster { cores: 8 }, NetRepr::Q7),
        (Target::WolfCluster { cores: 8 }, NetRepr::Q15),
    ];
    let mut emulated_rows = Vec::new();
    let mut et = Table::new(vec!["target", "repr", "placement", "cycles", "time", "inf/s"]);
    for (target, repr) in emu_cells {
        // A user-supplied --topo may legitimately not fit a target (or
        // not pack at q7): record the skip instead of failing the sweep.
        let bundle = match codegen::emit_float(&net, target, repr, 1.0) {
            Ok(b) => b,
            Err(e) => {
                println!("  (skipping {} {}: {e})", target.slug(), repr.label());
                continue;
            }
        };
        let report = emulator::emulate(&bundle.artifact, &xs[..n_in])?;
        let plan = &bundle.artifact.plan;
        et.row(vec![
            target.slug(),
            repr.label().to_string(),
            plan.region.name().to_string(),
            format!("{:.0}", report.cycles()),
            fmt_time(report.seconds),
            format!("{:.0}", 1.0 / report.seconds),
        ]);
        emulated_rows.push(
            Json::obj()
                .field("target", target.slug())
                .field("repr", repr.label())
                .field("region", plan.region.name())
                .field(
                    "dma",
                    match plan.dma {
                        Some(d) => Json::Str(format!("{d:?}")),
                        None => Json::Null,
                    },
                )
                .field("emulated_cycles", report.cycles())
                .field("seconds_per_inference", report.seconds)
                .field("energy_uj_per_inference", report.energy_uj)
                .field("inferences_per_sec", 1.0 / report.seconds)
                .build(),
        );
    }
    println!("\nemulated targets (one classification, analytic cycle model):");
    et.print();

    let json = Json::obj()
        .field("schema", "fann-on-mcu/bench-kernels/v1")
        .field(
            "topology",
            Json::Arr(sizes.iter().map(|&s| Json::Int(s as i64)).collect::<Vec<_>>()),
        )
        .field("samples", n)
        .field("reps", reps)
        .field("threads_requested", threads)
        .field("workers", workers)
        .field("seed", Json::Int(seed as i64))
        .field("macs_per_inference", net.macs())
        .field(
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj()
                            .field("kernel", r.kernel)
                            .field("mode", r.mode)
                            .field("seconds", r.seconds)
                            .field("seconds_min", r.seconds_min)
                            .field("seconds_max", r.seconds_max)
                            .field("reps", r.reps)
                            .field("samples_per_sec", r.samples_per_sec)
                            .field("bytes_per_network", r.bytes_per_network)
                            // Hex string: u64 digests don't fit JSON's
                            // i53-safe integer range.
                            .field("checksum", format!("{:016x}", r.checksum))
                            .build()
                    })
                    .collect::<Vec<_>>(),
            ),
        )
        .field("speedup_packed_q7_vs_fixed_q_serial", speedup_q7)
        .field("speedup_packed_q15_vs_fixed_q_serial", speedup_q15)
        .field("speedup_execplan_vs_dispatch_serial", speedup_execplan)
        .field("speedup_rowsplit_8w_vs_serial", fig11.speedup)
        .field("speedup_simd_q7_vs_scalar_serial", speedup_simd_q7)
        .field("speedup_simd_q15_vs_scalar_serial", speedup_simd_q15)
        .field("speedup_simd_f32_vs_blocked_serial", speedup_simd_f32)
        .field(
            "cpu_features",
            Json::obj()
                .field("arch", feats.arch)
                .field("detected", feats.detected.label())
                .field("selected", feats.selected.label())
                .field("sse2", feats.sse2)
                .field("avx2", feats.avx2)
                .field("fma", feats.fma)
                .field("neon", feats.neon)
                .build(),
        )
        .field(
            "autotune",
            Json::obj()
                .field("f32_rows_per_tile", tuning.f32_rows_per_tile)
                .field("q7_path", tuning.q7.label())
                .field("q15_path", tuning.q15.label())
                .field(
                    "candidates",
                    Json::Arr(
                        autotune_timings
                            .iter()
                            .map(|c| {
                                Json::obj()
                                    .field("knob", c.knob)
                                    .field("candidate", c.candidate.clone())
                                    .field("seconds", c.seconds)
                                    .field("chosen", c.chosen)
                                    .build()
                            })
                            .collect::<Vec<_>>(),
                    ),
                )
                .build(),
        )
        .field(
            "fig11_rowsplit",
            Json::obj()
                .field(
                    "topology",
                    Json::Arr(fig11.sizes.iter().map(|&s| Json::Int(s as i64)).collect::<Vec<_>>()),
                )
                .field("workers_requested", fig11.workers_requested)
                .field("serial_seconds", fig11.serial_seconds)
                .field("rowsplit_seconds", fig11.rowsplit_seconds)
                .field("samples_per_sec_serial", n as f64 / fig11.serial_seconds)
                .field("samples_per_sec_rowsplit", n as f64 / fig11.rowsplit_seconds)
                .field("checksum", format!("{:016x}", fig11.checksum))
                .build(),
        )
        .field("emulated", Json::Arr(emulated_rows))
        .build();
    std::fs::write(out_path, json.to_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `paper reproduce` — run the three wearable case studies end to end
/// (train → quantize → pack → plan → emit → emulate) across the modeled
/// targets and write the machine-readable `PAPER_RESULTS.json` plus the
/// rendered `RESULTS.md`.
fn cmd_paper_reproduce(args: &Args) -> Result<()> {
    use fann_on_mcu::bench::paper::{self, ReproduceOptions};

    args.expect_only(&["seed", "quick", "out"])?;
    let options = ReproduceOptions {
        seed: args.get_u64("seed", 7)?,
        quick: args.get_flag("quick")?,
    };
    let out_dir = Path::new(args.get_or("out", "."));
    println!(
        "paper reproduce: 3 apps x 6 targets, seed {}, {} mode",
        options.seed,
        if options.quick { "quick" } else { "full" }
    );

    let results = paper::reproduce(options)?;
    for a in &results.apps {
        let p = &a.pipeline;
        println!(
            "\n{} ({:?}, {}): float {:.1}% / quantized {:.1}% test accuracy{}",
            p.spec.title,
            p.spec.sizes,
            p.repr.label(),
            p.test_accuracy * 100.0,
            p.quantized_test_accuracy * 100.0,
            if p.meets_floor { "" } else { "  [below floor]" },
        );
        let mut t = Table::new(vec![
            "target", "placement", "latency", "energy/class", "power", "mem est/budget",
        ]);
        for r in &a.rows {
            t.row(vec![
                r.target.slug(),
                r.region.name().to_string(),
                fmt_time(r.seconds),
                fmt_energy(r.energy_uj * 1e-6),
                format!("{:.1} mW", r.active_mw),
                format!("{}/{} B", r.est_memory_bytes, r.budget_bytes),
            ]);
        }
        t.print();
        println!(
            "  wolf-8core vs cortex-m4f: {:.1}x speedup, {:.0}% energy reduction",
            a.speedup_wolf8_vs_m4,
            a.energy_reduction_wolf8_vs_m4 * 100.0
        );
    }

    println!(
        "\nheadline (geomean over apps): speedup_wolf8_vs_m4 {:.2}x, \
         energy_reduction_wolf8_vs_m4 {:.0}%",
        results.speedup_wolf8_vs_m4,
        results.energy_reduction_wolf8_vs_m4 * 100.0
    );
    let (json_path, md_path) = paper::write_results(&results, out_dir)?;
    println!("wrote {} and {}", json_path.display(), md_path.display());
    Ok(())
}

/// `service <mode>` — the multi-tenant inference host. `load` is the
/// synthetic client-replay harness; `chaos` is the seeded
/// fault-injection harness; `serve` stands the host up behind real
/// sockets (the wire front-end) until killed.
fn cmd_service(mode: &str, args: &Args) -> Result<()> {
    match mode {
        "load" => cmd_service_load(args),
        "chaos" => cmd_service_chaos(args),
        "serve" => cmd_service_serve(args),
        other => bail!("unknown service mode {other:?} (known: load, chaos, serve)"),
    }
}

/// `service serve` — stand the inference host up behind the wire
/// front-end on a Unix socket and/or a TCP listener, hosting the same
/// three seeded wearable demo models the harnesses replay. Runs for
/// `--duration-secs` (0, the default, means until killed); a bounded
/// run shuts down gracefully — in-flight requests answered `Aborted` —
/// and prints the wire counters.
fn cmd_service_serve(args: &Args) -> Result<()> {
    use fann_on_mcu::service::load::demo_registry;
    use fann_on_mcu::service::{BatchPolicy, InferenceService, ShardPolicy, WireConfig, WireServer};
    use std::sync::Arc;
    use std::time::Duration;

    args.expect_only(&[
        "uds",
        "tcp",
        "seed",
        "max-batch",
        "max-delay-us",
        "capacity",
        "shards",
        "workers",
        "max-frame",
        "max-in-flight",
        "duration-secs",
    ])?;
    let uds = args.get("uds");
    let tcp = args.get("tcp");
    if uds.is_none() && tcp.is_none() {
        bail!("service serve needs --uds PATH and/or --tcp ADDR");
    }
    let base = BatchPolicy::default();
    let policy = BatchPolicy {
        max_batch: args.get_usize("max-batch", base.max_batch)?,
        max_delay: Duration::from_micros(
            args.get_u64("max-delay-us", base.max_delay.as_micros() as u64)?,
        ),
        queue_capacity: args.get_usize("capacity", base.queue_capacity)?,
        exec_workers: args.get_usize("workers", base.exec_workers)?,
        ..base
    };
    let shards = args.get_usize("shards", 1)?.max(1);
    let seed = args.get_u64("seed", 7)?;
    let duration = args.get_u64("duration-secs", 0)?;
    let base_cfg = WireConfig::default();
    let cfg = WireConfig {
        max_frame: args.get_usize("max-frame", base_cfg.max_frame)?,
        max_in_flight: args.get_usize("max-in-flight", base_cfg.max_in_flight)?,
        ..base_cfg
    };

    let (registry, rows) = demo_registry(seed)?;
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &policy,
        &ShardPolicy::new(shards),
        None,
    ));
    let mut server = WireServer::start(svc, &cfg);
    if let Some(path) = uds {
        server
            .listen_uds(Path::new(path))
            .with_context(|| format!("binding UDS {path}"))?;
        println!("listening on uds {path}");
    }
    if let Some(addr) = tcp {
        let bound = server
            .listen_tcp(addr)
            .with_context(|| format!("binding TCP {addr}"))?;
        println!("listening on tcp {bound}");
    }
    for (id, n_in, n_out) in &rows {
        println!("  model {id}: {n_in} inputs -> {n_out} outputs");
    }
    println!(
        "policy: max_batch {}, max_delay {:?}, capacity {}, {} shard(s); \
         wire: max_frame {} B, max_in_flight {}",
        policy.max_batch,
        policy.max_delay,
        policy.queue_capacity,
        shards,
        cfg.max_frame,
        cfg.max_in_flight,
    );
    if duration == 0 {
        println!("serving until killed (pass --duration-secs N for a bounded run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    println!("serving for {duration}s");
    std::thread::sleep(Duration::from_secs(duration));
    let snap = server.shutdown_all();
    let w = snap.wire;
    println!(
        "wire: {} connections opened / {} closed, {} frames in / {} out, \
         {} bad frames, {} B in / {} B out",
        w.connections_opened,
        w.connections_closed,
        w.frames_rx,
        w.frames_tx,
        w.bad_frames,
        w.bytes_rx,
        w.bytes_tx,
    );
    Ok(())
}

/// `service load` — replay seeded simulated wearable clients through
/// the adaptive micro-batching `fann_on_mcu::service` host (three
/// registered models: packed-q7 EMG, q32 ECG, f32 EEG), assert every
/// coalesced reply bit-exact against serial per-request execution, and
/// write `BENCH_service.json`.
fn cmd_service_load(args: &Args) -> Result<()> {
    use fann_on_mcu::service::load::{self, LoadOptions};
    use std::time::Duration;

    args.expect_only(&[
        "quick",
        "wire",
        "clients",
        "requests",
        "seed",
        "max-batch",
        "max-delay-us",
        "capacity",
        "submitters",
        "shards",
        "adaptive",
        "workers",
        "out",
    ])?;
    let mut opts = if args.get_flag("quick")? {
        LoadOptions::quick()
    } else {
        LoadOptions::default()
    };
    opts.wire = args.get_flag("wire")?;
    opts.clients = args.get_usize("clients", opts.clients)?.max(1);
    opts.requests_per_client = args.get_usize("requests", opts.requests_per_client)?.max(1);
    opts.seed = args.get_u64("seed", opts.seed)?;
    opts.submitters = args.get_usize("submitters", opts.submitters)?.max(1);
    opts.shards = args.get_usize("shards", opts.shards)?.max(1);
    opts.policy.adaptive_delay = args.get_flag("adaptive")? || opts.policy.adaptive_delay;
    opts.policy.max_batch = args.get_usize("max-batch", opts.policy.max_batch)?;
    opts.policy.max_delay =
        Duration::from_micros(args.get_u64("max-delay-us", opts.policy.max_delay.as_micros() as u64)?);
    opts.policy.queue_capacity = args.get_usize("capacity", opts.policy.queue_capacity)?;
    opts.policy.exec_workers = args.get_usize("workers", opts.policy.exec_workers)?;
    let out_path = args.get_or("out", "BENCH_service.json");

    println!(
        "service load: {} clients x {} requests = {} total, max_batch {}, max_delay {:?}, \
         capacity {}, {} submitter(s), {} shard(s), {} exec worker(s), adaptive delay {}, \
         transport {}",
        opts.clients,
        opts.requests_per_client,
        opts.total_requests(),
        opts.policy.max_batch,
        opts.policy.max_delay,
        opts.policy.queue_capacity,
        opts.submitters,
        opts.shards,
        opts.policy.exec_workers,
        if opts.policy.adaptive_delay { "on" } else { "off" },
        if opts.wire { "wire (UDS frames)" } else { "in-process" },
    );

    let report = load::run(&opts)?;

    let mut t = Table::new(vec![
        "model", "repr", "topology", "completed", "shed", "batches", "mean batch", "p50", "p99",
        "peak depth",
    ]);
    for r in &report.rows {
        t.row(vec![
            r.model.clone(),
            r.repr.to_string(),
            format!("{:?}", r.topology),
            r.completed.to_string(),
            r.shed.to_string(),
            r.batches.to_string(),
            format!("{:.2}", r.mean_batch),
            format!("{} us", r.p50_us),
            format!("{} us", r.p99_us),
            r.peak_queue_depth.to_string(),
        ]);
    }
    t.print();
    println!(
        "headline: {:.0} samples/s coalesced vs {:.0} serial per-request ({:.2}x), \
         mean batch {:.2}, p50 {} us / p99 {} us, shed {} (retries {}), {} tenants, \
         outputs bit-exact vs serial",
        report.samples_per_sec,
        report.serial_samples_per_sec,
        report.speedup_service_vs_serial,
        report.mean_batch,
        report.p50_us,
        report.p99_us,
        report.shed_total,
        report.retries_total,
        report.tenants,
    );
    println!(
        "head-of-line: hot {} flooded vs cold {} — cold p99 {} us at 1 shard \
         vs {} us at {} shard(s)",
        report.head_of_line.hot_model,
        report.head_of_line.cold_model,
        report.head_of_line.cold_p99_us_single,
        report.head_of_line.cold_p99_us_sharded,
        report.head_of_line.shards,
    );
    if report.gave_up_total > 0 {
        println!(
            "warning: {} requests gave up after exhausting the shed-retry budget",
            report.gave_up_total
        );
    }
    if let Some(w) = &report.wire {
        println!(
            "wire: {} connections opened / {} closed, {} frames in / {} out, \
             {} bad frames, {} B in / {} B out, {} reset(s)",
            w.connections_opened,
            w.connections_closed,
            w.frames_rx,
            w.frames_tx,
            w.bad_frames,
            w.bytes_rx,
            w.bytes_tx,
            report.wire_resets,
        );
    }
    std::fs::write(out_path, report.to_json().to_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `service chaos` — replay the same simulated client fleet against a
/// service with an injected deterministic `FaultPlan`, write the audit
/// as `BENCH_chaos.json`, and exit non-zero if any fault-tolerance
/// invariant is violated (the artifact is written *before* the check,
/// so a red run still leaves the full report behind).
fn cmd_service_chaos(args: &Args) -> Result<()> {
    use fann_on_mcu::service::chaos::{self, ChaosOptions};

    args.expect_only(&[
        "quick", "wire", "clients", "requests", "seed", "submitters", "shards", "out",
    ])?;
    let mut opts = if args.get_flag("quick")? {
        ChaosOptions::quick()
    } else {
        ChaosOptions::default()
    };
    opts.wire = args.get_flag("wire")?;
    opts.clients = args.get_usize("clients", opts.clients)?.max(1);
    opts.requests_per_client = args.get_usize("requests", opts.requests_per_client)?.max(1);
    let seed = args.get_u64("seed", opts.seed)?;
    opts.seed = seed;
    opts.plan.seed = seed;
    opts.submitters = args.get_usize("submitters", opts.submitters)?.max(1);
    opts.shards = args.get_usize("shards", opts.shards)?.max(1);
    let out_path = args.get_or("out", "BENCH_chaos.json");

    println!(
        "service chaos: {} clients x {} requests = {} total on {} shard(s), transport {}; \
         panic window [{}, {}) on {}, \
         nan_prob {}, dispatcher kills at {:?}; breaker threshold {}, cooldown {:?}",
        opts.clients,
        opts.requests_per_client,
        opts.total_requests(),
        opts.shards,
        if opts.wire { "wire (UDS frames)" } else { "in-process" },
        opts.plan.panic_from,
        opts.plan.panic_until,
        opts.plan.panic_model,
        opts.plan.nan_prob,
        opts.plan.kill_at_iters,
        opts.breaker.failure_threshold,
        opts.breaker.cooldown,
    );

    let report = chaos::run(&opts)?;
    std::fs::write(out_path, report.to_json().to_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    println!(
        "replies: {} ok / {} exec-failed / {} timeout / {} aborted of {} accepted; \
         rejects: {} bad-input, {} shed-gave-up, {} quarantined-gave-up; \
         lost {}, duplicates {}, mismatches {}",
        report.replies_ok,
        report.replies_exec_failed,
        report.replies_timeout,
        report.replies_aborted,
        report.accepted,
        report.rejected_bad_input,
        report.shed_gave_up,
        report.quarantined_gave_up,
        report.lost_replies,
        report.duplicate_replies,
        report.mismatches,
    );
    println!(
        "quarantine: {} trips, {} probes, {} recoveries; watchdog restarts {}; \
         exec failures {}; p50 {} us / p99 {} us (faulted-model p99 {} us, healthy p99 {} us)",
        report.quarantine_trips,
        report.quarantine_probes,
        report.quarantine_recoveries,
        report.watchdog_restarts,
        report.exec_failures,
        report.p50_us,
        report.p99_us,
        report.p99_us_faulted_model,
        report.p99_us_healthy_models,
    );
    println!(
        "shards: {} dispatcher shard(s); per-shard counters reconcile: {}",
        report.shard_rows.len(),
        report.shard_accounting_ok,
    );
    if let Some(w) = &report.wire {
        println!(
            "wire: {} connections opened / {} closed, {} frames in / {} out, \
             {} bad frames, {} B in / {} B out, {} reset(s)",
            w.connections_opened,
            w.connections_closed,
            w.frames_rx,
            w.frames_tx,
            w.bad_frames,
            w.bytes_rx,
            w.bytes_tx,
            report.wire_resets,
        );
    }
    report.check()
}

fn cmd_info(args: &Args) -> Result<()> {
    args.expect_only(&["artifacts"])?;
    println!("applications:");
    for app in apps::ALL_APPS {
        println!(
            "  {:<10} {:<38} topology {:?} ({} MACs)",
            app.name,
            app.title,
            app.sizes,
            app.macs()
        );
    }
    println!("\ntargets: m4 (nRF52832), m4-stm32 (STM32L475VG), m0, ibex, cluster1..cluster8");
    match ArtifactDir::locate(args.get("artifacts").map(Path::new)) {
        Ok(a) => println!("\nartifacts: {}", a.root.display()),
        Err(_) => println!("\nartifacts: NOT BUILT (run `make artifacts`)"),
    }
    Ok(())
}

const HELP: &str = "\
fann-on-mcu — FANN-on-MCU reproduction toolkit

USAGE: fann-on-mcu <command> [--flag value]...

COMMANDS:
  train          --app <gesture|fall|activity> [--seed N] [--out PREFIX]
  train-pjrt     --topo <xor|gesture|fall|activity> [--steps N] [--seed N]  (needs --features pjrt)
  deploy         --net FILE.net --target T [--out DIR] [--dtype fixed]
  deploy emit    --target T [--net FILE.net | --topo \"64,64,32\" --seed N]
                 [--repr f32|q32|q7|q15] [--out DIR]
                 emit C sources + the machine-readable deploy_plan.json
  deploy emulate --target T [--net FILE.net | --topo ... --seed N] [--repr R]
                 [--input \"v1,v2,...\"]
                 execute the emitted artifact (bit-exact vs native kernels)
  run            --net FILE.net --target T --input \"v1,v2,...\" [--classifications N]
  throughput     [--topo \"64,64,64,8\"] [--samples N] [--threads T] [--reps R] [--seed N]
  bench json     [--topo \"64,64,32\"] [--samples N] [--threads T] [--reps R] [--seed N]
                 [--out FILE]   write the kernel sweep (incl. exec-plan
                 serial/row-split rows + fig11 row-split speedup) and
                 per-target emulated cycle counts to BENCH_kernels.json
  bench smoke    [--samples N] [--seed N]   assert the row-split path is
                 checksum-identical to serial under 1/2/8 workers
  paper reproduce [--seed N] [--quick] [--out DIR]
                 train the EMG/ECG/EEG wearable case studies, emit +
                 emulate each on cortex-m4f, wolf-fc and wolf-{1,2,4,8}core,
                 write PAPER_RESULTS.json + RESULTS.md (latency, memory
                 vs budget, energy, speedup_wolf8_vs_m4 headline)
  service load   [--quick] [--wire] [--clients N] [--requests N] [--seed N]
                 [--max-batch N] [--max-delay-us N] [--capacity N]
                 [--submitters N] [--shards N] [--adaptive] [--workers N]
                 [--out FILE]
                 replay simulated wearable clients (EMG q7 / ECG q32 /
                 EEG f32) through the multi-tenant micro-batching
                 service across N dispatcher shards; every coalesced
                 reply asserted bit-exact vs serial per-request
                 execution; writes BENCH_service.json (samples/s,
                 p50/p99 latency, mean batch size, per-shard rows, and
                 a hot/cold head-of-line decoupling probe); --wire
                 drives the run over real UDS clients of the frame
                 protocol and folds wire counters into the report
  service chaos  [--quick] [--wire] [--clients N] [--requests N] [--seed N]
                 [--submitters N] [--shards N] [--out FILE]
                 seeded fault injection against the same service (exec
                 panics, latency spikes, NaN-poisoned inputs, dispatcher
                 kills); audits exactly-one-terminal-reply, quarantine
                 trip/probe/recovery, watchdog restarts, and bit-exact
                 successful replies; writes BENCH_chaos.json and exits
                 non-zero on any violated invariant; --wire replays the
                 same faults across the socket boundary
  service serve  (--uds PATH and/or --tcp ADDR) [--seed N] [--max-batch N]
                 [--max-delay-us N] [--capacity N] [--shards N]
                 [--workers N] [--max-frame BYTES] [--max-in-flight N]
                 [--duration-secs N]
                 stand the inference host up behind the length-prefixed
                 wire protocol (see README \"Wire protocol\"), hosting
                 the three seeded wearable demo models; runs until
                 killed unless --duration-secs bounds it (then shuts
                 down gracefully and prints wire counters)
  info           show applications, targets, artifact status
  help           this text

TARGETS: m4, cortex-m4f, m0, ibex/wolf-fc, cluster1..cluster8 (wolf-8core, ...)
BENCHES: cargo bench (one binary per paper figure/table; see DESIGN.md)
";

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // `bench`, `deploy`, `paper` and `service` take one optional
    // positional mode word (`bench json`, `deploy emit`, `service
    // load`, ...) ahead of their flags; everything else is pure
    // `command --flag value` form.
    let sub_mode = if matches!(
        argv.first().map(String::as_str),
        Some("bench") | Some("deploy") | Some("paper") | Some("service")
    ) && argv.get(1).is_some_and(|a| !a.starts_with("--"))
    {
        Some(argv.remove(1))
    } else {
        None
    };
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "train-pjrt" => cmd_train_pjrt(&args),
        "deploy" => match sub_mode.as_deref() {
            None => cmd_deploy(&args),
            Some("emit") => cmd_deploy_emit(&args),
            Some("emulate") => cmd_deploy_emulate(&args),
            Some(other) => bail!("unknown deploy mode {other:?} (known: emit, emulate)"),
        },
        "run" => cmd_run(&args),
        "throughput" => cmd_throughput(&args),
        "bench" => cmd_bench(sub_mode.as_deref().unwrap_or("json"), &args),
        "paper" => match sub_mode.as_deref().unwrap_or("reproduce") {
            "reproduce" => cmd_paper_reproduce(&args),
            other => bail!("unknown paper mode {other:?} (known: reproduce)"),
        },
        "service" => cmd_service(sub_mode.as_deref().unwrap_or("load"), &args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}
