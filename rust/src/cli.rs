//! Hand-rolled CLI argument parsing (no `clap` in the offline crate set).
//!
//! Grammar: `fann-on-mcu <command> [--flag value]...`. Flags are
//! order-insensitive; unknown flags are errors. Flags listed in
//! [`BOOLEAN_FLAGS`] are switches: they may appear valueless
//! (`paper reproduce --quick` == `--quick true`); every other flag
//! still errors when its value is missing.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// The flags that parse as valueless boolean switches. Every other
/// flag keeps the `--flag value` grammar (and the "needs a value"
/// error), so forgetting a value can never silently become `"true"`.
pub const BOOLEAN_FLAGS: &[&str] = &["quick", "wire"];

/// Parsed command line: the subcommand and its `--key value` flags.
#[derive(Debug, Clone)]
pub struct Args {
    /// The subcommand word (`train`, `deploy`, `paper`, ...).
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, found {arg:?}"))?;
            // A registered switch directly followed by another flag (or
            // by the end of the line) is valueless and parses as true.
            let has_value = it.peek().is_some_and(|next| !next.starts_with("--"));
            let val = if has_value {
                it.next().unwrap()
            } else if BOOLEAN_FLAGS.contains(&key) {
                "true".to_string()
            } else {
                bail!("flag --{key} needs a value");
            };
            if flags.insert(key.to_string(), val).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(Self { command, flags })
    }

    /// The raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// The value of `--key`, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse `--key` as a `usize` (errors on malformed input).
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad --{key} {v:?}")),
            None => Ok(default),
        }
    }

    /// Parse `--key` as a `u64` (errors on malformed input).
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("bad --{key} {v:?}")),
            None => Ok(default),
        }
    }

    /// Boolean switch: absent → `false`; `--key` / `--key true` /
    /// `--key 1` → `true`; `--key false` / `--key 0` → `false`.
    pub fn get_flag(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(other) => bail!("bad boolean --{key} {other:?} (use true/false)"),
        }
    }

    /// Error on any flag not in `known` (typo guard).
    pub fn expect_only(&self, known: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !known.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for `{}` (known: {})",
                    self.command,
                    known.join(", ")
                );
            }
        }
        Ok(())
    }
}

/// Parse a `--target` value into a [`crate::targets::Target`]. Accepts
/// the historical short aliases plus every [`crate::targets::Target::slug`]
/// (`cortex-m4f`, `cortex-m4f-nrf52832`, `wolf-8core`, ...) so plans and
/// bench rows round-trip back through the CLI to the same target, chip
/// included.
pub fn parse_target(s: &str) -> Result<crate::targets::Target> {
    use crate::targets::{Chip, Target};
    fn parse_chip(s: &str) -> Result<Chip> {
        Ok(match s {
            "nrf52832" => Chip::Nrf52832,
            "stm32l475vg" => Chip::Stm32l475vg,
            "stm32f769" => Chip::Stm32f769,
            other => bail!("unknown chip {other:?} (known: nrf52832, stm32l475vg, stm32f769)"),
        })
    }
    Ok(match s {
        "m4" | "cortex-m4" | "nrf52832" => Target::CortexM4(Chip::Nrf52832),
        "m4f" | "cortex-m4f" | "m4-stm32" | "stm32l475vg" => Target::CortexM4(Chip::Stm32l475vg),
        "m7" | "m7f" | "cortex-m7" | "cortex-m7f" | "stm32f769" => Target::CortexM7(Chip::Stm32f769),
        "m0" | "cortex-m0" => Target::CortexM0(Chip::Nrf52832),
        "ibex" | "fc" | "wolf-fc" => Target::WolfFc,
        "riscy" | "cluster1" => Target::WolfCluster { cores: 1 },
        "cluster" | "cluster8" | "multi" => Target::WolfCluster { cores: 8 },
        other => {
            if let Some(n) = other.strip_prefix("cluster") {
                Target::WolfCluster {
                    cores: n.parse().with_context(|| format!("bad target {other:?}"))?,
                }
            } else if let Some(n) = other
                .strip_prefix("wolf-")
                .and_then(|rest| rest.strip_suffix("core"))
            {
                Target::WolfCluster {
                    cores: n.parse().with_context(|| format!("bad target {other:?}"))?,
                }
            } else if let Some(chip) = other.strip_prefix("cortex-m4f-") {
                Target::CortexM4(parse_chip(chip)?)
            } else if let Some(chip) = other.strip_prefix("cortex-m7f-") {
                Target::CortexM7(parse_chip(chip)?)
            } else if let Some(chip) = other.strip_prefix("cortex-m0-") {
                Target::CortexM0(parse_chip(chip)?)
            } else {
                bail!(
                    "unknown target {other:?} (try: m4, cortex-m4f, m7, m0, ibex, wolf-fc, cluster1..cluster8, wolf-8core)"
                )
            }
        }
    })
}

/// Parse a comma-separated float vector (`--input "0.1,0.2,..."`).
pub fn parse_csv_f32(s: &str) -> Result<Vec<f32>> {
    s.split(',')
        .map(|v| v.trim().parse::<f32>().with_context(|| format!("bad value {v:?}")))
        .collect()
}

/// Parse a comma-separated layer-size list (`--topo "64,64,64,8"`) into
/// network sizes `[in, h1, ..., out]`.
pub fn parse_sizes(s: &str) -> Result<Vec<usize>> {
    let sizes: Vec<usize> = s
        .split(',')
        .map(|v| v.trim().parse::<usize>().with_context(|| format!("bad layer size {v:?}")))
        .collect::<Result<_>>()?;
    if sizes.len() < 2 {
        bail!("topology needs at least input and output layers (got {s:?})");
    }
    if sizes.iter().any(|&v| v == 0) {
        bail!("zero-width layer in topology {s:?}");
    }
    Ok(sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::targets::{Chip, Target};

    fn args(v: &[&str]) -> Result<Args> {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_command_and_flags() {
        let a = args(&["train", "--app", "fall", "--seed", "7"]).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("app"), Some("fall"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.get_usize("epochs", 100).unwrap(), 100);
    }

    #[test]
    fn rejects_malformed() {
        assert!(args(&["run", "positional"]).is_err());
        assert!(args(&["run", "--a", "1", "--a", "2"]).is_err());
        // Non-switch flags still require a value — trailing or followed
        // by another flag.
        assert!(args(&["run", "--flag"]).is_err());
        assert!(args(&["paper", "--out"]).is_err());
        assert!(args(&["paper", "--out", "--quick"]).is_err());
    }

    #[test]
    fn boolean_switches() {
        // Trailing switch and switch-before-another-flag both parse true.
        let a = args(&["paper", "--quick"]).unwrap();
        assert!(a.get_flag("quick").unwrap());
        let a = args(&["paper", "--quick", "--seed", "9"]).unwrap();
        assert!(a.get_flag("quick").unwrap());
        assert_eq!(a.get_u64("seed", 0).unwrap(), 9);
        // Explicit values still work; absent defaults to false.
        assert!(!args(&["paper", "--quick", "false"]).unwrap().get_flag("quick").unwrap());
        assert!(!args(&["paper"]).unwrap().get_flag("quick").unwrap());
        assert!(args(&["paper", "--quick", "maybe"]).unwrap().get_flag("quick").is_err());
    }

    #[test]
    fn expect_only_catches_typos() {
        let a = args(&["train", "--sed", "7"]).unwrap();
        assert!(a.expect_only(&["seed"]).is_err());
        let a = args(&["train", "--seed", "7"]).unwrap();
        assert!(a.expect_only(&["seed"]).is_ok());
    }

    #[test]
    fn target_aliases() {
        assert_eq!(
            parse_target("m4").unwrap(),
            Target::CortexM4(Chip::Nrf52832)
        );
        assert_eq!(parse_target("ibex").unwrap(), Target::WolfFc);
        assert_eq!(
            parse_target("cluster4").unwrap(),
            Target::WolfCluster { cores: 4 }
        );
        assert!(parse_target("gpu").is_err());
    }

    #[test]
    fn target_slugs_round_trip_to_the_same_target() {
        for t in [
            Target::CortexM4(Chip::Stm32l475vg),
            Target::CortexM4(Chip::Nrf52832),
            Target::CortexM7(Chip::Stm32f769),
            Target::CortexM0(Chip::Nrf52832),
            Target::CortexM0(Chip::Stm32l475vg),
            Target::WolfFc,
            Target::WolfCluster { cores: 1 },
            Target::WolfCluster { cores: 8 },
        ] {
            // Full equality — chip included — not just slug-string
            // equality, so two chips can never alias through a plan file.
            assert_eq!(parse_target(&t.slug()).unwrap(), t, "slug {:?}", t.slug());
        }
        assert_eq!(
            parse_target("wolf-8core").unwrap(),
            Target::WolfCluster { cores: 8 }
        );
        assert!(parse_target("wolf-xcore").is_err());
        assert!(parse_target("cortex-m4f-unknownchip").is_err());
    }

    #[test]
    fn csv_parse() {
        assert_eq!(parse_csv_f32("1, 2.5,-3").unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(parse_csv_f32("a,b").is_err());
    }

    #[test]
    fn sizes_parse() {
        assert_eq!(parse_sizes("64, 64,64,8").unwrap(), vec![64, 64, 64, 8]);
        assert!(parse_sizes("64").is_err());
        assert!(parse_sizes("64,0,8").is_err());
        assert!(parse_sizes("64,x,8").is_err());
    }
}
