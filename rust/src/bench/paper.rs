//! The `paper reproduce` driver: run the three wearable case studies
//! ([`crate::apps::paper`]) across the modeled targets and assemble the
//! machine-readable `PAPER_RESULTS.json` plus the rendered `RESULTS.md`
//! — the reproduction of the shape of the paper's Figures 9–13
//! (per-app latency, memory footprint vs target budgets, energy per
//! classification, cluster-core scaling, and the octa-core-vs-M4
//! speedup / energy-reduction headline).
//!
//! Per app × target cell the driver runs the *target* half of the
//! pipeline: `codegen::emit_float` at the app's deployed representation
//! (placement → detailed plan → generated C + artifact), then
//! [`crate::emulator::emulate`] executes the emitted artifact — so
//! every number in the results file comes from walking an actually
//! emitted deployment, not from the analytic estimate alone — and the
//! emulated outputs are asserted bit-exact against the host quantized
//! network before any number is recorded.
//!
//! Headline semantics: `speedup_wolf8_vs_m4` and
//! `energy_reduction_wolf8_vs_m4` compare the emulated compute phase of
//! `wolf-8core` against `cortex-m4f` per app and aggregate with a
//! geometric mean; cluster bring-up (~1.2 ms, paid once per activation)
//! is excluded, matching the paper's asymptotic continuous-monitoring
//! numbers.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::apps::paper::{train_paper_app, PaperPipeline, PAPER_APPS, PAPER_MAX_ABS_INPUT};
use crate::codegen;
use crate::deploy::cluster_l1_budget;
use crate::emulator;
use crate::targets::{memspec, Chip, Region, Target};
use crate::util::json::Json;

/// The target sweep of the reproduction: the paper's single-core
/// Cortex-M4 reference, the Wolf fabric controller, and the cluster at
/// 1/2/4/8 active cores (the Fig. 9/12 scaling axis).
pub fn paper_targets() -> [Target; 6] {
    [
        Target::CortexM4(Chip::Stm32l475vg),
        Target::WolfFc,
        Target::WolfCluster { cores: 1 },
        Target::WolfCluster { cores: 2 },
        Target::WolfCluster { cores: 4 },
        Target::WolfCluster { cores: 8 },
    ]
}

/// Options of one `paper reproduce` run.
#[derive(Debug, Clone, Copy)]
pub struct ReproduceOptions {
    /// Master seed: datasets, initial weights and probe selection all
    /// derive from it, so a run is reproducible end to end.
    pub seed: u64,
    /// Shrink datasets/epochs for CI smoke runs. Topologies and
    /// targets are unchanged, so modeled numbers match a full run
    /// whenever the per-app representation choice (accuracy-dependent,
    /// recorded as `repr` in the results) matches; achieved accuracy
    /// is the only field that always differs.
    pub quick: bool,
}

impl Default for ReproduceOptions {
    fn default() -> Self {
        Self { seed: 7, quick: false }
    }
}

/// One app × target cell of the reproduction: the emulated deployment's
/// latency, memory and energy numbers.
#[derive(Debug, Clone)]
pub struct TargetRow {
    /// The deployment target of this cell.
    pub target: Target,
    /// Where the parameters rest. A placement that does not fit the
    /// target aborts the whole reproduction with a structured error
    /// (every app in the suite fits every swept target, pinned by
    /// `rust/tests/paper_repro.rs`), so recorded rows never hold
    /// `NoFit`.
    pub region: Region,
    /// DMA double-buffer strategy, if the deployment streams from L2.
    pub dma: Option<crate::deploy::DmaStrategy>,
    /// Emulated cycles for one classification.
    pub cycles: f64,
    /// Emulated compute-phase latency in seconds.
    pub seconds: f64,
    /// Emulated compute-phase energy per classification in µJ.
    pub energy_uj: f64,
    /// Modeled active power while computing, in mW.
    pub active_mw: f64,
    /// Cluster core-busy fraction (1.0 on single-core targets).
    pub utilization: f64,
    /// Sustained classifications per second (1 / `seconds`).
    pub throughput_hz: f64,
    /// Parameter bytes in the deployed representation.
    pub param_bytes: usize,
    /// Eq. (2) memory estimate (4-byte words, the planner's form).
    pub est_memory_bytes: usize,
    /// Capacity of the region the parameters rest in.
    pub budget_bytes: usize,
    /// DMA transfers programmed per classification.
    pub dma_chunks: usize,
    /// Peak emulated L1 occupancy in bytes (cluster targets).
    pub l1_peak_bytes: usize,
}

impl TargetRow {
    /// Emulated latency in microseconds.
    pub fn latency_us(&self) -> f64 {
        self.seconds * 1e6
    }

    /// Fraction of the resting region's capacity the Eq. (2) estimate
    /// occupies (0.0 when the region has no meaningful budget).
    pub fn memory_utilization(&self) -> f64 {
        if self.budget_bytes == 0 {
            0.0
        } else {
            self.est_memory_bytes as f64 / self.budget_bytes as f64
        }
    }
}

/// One reproduced case study: host-pipeline metadata plus the per-target
/// sweep and this app's headline ratios.
pub struct AppResult {
    /// The host half (trained nets, accuracy, chosen representation).
    pub pipeline: PaperPipeline,
    /// One row per entry of [`paper_targets`], in order.
    pub rows: Vec<TargetRow>,
    /// Emulated wolf-8core speedup over cortex-m4f (compute phase).
    pub speedup_wolf8_vs_m4: f64,
    /// `1 − E(wolf-8core)/E(cortex-m4f)` per classification.
    pub energy_reduction_wolf8_vs_m4: f64,
    /// `(cores, speedup-vs-1-core, utilization)` for the cluster rows —
    /// the Fig. 9/12 scaling curve.
    pub cluster_scaling: Vec<(u32, f64, f64)>,
}

/// The full `paper reproduce` output.
pub struct PaperResults {
    /// Options the run used.
    pub options: ReproduceOptions,
    /// One entry per [`PAPER_APPS`] element, in order.
    pub apps: Vec<AppResult>,
    /// Geometric mean of the per-app wolf-8core-vs-m4 speedups.
    pub speedup_wolf8_vs_m4: f64,
    /// `1 −` geometric mean of the per-app energy ratios.
    pub energy_reduction_wolf8_vs_m4: f64,
}

/// Capacity of the region a deployment's parameters rest in.
fn region_budget(target: Target, region: Region) -> usize {
    let wolf = memspec::WOLF_MEMORY;
    match (target, region) {
        (Target::CortexM4(c) | Target::CortexM7(c) | Target::CortexM0(c), Region::Ram) => {
            c.memory().ram
        }
        (Target::CortexM4(c) | Target::CortexM7(c) | Target::CortexM0(c), Region::Flash) => {
            c.memory().flash
        }
        (_, Region::PrivateL2) => wolf.private_l2,
        (_, Region::SharedL2) => wolf.shared_l2,
        (_, Region::L1) => cluster_l1_budget(),
        _ => 0,
    }
}

fn geomean(vals: impl IntoIterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in vals {
        sum += v.ln();
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        (sum / n as f64).exp()
    }
}

/// Emit + emulate one app on one target, cross-checking the emulated
/// outputs bit-exactly against the host quantized path before recording
/// any number.
fn run_cell(pipe: &PaperPipeline, target: Target, probe: &[f32]) -> Result<TargetRow> {
    let bundle = codegen::emit_float(&pipe.net, target, pipe.repr, PAPER_MAX_ABS_INPUT)
        .with_context(|| format!("emitting {} for {}", pipe.spec.name, target.slug()))?;
    let plan = &bundle.artifact.plan;
    let report = emulator::emulate(&bundle.artifact, probe)
        .with_context(|| format!("emulating {} on {}", pipe.spec.name, target.slug()))?;

    // The reproduction's parity gate: what the emulated deployment
    // computed must be exactly what the host quantized network computes
    // (the same invariant `deploy emulate` enforces).
    let native = pipe.fixed.run(probe);
    ensure!(
        report.outputs == native,
        "{} on {}: emulated outputs diverged from the host {} path",
        pipe.spec.name,
        target.slug(),
        pipe.repr.label()
    );

    Ok(TargetRow {
        target,
        region: plan.region,
        dma: plan.dma,
        cycles: report.cycles(),
        seconds: report.seconds,
        energy_uj: report.energy_uj,
        active_mw: report.active_mw,
        utilization: report.utilization,
        throughput_hz: 1.0 / report.seconds,
        param_bytes: plan.param_bytes(),
        est_memory_bytes: plan.est_memory_bytes,
        budget_bytes: region_budget(target, plan.region),
        dma_chunks: report.dma_chunks,
        l1_peak_bytes: report.l1_peak_bytes,
    })
}

/// Find the row of `slug` in a sweep.
fn row<'a>(rows: &'a [TargetRow], slug: &str) -> Result<&'a TargetRow> {
    rows.iter()
        .find(|r| r.target.slug() == slug)
        .with_context(|| format!("missing {slug} row in the target sweep"))
}

/// Run the whole reproduction: train the three case studies, sweep the
/// targets, compute the headline ratios.
pub fn reproduce(options: ReproduceOptions) -> Result<PaperResults> {
    let mut apps = Vec::with_capacity(PAPER_APPS.len());
    for spec in PAPER_APPS {
        let pipe = train_paper_app(spec, options.seed, options.quick)?;
        ensure!(!pipe.test.is_empty(), "{}: empty held-out split", spec.name);
        let probe = pipe.test.input(0).to_vec();

        let rows = paper_targets()
            .iter()
            .map(|&t| run_cell(&pipe, t, &probe))
            .collect::<Result<Vec<_>>>()?;

        let m4 = row(&rows, "cortex-m4f")?;
        let wolf8 = row(&rows, "wolf-8core")?;
        let speedup = m4.seconds / wolf8.seconds;
        let reduction = 1.0 - wolf8.energy_uj / m4.energy_uj;

        let one_core = row(&rows, "wolf-1core")?.seconds;
        let cluster_scaling = rows
            .iter()
            .filter(|r| matches!(r.target, Target::WolfCluster { .. }))
            .map(|r| (r.target.num_cores(), one_core / r.seconds, r.utilization))
            .collect();

        apps.push(AppResult {
            pipeline: pipe,
            rows,
            speedup_wolf8_vs_m4: speedup,
            energy_reduction_wolf8_vs_m4: reduction,
            cluster_scaling,
        });
    }

    let speedup_wolf8_vs_m4 = geomean(apps.iter().map(|a| a.speedup_wolf8_vs_m4));
    let energy_reduction_wolf8_vs_m4 =
        1.0 - geomean(apps.iter().map(|a| 1.0 - a.energy_reduction_wolf8_vs_m4));
    Ok(PaperResults {
        options,
        apps,
        speedup_wolf8_vs_m4,
        energy_reduction_wolf8_vs_m4,
    })
}

impl PaperResults {
    /// Render as the `PAPER_RESULTS.json` value tree.
    pub fn to_json(&self) -> Json {
        let apps = self
            .apps
            .iter()
            .map(|a| {
                let p = &a.pipeline;
                Json::obj()
                    .field("name", p.spec.name)
                    .field("title", p.spec.title)
                    .field(
                        "topology",
                        Json::Arr(p.spec.sizes.iter().map(|&s| Json::Int(s as i64)).collect()),
                    )
                    .field("macs_per_inference", p.spec.macs())
                    .field("repr", p.repr.label())
                    .field("decimal_point", p.decimal_point as usize)
                    .field("epochs_trained", p.mse_curve.len())
                    .field("train_accuracy", p.train_accuracy as f64)
                    .field("test_accuracy", p.test_accuracy as f64)
                    .field("quantized_test_accuracy", p.quantized_test_accuracy as f64)
                    .field("accuracy_floor", p.spec.accuracy_floor as f64)
                    .field("meets_accuracy_floor", p.meets_floor)
                    .field(
                        "targets",
                        Json::Arr(a.rows.iter().map(target_row_json).collect()),
                    )
                    .field("speedup_wolf8_vs_m4", a.speedup_wolf8_vs_m4)
                    .field("energy_reduction_wolf8_vs_m4", a.energy_reduction_wolf8_vs_m4)
                    .field(
                        "cluster_scaling",
                        Json::Arr(
                            a.cluster_scaling
                                .iter()
                                .map(|&(cores, speedup, util)| {
                                    Json::obj()
                                        .field("cores", cores as usize)
                                        .field("speedup_vs_1core", speedup)
                                        .field("utilization", util)
                                        .build()
                                })
                                .collect(),
                        ),
                    )
                    .build()
            })
            .collect::<Vec<_>>();

        Json::obj()
            .field("schema", "fann-on-mcu/paper-results/v1")
            .field("seed", Json::Int(self.options.seed as i64))
            .field("quick", self.options.quick)
            .field(
                "targets",
                Json::Arr(paper_targets().iter().map(|t| Json::Str(t.slug())).collect()),
            )
            .field("apps", Json::Arr(apps))
            .field(
                "headline",
                Json::obj()
                    .field("speedup_wolf8_vs_m4", self.speedup_wolf8_vs_m4)
                    .field("energy_reduction_wolf8_vs_m4", self.energy_reduction_wolf8_vs_m4)
                    .field(
                        "basis",
                        "geometric mean over the three apps; emulated compute phase \
                         (cluster bring-up excluded, the paper's asymptotic regime)",
                    )
                    .build(),
            )
            .build()
    }

    /// Render the human-readable `RESULTS.md` report.
    pub fn to_markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut md = String::new();
        let _ = writeln!(md, "# Paper-reproduction results\n");
        let _ = writeln!(
            md,
            "Generated by `fann-on-mcu paper reproduce` (seed {}, {} mode). Every\n\
             latency/energy number comes from emulating the actually *emitted*\n\
             deployment artifact; emulated outputs are asserted bit-exact against\n\
             the host quantized network before a number is recorded.\n",
            self.options.seed,
            if self.options.quick { "quick" } else { "full" },
        );
        let _ = writeln!(md, "## Headline (wolf-8core vs cortex-m4f)\n");
        let _ = writeln!(
            md,
            "| metric | value | paper |\n|---|---|---|\n\
             | speedup | {:.1}x | 22x |\n| energy reduction | {:.0}% | 69% |\n",
            self.speedup_wolf8_vs_m4,
            self.energy_reduction_wolf8_vs_m4 * 100.0,
        );
        let _ = writeln!(
            md,
            "Geometric mean over the three case studies, emulated compute phase\n\
             (cluster bring-up of ~1.2 ms amortized away — the continuous-monitoring\n\
             regime the paper's asymptotic numbers use).\n",
        );

        for a in &self.apps {
            let p = &a.pipeline;
            let _ = writeln!(md, "## {} (`{}`)\n", p.spec.title, p.spec.name);
            let _ = writeln!(
                md,
                "Topology {:?}, {} MACs/inference, deployed as {} (Q{}). Float test\n\
                 accuracy {:.1}%, quantized {:.1}% (floor {:.0}%{}).\n",
                p.spec.sizes,
                p.spec.macs(),
                p.repr.label(),
                p.decimal_point,
                p.test_accuracy * 100.0,
                p.quantized_test_accuracy * 100.0,
                p.spec.accuracy_floor * 100.0,
                if p.meets_floor { ", met" } else { ", MISSED" },
            );
            let _ = writeln!(
                md,
                "| target | placement | latency | cycles | energy/class | power | memory (est / budget) | DMA |\n\
                 |---|---|---|---|---|---|---|---|"
            );
            for r in &a.rows {
                let _ = writeln!(
                    md,
                    "| {} | {} | {:.1} us | {:.0} | {:.2} uJ | {:.1} mW | {} / {} B ({:.0}%) | {} |",
                    r.target.slug(),
                    r.region.name(),
                    r.latency_us(),
                    r.cycles,
                    r.energy_uj,
                    r.active_mw,
                    r.est_memory_bytes,
                    r.budget_bytes,
                    r.memory_utilization() * 100.0,
                    r.dma
                        .map(|d| format!("{d:?} x{}", r.dma_chunks))
                        .unwrap_or_else(|| "-".to_string()),
                );
            }
            let _ = writeln!(
                md,
                "\napp headline: {:.1}x speedup, {:.0}% energy reduction (wolf-8core vs cortex-m4f)\n",
                a.speedup_wolf8_vs_m4,
                a.energy_reduction_wolf8_vs_m4 * 100.0
            );
            let _ = writeln!(md, "Cluster scaling (vs wolf-1core):\n");
            let _ = writeln!(md, "| cores | speedup | utilization |\n|---|---|---|");
            for &(cores, speedup, util) in &a.cluster_scaling {
                let _ = writeln!(md, "| {cores} | {speedup:.2}x | {:.0}% |", util * 100.0);
            }
            md.push('\n');
        }
        md
    }
}

fn target_row_json(r: &TargetRow) -> Json {
    Json::obj()
        .field("target", r.target.slug())
        .field("region", r.region.name())
        .field(
            "dma",
            match r.dma {
                Some(d) => Json::Str(format!("{d:?}")),
                None => Json::Null,
            },
        )
        .field("latency_cycles", r.cycles)
        .field("latency_us", r.latency_us())
        .field("throughput_hz", r.throughput_hz)
        .field("energy_uj_per_classification", r.energy_uj)
        .field("active_mw", r.active_mw)
        .field("utilization", r.utilization)
        .field("param_bytes", r.param_bytes)
        .field("est_memory_bytes", r.est_memory_bytes)
        .field("memory_budget_bytes", r.budget_bytes)
        .field("memory_utilization", r.memory_utilization())
        .field("dma_chunks", r.dma_chunks)
        .field("l1_peak_bytes", r.l1_peak_bytes)
        .build()
}

/// Write `PAPER_RESULTS.json` and `RESULTS.md` under `dir`, returning
/// both paths.
pub fn write_results(results: &PaperResults, dir: &Path) -> Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let json_path = dir.join("PAPER_RESULTS.json");
    let md_path = dir.join("RESULTS.md");
    std::fs::write(&json_path, results.to_json().to_pretty())
        .with_context(|| format!("writing {}", json_path.display()))?;
    std::fs::write(&md_path, results.to_markdown())
        .with_context(|| format!("writing {}", md_path.display()))?;
    Ok((json_path, md_path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_sweep_covers_the_paper_grid() {
        let slugs: Vec<String> = paper_targets().iter().map(|t| t.slug()).collect();
        assert_eq!(
            slugs,
            ["cortex-m4f", "wolf-fc", "wolf-1core", "wolf-2core", "wolf-4core", "wolf-8core"]
        );
    }

    #[test]
    fn region_budgets_are_positive_for_real_regions() {
        let t = Target::WolfCluster { cores: 8 };
        assert!(region_budget(t, Region::L1) > 0);
        assert!(region_budget(t, Region::SharedL2) > 0);
        assert_eq!(region_budget(t, Region::NoFit), 0);
        let m4 = Target::CortexM4(Chip::Stm32l475vg);
        assert_eq!(region_budget(m4, Region::Ram), 96 * 1024);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean([4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(std::iter::empty::<f64>()).is_nan());
    }
}
