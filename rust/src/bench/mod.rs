//! Shared benchmark harness for the figure/table reproduction binaries in
//! `rust/benches/` (declared `harness = false`; the offline crate set has
//! no criterion — wall-clock timing where needed is hand-rolled here).
//!
//! [`batch`] is the parallel batch-inference driver used by the
//! throughput bench (`benches/perf_batch.rs`), the `throughput` CLI
//! command and the continuous-classification app helpers. [`paper`] is
//! the `paper reproduce` driver that sweeps the wearable case studies
//! across the modeled targets and writes `PAPER_RESULTS.json`.

pub mod batch;
pub mod paper;

use std::time::Instant;

use crate::deploy::{self, NetShape};
use crate::fann::activation::Activation;
use crate::simulator::{cost, CostOptions};
use crate::targets::{DataType, Target};

/// The in/out grid swept by Figs. 8–10 (powers of two, 2..=2048).
pub fn fig8_grid() -> Vec<usize> {
    (1..=11).map(|p| 1usize << p).collect()
}

/// Eq. (3): number of neurons in hidden layer `l` (1-based) for growth
/// parameter `d`.
pub fn eq3_hidden_units(l: usize, d: usize) -> usize {
    (l % 2 + l / 2) * d
}

/// The Fig. 11/12 network family: 100 inputs, `l_total` hidden layers by
/// Eq. (3) with d = 8, 8 output classes.
pub fn fig11_shape(l_total: usize, d: usize) -> NetShape {
    let mut sizes = vec![100];
    for l in 1..=l_total {
        sizes.push(eq3_hidden_units(l, d));
    }
    sizes.push(8);
    NetShape::new(&sizes)
}

/// Total hidden units of the Fig. 11 family (Eq. (4)).
pub fn eq4_total_hidden(l_total: usize, d: usize) -> usize {
    (1..=l_total).map(|l| eq3_hidden_units(l, d)).sum()
}

/// Activations used across all benches: tanh hidden, sigmoid output
/// (the paper's showcase configuration).
pub fn bench_acts(n_layers: usize) -> Vec<Activation> {
    let mut v = vec![Activation::Tanh; n_layers - 1];
    v.push(Activation::Sigmoid);
    v
}

/// Model the cycles of a single `n_in -> n_out` layer on `target`
/// (Figs. 8–10). Returns `None` when the layer does not fit (the paper's
/// "0.0" cells).
pub fn single_layer_cycles(n_in: usize, n_out: usize, target: Target, dtype: DataType) -> Option<f64> {
    let shape = NetShape::new(&[n_in, n_out]);
    let plan = deploy::plan(&shape, target, dtype).ok()?;
    if !plan.fits() {
        return None;
    }
    let b = cost::layer_cycles(
        &plan,
        n_in,
        n_out,
        Activation::Tanh,
        0.0,
        true,
        CostOptions::default(),
    );
    Some(b.total())
}

/// Whole-network cycles on `target` (Figs. 11–12); `None` on no-fit.
pub fn whole_network_cycles(shape: &NetShape, target: Target, dtype: DataType) -> Option<f64> {
    let plan = deploy::plan(shape, target, dtype).ok()?;
    if !plan.fits() {
        return None;
    }
    let acts = bench_acts(shape.sizes.len() - 1);
    Some(cost::network_cycles(&plan, &acts, CostOptions::default()).total())
}

/// Summary statistics of one timed row: median (the headline number
/// the gates compare), plus min/max/rep-count so a noisy-runner
/// regression is diagnosable from the `BENCH_kernels.json` artifact
/// alone (a wide min..max spread at an unchanged min means scheduler
/// noise, not a code regression).
#[derive(Debug, Clone, Copy)]
pub struct TimeStats {
    /// Median seconds per call across the measured reps.
    pub median: f64,
    /// Fastest measured rep.
    pub min: f64,
    /// Slowest measured rep.
    pub max: f64,
    /// Number of measured reps (after clamping to >= 1).
    pub reps: usize,
}

/// Wall-clock timing helper for the perf bench: median/min/max of
/// `reps` runs after `warmup` runs, seconds per call. `reps` is clamped
/// to a minimum of 1 — `reps == 0` used to index the median of an empty
/// sample vector and panic.
pub fn time_stats<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> TimeStats {
    let reps = reps.max(1);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    TimeStats {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: samples[samples.len() - 1],
        reps,
    }
}

/// Median-only convenience wrapper over [`time_stats`].
pub fn time_median<F: FnMut()>(warmup: usize, reps: usize, f: F) -> f64 {
    time_stats(warmup, reps, f).median
}

/// Format a speedup cell, using the paper's 0.0 marker for no-fit.
pub fn speedup_cell(base: Option<f64>, new: Option<f64>) -> String {
    match (base, new) {
        (Some(b), Some(n)) if n > 0.0 => format!("{:.2}", b / n),
        _ => "0.0".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::targets::Chip;

    #[test]
    fn eq3_matches_paper_growth() {
        // d=8: layers grow 8, 8, 16, 16, 24, 24, ...
        let d = 8;
        let units: Vec<usize> = (1..=6).map(|l| eq3_hidden_units(l, d)).collect();
        assert_eq!(units, vec![8, 8, 16, 16, 24, 24]);
    }

    #[test]
    fn eq4_paper_calibration_points() {
        // Paper: 12 hidden layers = 336 hidden units; 24 layers = 1248.
        assert_eq!(eq4_total_hidden(12, 8), 336);
        assert_eq!(eq4_total_hidden(24, 8), 1248);
    }

    #[test]
    fn fig11_shape_structure() {
        let s = fig11_shape(3, 8);
        assert_eq!(s.sizes, vec![100, 8, 8, 16, 8]);
    }

    #[test]
    fn single_layer_nofit_is_none() {
        // 2048x2048 f32 = 16 MB: no fit anywhere.
        assert!(single_layer_cycles(
            2048,
            2048,
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Float32
        )
        .is_none());
        assert!(single_layer_cycles(
            16,
            16,
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Float32
        )
        .is_some());
    }

    #[test]
    fn speedup_cell_formats() {
        assert_eq!(speedup_cell(Some(10.0), Some(5.0)), "2.00");
        assert_eq!(speedup_cell(None, Some(5.0)), "0.0");
        assert_eq!(speedup_cell(Some(10.0), None), "0.0");
    }

    #[test]
    fn time_median_positive() {
        let mut x = 0u64;
        let t = time_median(1, 5, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn time_median_zero_reps_clamps_instead_of_panicking() {
        // Regression: reps == 0 indexed samples[0] of an empty vec.
        let mut calls = 0usize;
        let t = time_median(0, 0, || {
            calls += 1;
        });
        assert!(t >= 0.0);
        assert_eq!(calls, 1, "clamped to one measured rep");
    }

    #[test]
    fn time_stats_orders_min_median_max() {
        let mut x = 0u64;
        let s = time_stats(1, 7, || {
            for _ in 0..1000 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            std::hint::black_box(x);
        });
        assert_eq!(s.reps, 7);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.min >= 0.0);
    }
}
