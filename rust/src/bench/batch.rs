//! Parallel batch inference driver — the host-side throughput harness
//! for continuous-classification workloads (the `apps/` showcases and
//! the `throughput` CLI command).
//!
//! Work splitting is deliberately simple: the sample axis is chopped
//! into one contiguous chunk per worker and each worker runs the batched
//! kernel path ([`crate::fann::Network::run_batch`]) on its chunk with
//! `std::thread::scope` (the offline crate set has no `rayon`; scoped
//! threads give the same fork-join shape without a dependency). Because
//! the batched kernels are bit-identical to single-sample inference per
//! sample, neither chunking nor thread count changes any output —
//! `rust/tests/batch_consistency.rs` pins this.

use std::num::NonZeroUsize;

use crate::fann::{FixedNetwork, Network};
use crate::kernels::DenseKernel;

/// Resolve a requested worker count: 0 means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Split `n` items into at most `workers` contiguous `(start, len)`
/// chunks of near-equal size (first `n % workers` chunks get one extra).
pub fn chunks(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let workers = workers.max(1).min(n.max(1));
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        if len == 0 {
            break;
        }
        out.push((start, len));
        start += len;
    }
    out
}

/// The shared fork-join skeleton: split the sample axis into one
/// contiguous chunk per worker, run `run(chunk_inputs, chunk_len)` on
/// each under `std::thread::scope`, and reassemble the outputs in
/// order. Element-type generic so the float and fixed drivers share
/// one copy of the splitting logic.
fn parallel_chunks<E, F>(
    inputs: &[E],
    n_samples: usize,
    n_in: usize,
    n_out: usize,
    workers: usize,
    run: F,
) -> Vec<E>
where
    E: Copy + Default + Send + Sync,
    F: Fn(&[E], usize) -> Vec<E> + Sync,
{
    let mut out = vec![E::default(); n_samples * n_out];
    let plan = chunks(n_samples, workers);
    // Hand each worker a disjoint slice of the output buffer.
    let mut out_slices: Vec<&mut [E]> = Vec::with_capacity(plan.len());
    let mut rest = out.as_mut_slice();
    for &(_, len) in &plan {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(len * n_out);
        out_slices.push(head);
        rest = tail;
    }
    std::thread::scope(|scope| {
        for (&(start, len), out_chunk) in plan.iter().zip(out_slices) {
            let in_chunk = &inputs[start * n_in..(start + len) * n_in];
            let run = &run;
            scope.spawn(move || {
                out_chunk.copy_from_slice(&run(in_chunk, len));
            });
        }
    });
    out
}

/// Run `n_samples` packed float rows through `net` on `threads` workers
/// (0 = auto). Output is bit-identical to `net.run_batch(inputs,
/// n_samples)` and therefore to `n_samples` single `run` calls.
pub fn run_batch_parallel(
    net: &Network,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
) -> Vec<f32> {
    run_batch_parallel_with_kernel(net, crate::kernels::default_f32(), inputs, n_samples, threads)
}

/// [`run_batch_parallel`] through an explicit kernel.
pub fn run_batch_parallel_with_kernel(
    net: &Network,
    kernel: &dyn DenseKernel<f32>,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
) -> Vec<f32> {
    let n_in = net.num_inputs();
    assert_eq!(inputs.len(), n_samples * n_in);
    let workers = resolve_threads(threads);
    if workers <= 1 || n_samples <= 1 {
        return net.run_batch_with_kernel(kernel, inputs, n_samples);
    }
    parallel_chunks(inputs, n_samples, n_in, net.num_outputs(), workers, |chunk, len| {
        net.run_batch_with_kernel(kernel, chunk, len)
    })
}

/// Fixed-point counterpart: run `n_samples` packed Q(dec) rows on
/// `threads` workers. Bit-exact vs [`FixedNetwork::run_batch_q`].
pub fn run_batch_q_parallel(
    net: &FixedNetwork,
    inputs_q: &[i32],
    n_samples: usize,
    threads: usize,
) -> Vec<i32> {
    let n_in = net.num_inputs();
    assert_eq!(inputs_q.len(), n_samples * n_in);
    let workers = resolve_threads(threads);
    if workers <= 1 || n_samples <= 1 {
        return net.run_batch_q(inputs_q, n_samples);
    }
    parallel_chunks(inputs_q, n_samples, n_in, net.num_outputs(), workers, |chunk, len| {
        net.run_batch_q(chunk, len)
    })
}

/// One measured execution mode of the standard throughput comparison.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub name: &'static str,
    /// Median wall time for the whole batch.
    pub seconds: f64,
    /// The looped single-sample baseline this row is compared against
    /// (the float loop for float rows, the fixed loop for fixed rows).
    pub baseline_seconds: f64,
}

/// Measure the six standard modes — float/fixed × {looped single-sample,
/// batched kernels, parallel driver} — on the same network and inputs.
/// Shared by `benches/perf_batch.rs` and the `throughput` CLI command so
/// the two can't drift. Asserts first that every mode produces
/// bit-identical outputs; panics otherwise (a wrong-answer mode must
/// never be timed as if it were an optimization).
pub fn measure_throughput(
    net: &Network,
    fixed: &FixedNetwork,
    xs: &[f32],
    n_samples: usize,
    threads: usize,
    warmup: usize,
    reps: usize,
) -> Vec<ThroughputRow> {
    let n_in = net.num_inputs();
    assert_eq!(xs.len(), n_samples * n_in);
    let xq = fixed.quantize_input(xs);

    let mut looped = Vec::with_capacity(n_samples * net.num_outputs());
    for s in 0..n_samples {
        looped.extend_from_slice(&net.run(&xs[s * n_in..(s + 1) * n_in]));
    }
    assert_eq!(looped, net.run_batch(xs, n_samples), "run_batch diverged from looped run");
    assert_eq!(
        looped,
        run_batch_parallel(net, xs, n_samples, threads),
        "parallel driver diverged from looped run"
    );
    let mut looped_q = Vec::with_capacity(n_samples * fixed.num_outputs());
    for s in 0..n_samples {
        looped_q.extend_from_slice(&fixed.run_q(&xq[s * n_in..(s + 1) * n_in]));
    }
    assert_eq!(looped_q, fixed.run_batch_q(&xq, n_samples), "fixed run_batch_q diverged");
    assert_eq!(
        looped_q,
        run_batch_q_parallel(fixed, &xq, n_samples, threads),
        "fixed parallel driver diverged"
    );

    let mut scratch = crate::fann::Scratch::for_network(net);
    let t_loop = super::time_median(warmup, reps, || {
        for s in 0..n_samples {
            std::hint::black_box(net.run_with(&mut scratch, &xs[s * n_in..(s + 1) * n_in]));
        }
    });
    let t_batch = super::time_median(warmup, reps, || {
        std::hint::black_box(net.run_batch(xs, n_samples));
    });
    let t_par = super::time_median(warmup, reps, || {
        std::hint::black_box(run_batch_parallel(net, xs, n_samples, threads));
    });
    let t_loop_q = super::time_median(warmup, reps, || {
        for s in 0..n_samples {
            std::hint::black_box(fixed.run_q(&xq[s * n_in..(s + 1) * n_in]));
        }
    });
    let t_batch_q = super::time_median(warmup, reps, || {
        std::hint::black_box(fixed.run_batch_q(&xq, n_samples));
    });
    let t_par_q = super::time_median(warmup, reps, || {
        std::hint::black_box(run_batch_q_parallel(fixed, &xq, n_samples, threads));
    });

    vec![
        ThroughputRow { name: "float: looped run()", seconds: t_loop, baseline_seconds: t_loop },
        ThroughputRow { name: "float: run_batch()", seconds: t_batch, baseline_seconds: t_loop },
        ThroughputRow { name: "float: parallel driver", seconds: t_par, baseline_seconds: t_loop },
        ThroughputRow { name: "fixed: looped run_q()", seconds: t_loop_q, baseline_seconds: t_loop_q },
        ThroughputRow { name: "fixed: run_batch_q()", seconds: t_batch_q, baseline_seconds: t_loop_q },
        ThroughputRow { name: "fixed: parallel driver", seconds: t_par_q, baseline_seconds: t_loop_q },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, FixedNetwork, Network};
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn chunks_cover_exactly_once() {
        for n in [0usize, 1, 2, 7, 64, 100] {
            for w in [1usize, 2, 3, 8, 200] {
                let cs = chunks(n, w);
                let mut next = 0;
                for (start, len) in cs {
                    assert_eq!(start, next);
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn parallel_float_is_bit_identical_to_serial() {
        let net = net(&[6, 11, 4], 77);
        let mut rng = Rng::new(5);
        let n = 23; // deliberately not a multiple of the worker count
        let xs: Vec<f32> = (0..n * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let serial = net.run_batch(&xs, n);
        for threads in [1, 2, 3, 8] {
            let par = run_batch_parallel(&net, &xs, n, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
        // Per-sample equality too.
        for s in 0..n {
            assert_eq!(&serial[s * 4..(s + 1) * 4], &net.run(&xs[s * 6..(s + 1) * 6])[..]);
        }
    }

    #[test]
    fn parallel_fixed_is_bit_exact() {
        let fnet = net(&[4, 8, 3], 31);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let mut rng = Rng::new(9);
        let n = 17;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| crate::quantize::quantize(v, fixed.decimal_point))
            .collect();
        let serial = fixed.run_batch_q(&q, n);
        for threads in [1, 2, 5] {
            assert_eq!(run_batch_q_parallel(&fixed, &q, n, threads), serial);
        }
    }

    #[test]
    fn measure_throughput_reports_all_six_modes() {
        let fnet = net(&[4, 6, 2], 3);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let mut rng = Rng::new(2);
        let n = 8;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rows = measure_throughput(&fnet, &fixed, &xs, n, 2, 0, 1);
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.seconds >= 0.0 && r.baseline_seconds >= 0.0));
        assert_eq!(rows[0].seconds, rows[0].baseline_seconds);
    }

    #[test]
    fn empty_batch_and_auto_threads() {
        let net = net(&[3, 2], 1);
        assert!(run_batch_parallel(&net, &[], 0, 0).is_empty());
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }
}
