//! Parallel batch inference driver — the host-side throughput harness
//! for continuous-classification workloads (the `apps/` showcases and
//! the `throughput` / `bench json` CLI commands).
//!
//! Work splitting is deliberately simple: the sample axis is chopped
//! into one contiguous chunk per worker and each worker runs the
//! allocation-free batched kernel path
//! ([`crate::fann::Network::run_batch_into`]) on its chunk, writing
//! straight into its disjoint slice of the output. Workers come from a
//! persistent [`BatchPool`] (the offline crate set has no `rayon`;
//! this hand-rolled pool gives the same fork-join shape), so thread
//! spawn cost is paid once per process/stream instead of once per
//! batch as with the seed's `std::thread::scope`, and each worker's
//! thread-local [`crate::kernels::BatchScratch`] arena survives across
//! batches — the steady state performs no allocation beyond the output
//! vector. Because the batched kernels are bit-identical to
//! single-sample inference per sample, neither chunking nor thread
//! count changes any output — `rust/tests/batch_consistency.rs` pins
//! this.

use std::num::NonZeroUsize;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::fann::{from_float_packed, FixedNetwork, Network, PackedNetwork};
use crate::kernels::{
    self, BlockedF32, DenseKernel, ExecPlan, PackedWidth, PlanScratch, ScalarF32, SimdF32,
};

/// Resolve a requested worker count: 0 means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Split `n` items into at most `workers` contiguous `(start, len)`
/// chunks of near-equal size (first `n % workers` chunks get one
/// extra). Delegates to the crate's one row/sample partition
/// ([`kernels::split_rows`]) so the inter-sample chunking and the
/// intra-layer row split can never drift apart.
pub fn chunks(n: usize, workers: usize) -> Vec<(usize, usize)> {
    kernels::split_rows(n, workers)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent fork-join worker pool. Threads are spawned once (at
/// construction, or once per process for [`global_pool`]) and then
/// reused for every [`execute`](Self::execute) call; each worker keeps
/// its thread-local kernel scratch alive between batches, so the
/// per-batch cost is one channel send per chunk rather than a thread
/// spawn plus two arena allocations.
pub struct BatchPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl BatchPool {
    /// Spawn `workers` (≥ 1) threads that park on the job channel.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // Take the job with the lock released before running
                    // it, so other workers can pull concurrently.
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // pool dropped
                    }
                })
            })
            .collect();
        Self {
            tx: Some(tx),
            handles,
            workers,
        }
    }

    /// Worker threads the pool runs.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `jobs` to completion on the pool and return once **all** of
    /// them have finished. Jobs may borrow from the caller's stack:
    /// this call blocks until every job has run (or panicked inside the
    /// pool, which re-panics here after all jobs have quiesced), so no
    /// borrow outlives the call. Do not submit jobs that themselves
    /// call `execute` on the same pool — with every worker waiting, the
    /// nested call would deadlock.
    pub fn execute<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let (ack_tx, ack_rx) = mpsc::channel::<std::thread::Result<()>>();
        let tx = self.tx.as_ref().expect("pool alive while not dropped");
        for job in jobs {
            let ack = ack_tx.clone();
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                // The ack must fire even if the job panics, or the
                // barrier below would deadlock; the panic payload rides
                // along and is re-raised after the barrier instead.
                let _ = ack.send(catch_unwind(AssertUnwindSafe(job)));
            });
            // SAFETY: the job is erased to 'static only to cross the
            // channel; this function does not return (or unwind) until
            // the barrier below has observed every job's completion
            // ack, so all captured borrows strictly outlive the job's
            // execution. Workers never drop a received job unexecuted
            // (they only exit between jobs, on channel close).
            let wrapped = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
            };
            tx.send(wrapped).expect("pool workers outlive the pool handle");
        }
        drop(ack_tx);
        let mut first_panic = None;
        for _ in 0..n {
            match ack_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(payload)) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
                // Disconnect means every ack sender (each owned by a
                // job wrapper) is gone, i.e. all jobs finished.
                Err(_) => break,
            }
        }
        if let Some(payload) = first_panic {
            // Re-raise the original panic (message intact) only after
            // every job has quiesced — borrowed data is safe to unwind.
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for BatchPool {
    fn drop(&mut self) {
        // Closing the channel wakes every worker out of recv().
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide pool the `run_batch_*_parallel` drivers submit to,
/// sized to the machine and spawned on first use — so a stream of
/// batches pays thread-spawn cost exactly once.
pub fn global_pool() -> &'static BatchPool {
    static POOL: OnceLock<BatchPool> = OnceLock::new();
    POOL.get_or_init(|| BatchPool::new(resolve_threads(0)))
}

/// The worker count a request for `requested` threads (0 = auto)
/// actually gets from the global pool: parallelism never exceeds the
/// pool's size (the machine's cores). The throughput harness reports
/// THIS number, not the request, so scaling tables and
/// `BENCH_kernels.json` never claim more parallelism than ran.
pub fn effective_workers(requested: usize) -> usize {
    resolve_threads(requested).min(global_pool().workers())
}

/// The shared fork-join skeleton: split the sample axis into one
/// contiguous chunk per requested worker, run `run(chunk_inputs,
/// chunk_len, chunk_out)` for each on the global pool, writing straight
/// into disjoint slices of `out`. Element-type generic so the float,
/// fixed and packed drivers share one copy of the splitting logic.
fn parallel_chunks_into<E, F>(
    inputs: &[E],
    n_samples: usize,
    n_in: usize,
    n_out: usize,
    workers: usize,
    out: &mut [E],
    run: &F,
) where
    E: Send + Sync,
    F: Fn(&[E], usize, &mut [E]) + Sync,
{
    debug_assert_eq!(out.len(), n_samples * n_out);
    // Chunk only as wide as the pool can actually run: more chunks than
    // workers would just queue, adding per-chunk overhead while the
    // measured parallelism silently stayed at the pool size.
    let plan = chunks(n_samples, workers.min(global_pool().workers()));
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(plan.len());
    let mut rest = out;
    for &(start, len) in &plan {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(len * n_out);
        let in_chunk = &inputs[start * n_in..(start + len) * n_in];
        jobs.push(Box::new(move || run(in_chunk, len, head)));
        rest = tail;
    }
    global_pool().execute(jobs);
}

/// Run `n_samples` packed float rows through `net` on `threads` workers
/// (0 = auto). Output is bit-identical to `net.run_batch(inputs,
/// n_samples)` and therefore to `n_samples` single `run` calls.
pub fn run_batch_parallel(
    net: &Network,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
) -> Vec<f32> {
    run_batch_parallel_with_kernel(net, crate::kernels::default_f32(), inputs, n_samples, threads)
}

/// [`run_batch_parallel`] through an explicit kernel.
pub fn run_batch_parallel_with_kernel(
    net: &Network,
    kernel: &dyn DenseKernel<f32>,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
) -> Vec<f32> {
    let n_in = net.num_inputs();
    assert_eq!(inputs.len(), n_samples * n_in);
    let workers = resolve_threads(threads);
    if workers <= 1 || n_samples <= 1 {
        return net.run_batch_with_kernel(kernel, inputs, n_samples);
    }
    let n_out = net.num_outputs();
    let mut out = vec![0.0f32; n_samples * n_out];
    parallel_chunks_into(inputs, n_samples, n_in, n_out, workers, &mut out, &|chunk,
                                                                              len,
                                                                              dst| {
        kernels::with_thread_scratch_f32(|s| net.run_batch_into(kernel, chunk, len, s, dst))
    });
    out
}

/// Fixed-point counterpart: run `n_samples` packed Q(dec) rows on
/// `threads` workers. Bit-exact vs [`FixedNetwork::run_batch_q`].
pub fn run_batch_q_parallel(
    net: &FixedNetwork,
    inputs_q: &[i32],
    n_samples: usize,
    threads: usize,
) -> Vec<i32> {
    let n_in = net.num_inputs();
    assert_eq!(inputs_q.len(), n_samples * n_in);
    let workers = resolve_threads(threads);
    if workers <= 1 || n_samples <= 1 {
        return net.run_batch_q(inputs_q, n_samples);
    }
    let n_out = net.num_outputs();
    let mut out = vec![0i32; n_samples * n_out];
    parallel_chunks_into(inputs_q, n_samples, n_in, n_out, workers, &mut out, &|chunk,
                                                                                len,
                                                                                dst| {
        kernels::with_thread_scratch_i32(|s| net.run_batch_q_into(chunk, len, s, dst))
    });
    out
}

/// Packed-kernel counterpart: run `n_samples` packed Q(dec) rows
/// through a [`PackedNetwork`] on `threads` workers. Bit-exact vs
/// [`PackedNetwork::run_batch_q`] (and therefore vs the `FixedNetwork`
/// the packed net came from).
pub fn run_batch_packed_parallel(
    net: &PackedNetwork,
    inputs_q: &[i32],
    n_samples: usize,
    threads: usize,
) -> Vec<i32> {
    let n_in = net.num_inputs();
    assert_eq!(inputs_q.len(), n_samples * n_in);
    let workers = resolve_threads(threads);
    if workers <= 1 || n_samples <= 1 {
        return net.run_batch_q(inputs_q, n_samples);
    }
    let n_out = net.num_outputs();
    let mut out = vec![0i32; n_samples * n_out];
    parallel_chunks_into(inputs_q, n_samples, n_in, n_out, workers, &mut out, &|chunk,
                                                                                len,
                                                                                dst| {
        kernels::with_thread_scratch_i32(|s| net.run_batch_q_into(chunk, len, s, dst))
    });
    out
}

/// Raw-pointer wrapper that lets row-split jobs write their disjoint
/// (but sample-interleaved, hence not slice-splittable) row ranges of
/// one shared output buffer from pool workers.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// The neuron-parallel (output-row-split) driver core: for every layer,
/// partition the output rows across `workers` (the paper's intra-network
/// parallelization — each cluster core computes a contiguous block of
/// neurons), run one job per range on the persistent [`BatchPool`], and
/// let `execute`'s completion barrier be the per-layer barrier. Each
/// job computes its rows for ALL samples into a contiguous thread-local
/// block and scatters them into the sample-major output (single-sample
/// runs write in place — their row range IS contiguous). Row
/// accumulation is independent, so any core count and any ragged split
/// is bit-exact vs the serial plan run — `rust/tests/prop_rowsplit.rs`
/// pins this.
///
/// This composes with (rather than replaces) the inter-sample chunking
/// of [`run_batch_parallel`]: row-splitting parallelizes the *latency*
/// of one sample stream, sample-chunking parallelizes *throughput* over
/// many; see README "Performance".
fn rowsplit_f32_core(
    plan: &ExecPlan,
    inputs: &[f32],
    n_samples: usize,
    workers: usize,
    out: &mut [f32],
) {
    let n_layers = plan.num_layers();
    kernels::with_thread_scratch_f32(|scratch| {
        let (a, b) = scratch.buffers(plan.max_layer_width() * n_samples);
        for li in 0..n_layers {
            let last = li + 1 == n_layers;
            let (n_in, n_out) = plan.layer_dims(li);
            let (src, dst) = kernels::batch_route(li, last, inputs, a, b, out);
            let src = &src[..n_in * n_samples];
            let dst = &mut dst[..n_out * n_samples];
            let ranges = plan.partition_rows(li, workers);
            if ranges.len() <= 1 {
                plan.run_layer_rows_f32(li, src, n_samples, 0..n_out, dst);
                continue;
            }
            let ptr = SendPtr(dst.as_mut_ptr());
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
            for &(r0, r1) in &ranges {
                jobs.push(Box::new(move || {
                    let rr = r1 - r0;
                    // SAFETY: every job writes only rows [r0, r1) of each
                    // sample's output; ranges are disjoint and cover
                    // [0, n_out), and `execute` does not return until
                    // every job has acked — no two writers alias, no
                    // reader runs concurrently. Jobs run on pool worker
                    // threads, so their thread-local scratch never
                    // collides with this (caller) thread's arena.
                    if n_samples == 1 {
                        let d = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r0), rr) };
                        plan.run_layer_rows_f32(li, src, 1, r0..r1, d);
                    } else {
                        kernels::with_thread_scratch_f32(|s| {
                            let (tmp, _) = s.buffers(rr * n_samples);
                            plan.run_layer_rows_f32(li, src, n_samples, r0..r1, tmp);
                            for smp in 0..n_samples {
                                let d = unsafe {
                                    std::slice::from_raw_parts_mut(ptr.0.add(smp * n_out + r0), rr)
                                };
                                d.copy_from_slice(&tmp[smp * rr..(smp + 1) * rr]);
                            }
                        });
                    }
                }));
            }
            // Per-layer barrier: execute() returns only when every row
            // job of this layer has finished.
            global_pool().execute(jobs);
        }
    });
}

/// Q-format row-split core: identical structure to the f32 core, plus
/// the layer's narrow-path input scan hoisted out of the jobs (one scan
/// per layer, shared verdict — not one scan per row job).
fn rowsplit_q_core(
    plan: &ExecPlan,
    inputs: &[i32],
    n_samples: usize,
    workers: usize,
    out: &mut [i32],
) {
    let n_layers = plan.num_layers();
    kernels::with_thread_scratch_i32(|scratch| {
        let (a, b) = scratch.buffers(plan.max_layer_width() * n_samples);
        for li in 0..n_layers {
            let last = li + 1 == n_layers;
            let (n_in, n_out) = plan.layer_dims(li);
            let (src, dst) = kernels::batch_route(li, last, inputs, a, b, out);
            let src = &src[..n_in * n_samples];
            let dst = &mut dst[..n_out * n_samples];
            let ranges = plan.partition_rows(li, workers);
            let narrow = plan.narrow_ok(li, src);
            if ranges.len() <= 1 {
                plan.run_layer_rows_q_hinted(li, src, n_samples, (0..n_out, narrow), dst);
                continue;
            }
            let ptr = SendPtr(dst.as_mut_ptr());
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
            for &(r0, r1) in &ranges {
                jobs.push(Box::new(move || {
                    let rr = r1 - r0;
                    // SAFETY: see rowsplit_f32_core — disjoint row
                    // ranges, barrier before any other access.
                    if n_samples == 1 {
                        let d = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r0), rr) };
                        plan.run_layer_rows_q_hinted(li, src, 1, (r0..r1, narrow), d);
                    } else {
                        kernels::with_thread_scratch_i32(|s| {
                            let (tmp, _) = s.buffers(rr * n_samples);
                            plan.run_layer_rows_q_hinted(li, src, n_samples, (r0..r1, narrow), tmp);
                            for smp in 0..n_samples {
                                let d = unsafe {
                                    std::slice::from_raw_parts_mut(ptr.0.add(smp * n_out + r0), rr)
                                };
                                d.copy_from_slice(&tmp[smp * rr..(smp + 1) * rr]);
                            }
                        });
                    }
                }));
            }
            global_pool().execute(jobs);
        }
    });
}

/// Run a compiled f32 [`ExecPlan`] with every layer's output rows split
/// across `threads` workers (0 = all cores; clamped to the global
/// pool's worker count via [`effective_workers`], so a huge request
/// degrades to full fan-out instead of slicing layers into more
/// fragments than there are threads to run them). Bit-identical to the
/// serial plan run and therefore to the dispatch path.
///
/// Must be called from OUTSIDE the global pool: the per-layer barrier
/// submits jobs to [`global_pool`] and blocks for them, so invoking
/// this (or [`run_plan_q_rowsplit`]) from inside a job already running
/// on that pool — e.g. from work submitted via the `run_batch_*_parallel`
/// drivers — can deadlock with every worker waiting. The two
/// parallelism axes compose at the call-site level (pick per workload),
/// not by nesting.
pub fn run_plan_rowsplit(
    plan: &ExecPlan,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n_samples * plan.num_outputs()];
    run_plan_rowsplit_into(plan, inputs, n_samples, threads, &mut out);
    out
}

/// [`run_plan_rowsplit`] writing into a caller-owned buffer — the
/// allocation-free form timed loops reuse.
pub fn run_plan_rowsplit_into(
    plan: &ExecPlan,
    inputs: &[f32],
    n_samples: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert!(plan.is_float(), "f32 row-split driver on a {} plan", plan.repr_label());
    assert_eq!(inputs.len(), n_samples * plan.num_inputs());
    assert_eq!(out.len(), n_samples * plan.num_outputs());
    if n_samples == 0 {
        return;
    }
    rowsplit_f32_core(plan, inputs, n_samples, effective_workers(threads), out);
}

/// Q-format counterpart of [`run_plan_rowsplit`] for Q32 and packed
/// plans. Bit-exact vs [`ExecPlan::run_batch_q`] for any core count.
/// Same no-nesting rule and [`effective_workers`] clamp as
/// [`run_plan_rowsplit`].
pub fn run_plan_q_rowsplit(
    plan: &ExecPlan,
    inputs_q: &[i32],
    n_samples: usize,
    threads: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; n_samples * plan.num_outputs()];
    run_plan_q_rowsplit_into(plan, inputs_q, n_samples, threads, &mut out);
    out
}

/// [`run_plan_q_rowsplit`] writing into a caller-owned buffer.
pub fn run_plan_q_rowsplit_into(
    plan: &ExecPlan,
    inputs_q: &[i32],
    n_samples: usize,
    threads: usize,
    out: &mut [i32],
) {
    assert!(!plan.is_float(), "Q row-split driver on an f32 plan");
    assert_eq!(inputs_q.len(), n_samples * plan.num_inputs());
    assert_eq!(out.len(), n_samples * plan.num_outputs());
    if n_samples == 0 {
        return;
    }
    rowsplit_q_core(plan, inputs_q, n_samples, effective_workers(threads), out);
}

/// Order-sensitive digest of a float output buffer (bit patterns, so
/// "close enough" never masks a divergence).
pub fn checksum_f32(xs: &[f32]) -> u64 {
    xs.iter()
        .fold(0u64, |h, &v| h.wrapping_mul(0x100000001B3).wrapping_add(v.to_bits() as u64))
}

/// Order-sensitive digest of a Q-format output buffer.
pub fn checksum_i32(xs: &[i32]) -> u64 {
    xs.iter()
        .fold(0u64, |h, &v| h.wrapping_mul(0x100000001B3).wrapping_add(v as u32 as u64))
}

/// One measured execution mode of the standard throughput comparison.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    /// Display name of the execution mode.
    pub name: &'static str,
    /// Median wall time for the whole batch.
    pub seconds: f64,
    /// The looped single-sample baseline this row is compared against
    /// (the float loop for float rows, the fixed loop for fixed and
    /// packed rows).
    pub baseline_seconds: f64,
    /// Digest of the outputs produced inside the timed loop. Serves two
    /// purposes: the timed computation feeds a value the optimizer
    /// cannot elide, and modes of the same representation must agree
    /// ([`measure_throughput`] asserts it), doubling as a parity check.
    pub checksum: u64,
}

/// Measure the standard modes — float/fixed × {looped single-sample,
/// batched kernels, parallel driver} plus the packed Q7/Q15 kernels ×
/// {batched, parallel} — on the same network and inputs. Shared by
/// `benches/perf_batch.rs` and the `throughput` CLI command so the two
/// can't drift. Asserts first that every mode produces bit-identical
/// outputs within its representation; panics otherwise (a wrong-answer
/// mode must never be timed as if it were an optimization).
pub fn measure_throughput(
    net: &Network,
    fixed: &FixedNetwork,
    xs: &[f32],
    n_samples: usize,
    threads: usize,
    warmup: usize,
    reps: usize,
) -> Vec<ThroughputRow> {
    let n_in = net.num_inputs();
    assert_eq!(xs.len(), n_samples * n_in);
    let xq = fixed.quantize_input(xs);

    // The packed networks quantize at their own (narrower-weight)
    // decimal points; each is bit-exact against a FixedQ reference at
    // the same dec, asserted below.
    let (fixed7, packed7) = from_float_packed(net, 1.0, PackedWidth::Q7).expect("q7 pack");
    let (fixed15, packed15) = from_float_packed(net, 1.0, PackedWidth::Q15).expect("q15 pack");
    let xq7 = packed7.quantize_input(xs);
    let xq15 = packed15.quantize_input(xs);

    let mut looped = Vec::with_capacity(n_samples * net.num_outputs());
    for s in 0..n_samples {
        looped.extend_from_slice(&net.run(&xs[s * n_in..(s + 1) * n_in]));
    }
    assert_eq!(looped, net.run_batch(xs, n_samples), "run_batch diverged from looped run");
    assert_eq!(
        looped,
        run_batch_parallel(net, xs, n_samples, threads),
        "parallel driver diverged from looped run"
    );
    let mut looped_q = Vec::with_capacity(n_samples * fixed.num_outputs());
    for s in 0..n_samples {
        looped_q.extend_from_slice(&fixed.run_q(&xq[s * n_in..(s + 1) * n_in]));
    }
    assert_eq!(looped_q, fixed.run_batch_q(&xq, n_samples), "fixed run_batch_q diverged");
    assert_eq!(
        looped_q,
        run_batch_q_parallel(fixed, &xq, n_samples, threads),
        "fixed parallel driver diverged"
    );
    // Packed bit-exactness vs the wide FixedQ reference at the same
    // decimal point — the kernel-family headline, re-verified on every
    // measurement.
    let packed7_out = packed7.run_batch_q(&xq7, n_samples);
    assert_eq!(
        packed7_out,
        fixed7.run_batch_q(&xq7, n_samples),
        "packed q7 diverged from FixedQ at dec {}",
        packed7.decimal_point
    );
    assert_eq!(
        packed7_out,
        run_batch_packed_parallel(&packed7, &xq7, n_samples, threads),
        "packed q7 parallel driver diverged"
    );
    let packed15_out = packed15.run_batch_q(&xq15, n_samples);
    assert_eq!(
        packed15_out,
        fixed15.run_batch_q(&xq15, n_samples),
        "packed q15 diverged from FixedQ at dec {}",
        packed15.decimal_point
    );
    assert_eq!(
        packed15_out,
        run_batch_packed_parallel(&packed15, &xq15, n_samples, threads),
        "packed q15 parallel driver diverged"
    );

    let mut scratch = crate::fann::Scratch::for_network(net);
    let mut ck = 0u64;
    let t_loop = super::time_median(warmup, reps, || {
        ck = 0;
        for s in 0..n_samples {
            let out = net.run_with(&mut scratch, &xs[s * n_in..(s + 1) * n_in]);
            ck = ck.wrapping_add(checksum_f32(out));
        }
        std::hint::black_box(ck);
    });
    let ck_loop = ck;
    let t_batch = super::time_median(warmup, reps, || {
        ck = checksum_f32(&net.run_batch(xs, n_samples));
        std::hint::black_box(ck);
    });
    let ck_batch = ck;
    let t_par = super::time_median(warmup, reps, || {
        ck = checksum_f32(&run_batch_parallel(net, xs, n_samples, threads));
        std::hint::black_box(ck);
    });
    let ck_par = ck;
    let t_loop_q = super::time_median(warmup, reps, || {
        ck = 0;
        for s in 0..n_samples {
            ck = ck.wrapping_add(checksum_i32(&fixed.run_q(&xq[s * n_in..(s + 1) * n_in])));
        }
        std::hint::black_box(ck);
    });
    let ck_loop_q = ck;
    let t_batch_q = super::time_median(warmup, reps, || {
        ck = checksum_i32(&fixed.run_batch_q(&xq, n_samples));
        std::hint::black_box(ck);
    });
    let ck_batch_q = ck;
    let t_par_q = super::time_median(warmup, reps, || {
        ck = checksum_i32(&run_batch_q_parallel(fixed, &xq, n_samples, threads));
        std::hint::black_box(ck);
    });
    let ck_par_q = ck;
    let t_p7 = super::time_median(warmup, reps, || {
        ck = checksum_i32(&packed7.run_batch_q(&xq7, n_samples));
        std::hint::black_box(ck);
    });
    let ck_p7 = ck;
    let t_p7_par = super::time_median(warmup, reps, || {
        ck = checksum_i32(&run_batch_packed_parallel(&packed7, &xq7, n_samples, threads));
        std::hint::black_box(ck);
    });
    let ck_p7_par = ck;
    let t_p15 = super::time_median(warmup, reps, || {
        ck = checksum_i32(&packed15.run_batch_q(&xq15, n_samples));
        std::hint::black_box(ck);
    });
    let ck_p15 = ck;
    let t_p15_par = super::time_median(warmup, reps, || {
        ck = checksum_i32(&run_batch_packed_parallel(&packed15, &xq15, n_samples, threads));
        std::hint::black_box(ck);
    });
    let ck_p15_par = ck;

    // Compiled execution plans: serial (static dispatch, contiguous
    // arena, compile-time narrow-kernel resolution) and the
    // neuron-parallel row-split driver. Parity asserted before timing.
    let plan_f = ExecPlan::compile(net);
    let plan_q = ExecPlan::compile(fixed);
    assert_eq!(
        plan_f.run_batch_f32(xs, n_samples),
        net.run_batch(xs, n_samples),
        "f32 exec plan diverged from dispatch"
    );
    assert_eq!(
        run_plan_rowsplit(&plan_f, xs, n_samples, threads),
        net.run_batch(xs, n_samples),
        "f32 row-split diverged from dispatch"
    );
    assert_eq!(
        plan_q.run_batch_q(&xq, n_samples),
        fixed.run_batch_q(&xq, n_samples),
        "q32 exec plan diverged from dispatch"
    );
    assert_eq!(
        run_plan_q_rowsplit(&plan_q, &xq, n_samples, threads),
        fixed.run_batch_q(&xq, n_samples),
        "q32 row-split diverged from dispatch"
    );
    let mut pscratch = PlanScratch::new();
    let mut plan_out_f = vec![0.0f32; n_samples * net.num_outputs()];
    let t_planf = super::time_median(warmup, reps, || {
        plan_f.run_batch_f32_into(xs, n_samples, &mut pscratch, &mut plan_out_f);
        ck = checksum_f32(&plan_out_f);
        std::hint::black_box(ck);
    });
    let ck_planf = ck;
    let t_planf_rs = super::time_median(warmup, reps, || {
        run_plan_rowsplit_into(&plan_f, xs, n_samples, threads, &mut plan_out_f);
        ck = checksum_f32(&plan_out_f);
        std::hint::black_box(ck);
    });
    let ck_planf_rs = ck;
    let mut plan_out_q = vec![0i32; n_samples * fixed.num_outputs()];
    let t_planq = super::time_median(warmup, reps, || {
        plan_q.run_batch_q_into(&xq, n_samples, &mut pscratch, &mut plan_out_q);
        ck = checksum_i32(&plan_out_q);
        std::hint::black_box(ck);
    });
    let ck_planq = ck;
    let t_planq_rs = super::time_median(warmup, reps, || {
        run_plan_q_rowsplit_into(&plan_q, &xq, n_samples, threads, &mut plan_out_q);
        ck = checksum_i32(&plan_out_q);
        std::hint::black_box(ck);
    });
    let ck_planq_rs = ck;

    let rows = vec![
        ThroughputRow { name: "float: looped run()", seconds: t_loop, baseline_seconds: t_loop, checksum: ck_loop },
        ThroughputRow { name: "float: run_batch()", seconds: t_batch, baseline_seconds: t_loop, checksum: ck_batch },
        ThroughputRow { name: "float: parallel driver", seconds: t_par, baseline_seconds: t_loop, checksum: ck_par },
        ThroughputRow { name: "fixed: looped run_q()", seconds: t_loop_q, baseline_seconds: t_loop_q, checksum: ck_loop_q },
        ThroughputRow { name: "fixed: run_batch_q()", seconds: t_batch_q, baseline_seconds: t_loop_q, checksum: ck_batch_q },
        ThroughputRow { name: "fixed: parallel driver", seconds: t_par_q, baseline_seconds: t_loop_q, checksum: ck_par_q },
        ThroughputRow { name: "packed q7: run_batch_q()", seconds: t_p7, baseline_seconds: t_loop_q, checksum: ck_p7 },
        ThroughputRow { name: "packed q7: parallel driver", seconds: t_p7_par, baseline_seconds: t_loop_q, checksum: ck_p7_par },
        ThroughputRow { name: "packed q15: run_batch_q()", seconds: t_p15, baseline_seconds: t_loop_q, checksum: ck_p15 },
        ThroughputRow { name: "packed q15: parallel driver", seconds: t_p15_par, baseline_seconds: t_loop_q, checksum: ck_p15_par },
        ThroughputRow { name: "float: exec plan", seconds: t_planf, baseline_seconds: t_loop, checksum: ck_planf },
        ThroughputRow { name: "float: exec plan row-split", seconds: t_planf_rs, baseline_seconds: t_loop, checksum: ck_planf_rs },
        ThroughputRow { name: "fixed: exec plan", seconds: t_planq, baseline_seconds: t_loop_q, checksum: ck_planq },
        ThroughputRow { name: "fixed: exec plan row-split", seconds: t_planq_rs, baseline_seconds: t_loop_q, checksum: ck_planq_rs },
    ];
    // Checksums within one representation must agree — an elided or
    // divergent timed loop must never be reported as a speedup. The
    // looped float checksum uses a per-sample sum (different fold
    // order), so batch and parallel rows are compared to each other.
    assert_eq!(rows[1].checksum, rows[2].checksum, "float batch/parallel checksum");
    assert_eq!(rows[4].checksum, rows[5].checksum, "fixed batch/parallel checksum");
    assert_eq!(rows[6].checksum, rows[7].checksum, "packed q7 checksum");
    assert_eq!(rows[8].checksum, rows[9].checksum, "packed q15 checksum");
    assert_eq!(rows[10].checksum, rows[1].checksum, "f32 exec plan checksum");
    assert_eq!(rows[11].checksum, rows[1].checksum, "f32 row-split checksum");
    assert_eq!(rows[12].checksum, rows[4].checksum, "q32 exec plan checksum");
    assert_eq!(rows[13].checksum, rows[4].checksum, "q32 row-split checksum");
    rows
}

/// One row of the machine-readable kernel sweep (`bench json`).
#[derive(Debug, Clone)]
pub struct SweepRow {
    /// Kernel family name (`scalar_f32`, `packed_q7`, ...).
    pub kernel: &'static str,
    /// `"serial"` or `"parallel"`.
    pub mode: &'static str,
    /// Median wall time for the whole batch.
    pub seconds: f64,
    /// Fastest measured rep (noise diagnosis; see
    /// [`super::TimeStats`]).
    pub seconds_min: f64,
    /// Slowest measured rep.
    pub seconds_max: f64,
    /// Number of measured reps behind the median.
    pub reps: usize,
    /// Throughput over the whole batch.
    pub samples_per_sec: f64,
    /// Parameter storage (weights + biases) in this kernel's
    /// representation — the packed kernels' footprint win.
    pub bytes_per_network: usize,
    /// Digest of the outputs produced inside the timed loop.
    pub checksum: u64,
}

/// The full kernel × execution-mode throughput sweep behind
/// `bench json`: every dense kernel (scalar/blocked float, wide
/// FixedQ, packed Q7/Q15) in serial and pool-parallel batched mode on
/// the same randomized network and inputs. Asserts serial/parallel
/// bit-parity per kernel before timing anything.
pub fn kernel_sweep(
    net: &Network,
    xs: &[f32],
    n_samples: usize,
    threads: usize,
    warmup: usize,
    reps: usize,
) -> Vec<SweepRow> {
    let n_in = net.num_inputs();
    assert_eq!(xs.len(), n_samples * n_in);
    let fixed = FixedNetwork::from_float(net, 1.0).expect("fixed conversion");
    let xq = fixed.quantize_input(xs);
    let (fixed7, packed7) = from_float_packed(net, 1.0, PackedWidth::Q7).expect("q7 pack");
    let (fixed15, packed15) = from_float_packed(net, 1.0, PackedWidth::Q15).expect("q15 pack");
    let xq7 = packed7.quantize_input(xs);
    let xq15 = packed15.quantize_input(xs);

    let n_biases: usize = net.layers.iter().map(|l| l.biases.len()).sum();
    let wide_bytes = 4 * (net.num_weights() + n_biases);

    // One timing protocol for every (kernel, mode) cell: run the mode,
    // fold its output into a checksum the optimizer cannot elide, keep
    // the median wall time.
    let timed_row = |kernel: &'static str, mode: &'static str, bytes: usize, run: &dyn Fn() -> u64| {
        let mut ck = 0u64;
        let t = super::time_stats(warmup, reps, || {
            ck = run();
            std::hint::black_box(ck);
        });
        SweepRow {
            kernel,
            mode,
            seconds: t.median,
            seconds_min: t.min,
            seconds_max: t.max,
            reps: t.reps,
            samples_per_sec: n_samples as f64 / t.median,
            bytes_per_network: bytes,
            checksum: ck,
        }
    };

    let mut rows: Vec<SweepRow> = Vec::with_capacity(10);

    // Float kernels (SimdF32 rides the same loop: its serial/parallel
    // pair and checksum assert come for free).
    for kernel in [&ScalarF32 as &dyn DenseKernel<f32>, &BlockedF32, &SimdF32] {
        let serial = net.run_batch_with_kernel(kernel, xs, n_samples);
        let parallel = run_batch_parallel_with_kernel(net, kernel, xs, n_samples, threads);
        assert_eq!(serial, parallel, "{}: parallel diverged", kernel.name());
        rows.push(timed_row(kernel.name(), "serial", wide_bytes, &|| {
            checksum_f32(&net.run_batch_with_kernel(kernel, xs, n_samples))
        }));
        rows.push(timed_row(kernel.name(), "parallel", wide_bytes, &|| {
            checksum_f32(&run_batch_parallel_with_kernel(net, kernel, xs, n_samples, threads))
        }));
    }

    // Wide fixed-point kernel.
    {
        let serial = fixed.run_batch_q(&xq, n_samples);
        assert_eq!(
            serial,
            run_batch_q_parallel(&fixed, &xq, n_samples, threads),
            "fixed_q: parallel diverged"
        );
        rows.push(timed_row("fixed_q", "serial", wide_bytes, &|| {
            checksum_i32(&fixed.run_batch_q(&xq, n_samples))
        }));
        rows.push(timed_row("fixed_q", "parallel", wide_bytes, &|| {
            checksum_i32(&run_batch_q_parallel(&fixed, &xq, n_samples, threads))
        }));
    }

    // Packed kernels, each pinned to its same-dec FixedQ reference.
    for (name, reference, packed, xqp) in [
        ("packed_q7", &fixed7, &packed7, &xq7),
        ("packed_q15", &fixed15, &packed15, &xq15),
    ] {
        let serial = packed.run_batch_q(xqp, n_samples);
        assert_eq!(
            serial,
            reference.run_batch_q(xqp, n_samples),
            "{name}: diverged from FixedQ reference"
        );
        assert_eq!(
            serial,
            run_batch_packed_parallel(packed, xqp, n_samples, threads),
            "{name}: parallel diverged"
        );
        rows.push(timed_row(name, "serial", packed.param_bytes(), &|| {
            checksum_i32(&packed.run_batch_q(xqp, n_samples))
        }));
        rows.push(timed_row(name, "parallel", packed.param_bytes(), &|| {
            checksum_i32(&run_batch_packed_parallel(packed, xqp, n_samples, threads))
        }));
    }

    // Compiled execution plans, one per kernel family: serial (static
    // dispatch over the contiguous arena) and the neuron-parallel
    // row-split driver. Output checksums must be identical to the
    // dispatch path of the same family — a compiled plan that computes
    // anything else must never be timed as an optimization.
    {
        use std::cell::RefCell;
        let pscratch = RefCell::new(PlanScratch::new());
        let plan_f = ExecPlan::compile(net);
        let plan_q = ExecPlan::compile(&fixed);
        let plan_q7 = ExecPlan::compile(&packed7);
        let plan_q15 = ExecPlan::compile(&packed15);
        // Output buffers hoisted out of the timed closures: these rows
        // measure the execution strategy, not the allocator (the plan's
        // whole point is zero steady-state allocation).
        let out_f = RefCell::new(vec![0.0f32; n_samples * plan_f.num_outputs()]);
        let out_q = RefCell::new(vec![0i32; n_samples * plan_q.num_outputs()]);

        let dispatch_f = net.run_batch_with_kernel(&BlockedF32, xs, n_samples);
        assert_eq!(plan_f.run_batch_f32(xs, n_samples), dispatch_f, "exec_plan_f32 diverged");
        assert_eq!(
            run_plan_rowsplit(&plan_f, xs, n_samples, threads),
            dispatch_f,
            "exec_plan_f32 row-split diverged"
        );
        rows.push(timed_row("exec_plan_f32", "serial", plan_f.param_bytes(), &|| {
            let mut out = out_f.borrow_mut();
            plan_f.run_batch_f32_into(xs, n_samples, &mut pscratch.borrow_mut(), &mut out);
            checksum_f32(&out)
        }));
        rows.push(timed_row("exec_plan_f32", "rowsplit", plan_f.param_bytes(), &|| {
            let mut out = out_f.borrow_mut();
            run_plan_rowsplit_into(&plan_f, xs, n_samples, threads, &mut out);
            checksum_f32(&out)
        }));

        for (name, plan, xqp) in [
            ("exec_plan_q32", &plan_q, &xq),
            ("exec_plan_q7", &plan_q7, &xq7),
            ("exec_plan_q15", &plan_q15, &xq15),
        ] {
            let dispatch = match name {
                "exec_plan_q32" => fixed.run_batch_q(xqp, n_samples),
                "exec_plan_q7" => packed7.run_batch_q(xqp, n_samples),
                _ => packed15.run_batch_q(xqp, n_samples),
            };
            assert_eq!(plan.run_batch_q(xqp, n_samples), dispatch, "{name} diverged");
            assert_eq!(
                run_plan_q_rowsplit(plan, xqp, n_samples, threads),
                dispatch,
                "{name} row-split diverged"
            );
            rows.push(timed_row(name, "serial", plan.param_bytes(), &|| {
                let mut out = out_q.borrow_mut();
                plan.run_batch_q_into(xqp, n_samples, &mut pscratch.borrow_mut(), &mut out);
                checksum_i32(&out)
            }));
            rows.push(timed_row(name, "rowsplit", plan.param_bytes(), &|| {
                let mut out = out_q.borrow_mut();
                run_plan_q_rowsplit_into(plan, xqp, n_samples, threads, &mut out);
                checksum_i32(&out)
            }));
        }
    }

    for pair in rows.chunks(2) {
        assert_eq!(
            pair[0].checksum, pair[1].checksum,
            "{} {}/{} checksum mismatch",
            pair[0].kernel, pair[0].mode, pair[1].mode
        );
    }
    // Every exec-plan family must checksum identically to its dispatch
    // counterpart (same representation, same inputs).
    let ck_of = |kernel: &str| {
        rows.iter()
            .find(|r| r.kernel == kernel && r.mode == "serial")
            .map(|r| r.checksum)
            .unwrap()
    };
    assert_eq!(ck_of("exec_plan_f32"), ck_of("blocked_f32"), "f32 plan/dispatch checksum");
    assert_eq!(ck_of("exec_plan_q32"), ck_of("fixed_q"), "q32 plan/dispatch checksum");
    assert_eq!(ck_of("exec_plan_q7"), ck_of("packed_q7"), "q7 plan/dispatch checksum");
    assert_eq!(ck_of("exec_plan_q15"), ck_of("packed_q15"), "q15 plan/dispatch checksum");
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::{Activation, FixedNetwork, Network};
    use crate::util::rng::Rng;

    fn net(sizes: &[usize], seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        n.randomize(&mut rng, None);
        n
    }

    #[test]
    fn chunks_cover_exactly_once() {
        for n in [0usize, 1, 2, 7, 64, 100] {
            for w in [1usize, 2, 3, 8, 200] {
                let cs = chunks(n, w);
                let mut next = 0;
                for (start, len) in cs {
                    assert_eq!(start, next);
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn pool_reuses_threads_across_executes() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let pool = BatchPool::new(3);
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        // Ten batches of three jobs: were threads spawned per batch the
        // set would approach 30 distinct ids; a true pool stays ≤ 3.
        for _ in 0..10 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                .map(|_| {
                    Box::new(|| {
                        ids.lock().unwrap().insert(std::thread::current().id());
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.execute(jobs);
        }
        let distinct = ids.lock().unwrap().len();
        assert!((1..=3).contains(&distinct), "saw {distinct} worker threads");
    }

    #[test]
    fn pool_runs_more_jobs_than_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = BatchPool::new(2);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..17)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.execute(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn pool_propagates_job_panics_after_quiescing() {
        let pool = BatchPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.execute(vec![
                Box::new(|| panic!("boom")) as Box<dyn FnOnce() + Send + '_>,
                Box::new(|| {}),
            ]);
        }));
        // The original payload (not a generic wrapper) reaches the
        // caller, so diagnostics keep the panicking job's message.
        let payload = result.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The pool survives a panicked job (catch_unwind in the worker).
        pool.execute(vec![Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>]);
    }

    #[test]
    fn parallel_float_is_bit_identical_to_serial() {
        let net = net(&[6, 11, 4], 77);
        let mut rng = Rng::new(5);
        let n = 23; // deliberately not a multiple of the worker count
        let xs: Vec<f32> = (0..n * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let serial = net.run_batch(&xs, n);
        for threads in [1, 2, 3, 8] {
            let par = run_batch_parallel(&net, &xs, n, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
        // Per-sample equality too.
        for s in 0..n {
            assert_eq!(&serial[s * 4..(s + 1) * 4], &net.run(&xs[s * 6..(s + 1) * 6])[..]);
        }
    }

    #[test]
    fn parallel_fixed_is_bit_exact() {
        let fnet = net(&[4, 8, 3], 31);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let mut rng = Rng::new(9);
        let n = 17;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| crate::quantize::quantize(v, fixed.decimal_point))
            .collect();
        let serial = fixed.run_batch_q(&q, n);
        for threads in [1, 2, 5] {
            assert_eq!(run_batch_q_parallel(&fixed, &q, n, threads), serial);
        }
    }

    #[test]
    fn parallel_packed_is_bit_exact() {
        let fnet = net(&[5, 9, 3], 13);
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (_, packed) = from_float_packed(&fnet, 1.0, width).unwrap();
            let mut rng = Rng::new(21);
            let n = 19;
            let xs: Vec<f32> = (0..n * 5).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let q = packed.quantize_input(&xs);
            let serial = packed.run_batch_q(&q, n);
            for threads in [1, 2, 6] {
                assert_eq!(
                    run_batch_packed_parallel(&packed, &q, n, threads),
                    serial,
                    "{width:?} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn measure_throughput_reports_all_fourteen_modes() {
        let fnet = net(&[4, 6, 2], 3);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let mut rng = Rng::new(2);
        let n = 8;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rows = measure_throughput(&fnet, &fixed, &xs, n, 2, 0, 1);
        assert_eq!(rows.len(), 14);
        assert!(rows.iter().all(|r| r.seconds >= 0.0 && r.baseline_seconds >= 0.0));
        assert_eq!(rows[0].seconds, rows[0].baseline_seconds);
        assert!(rows.iter().any(|r| r.name == "fixed: exec plan row-split"));
    }

    #[test]
    fn kernel_sweep_covers_all_kernels_and_agrees() {
        let fnet = net(&[6, 8, 3], 11);
        let mut rng = Rng::new(4);
        let n = 12;
        let xs: Vec<f32> = (0..n * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rows = kernel_sweep(&fnet, &xs, n, 2, 0, 1);
        let kernels: Vec<_> = rows.iter().map(|r| (r.kernel, r.mode)).collect();
        for k in ["scalar_f32", "blocked_f32", "simd_f32", "fixed_q", "packed_q7", "packed_q15"] {
            assert!(kernels.contains(&(k, "serial")), "{k} serial missing");
            assert!(kernels.contains(&(k, "parallel")), "{k} parallel missing");
        }
        // Rep diagnostics bracket the median on every row.
        for r in &rows {
            assert!(r.reps >= 1, "{} {}: reps", r.kernel, r.mode);
            assert!(
                r.seconds_min <= r.seconds && r.seconds <= r.seconds_max,
                "{} {}: min/median/max out of order",
                r.kernel,
                r.mode
            );
        }
        for k in ["exec_plan_f32", "exec_plan_q32", "exec_plan_q7", "exec_plan_q15"] {
            assert!(kernels.contains(&(k, "serial")), "{k} serial missing");
            assert!(kernels.contains(&(k, "rowsplit")), "{k} rowsplit missing");
        }
        // Packed storage beats the wide i32 representation.
        let wide = rows.iter().find(|r| r.kernel == "fixed_q").unwrap().bytes_per_network;
        let p7 = rows.iter().find(|r| r.kernel == "packed_q7").unwrap().bytes_per_network;
        let p15 = rows.iter().find(|r| r.kernel == "packed_q15").unwrap().bytes_per_network;
        assert!(p7 < wide && p15 < wide && p7 < p15);
    }

    #[test]
    fn rowsplit_bit_identical_to_serial_plan_all_worker_counts() {
        let fnet = net(&[6, 11, 1, 4], 41); // includes a single-neuron layer
        let plan_f = ExecPlan::compile(&fnet);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let plan_q = ExecPlan::compile(&fixed);
        let mut rng = Rng::new(6);
        for n in [1usize, 5, 23] {
            let xs: Vec<f32> = (0..n * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let want_f = plan_f.run_batch_f32(&xs, n);
            let xq = fixed.quantize_input(&xs);
            let want_q = plan_q.run_batch_q(&xq, n);
            for workers in [1usize, 2, 3, 8] {
                assert_eq!(
                    run_plan_rowsplit(&plan_f, &xs, n, workers),
                    want_f,
                    "f32 n={n} workers={workers}"
                );
                assert_eq!(
                    run_plan_q_rowsplit(&plan_q, &xq, n, workers),
                    want_q,
                    "q32 n={n} workers={workers}"
                );
            }
        }
        // Empty batches are no-ops.
        assert!(run_plan_rowsplit(&plan_f, &[], 0, 4).is_empty());
        assert!(run_plan_q_rowsplit(&plan_q, &[], 0, 4).is_empty());
    }

    #[test]
    fn rowsplit_clamps_oversized_worker_requests_to_the_pool() {
        // Requesting far more workers than the pool has must behave
        // exactly like full fan-out, not slice every layer into
        // thousands of sub-row fragments (the pre-clamp bug: the
        // drivers fed the raw request into the row splitter).
        assert_eq!(effective_workers(10_000), global_pool().workers());
        let fnet = net(&[6, 11, 4], 42);
        let plan_f = ExecPlan::compile(&fnet);
        let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
        let plan_q = ExecPlan::compile(&fixed);
        let mut rng = Rng::new(9);
        let n = 7;
        let xs: Vec<f32> = (0..n * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let xq = fixed.quantize_input(&xs);
        assert_eq!(
            run_plan_rowsplit(&plan_f, &xs, n, 10_000),
            plan_f.run_batch_f32(&xs, n)
        );
        assert_eq!(
            run_plan_q_rowsplit(&plan_q, &xq, n, 10_000),
            plan_q.run_batch_q(&xq, n)
        );
    }

    #[test]
    fn empty_batch_and_auto_threads() {
        let net = net(&[3, 2], 1);
        assert!(run_batch_parallel(&net, &[], 0, 0).is_empty());
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }

    #[test]
    fn checksums_detect_divergence() {
        assert_eq!(checksum_f32(&[1.0, 2.0]), checksum_f32(&[1.0, 2.0]));
        assert_ne!(checksum_f32(&[1.0, 2.0]), checksum_f32(&[2.0, 1.0]));
        assert_ne!(checksum_i32(&[1, 2, 3]), checksum_i32(&[1, 2, 4]));
        // -0.0 and +0.0 are different bit patterns: the digest sees it.
        assert_ne!(checksum_f32(&[0.0]), checksum_f32(&[-0.0]));
    }
}
