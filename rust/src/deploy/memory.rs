//! Eq. (2) of the paper: the memory estimator that drives placement.
//!
//! ```text
//! E_m = (2·L_data_buffer + 5·N_neurons + N_weights + 2·N_fann_layers)
//!       · sizeof(dtype)
//! ```
//!
//! * `L_data_buffer` — widest layer, doubled for the ping-pong activation
//!   buffers used for continuous sensor processing;
//! * `N_neurons` — all neurons incl. one bias pseudo-neuron per layer,
//!   ×5 for {first-connection idx, last-connection idx, steepness,
//!   activation type, neuron output};
//! * `N_weights` — all connection weights;
//! * `N_fann_layers` — layers incl. input, ×2 for {first, last} neuron
//!   indexes.

use crate::targets::DataType;

/// Shape-only view of a network: the layer sizes `[in, h1, .., out]`.
/// Both the float and the fixed network convert into this, so the
/// deployment planner is representation-agnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetShape {
    /// Layer sizes `[in, h1, ..., out]`.
    pub sizes: Vec<usize>,
}

impl NetShape {
    /// Shape from explicit layer sizes (panics on < 2 layers).
    pub fn new(sizes: &[usize]) -> Self {
        assert!(sizes.len() >= 2, "need at least input and output layers");
        Self {
            sizes: sizes.to_vec(),
        }
    }

    /// Total connection weights.
    pub fn num_weights(&self) -> usize {
        self.sizes.windows(2).map(|w| w[0] * w[1]).sum()
    }

    /// Neurons incl. one bias pseudo-neuron per layer (FANN layout).
    pub fn num_neurons_with_bias(&self) -> usize {
        self.sizes.iter().map(|s| s + 1).sum()
    }

    /// Layer count incl. the input layer (FANN convention).
    pub fn num_fann_layers(&self) -> usize {
        self.sizes.len()
    }

    /// Widest layer (sizes the ping-pong activation buffers).
    pub fn max_layer_width(&self) -> usize {
        *self.sizes.iter().max().unwrap()
    }

    /// Multiply-accumulates per classification (= weights).
    pub fn macs(&self) -> usize {
        self.num_weights()
    }

    /// Weight+bias bytes of the largest single layer (drives the
    /// layer-wise vs neuron-wise DMA decision).
    pub fn max_layer_param_bytes(&self, dtype: DataType) -> usize {
        self.sizes
            .windows(2)
            .map(|w| (w[0] * w[1] + w[1]) * dtype_size(dtype))
            .max()
            .unwrap()
    }

    /// Weight bytes of the largest single neuron (one weight row).
    pub fn max_neuron_row_bytes(&self, dtype: DataType) -> usize {
        self.sizes
            .windows(2)
            .map(|w| w[0] * dtype_size(dtype))
            .max()
            .unwrap()
    }

    /// Total parameter bytes (weights + biases).
    pub fn param_bytes(&self, dtype: DataType) -> usize {
        let biases: usize = self.sizes[1..].iter().sum();
        (self.num_weights() + biases) * dtype_size(dtype)
    }
}

impl From<&crate::fann::Network> for NetShape {
    fn from(net: &crate::fann::Network) -> Self {
        NetShape::new(&net.layer_sizes())
    }
}

impl From<&crate::fann::FixedNetwork> for NetShape {
    fn from(net: &crate::fann::FixedNetwork) -> Self {
        NetShape::new(&net.layer_sizes())
    }
}

/// Element size: both f32 and Q-format i32 are 4 bytes on these MCUs.
pub fn dtype_size(dtype: DataType) -> usize {
    match dtype {
        DataType::Float32 => 4,
        DataType::Fixed => 4,
    }
}

/// Eq. (2): estimated bytes needed to host the network + runtime buffers.
pub fn estimate_memory(shape: &NetShape, dtype: DataType) -> usize {
    let l_data_buffer = shape.max_layer_width();
    let words = 2 * l_data_buffer
        + 5 * shape.num_neurons_with_bias()
        + shape.num_weights()
        + 2 * shape.num_fann_layers();
    words * dtype_size(dtype)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_hand_computed_example() {
        // 2-4-1 xor net: buffer 4, neurons (2+1)+(4+1)+(1+1)=10,
        // weights 2·4+4·1=12, layers 3.
        let shape = NetShape::new(&[2, 4, 1]);
        let words = 2 * 4 + 5 * 10 + 12 + 2 * 3;
        assert_eq!(estimate_memory(&shape, DataType::Float32), words * 4);
    }

    #[test]
    fn eq2_application_a() {
        // 76-300-200-100-10: weights 103800, neurons 691, buffer 300,
        // layers 5 -> dominated by the weights as the paper notes.
        let shape = NetShape::new(&[76, 300, 200, 100, 10]);
        let e = estimate_memory(&shape, DataType::Float32);
        let weights_bytes = 103_800 * 4;
        assert!(e > weights_bytes);
        assert!(e < weights_bytes + 20_000, "estimate {e}");
    }

    #[test]
    fn estimate_monotone_in_layer_width() {
        let small = estimate_memory(&NetShape::new(&[10, 20, 5]), DataType::Fixed);
        let big = estimate_memory(&NetShape::new(&[10, 40, 5]), DataType::Fixed);
        assert!(big > small);
    }

    #[test]
    fn layer_and_neuron_byte_helpers() {
        let shape = NetShape::new(&[76, 300, 200, 100, 10]);
        // largest layer by params: 300x200 + 200 biases.
        assert_eq!(
            shape.max_layer_param_bytes(DataType::Float32),
            (300 * 200 + 200) * 4
        );
        // largest neuron row: 300 inputs.
        assert_eq!(shape.max_neuron_row_bytes(DataType::Float32), 300 * 4);
    }

    #[test]
    fn shape_from_network() {
        use crate::fann::{Activation, Network};
        let net = Network::new(&[5, 7, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        let shape = NetShape::from(&net);
        assert_eq!(shape.sizes, vec![5, 7, 2]);
        assert_eq!(shape.num_weights(), 5 * 7 + 7 * 2);
    }
}
