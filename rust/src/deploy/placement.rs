//! The placement policy of Sec. IV-B: given the Eq. (2) estimate and the
//! selected processor, choose the memory level closest to the core that
//! still fits the network, and — for the cluster — the DMA strategy.
//!
//! * Cortex-M: RAM if `E_m` fits, else network constants in flash
//!   (buffers stay in RAM), else no-fit.
//! * Wolf FC: private L2, else shared L2, else no-fit.
//! * Wolf cluster: L1, else shared-L2-resident with DMA double-buffering —
//!   layer-wise while the two largest adjacent layers fit L1, neuron-wise
//!   while two neuron rows fit, else no-fit.

use anyhow::{bail, Result};

use super::memory::{dtype_size, estimate_memory, NetShape};
use crate::targets::{memspec, Chip, DataType, Region, Target};

/// DMA double-buffering granularity for L2-resident cluster networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaStrategy {
    /// Whole-layer transfers (largest layer fits L1 with double buffer).
    LayerWise,
    /// One weight row (neuron) at a time.
    NeuronWise,
}

/// The result of planning a deployment.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    /// The deployment target.
    pub target: Target,
    /// Numeric type the network deploys as.
    pub dtype: DataType,
    /// Where the network parameters live.
    pub region: Region,
    /// DMA streaming strategy (cluster targets with L2-resident nets).
    pub dma: Option<DmaStrategy>,
    /// Eq. (2) estimate in bytes.
    pub est_memory_bytes: usize,
    /// Shape of the deployed network.
    pub shape: NetShape,
}

impl DeploymentPlan {
    /// Whether a region was found that holds the network.
    pub fn fits(&self) -> bool {
        self.region != Region::NoFit
    }
}

/// L1 bytes available for network data on the cluster; the balance is
/// reserved for stacks + activation buffers of the eight cores. Public
/// because the detailed-plan builder ([`crate::codegen::plan`]) and the
/// emulator enforce the same budget on their schedules.
pub fn cluster_l1_budget() -> usize {
    memspec::WOLF_MEMORY.l1 - 8 * 1024
}

/// Plan a deployment; fails only on unsupported dtype/target combinations
/// (no-fit is reported via `region == NoFit` so sweeps can show the
/// paper's "0.0" cells rather than erroring).
pub fn plan(shape: &NetShape, target: Target, dtype: DataType) -> Result<DeploymentPlan> {
    if dtype == DataType::Float32 && !target.supports_float() {
        bail!(
            "{} has no FPU: convert the network to fixed point first \
             (fann_save_to_fixed)",
            target.label()
        );
    }
    let est = estimate_memory(shape, dtype);
    let (region, dma) = match target {
        Target::CortexM4(chip) | Target::CortexM7(chip) | Target::CortexM0(chip) => {
            place_cortex_m(shape, chip, dtype, est)
        }
        Target::WolfFc => place_wolf_fc(est),
        Target::WolfCluster { .. } => place_wolf_cluster(shape, dtype, est),
    };
    Ok(DeploymentPlan {
        target,
        dtype,
        region,
        dma,
        est_memory_bytes: est,
        shape: shape.clone(),
    })
}

fn place_cortex_m(
    shape: &NetShape,
    chip: Chip,
    dtype: DataType,
    est: usize,
) -> (Region, Option<DmaStrategy>) {
    let mem = chip.memory();
    place_cortex_with(shape, dtype, est, mem.ram, mem.flash)
}

/// The Cortex-M placement policy against explicit budgets (RAM-resident,
/// else constants in flash with runtime buffers in RAM, else no-fit).
/// Budget-parameterized so `rust/tests/prop_placement.rs` can sweep
/// random memory geometries, not just the three modeled chips.
pub fn place_cortex_with(
    shape: &NetShape,
    dtype: DataType,
    est: usize,
    ram: usize,
    flash: usize,
) -> (Region, Option<DmaStrategy>) {
    if est <= ram {
        (Region::Ram, None)
    } else {
        // Parameters go to flash; the RAM must still hold the runtime
        // buffers + bookkeeping (Eq. 2 minus the weights).
        let params = shape.param_bytes(dtype);
        let runtime = est - shape.num_weights() * dtype_size(dtype);
        if params <= flash && runtime <= ram {
            (Region::Flash, None)
        } else {
            (Region::NoFit, None)
        }
    }
}

fn place_wolf_fc(est: usize) -> (Region, Option<DmaStrategy>) {
    let mem = memspec::WOLF_MEMORY;
    place_fc_with(est, mem.private_l2, mem.shared_l2)
}

/// The FC placement policy against explicit budgets (private L2, else
/// shared L2, else no-fit).
pub fn place_fc_with(
    est: usize,
    private_l2: usize,
    shared_l2: usize,
) -> (Region, Option<DmaStrategy>) {
    if est <= private_l2 {
        (Region::PrivateL2, None)
    } else if est <= shared_l2 {
        (Region::SharedL2, None)
    } else {
        (Region::NoFit, None)
    }
}

fn place_wolf_cluster(shape: &NetShape, dtype: DataType, est: usize) -> (Region, Option<DmaStrategy>) {
    place_cluster_with(
        shape,
        dtype,
        est,
        cluster_l1_budget(),
        memspec::WOLF_MEMORY.shared_l2,
    )
}

/// The cluster placement policy against explicit budgets: L1-resident,
/// else shared-L2-resident with layer-wise double buffering while the
/// largest layer pair fits `l1_budget`, else neuron-wise while two
/// weight rows fit, else no-fit.
pub fn place_cluster_with(
    shape: &NetShape,
    dtype: DataType,
    est: usize,
    l1_budget: usize,
    shared_l2: usize,
) -> (Region, Option<DmaStrategy>) {
    if est <= l1_budget {
        return (Region::L1, None);
    }
    // L2-resident, streamed. The network itself must fit shared L2.
    if shape.param_bytes(dtype) > shared_l2 {
        return (Region::NoFit, None);
    }
    // Layer-wise double buffering: current + next layer resident.
    let largest_layer = shape.max_layer_param_bytes(dtype);
    if 2 * largest_layer <= l1_budget {
        return (Region::SharedL2, Some(DmaStrategy::LayerWise));
    }
    // Neuron-wise double buffering: two weight rows resident.
    let row = shape.max_neuron_row_bytes(dtype);
    if 2 * row <= l1_budget {
        return (Region::SharedL2, Some(DmaStrategy::NeuronWise));
    }
    (Region::NoFit, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(sizes: &[usize]) -> NetShape {
        NetShape::new(sizes)
    }

    #[test]
    fn small_net_lands_in_fastest_memory_everywhere() {
        let s = shape(&[7, 6, 5]); // application C
        for (t, want) in [
            (Target::CortexM4(Chip::Nrf52832), Region::Ram),
            (Target::WolfFc, Region::PrivateL2),
            (Target::WolfCluster { cores: 8 }, Region::L1),
        ] {
            let p = plan(&s, t, DataType::Fixed).unwrap();
            assert_eq!(p.region, want, "{t:?}");
            assert!(p.dma.is_none());
        }
    }

    #[test]
    fn application_a_placements_match_paper() {
        // 76-300-200-100-10: 415 kB of f32 parameters.
        let s = shape(&[76, 300, 200, 100, 10]);
        // nRF52832: > 64 kB RAM -> flash.
        let p = plan(&s, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        assert_eq!(p.region, Region::Flash);
        // FC: > 64 kB private -> shared L2.
        let p = plan(&s, Target::WolfFc, DataType::Fixed).unwrap();
        assert_eq!(p.region, Region::SharedL2);
        // Cluster: largest layer 300x200 = 240 kB > L1 -> neuron-wise DMA.
        let p = plan(&s, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.region, Region::SharedL2);
        assert_eq!(p.dma, Some(DmaStrategy::NeuronWise));
    }

    #[test]
    fn layerwise_dma_when_layers_fit_individually() {
        // ~96 kB of parameters (> L1 budget) but the largest layer is
        // ~24 kB: two layers double-buffer within L1 -> layer-wise.
        let s = shape(&[50, 100, 60, 100, 60, 8]);
        let p = plan(&s, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.region, Region::SharedL2);
        assert_eq!(p.dma, Some(DmaStrategy::LayerWise));
    }

    #[test]
    fn float_on_fpu_less_targets_rejected() {
        let s = shape(&[4, 3, 2]);
        assert!(plan(&s, Target::WolfFc, DataType::Float32).is_err());
        assert!(plan(&s, Target::CortexM0(Chip::Nrf52832), DataType::Float32).is_err());
    }

    #[test]
    fn giant_net_reports_nofit_not_error() {
        // ~4 M weights float: over every memory.
        let s = shape(&[2048, 2048, 8]);
        let p = plan(&s, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        assert_eq!(p.region, Region::NoFit);
        assert!(!p.fits());
        let p = plan(&s, Target::WolfFc, DataType::Fixed).unwrap();
        assert_eq!(p.region, Region::NoFit);
    }

    #[test]
    fn neuron_wise_when_single_row_is_huge() {
        // 3000-input rows = 12 kB: the largest layer (~360 kB) exceeds
        // L1 but two rows double-buffer -> neuron-wise; the total
        // (~362 kB) still fits shared L2.
        let s = shape(&[3000, 30, 8]);
        let p = plan(&s, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.dma, Some(DmaStrategy::NeuronWise));
        // 12000-input rows = 48 kB: two rows exceed the L1 budget (and
        // the 1.9 MB of parameters exceed shared L2) -> no fit.
        let s = shape(&[12_000, 40, 8]);
        let p = plan(&s, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.region, Region::NoFit);
    }

    #[test]
    fn stm32_ram_larger_than_nrf_changes_boundary() {
        // ~80 kB net: fits STM32 (96 kB) RAM, not nRF52832 (64 kB).
        let s = shape(&[100, 190, 8]);
        let est = estimate_memory(&s, DataType::Float32);
        assert!(est > 64 * 1024 && est < 96 * 1024, "est {est}");
        let p = plan(&s, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
        assert_eq!(p.region, Region::Ram);
        let p = plan(&s, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        assert_eq!(p.region, Region::Flash);
    }
}
