//! The deployment planner — the toolkit half of the paper's contribution.
//!
//! [`memory`] implements the Eq. (2) estimator; [`placement`] implements
//! the Sec. IV-B policy that picks the memory level closest to the
//! processing unit that still holds the network, plus the DMA
//! double-buffering strategy for L2-resident cluster deployments.

pub mod memory;
pub mod placement;

pub use memory::{estimate_memory, NetShape};
pub use placement::{
    cluster_l1_budget, place_cluster_with, place_cortex_with, place_fc_with, plan, DeploymentPlan,
    DmaStrategy,
};
