//! Memory geometry of the modeled chips and the per-region access
//! penalties that produce the paper's placement boundaries (blue grid =
//! RAM→flash on Cortex-M, purple = private→shared L2 on the FC, gray =
//! L1→L2-with-DMA on the cluster).

/// A memory region a network (or one streaming buffer) can live in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Region {
    /// Cortex-M on-chip SRAM.
    Ram,
    /// Cortex-M non-volatile flash (wait states on random reads).
    Flash,
    /// Mr. Wolf FC private L2 (64 kB, zero-conflict).
    PrivateL2,
    /// Mr. Wolf shared L2 (448 kB, 4 banks, arbitration).
    SharedL2,
    /// Mr. Wolf cluster L1 TCDM (64 kB, 16 banks, single-cycle).
    L1,
    /// Network does not fit anywhere — deployment fails (the paper's
    /// "0.0" cells in Figs. 8–10).
    NoFit,
}

impl Region {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Region::Ram => "RAM",
            Region::Flash => "flash",
            Region::PrivateL2 => "private L2",
            Region::SharedL2 => "shared L2",
            Region::L1 => "L1",
            Region::NoFit => "NO FIT",
        }
    }
}

/// Chip-level memory spec (sizes in bytes).
#[derive(Debug, Clone, Copy)]
pub struct ChipMemory {
    /// SRAM usable for network + buffers (Cortex-M chips).
    pub ram: usize,
    /// Flash usable for constant network data (Cortex-M chips).
    pub flash: usize,
    /// Extra cycles per 32-bit weight load when running from flash.
    pub flash_penalty_per_word: f64,
}

/// The evaluation chips.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Chip {
    /// STM32L475VG (Fig. 7/8/10/11/12 measurements): 96 kB usable RAM
    /// (paper Sec. IV-B example), 1 MB flash, ART cache keeps the flash
    /// penalty mild ("degrades slightly").
    Stm32l475vg,
    /// nRF52832 on InfiniWolf (Table II): 64 kB RAM, 512 kB flash, no
    /// flash cache comparable to ART — larger effective penalty.
    Nrf52832,
    /// STM32F769 (Cortex-M7 @216 MHz): 512 kB SRAM, 2 MB flash behind
    /// the ART accelerator + L1 cache.
    Stm32f769,
}

impl Chip {
    /// Memory geometry of the chip.
    pub fn memory(self) -> ChipMemory {
        match self {
            Chip::Stm32l475vg => ChipMemory {
                ram: 96 * 1024,
                flash: 1024 * 1024,
                flash_penalty_per_word: 1.0,
            },
            Chip::Nrf52832 => ChipMemory {
                ram: 64 * 1024,
                flash: 512 * 1024,
                flash_penalty_per_word: 2.5,
            },
            Chip::Stm32f769 => ChipMemory {
                ram: 512 * 1024,
                flash: 2 * 1024 * 1024,
                flash_penalty_per_word: 0.5,
            },
        }
    }

    /// Core clock used in the paper's measurements.
    pub fn freq_hz(self) -> f64 {
        match self {
            Chip::Stm32l475vg => 80.0e6,
            Chip::Nrf52832 => 64.0e6,
            Chip::Stm32f769 => 216.0e6,
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Chip::Stm32l475vg => "STM32L475VG",
            Chip::Nrf52832 => "nRF52832",
            Chip::Stm32f769 => "STM32F769",
        }
    }
}

/// Mr. Wolf memory geometry (Sec. III-B): 512 kB L2 split into 448 kB
/// shared + 64 kB FC-private; 64 kB cluster L1 (16 × 4 kB banks).
#[derive(Debug, Clone, Copy)]
pub struct WolfMemory {
    /// FC-private L2 bytes.
    pub private_l2: usize,
    /// Shared L2 bytes (4 banks).
    pub shared_l2: usize,
    /// Cluster L1 TCDM bytes (16 banks).
    pub l1: usize,
    /// Extra cycles per word for FC accesses to *shared* L2 (bank
    /// arbitration) relative to private L2.
    pub shared_l2_penalty_per_word: f64,
    /// Extra cycles per word for cluster cores reading directly from L2
    /// instead of L1 (only relevant without DMA staging).
    pub cluster_l2_penalty_per_word: f64,
}

/// The Mr. Wolf memory geometry (Sec. III-B).
pub const WOLF_MEMORY: WolfMemory = WolfMemory {
    private_l2: 64 * 1024,
    shared_l2: 448 * 1024,
    l1: 64 * 1024,
    shared_l2_penalty_per_word: 0.5,
    cluster_l2_penalty_per_word: 4.0,
};

/// Mr. Wolf SoC/cluster clock used in the paper's measurements (100 MHz:
/// "at this frequency the energy efficiency is maximized").
pub const WOLF_FREQ_HZ: f64 = 100.0e6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_sizes_match_datasheets() {
        assert_eq!(Chip::Stm32l475vg.memory().ram, 98_304);
        assert_eq!(Chip::Nrf52832.memory().ram, 65_536);
        assert_eq!(Chip::Nrf52832.memory().flash, 524_288);
    }

    #[test]
    fn wolf_l2_split_matches_paper() {
        // 448 kB shared + 64 kB private = 512 kB total L2.
        assert_eq!(WOLF_MEMORY.shared_l2 + WOLF_MEMORY.private_l2, 512 * 1024);
        assert_eq!(WOLF_MEMORY.l1, 16 * 4 * 1024);
    }

    #[test]
    fn flash_penalty_ordering() {
        // ART-cached STM32 flash must be cheaper than nRF52 flash.
        assert!(
            Chip::Stm32l475vg.memory().flash_penalty_per_word
                < Chip::Nrf52832.memory().flash_penalty_per_word
        );
    }

    #[test]
    fn region_names_unique() {
        use std::collections::HashSet;
        let names: HashSet<&str> = [
            Region::Ram,
            Region::Flash,
            Region::PrivateL2,
            Region::SharedL2,
            Region::L1,
            Region::NoFit,
        ]
        .iter()
        .map(|r| r.name())
        .collect();
        assert_eq!(names.len(), 6);
    }
}
