//! MCU target models: instruction costs ([`isa`]), memory geometry
//! ([`memspec`]), DMA streaming ([`dma`]) and power ([`power`]).
//!
//! A [`Target`] bundles one deployable execution configuration — the
//! paper's four Table II columns plus the Cortex-M0 and the STM32 chip
//! used in the microbenchmark figures.

pub mod dma;
pub mod isa;
pub mod memspec;
pub mod power;

pub use isa::{Core, DataType, IsaExtensions};
pub use memspec::{Chip, Region};

/// One deployable execution target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// ARM Cortex-M4F on the given chip.
    CortexM4(Chip),
    /// ARM Cortex-M7F on the given chip.
    CortexM7(Chip),
    /// ARM Cortex-M0+ on the given chip (no FPU: fixed-point only in
    /// practice).
    CortexM0(Chip),
    /// Mr. Wolf fabric controller (IBEX, RV32IMC).
    WolfFc,
    /// Mr. Wolf cluster with `1..=8` active RI5CY cores.
    WolfCluster { cores: u32 },
}

impl Target {
    /// The core microarchitecture this target executes on.
    pub fn core(self) -> Core {
        match self {
            Target::CortexM4(_) => Core::CortexM4,
            Target::CortexM7(_) => Core::CortexM7,
            Target::CortexM0(_) => Core::CortexM0,
            Target::WolfFc => Core::Ibex,
            Target::WolfCluster { .. } => Core::Riscy,
        }
    }

    /// Number of cores computing in parallel.
    pub fn num_cores(self) -> u32 {
        match self {
            Target::WolfCluster { cores } => cores.clamp(1, 8),
            _ => 1,
        }
    }

    /// Core clock frequency (the paper's measurement operating points).
    pub fn freq_hz(self) -> f64 {
        match self {
            Target::CortexM4(chip) | Target::CortexM7(chip) | Target::CortexM0(chip) => {
                chip.freq_hz()
            }
            Target::WolfFc | Target::WolfCluster { .. } => memspec::WOLF_FREQ_HZ,
        }
    }

    /// Active power in mW while computing (utilization 1.0; the
    /// simulator refines cluster power with the measured utilization).
    pub fn active_mw(self) -> f64 {
        match self {
            Target::CortexM7(_) => power::STM32F769_M7.active_mw,
            Target::CortexM4(Chip::Nrf52832) | Target::CortexM0(Chip::Nrf52832) => {
                power::NRF52832_M4.active_mw
            }
            Target::CortexM4(_) | Target::CortexM0(_) => power::STM32L475.active_mw,
            Target::WolfFc => power::WOLF_FC.active_mw,
            Target::WolfCluster { cores } => power::WOLF_CLUSTER.active_mw(cores.clamp(1, 8), 1.0),
        }
    }

    /// Does this target support hardware floats?
    pub fn supports_float(self) -> bool {
        self.core().has_fpu()
    }

    /// One-time cluster bring-up cost in seconds (activation + init +
    /// deactivation, Table II footnote: "around 1~1.3 ms"); zero for
    /// non-cluster targets.
    pub fn fixed_overhead_seconds(self) -> f64 {
        match self {
            Target::WolfCluster { .. } => 1.2e-3,
            _ => 0.0,
        }
    }

    /// Average power during the fixed-overhead phase.
    pub fn fixed_overhead_mw(self) -> f64 {
        match self {
            Target::WolfCluster { .. } => power::WOLF_CLUSTER.overhead_phase_mw,
            _ => 0.0,
        }
    }

    /// Stable machine-readable name used by the deploy-plan JSON, the
    /// bench JSON's emulated-target rows and the CLI (`--target` accepts
    /// every slug; `cli::parse_target` round-trips them to the *same*
    /// target, chip included). The paper's reference chip per core gets
    /// the bare slug; any other chip is suffixed with its lowercase name
    /// so two chips can never collapse to one slug.
    pub fn slug(self) -> String {
        fn suffixed(base: &str, canonical: Chip, chip: Chip) -> String {
            if chip == canonical {
                base.to_string()
            } else {
                format!("{base}-{}", chip.name().to_lowercase())
            }
        }
        match self {
            Target::CortexM4(chip) => suffixed("cortex-m4f", Chip::Stm32l475vg, chip),
            Target::CortexM7(chip) => suffixed("cortex-m7f", Chip::Stm32f769, chip),
            Target::CortexM0(chip) => suffixed("cortex-m0", Chip::Nrf52832, chip),
            Target::WolfFc => "wolf-fc".to_string(),
            Target::WolfCluster { cores } => format!("wolf-{}core", cores.clamp(1, 8)),
        }
    }

    /// Human-readable name (Table II column headings).
    pub fn label(self) -> String {
        match self {
            Target::CortexM4(chip) => format!("Cortex-M4 ({})", chip.name()),
            Target::CortexM7(chip) => format!("Cortex-M7 ({})", chip.name()),
            Target::CortexM0(chip) => format!("Cortex-M0 ({})", chip.name()),
            Target::WolfFc => "IBEX (Wolf FC)".to_string(),
            Target::WolfCluster { cores: 1 } => "Single-RI5CY".to_string(),
            Target::WolfCluster { cores } => format!("Multi-RI5CY ({cores})"),
        }
    }

    /// The four Table II columns.
    pub fn table2_targets() -> [Target; 4] {
        [
            Target::CortexM4(Chip::Nrf52832),
            Target::WolfFc,
            Target::WolfCluster { cores: 1 },
            Target::WolfCluster { cores: 8 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_targets_have_expected_cores() {
        let [m4, fc, s, m] = Target::table2_targets();
        assert_eq!(m4.core(), Core::CortexM4);
        assert_eq!(fc.core(), Core::Ibex);
        assert_eq!(s.core(), Core::Riscy);
        assert_eq!(m.num_cores(), 8);
    }

    #[test]
    fn cluster_cores_clamped() {
        assert_eq!(Target::WolfCluster { cores: 0 }.num_cores(), 1);
        assert_eq!(Target::WolfCluster { cores: 12 }.num_cores(), 8);
    }

    #[test]
    fn only_cluster_pays_activation() {
        assert_eq!(Target::WolfFc.fixed_overhead_seconds(), 0.0);
        assert!(Target::WolfCluster { cores: 8 }.fixed_overhead_seconds() > 0.0);
    }

    #[test]
    fn slugs_are_stable_and_unique() {
        use std::collections::HashSet;
        let targets = [
            Target::CortexM4(Chip::Stm32l475vg),
            Target::CortexM4(Chip::Nrf52832),
            Target::CortexM7(Chip::Stm32f769),
            Target::CortexM0(Chip::Nrf52832),
            Target::WolfFc,
            Target::WolfCluster { cores: 1 },
            Target::WolfCluster { cores: 8 },
        ];
        let slugs: HashSet<String> = targets.iter().map(|t| t.slug()).collect();
        assert_eq!(slugs.len(), targets.len(), "two targets share a slug");
        assert_eq!(Target::CortexM4(Chip::Stm32l475vg).slug(), "cortex-m4f");
        // Non-reference chips keep their identity in the slug.
        assert_eq!(Target::CortexM4(Chip::Nrf52832).slug(), "cortex-m4f-nrf52832");
        assert_eq!(Target::WolfCluster { cores: 8 }.slug(), "wolf-8core");
        assert_eq!(Target::WolfCluster { cores: 99 }.slug(), "wolf-8core");
    }

    #[test]
    fn float_support_follows_fpu() {
        assert!(Target::CortexM4(Chip::Nrf52832).supports_float());
        assert!(!Target::WolfFc.supports_float());
        assert!(Target::WolfCluster { cores: 1 }.supports_float());
    }

    #[test]
    fn frequencies_match_paper_operating_points() {
        assert_eq!(Target::CortexM4(Chip::Nrf52832).freq_hz(), 64.0e6);
        assert_eq!(Target::WolfFc.freq_hz(), 100.0e6);
    }

    #[test]
    fn m7_is_faster_per_mac_than_m4_but_hungrier() {
        use crate::targets::isa::DataType;
        let m7 = Target::CortexM7(Chip::Stm32f769);
        let m4 = Target::CortexM4(Chip::Stm32l475vg);
        assert!(
            m7.core().mac_cycles(DataType::Float32) < m4.core().mac_cycles(DataType::Float32)
        );
        assert!(m7.freq_hz() > m4.freq_hz());
        assert!(m7.active_mw() > m4.active_mw());
        assert!(m7.supports_float());
    }
}
