//! Power and energy model, fitted to the paper's Table II operating
//! points (Keysight N6705C measurements on InfiniWolf).
//!
//! | configuration                  | paper (app A) | model |
//! |--------------------------------|---------------|-------|
//! | nRF52832 Cortex-M4 @64 MHz     | 10.44 mW      | 10.5  |
//! | Mr. Wolf IBEX (FC) @100 MHz    | 10.75 mW      | 10.75 |
//! | Mr. Wolf 1× RI5CY @100 MHz     | 20.35 mW      | 20.3  |
//! | Mr. Wolf 8× RI5CY @100 MHz     | 61.79 mW      | 61.6  |
//! | cluster activation phase       | 11.88 mW      | 11.88 |
//!
//! Cluster power decomposes as `base + n_cores · per_core`; the base
//! covers the SoC domain + cluster infrastructure (interconnect, event
//! unit, shared FPUs, DMA). Table II's sub-sample-interval measurements
//! for apps B/C smear active power with idle time (the paper footnotes
//! the 0.1024 ms instrument resolution); our model reports true active
//! power, so B/C *power* columns differ from the paper while runtime and
//! energy *ratios* reproduce — EXPERIMENTS.md discusses this.

/// Power states of a single-core MCU (Cortex-M or FC).
#[derive(Debug, Clone, Copy)]
pub struct McuPower {
    /// Average power while computing.
    pub active_mw: f64,
    /// Deep-sleep power (retention on).
    pub sleep_mw: f64,
}

/// nRF52832 @64 MHz, DC/DC enabled.
pub const NRF52832_M4: McuPower = McuPower {
    active_mw: 10.5,
    sleep_mw: 0.0057, // 1.9 µA × 3 V system-on sleep
};

/// STM32L475VG @80 MHz.
pub const STM32L475: McuPower = McuPower {
    active_mw: 8.8,
    sleep_mw: 0.0042,
};

/// STM32F769 Cortex-M7 @216 MHz (datasheet run-mode typ.).
pub const STM32F769_M7: McuPower = McuPower {
    active_mw: 95.0,
    sleep_mw: 0.0090,
};

/// Mr. Wolf fabric controller @100 MHz.
pub const WOLF_FC: McuPower = McuPower {
    active_mw: 10.75,
    sleep_mw: 0.0072,
};

/// Mr. Wolf cluster power decomposition @100 MHz.
#[derive(Debug, Clone, Copy)]
pub struct ClusterPower {
    /// SoC domain + cluster infrastructure while the cluster is active.
    pub base_mw: f64,
    /// Incremental power per busy RI5CY core.
    pub per_core_mw: f64,
    /// Average power during cluster activation/init/deactivation.
    pub overhead_phase_mw: f64,
}

/// Mr. Wolf cluster power fit (Table II operating points).
pub const WOLF_CLUSTER: ClusterPower = ClusterPower {
    base_mw: 14.4,
    per_core_mw: 5.9,
    overhead_phase_mw: 11.88,
};

impl ClusterPower {
    /// Active power with `cores` busy cores at average utilization
    /// `util` ∈ [0, 1] (idle cores clock-gate at the barrier).
    pub fn active_mw(&self, cores: u32, util: f64) -> f64 {
        self.base_mw + self.per_core_mw * cores as f64 * util.clamp(0.0, 1.0)
    }
}

/// Energy of a phase: `seconds × milliwatts` in microjoules.
pub fn energy_uj(seconds: f64, milliwatts: f64) -> f64 {
    seconds * milliwatts * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_fit_matches_table2_app_a() {
        // 1 core: 20.35 mW, 8 cores: 61.79 mW (paper, app A).
        let single = WOLF_CLUSTER.active_mw(1, 1.0);
        let multi = WOLF_CLUSTER.active_mw(8, 1.0);
        assert!((single - 20.35).abs() < 0.1, "{single}");
        assert!((multi - 61.79).abs() < 0.6, "{multi}");
    }

    #[test]
    fn utilization_reduces_power() {
        let full = WOLF_CLUSTER.active_mw(8, 1.0);
        let half = WOLF_CLUSTER.active_mw(8, 0.5);
        assert!(half < full);
        assert!(half > WOLF_CLUSTER.base_mw);
    }

    #[test]
    fn table2_energy_reproduction_app_a() {
        // M4: 17.6 ms × 10.44 mW = 183.74 µJ (paper).
        let e = energy_uj(17.6e-3, 10.44);
        assert!((e - 183.74).abs() < 0.1);
        // Multi-RI5CY: 0.8 ms × 61.79 mW = 49.43 µJ (paper).
        let e = energy_uj(0.8e-3, 61.79);
        assert!((e - 49.43).abs() < 0.1);
    }

    #[test]
    fn sleep_far_below_active() {
        for p in [NRF52832_M4, STM32L475, WOLF_FC] {
            assert!(p.sleep_mw < p.active_mw / 100.0);
        }
    }
}
