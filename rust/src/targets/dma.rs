//! Cluster DMA engine model: L2 → L1 weight streaming with
//! double-buffering (Sec. IV-B of the paper).
//!
//! When a network (or a single layer) does not fit the 64 kB L1 TCDM, the
//! toolkit streams weights from L2 with the cluster's autonomous DMA,
//! overlapping the transfer of chunk *k+1* with the computation of chunk
//! *k* (ping-pong buffers). Two granularities exist:
//!
//! * **layer-wise** — the whole next layer's parameters in one transfer
//!   (possible while the largest layer fits half of L1);
//! * **neuron-wise** — one output neuron's weight row at a time (the
//!   fallback when even a single layer overflows L1).
//!
//! The model: a transfer of `n` bytes completes in
//! `setup + n / bytes_per_cycle` cycles; with double buffering the
//! *visible* cost per chunk is `setup + max(0, transfer - compute)` —
//! compute hides the bulk transfer but not the programming overhead.

/// DMA timing parameters (Mr. Wolf cluster DMA, 64-bit transfers).
#[derive(Debug, Clone, Copy)]
pub struct DmaModel {
    /// Cycles to program + trigger one transfer descriptor.
    pub setup_cycles: f64,
    /// Payload bandwidth in bytes per cycle.
    pub bytes_per_cycle: f64,
}

/// The Mr. Wolf cluster DMA timing fit.
pub const WOLF_DMA: DmaModel = DmaModel {
    setup_cycles: 30.0,
    bytes_per_cycle: 8.0,
};

impl DmaModel {
    /// Raw (un-overlapped) duration of one transfer.
    pub fn transfer_cycles(&self, bytes: usize) -> f64 {
        self.setup_cycles + bytes as f64 / self.bytes_per_cycle
    }

    /// Visible cost of one double-buffered chunk: the DMA programming is
    /// on the critical path; the payload is hidden behind `compute_cycles`
    /// of work on the previous chunk.
    pub fn overlapped_cost(&self, bytes: usize, compute_cycles: f64) -> f64 {
        let payload = bytes as f64 / self.bytes_per_cycle;
        self.setup_cycles + (payload - compute_cycles).max(0.0)
    }

    /// Stall produced by streaming `chunks` chunks of `chunk_bytes` each,
    /// where each chunk's payload can hide behind `compute_per_chunk`
    /// cycles of computation. The first chunk cannot be hidden (cold
    /// start).
    pub fn streaming_overhead(
        &self,
        chunks: usize,
        chunk_bytes: usize,
        compute_per_chunk: f64,
    ) -> f64 {
        if chunks == 0 {
            return 0.0;
        }
        let cold = self.transfer_cycles(chunk_bytes);
        let steady: f64 = (chunks - 1) as f64 * self.overlapped_cost(chunk_bytes, compute_per_chunk);
        cold + steady
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let d = WOLF_DMA;
        let t1 = d.transfer_cycles(800);
        let t2 = d.transfer_cycles(1600);
        assert!((t2 - t1 - 100.0).abs() < 1e-9); // +800 bytes @ 8 B/cyc
    }

    #[test]
    fn fully_hidden_when_compute_dominates() {
        let d = WOLF_DMA;
        // 304-byte neuron row (76 weights), 380 cycles of compute: only
        // the setup shows.
        assert_eq!(d.overlapped_cost(304, 380.0), d.setup_cycles);
    }

    #[test]
    fn stall_when_transfer_dominates() {
        let d = WOLF_DMA;
        let c = d.overlapped_cost(8000, 100.0); // 1000-cycle payload
        assert!((c - (30.0 + 900.0)).abs() < 1e-9);
    }

    #[test]
    fn streaming_cold_start_counts_once() {
        let d = WOLF_DMA;
        let one = d.streaming_overhead(1, 400, 1000.0);
        assert_eq!(one, d.transfer_cycles(400));
        let many = d.streaming_overhead(10, 400, 1000.0);
        assert_eq!(many, one + 9.0 * d.setup_cycles);
    }

    #[test]
    fn zero_chunks_zero_cost() {
        assert_eq!(WOLF_DMA.streaming_overhead(0, 100, 10.0), 0.0);
    }
}
