//! Instruction-level cost model of the inner dot-product loop — Table I
//! of the paper, plus the XPULP ISA-extension factors of Fig. 3.
//!
//! The paper's entire runtime story reduces to: *how many cycles does one
//! multiply-accumulate cost on this core, in this data type, with the
//! operands in this memory?* Table I gives the measured inner loops:
//!
//! | core            | float | fixed |
//! |-----------------|-------|-------|
//! | Cortex-M4       | 8     | 7     |
//! | RI5CY (XPULP)   | 5     | 5     |
//!
//! and the text calibrates IBEX (plain RV32IMC, no FPU, 2-cycle loads) at
//! ≈2.2× a RI5CY core, i.e. ~11 cycles/MAC fixed. Cortex-M0 has no DSP
//! extension and a slower memory path (~10 cycles/MAC fixed, soft-float
//! for float). These constants drive every figure reproduction; they are
//! the *model inputs*, taken from the paper, not outputs.

use crate::fann::activation::Activation;

/// Numeric type of a deployed network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataType {
    /// IEEE f32.
    Float32,
    /// Q(dec) fixed point in i32.
    Fixed,
}

/// Core microarchitectures the toolkit targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Core {
    /// ARM Cortex-M0/M0+: no DSP, no FPU.
    CortexM0,
    /// ARM Cortex-M4F: DSP + single-precision FPU.
    CortexM4,
    /// ARM Cortex-M7F: dual-issue, DSP + FPU (the family's top end).
    CortexM7,
    /// PULP fabric controller: IBEX, plain RV32IMC, no FPU.
    Ibex,
    /// PULP cluster core: RI5CY with XPULP extensions (+shared FPUs).
    Riscy,
}

impl Core {
    /// Cycles per multiply-accumulate in the inner loop (Table I),
    /// operands in the core's fastest data memory.
    pub fn mac_cycles(self, dtype: DataType) -> f64 {
        match (self, dtype) {
            // Table I, left two columns.
            (Core::CortexM4, DataType::Float32) => 8.0,
            (Core::CortexM4, DataType::Fixed) => 7.0,
            // M7: dual-issue pipeline pairs the loads with the MAC ops,
            // ~1.6x the M4's per-MAC throughput (ARM's published
            // CoreMark/DSP ratios).
            (Core::CortexM7, DataType::Float32) => 5.0,
            (Core::CortexM7, DataType::Fixed) => 4.5,
            // Table I, right two columns (5 single-cycle instructions).
            (Core::Riscy, DataType::Float32) => 5.0,
            (Core::Riscy, DataType::Fixed) => 5.0,
            // RV32IMC without post-increment loads or hardware loops:
            // 2-cycle loads on IBEX, explicit pointer/counter arithmetic,
            // taken branch — calibrated to the paper's ≈2.2× RI5CY gap.
            // (10.5 with operands in private L2; shared-L2 arbitration
            // adds `memspec::WolfMemory::shared_l2_penalty_per_word`.)
            (Core::Ibex, DataType::Fixed) => 10.5,
            // Soft-float emulation on IBEX (no FPU) — deployment on the
            // FC always uses the fixed-point path in practice.
            (Core::Ibex, DataType::Float32) => 40.0,
            // M0: 2-cycle loads, single-cycle mul (M0+), no DSP.
            (Core::CortexM0, DataType::Fixed) => 10.0,
            (Core::CortexM0, DataType::Float32) => 55.0,
        }
    }

    /// Whether the core has hardware float support (shared FPU counts).
    pub fn has_fpu(self) -> bool {
        matches!(self, Core::CortexM4 | Core::CortexM7 | Core::Riscy)
    }

    /// Fixed overhead per output neuron: loop prologue/epilogue, bias
    /// load, accumulator setup, output store.
    pub fn per_neuron_overhead(self) -> f64 {
        match self {
            Core::CortexM4 => 12.0,
            Core::CortexM7 => 10.0,
            Core::CortexM0 => 16.0,
            Core::Ibex => 14.0,
            Core::Riscy => 8.0, // hardware loop setup amortizes most of it
        }
    }

    /// Fixed overhead per layer: function call, pointer setup, buffer
    /// swap.
    pub fn per_layer_overhead(self) -> f64 {
        match self {
            Core::CortexM4 => 60.0,
            Core::CortexM7 => 55.0,
            Core::CortexM0 => 80.0,
            Core::Ibex => 70.0,
            Core::Riscy => 50.0,
        }
    }

    /// Cycles for one activation evaluation (step-linear approximation on
    /// MCUs; the FPU cores use the same table-based code in the paper's
    /// generated C).
    pub fn activation_cycles(self, act: Activation) -> f64 {
        let base = act.mcu_cycle_cost() as f64;
        match self {
            Core::CortexM0 => base * 1.5,
            _ => base,
        }
    }

    /// Display name of the extension rung.
    pub fn name(self) -> &'static str {
        match self {
            Core::CortexM0 => "Cortex-M0",
            Core::CortexM4 => "Cortex-M4",
            Core::CortexM7 => "Cortex-M7",
            Core::Ibex => "IBEX",
            Core::Riscy => "RI5CY",
        }
    }
}

/// XPULP ISA-extension toggles — the Fig. 3 ablation. `Core::Riscy`'s
/// 5 cycles/MAC is `ALL` (hw loop + post-increment); SIMD further packs
/// 2 (16-bit) or 4 (8-bit) MACs per instruction via `pv.sdotsp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IsaExtensions {
    /// Zero-overhead hardware loops (`lp.setup`).
    pub hardware_loop: bool,
    /// Post-incrementing loads/stores.
    pub post_increment: bool,
    /// SIMD lanes packed per MAC instruction: 1 (off), 2 (16-bit), 4 (8-bit).
    pub simd_lanes: u8,
}

impl IsaExtensions {
    /// Plain RV32IMC (the Fig. 3 baseline).
    pub const BASELINE_RV32IMC: Self = Self {
        hardware_loop: false,
        post_increment: false,
        simd_lanes: 1,
    };
    /// XPULP loops + post-increment, SIMD off.
    pub const XPULP_NO_SIMD: Self = Self {
        hardware_loop: true,
        post_increment: true,
        simd_lanes: 1,
    };
    /// XPULP with 2-lane (16-bit) SIMD dot products.
    pub const XPULP_SIMD2: Self = Self {
        hardware_loop: true,
        post_increment: true,
        simd_lanes: 2,
    };
    /// XPULP with 4-lane (8-bit) SIMD dot products.
    pub const XPULP_SIMD4: Self = Self {
        hardware_loop: true,
        post_increment: true,
        simd_lanes: 4,
    };

    /// Cycles per MAC on a RISC-V core with this extension set (fixed
    /// point). Reproduces the Fig. 3 ladder: baseline 11 → ~2× with
    /// hw-loop + post-increment → ~10× with packed 8-bit SIMD.
    pub fn mac_cycles(self) -> f64 {
        // Baseline RV32IMC inner loop: lw(2) lw(2) mul add sra addi addi
        // addi(counter) bne(2) = 11 (IBEX-like 2-cycle loads).
        let mut cycles = 11.0;
        if self.hardware_loop {
            // drop counter addi + taken bne
            cycles -= 3.0;
        }
        if self.post_increment {
            // drop the two pointer addis; p.lw is single-cycle on RI5CY
            cycles -= 2.0 + 2.0 * 0.5;
        }
        // With both: 11 - 3 - 3 = 5  (Table I right column).
        cycles / self.simd_lanes as f64
    }

    /// Speedup over the RV32IMC baseline (the Fig. 3 y-axis).
    pub fn speedup_vs_baseline(self) -> f64 {
        Self::BASELINE_RV32IMC.mac_cycles() / self.mac_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_inner_loop_constants() {
        assert_eq!(Core::CortexM4.mac_cycles(DataType::Float32), 8.0);
        assert_eq!(Core::CortexM4.mac_cycles(DataType::Fixed), 7.0);
        assert_eq!(Core::Riscy.mac_cycles(DataType::Float32), 5.0);
        assert_eq!(Core::Riscy.mac_cycles(DataType::Fixed), 5.0);
    }

    #[test]
    fn paper_cycle_ratios_hold() {
        // Sec. V-B: "the ratio of the cycle counts between the Cortex-M
        // and single-core RI5CY implementations match the expected 7/5
        // and 8/5 factors for fixed/float".
        let f = Core::CortexM4.mac_cycles(DataType::Fixed) / Core::Riscy.mac_cycles(DataType::Fixed);
        let fl =
            Core::CortexM4.mac_cycles(DataType::Float32) / Core::Riscy.mac_cycles(DataType::Float32);
        assert!((f - 7.0 / 5.0).abs() < 1e-9);
        assert!((fl - 8.0 / 5.0).abs() < 1e-9);
    }

    #[test]
    fn riscy_vs_ibex_factor_matches_fig9a() {
        // Fig. 9a: up to 2.2x speedup of RI5CY over IBEX.
        let s = Core::Ibex.mac_cycles(DataType::Fixed) / Core::Riscy.mac_cycles(DataType::Fixed);
        assert!((2.0..=2.4).contains(&s), "{s}");
    }

    #[test]
    fn fig3_extension_ladder() {
        assert_eq!(IsaExtensions::BASELINE_RV32IMC.mac_cycles(), 11.0);
        // hw loop + post-increment: ~2x (paper Fig. 3).
        let s = IsaExtensions::XPULP_NO_SIMD.speedup_vs_baseline();
        assert!((1.9..=2.3).contains(&s), "{s}");
        // packed 8-bit SIMD: ~10x.
        let s4 = IsaExtensions::XPULP_SIMD4.speedup_vs_baseline();
        assert!((8.0..=10.5).contains(&s4), "{s4}");
        // monotone ladder
        assert!(
            IsaExtensions::XPULP_SIMD2.speedup_vs_baseline() > s
                && s4 > IsaExtensions::XPULP_SIMD2.speedup_vs_baseline()
        );
    }

    #[test]
    fn xpulp_no_simd_matches_riscy_core_model() {
        assert_eq!(
            IsaExtensions::XPULP_NO_SIMD.mac_cycles(),
            Core::Riscy.mac_cycles(DataType::Fixed)
        );
    }

    #[test]
    fn fpu_flags() {
        assert!(Core::CortexM4.has_fpu());
        assert!(Core::Riscy.has_fpu());
        assert!(!Core::Ibex.has_fpu());
        assert!(!Core::CortexM0.has_fpu());
    }
}
