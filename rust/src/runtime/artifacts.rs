//! Artifact registry: locates and describes the AOT outputs of
//! `python/compile/aot.py` (`make artifacts`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Parsed `<name>_manifest.txt` — the arg-shape contract between the
/// L2 lowering and the Rust runtime.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Topology name (`xor`, `gesture`, ...).
    pub name: String,
    /// Input feature count.
    pub inputs: usize,
    /// Output unit count.
    pub outputs: usize,
    /// Hidden layer widths.
    pub hidden: Vec<usize>,
    /// Hidden activation name.
    pub hidden_activation: String,
    /// Output activation name.
    pub output_activation: String,
    /// SGD learning rate baked into the train step.
    pub learning_rate: f32,
    /// Batch sizes the forward pass was lowered at.
    pub fwd_batches: Vec<usize>,
    /// Batch size the train step was lowered at.
    pub train_batch: usize,
    /// Multiply-accumulates per inference.
    pub macs: usize,
    /// Total trainable parameters.
    pub num_params: usize,
}

impl Manifest {
    /// Layer sizes `[in, hidden..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut v = vec![self.inputs];
        v.extend(&self.hidden);
        v.push(self.outputs);
        v
    }

    /// Parse the `key value` manifest text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Manifest {
            name: String::new(),
            inputs: 0,
            outputs: 0,
            hidden: Vec::new(),
            hidden_activation: String::new(),
            output_activation: String::new(),
            learning_rate: 0.0,
            fwd_batches: Vec::new(),
            train_batch: 0,
            macs: 0,
            num_params: 0,
        };
        for line in text.lines() {
            let (key, val) = match line.split_once(' ') {
                Some(kv) => kv,
                None => (line, ""),
            };
            match key {
                "name" => m.name = val.to_string(),
                "inputs" => m.inputs = val.parse()?,
                "outputs" => m.outputs = val.parse()?,
                "hidden" => {
                    m.hidden = val
                        .split_whitespace()
                        .map(|v| v.parse().context("bad hidden size"))
                        .collect::<Result<_>>()?
                }
                "hidden_activation" => m.hidden_activation = val.to_string(),
                "output_activation" => m.output_activation = val.to_string(),
                "learning_rate" => m.learning_rate = val.parse()?,
                "fwd_batches" => {
                    m.fwd_batches = val
                        .split_whitespace()
                        .map(|v| v.parse().context("bad batch"))
                        .collect::<Result<_>>()?
                }
                "train_batch" => m.train_batch = val.parse()?,
                "macs" => m.macs = val.parse()?,
                "num_params" => m.num_params = val.parse()?,
                _ => bail!("unknown manifest key {key:?}"),
            }
        }
        if m.name.is_empty() || m.inputs == 0 {
            bail!("incomplete manifest");
        }
        Ok(m)
    }
}

/// Handle to an artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    /// Directory holding the `*.hlo.txt` / manifest files.
    pub root: PathBuf,
}

impl ArtifactDir {
    /// Locate `artifacts/` relative to the crate root (or a caller-
    /// supplied override, e.g. the CLI's `--artifacts` flag).
    pub fn locate(override_path: Option<&Path>) -> Result<Self> {
        let root = match override_path {
            Some(p) => p.to_path_buf(),
            None => {
                let candidates = [
                    PathBuf::from("artifacts"),
                    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
                ];
                candidates
                    .into_iter()
                    .find(|p| p.is_dir())
                    .context("artifacts/ not found: run `make artifacts` first")?
            }
        };
        if !root.is_dir() {
            bail!("artifact directory {} does not exist", root.display());
        }
        Ok(Self { root })
    }

    /// Load and parse the manifest of topology `name`.
    pub fn manifest(&self, name: &str) -> Result<Manifest> {
        let path = self.root.join(format!("{name}_manifest.txt"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    /// Path of the forward-pass HLO lowered at `batch`.
    pub fn forward_hlo(&self, name: &str, batch: usize) -> PathBuf {
        self.root.join(format!("{name}_fwd_b{batch}.hlo.txt"))
    }

    /// Path of the train-step HLO lowered at `batch`.
    pub fn train_hlo(&self, name: &str, batch: usize) -> PathBuf {
        self.root.join(format!("{name}_train_b{batch}.hlo.txt"))
    }

    /// Path of a golden parity TSV (`weights`, `forward`, ...).
    pub fn parity_file(&self, which: &str) -> PathBuf {
        self.root.join(format!("parity_{which}.tsv"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name fall\ninputs 117\noutputs 2\nhidden 20\nhidden_activation tanh\noutput_activation sigmoid\nlearning_rate 0.1\nfwd_batches 1 32\ntrain_batch 32\nmacs 2380\nnum_params 2402\n";

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "fall");
        assert_eq!(m.layer_sizes(), vec![117, 20, 2]);
        assert_eq!(m.fwd_batches, vec![1, 32]);
        assert_eq!(m.macs, 2380);
    }

    #[test]
    fn manifest_rejects_unknown_keys() {
        assert!(Manifest::parse("bogus 1\n").is_err());
        assert!(Manifest::parse("").is_err());
    }

    #[test]
    fn artifact_paths() {
        let dir = ArtifactDir {
            root: PathBuf::from("/tmp/a"),
        };
        assert_eq!(
            dir.forward_hlo("xor", 1),
            PathBuf::from("/tmp/a/xor_fwd_b1.hlo.txt")
        );
        assert_eq!(
            dir.train_hlo("xor", 32),
            PathBuf::from("/tmp/a/xor_train_b32.hlo.txt")
        );
    }
}
