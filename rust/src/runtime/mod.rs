//! PJRT runtime: the bridge to the AOT-compiled L2/L1 programs.
//!
//! [`client`] wraps the `xla` crate (PJRT CPU); [`artifacts`] locates and
//! describes `artifacts/*.hlo.txt`; [`trainer`] drives the AOT training
//! step from Rust (the end-to-end example's training loop).

pub mod artifacts;
pub mod client;
pub mod trainer;

pub use artifacts::{ArtifactDir, Manifest};
pub use client::{CompiledModel, Runtime};
pub use trainer::PjrtTrainer;
