//! PJRT runtime: the bridge to the AOT-compiled L2/L1 programs.
//!
//! [`artifacts`] locates and describes `artifacts/*.hlo.txt` and is
//! always compiled (it is plain file parsing, used by the parity tests
//! and the CLI's `info` command). The executing half — `client`
//! wrapping the `xla` crate (PJRT CPU) and `trainer` driving the AOT
//! training step — is gated behind the off-by-default `pjrt` feature so
//! the tier-1 build needs neither an XLA install nor network access.
//! The offline build wires `--features pjrt` to a stub `xla` crate that
//! compiles everywhere and errors at runtime; point `rust/Cargo.toml`
//! at the real `xla` crate to actually execute artifacts.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use artifacts::{ArtifactDir, Manifest};
#[cfg(feature = "pjrt")]
pub use client::{CompiledModel, Runtime};
#[cfg(feature = "pjrt")]
pub use trainer::PjrtTrainer;
