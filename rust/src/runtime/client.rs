//! PJRT client wrapper: loads AOT-lowered HLO text and executes it.
//!
//! This is the only place the process touches XLA. HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized
//! protos with 64-bit instruction ids; the text parser reassigns ids —
//! see /opt/xla-example/README.md and python/compile/aot.py).

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled HLO program ready to execute on the CPU PJRT client.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    /// Name the model was compiled under (for diagnostics).
    pub name: String,
}

/// Process-wide PJRT CPU client + compilation cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledModel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl CompiledModel {
    /// Execute with f32 tensor arguments given as `(shape, data)` pairs;
    /// returns the flat f32 contents of every tuple element (the AOT
    /// pipeline lowers with `return_tuple=True`).
    pub fn run_f32(&self, args: &[(&[i64], &[f32])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|(shape, data)| {
                let lit = xla::Literal::vec1(data);
                if shape.len() == 1 {
                    Ok(lit)
                } else {
                    lit.reshape(shape).context("reshaping argument")
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing PJRT program")?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let elems = root.to_tuple().context("untupling result")?;
        elems
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need artifacts live in rust/tests/
    // integration_runtime.rs (they require `make artifacts` to have run).
    use super::*;

    #[test]
    fn cpu_client_comes_up_or_reports_stub() {
        // Against real xla this must produce a CPU client; against the
        // offline vendor stub it must fail loudly (never hang or panic).
        match Runtime::cpu() {
            Ok(rt) => assert!(rt.platform().to_lowercase().contains("cpu")),
            Err(e) => assert!(
                format!("{e:?}").contains("stub"),
                "unexpected PJRT error: {e:?}"
            ),
        }
    }

    #[test]
    fn missing_file_is_error() {
        if let Ok(rt) = Runtime::cpu() {
            assert!(rt.load_hlo_text(Path::new("/nonexistent.hlo.txt")).is_err());
        }
    }
}
