//! PJRT training driver: runs the AOT-lowered SGD step (`*_train_b32`)
//! from Rust — the L3 coordinator training loop. Python authored the
//! program once at `make artifacts`; it never runs here.

use anyhow::{ensure, Context, Result};

use super::artifacts::{ArtifactDir, Manifest};
use super::client::{CompiledModel, Runtime};
use crate::fann::{Activation, Network, TrainData};
use crate::util::rng::Rng;

/// Flat parameter buffers in the AOT calling convention
/// `[w0, b0, w1, b1, ..., x(, y)]`; `w_l` is `(n_in, n_out)` row-major
/// over `n_in` (JAX layout).
pub struct PjrtTrainer {
    /// The artifact manifest this trainer was built from.
    pub manifest: Manifest,
    train_step: CompiledModel,
    fwd1: CompiledModel,
    fwd_batch: CompiledModel,
    /// `(shape, data)` per parameter tensor.
    params: Vec<(Vec<i64>, Vec<f32>)>,
}

impl PjrtTrainer {
    /// Load the artifacts for `name` and initialize parameters
    /// (Glorot-uniform, seeded — mirrors `model.init_params`).
    pub fn new(rt: &Runtime, art: &ArtifactDir, name: &str, seed: u64) -> Result<Self> {
        let manifest = art.manifest(name)?;
        let train_step = rt
            .load_hlo_text(&art.train_hlo(name, manifest.train_batch))
            .context("loading train step")?;
        let fwd1 = rt
            .load_hlo_text(&art.forward_hlo(name, 1))
            .context("loading fwd_b1")?;
        let batch = *manifest
            .fwd_batches
            .iter()
            .max()
            .context("no fwd batches")?;
        let fwd_batch = rt
            .load_hlo_text(&art.forward_hlo(name, batch))
            .context("loading batched fwd")?;

        let mut rng = Rng::new(seed);
        let sizes = manifest.layer_sizes();
        let mut params = Vec::new();
        for w in sizes.windows(2) {
            let (n_in, n_out) = (w[0], w[1]);
            let limit = (6.0 / (n_in + n_out) as f32).sqrt();
            let weights: Vec<f32> = (0..n_in * n_out)
                .map(|_| rng.range_f32(-limit, limit))
                .collect();
            params.push((vec![n_in as i64, n_out as i64], weights));
            params.push((vec![n_out as i64], vec![0.0; n_out]));
        }
        Ok(Self {
            manifest,
            train_step,
            fwd1,
            fwd_batch,
            params,
        })
    }

    /// Batch size of the batched forward executable.
    pub fn eval_batch(&self) -> usize {
        *self.manifest.fwd_batches.iter().max().unwrap()
    }

    /// One SGD step on a `(train_batch, inputs)` / `(train_batch,
    /// outputs)` minibatch; updates the parameters in place and returns
    /// the loss.
    pub fn step(&mut self, x: &[f32], y: &[f32]) -> Result<f32> {
        let b = self.manifest.train_batch;
        ensure!(x.len() == b * self.manifest.inputs, "bad x length");
        ensure!(y.len() == b * self.manifest.outputs, "bad y length");

        let x_shape = [b as i64, self.manifest.inputs as i64];
        let y_shape = [b as i64, self.manifest.outputs as i64];
        let mut args: Vec<(&[i64], &[f32])> = self
            .params
            .iter()
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .collect();
        args.push((&x_shape, x));
        args.push((&y_shape, y));

        let mut out = self.train_step.run_f32(&args)?;
        ensure!(
            out.len() == self.params.len() + 1,
            "train step returned {} tensors, want {}",
            out.len(),
            self.params.len() + 1
        );
        let loss = out.pop().unwrap();
        for (slot, new) in self.params.iter_mut().zip(out) {
            slot.1 = new;
        }
        Ok(loss[0])
    }

    /// Train for `steps` minibatches cycling through `data`; returns the
    /// per-step loss curve.
    pub fn train(&mut self, data: &TrainData, steps: usize, rng: &mut Rng) -> Result<Vec<f32>> {
        let b = self.manifest.train_batch;
        ensure!(data.num_inputs == self.manifest.inputs, "input dim mismatch");
        ensure!(data.num_outputs == self.manifest.outputs, "output dim mismatch");
        ensure!(data.len() >= 1, "empty dataset");

        let mut curve = Vec::with_capacity(steps);
        let mut x = vec![0.0f32; b * data.num_inputs];
        let mut y = vec![0.0f32; b * data.num_outputs];
        for _ in 0..steps {
            for j in 0..b {
                let i = rng.below(data.len());
                x[j * data.num_inputs..(j + 1) * data.num_inputs]
                    .copy_from_slice(data.input(i));
                y[j * data.num_outputs..(j + 1) * data.num_outputs]
                    .copy_from_slice(data.target(i));
            }
            curve.push(self.step(&x, &y)?);
        }
        Ok(curve)
    }

    /// Single-sample forward through the `fwd_b1` executable.
    pub fn forward1(&self, input: &[f32]) -> Result<Vec<f32>> {
        ensure!(input.len() == self.manifest.inputs, "bad input length");
        let x_shape = [1i64, self.manifest.inputs as i64];
        let mut args: Vec<(&[i64], &[f32])> = self
            .params
            .iter()
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .collect();
        args.push((&x_shape, input));
        let out = self.fwd1.run_f32(&args)?;
        Ok(out.into_iter().next().context("empty forward output")?)
    }

    /// Classification accuracy over `data`, evaluated in PJRT batches.
    pub fn accuracy(&self, data: &TrainData) -> Result<f32> {
        let b = self.eval_batch();
        let mut correct = 0usize;
        let mut x = vec![0.0f32; b * data.num_inputs];
        let mut i = 0;
        while i < data.len() {
            for j in 0..b {
                // pad the tail batch by repeating the last sample (padded
                // rows are skipped when counting below)
                let k = (i + j).min(data.len() - 1);
                x[j * data.num_inputs..(j + 1) * data.num_inputs]
                    .copy_from_slice(data.input(k));
            }
            let x_shape = [b as i64, self.manifest.inputs as i64];
            let mut args: Vec<(&[i64], &[f32])> = self
                .params
                .iter()
                .map(|(s, d)| (s.as_slice(), d.as_slice()))
                .collect();
            args.push((&x_shape, &x));
            let out = &self.fwd_batch.run_f32(&args)?[0];
            let no = self.manifest.outputs;
            for j in 0..b {
                let k = i + j;
                if k >= data.len() {
                    break;
                }
                let row = &out[j * no..(j + 1) * no];
                let pred = if no == 1 {
                    usize::from(row[0] >= 0.5)
                } else {
                    crate::util::argmax(row)
                };
                if pred == data.label(k) {
                    correct += 1;
                }
            }
            i += b;
        }
        Ok(correct as f32 / data.len() as f32)
    }

    /// Export the trained parameters as a [`Network`] so the deployment
    /// toolkit can quantize/place/simulate it. Transposes the JAX
    /// `(in, out)` weight layout to FANN's per-neuron rows.
    pub fn to_network(&self) -> Result<Network> {
        let sizes = self.manifest.layer_sizes();
        let hidden = Activation::parse(&self.manifest.hidden_activation)?;
        let output = Activation::parse(&self.manifest.output_activation)?;
        let mut net = Network::new(&sizes, hidden, output)?;
        for (l, w) in sizes.windows(2).enumerate() {
            let (n_in, n_out) = (w[0], w[1]);
            let jax_w = &self.params[2 * l].1;
            let jax_b = &self.params[2 * l + 1].1;
            let layer = &mut net.layers[l];
            for o in 0..n_out {
                for i in 0..n_in {
                    layer.weights[o * n_in + i] = jax_w[i * n_out + o];
                }
            }
            layer.biases.copy_from_slice(jax_b);
        }
        Ok(net)
    }
}

// Integration tests for the trainer (which need `make artifacts`) live in
// rust/tests/integration_runtime.rs.
