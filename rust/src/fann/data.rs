//! Training data container + the FANN `.data` text format.
//!
//! FANN's format (one header line, then alternating input/output lines):
//!
//! ```text
//! <num_samples> <num_inputs> <num_outputs>
//! <in_0> <in_1> ... <in_{I-1}>
//! <out_0> ... <out_{O-1}>
//! ...
//! ```

use anyhow::{bail, ensure, Context, Result};

use crate::util::rng::Rng;

/// A supervised dataset: `inputs` is row-major `[n][num_inputs]`,
/// `targets` is `[n][num_outputs]`.
#[derive(Debug, Clone)]
pub struct TrainData {
    /// Features per sample.
    pub num_inputs: usize,
    /// Target values per sample.
    pub num_outputs: usize,
    /// All inputs, row-major `[len][num_inputs]`.
    pub inputs: Vec<f32>,
    /// All targets, row-major `[len][num_outputs]`.
    pub targets: Vec<f32>,
}

impl TrainData {
    /// Empty dataset with the given row shapes.
    pub fn new(num_inputs: usize, num_outputs: usize) -> Self {
        Self {
            num_inputs,
            num_outputs,
            inputs: Vec::new(),
            targets: Vec::new(),
        }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        if self.num_inputs == 0 {
            0
        } else {
            self.inputs.len() / self.num_inputs
        }
    }

    /// Whether the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one `(input, target)` sample.
    pub fn push(&mut self, input: &[f32], target: &[f32]) {
        assert_eq!(input.len(), self.num_inputs);
        assert_eq!(target.len(), self.num_outputs);
        self.inputs.extend_from_slice(input);
        self.targets.extend_from_slice(target);
    }

    /// Input row of sample `i`.
    pub fn input(&self, i: usize) -> &[f32] {
        &self.inputs[i * self.num_inputs..(i + 1) * self.num_inputs]
    }

    /// Target row of sample `i`.
    pub fn target(&self, i: usize) -> &[f32] {
        &self.targets[i * self.num_outputs..(i + 1) * self.num_outputs]
    }

    /// Class label of sample `i` (argmax of the one-hot target; for a
    /// single sigmoid output, thresholds at 0.5).
    pub fn label(&self, i: usize) -> usize {
        let t = self.target(i);
        if self.num_outputs == 1 {
            usize::from(t[0] >= 0.5)
        } else {
            crate::util::argmax(t)
        }
    }

    /// Shuffle samples in place (paired input/target rows).
    pub fn shuffle(&mut self, rng: &mut Rng) {
        let n = self.len();
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            if i != j {
                for k in 0..self.num_inputs {
                    self.inputs.swap(i * self.num_inputs + k, j * self.num_inputs + k);
                }
                for k in 0..self.num_outputs {
                    self.targets
                        .swap(i * self.num_outputs + k, j * self.num_outputs + k);
                }
            }
        }
    }

    /// Split into (train, test) with the first `frac` fraction as train.
    pub fn split(&self, frac: f64) -> (TrainData, TrainData) {
        let n_train = ((self.len() as f64) * frac).round() as usize;
        let mut train = TrainData::new(self.num_inputs, self.num_outputs);
        let mut test = TrainData::new(self.num_inputs, self.num_outputs);
        for i in 0..self.len() {
            let dst = if i < n_train { &mut train } else { &mut test };
            dst.push(self.input(i), self.target(i));
        }
        (train, test)
    }

    /// Per-feature min/max scaling to [-1, 1] (the paper rescales inputs
    /// before fixed-point conversion). Returns the (min, max) per feature
    /// so the deployment target can apply the same scaling.
    pub fn normalize_inputs(&mut self) -> Vec<(f32, f32)> {
        let n = self.len();
        let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); self.num_inputs];
        for i in 0..n {
            for (k, &v) in self.input(i).iter().enumerate() {
                ranges[k].0 = ranges[k].0.min(v);
                ranges[k].1 = ranges[k].1.max(v);
            }
        }
        for i in 0..n {
            for k in 0..self.num_inputs {
                let (lo, hi) = ranges[k];
                let v = &mut self.inputs[i * self.num_inputs + k];
                *v = if hi > lo { 2.0 * (*v - lo) / (hi - lo) - 1.0 } else { 0.0 };
            }
        }
        ranges
    }

    /// Serialize to the FANN `.data` text format.
    pub fn to_fann_format(&self) -> String {
        let mut out = format!("{} {} {}\n", self.len(), self.num_inputs, self.num_outputs);
        for i in 0..self.len() {
            let line = |xs: &[f32]| {
                xs.iter()
                    .map(|v| format!("{v}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            out.push_str(&line(self.input(i)));
            out.push('\n');
            out.push_str(&line(self.target(i)));
            out.push('\n');
        }
        out
    }

    /// Parse the FANN `.data` text format.
    pub fn from_fann_format(text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty .data file")?;
        let mut it = header.split_whitespace();
        let n: usize = it.next().context("missing count")?.parse()?;
        let ni: usize = it.next().context("missing num_inputs")?.parse()?;
        let no: usize = it.next().context("missing num_outputs")?.parse()?;
        let mut data = TrainData::new(ni, no);
        for s in 0..n {
            let parse_line = |line: &str, want: usize| -> Result<Vec<f32>> {
                let vals: Vec<f32> = line
                    .split_whitespace()
                    .map(|v| v.parse::<f32>().context("bad number"))
                    .collect::<Result<_>>()?;
                ensure!(vals.len() == want, "expected {want} values, got {}", vals.len());
                Ok(vals)
            };
            let Some(in_line) = lines.next() else {
                bail!("truncated .data file at sample {s}");
            };
            let Some(out_line) = lines.next() else {
                bail!("truncated .data file at sample {s}");
            };
            data.push(&parse_line(in_line, ni)?, &parse_line(out_line, no)?);
        }
        Ok(data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainData {
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        d
    }

    #[test]
    fn fann_format_roundtrip() {
        let d = sample();
        let text = d.to_fann_format();
        let back = TrainData::from_fann_format(&text).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back.input(2), d.input(2));
        assert_eq!(back.target(3), d.target(3));
    }

    #[test]
    fn rejects_truncated() {
        assert!(TrainData::from_fann_format("2 2 1\n0 0\n0\n1 1\n").is_err());
        assert!(TrainData::from_fann_format("1 2 1\n0\n0\n").is_err());
    }

    #[test]
    fn shuffle_preserves_pairs() {
        let mut d = TrainData::new(1, 1);
        for i in 0..32 {
            d.push(&[i as f32], &[i as f32 * 10.0]);
        }
        let mut rng = Rng::new(11);
        d.shuffle(&mut rng);
        for i in 0..32 {
            assert_eq!(d.target(i)[0], d.input(i)[0] * 10.0);
        }
    }

    #[test]
    fn split_sizes() {
        let d = sample();
        let (tr, te) = d.split(0.75);
        assert_eq!(tr.len(), 3);
        assert_eq!(te.len(), 1);
    }

    #[test]
    fn normalize_bounds() {
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 10.0], &[0.0]);
        d.push(&[5.0, 20.0], &[1.0]);
        d.push(&[10.0, 30.0], &[1.0]);
        let ranges = d.normalize_inputs();
        assert_eq!(ranges[0], (0.0, 10.0));
        for i in 0..d.len() {
            for &v in d.input(i) {
                assert!((-1.0..=1.0).contains(&v));
            }
        }
        assert_eq!(d.input(1)[0], 0.0); // midpoint maps to 0
    }

    #[test]
    fn label_argmax_and_threshold() {
        let mut d = TrainData::new(1, 3);
        d.push(&[0.0], &[0.0, 1.0, 0.0]);
        assert_eq!(d.label(0), 1);
        let s = sample();
        assert_eq!(s.label(0), 0);
        assert_eq!(s.label(1), 1);
    }
}
