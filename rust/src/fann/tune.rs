//! FANNTool-style automatic hyper-parameter selection (paper Sec. II-B:
//! "fully-automated selection of the network's hyperparameters by
//! iteratively testing all the available options present in FANN").
//!
//! Grid search over hidden width, activation, and trainer with a
//! train/validation split; optionally constrained by a deployment
//! memory budget so the winner is guaranteed to fit the target MCU —
//! the toolkit-specific twist on FANNTool.

use anyhow::Result;

use super::activation::Activation;
use super::data::TrainData;
use super::net::Network;
use super::train::backprop::{Batch, BackpropConfig, Incremental};
use super::train::rprop::{Rprop, RpropConfig};
use super::train::{accuracy, mse};
use crate::deploy::{estimate_memory, NetShape};
use crate::util::rng::Rng;

/// Trainer choices the search iterates over (FANN's training algorithms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerKind {
    /// iRPROP- (FANN_TRAIN_RPROP, the library default).
    Rprop,
    /// Full-batch gradient descent (FANN_TRAIN_BATCH).
    Batch,
    /// Per-sample gradient descent (FANN_TRAIN_INCREMENTAL).
    Incremental,
}

impl TrainerKind {
    /// Every trainer the search can pick from.
    pub const ALL: [TrainerKind; 3] =
        [TrainerKind::Rprop, TrainerKind::Batch, TrainerKind::Incremental];

    /// Stable lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            TrainerKind::Rprop => "rprop",
            TrainerKind::Batch => "batch",
            TrainerKind::Incremental => "incremental",
        }
    }
}

/// Search space definition.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Candidate hidden-layer widths (single hidden layer, FANNTool's
    /// default exploration shape).
    pub hidden_widths: Vec<usize>,
    /// Hidden activations the search tries.
    pub hidden_activations: Vec<Activation>,
    /// Trainers the search tries.
    pub trainers: Vec<TrainerKind>,
    /// Training epochs per trial.
    pub epochs: usize,
    /// Optional Eq. (2) memory cap in bytes (configurations whose
    /// estimate exceeds it are skipped).
    pub memory_budget: Option<usize>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        Self {
            hidden_widths: vec![4, 8, 16, 32],
            hidden_activations: vec![Activation::Tanh, Activation::Sigmoid],
            trainers: vec![TrainerKind::Rprop, TrainerKind::Batch],
            epochs: 60,
            memory_budget: None,
        }
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct TrialResult {
    /// Hidden-layer width of the trial.
    pub hidden: usize,
    /// Hidden activation of the trial.
    pub activation: Activation,
    /// Trainer used by the trial.
    pub trainer: TrainerKind,
    /// Validation MSE after training.
    pub val_mse: f32,
    /// Validation accuracy after training.
    pub val_accuracy: f32,
    /// Eq. (2) memory estimate of the trial topology.
    pub est_memory: usize,
}

/// Search outcome: best network + the full trial table.
pub struct TuneResult {
    /// The winning trained network.
    pub best: Network,
    /// Metrics of the winning trial.
    pub best_trial: TrialResult,
    /// Every evaluated trial, in search order.
    pub trials: Vec<TrialResult>,
}

fn train_one(
    kind: TrainerKind,
    net: &mut Network,
    data: &TrainData,
    epochs: usize,
) {
    match kind {
        TrainerKind::Rprop => {
            let mut t = Rprop::new(net, RpropConfig::default());
            for _ in 0..epochs {
                t.train_epoch(net, data);
            }
        }
        TrainerKind::Batch => {
            let mut t = Batch::new(net, BackpropConfig { learning_rate: 0.3, momentum: 0.0 });
            for _ in 0..epochs {
                t.train_epoch(net, data);
            }
        }
        TrainerKind::Incremental => {
            let mut t = Incremental::new(
                net,
                BackpropConfig { learning_rate: 0.1, momentum: 0.1 },
            );
            for _ in 0..epochs {
                t.train_epoch(net, data);
            }
        }
    }
}

/// Run the grid search. `data` is split 80/20 into train/validation;
/// selection is by validation MSE (FANNTool's criterion).
pub fn tune(data: &TrainData, space: &SearchSpace, seed: u64) -> Result<TuneResult> {
    let (train, val) = data.split(0.8);
    let mut trials = Vec::new();
    let mut best: Option<(Network, TrialResult)> = None;

    for &hidden in &space.hidden_widths {
        for &act in &space.hidden_activations {
            let shape = NetShape::new(&[data.num_inputs, hidden, data.num_outputs]);
            let est = estimate_memory(&shape, crate::targets::DataType::Fixed);
            if let Some(budget) = space.memory_budget {
                if est > budget {
                    continue;
                }
            }
            for &trainer in &space.trainers {
                let mut rng = Rng::new(seed ^ (hidden as u64) << 8 ^ trainer as u64);
                let mut net = Network::new(
                    &[data.num_inputs, hidden, data.num_outputs],
                    act,
                    Activation::Sigmoid,
                )?;
                net.randomize(&mut rng, None);
                train_one(trainer, &mut net, &train, space.epochs);
                let trial = TrialResult {
                    hidden,
                    activation: act,
                    trainer,
                    val_mse: mse(&net, &val),
                    val_accuracy: accuracy(&net, &val),
                    est_memory: est,
                };
                let better = match &best {
                    None => true,
                    Some((_, b)) => trial.val_mse < b.val_mse,
                };
                if better {
                    best = Some((net, trial.clone()));
                }
                trials.push(trial);
            }
        }
    }

    let (best, best_trial) =
        best.ok_or_else(|| anyhow::anyhow!("no configuration fits the memory budget"))?;
    Ok(TuneResult {
        best,
        best_trial,
        trials,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    #[test]
    fn tune_finds_working_activity_config() {
        let mut data = datasets::activity(3);
        data.normalize_inputs();
        let space = SearchSpace {
            hidden_widths: vec![4, 6],
            hidden_activations: vec![Activation::Tanh],
            trainers: vec![TrainerKind::Rprop],
            epochs: 60,
            memory_budget: None,
        };
        let result = tune(&data, &space, 1).unwrap();
        assert_eq!(result.trials.len(), 2);
        assert!(result.best_trial.val_accuracy > 0.8, "{:?}", result.best_trial);
    }

    #[test]
    fn memory_budget_filters_configs() {
        let data = datasets::xor();
        let space = SearchSpace {
            hidden_widths: vec![2, 4, 4096],
            hidden_activations: vec![Activation::Tanh],
            trainers: vec![TrainerKind::Batch],
            epochs: 5,
            memory_budget: Some(8 * 1024),
        };
        let result = tune(&data, &space, 2).unwrap();
        // 4096-wide config exceeds 8 kB and is skipped.
        assert_eq!(result.trials.len(), 2);
        assert!(result.trials.iter().all(|t| t.est_memory <= 8 * 1024));
    }

    #[test]
    fn impossible_budget_errors() {
        let data = datasets::xor();
        let space = SearchSpace {
            hidden_widths: vec![64],
            memory_budget: Some(16),
            ..SearchSpace::default()
        };
        assert!(tune(&data, &space, 3).is_err());
    }

    #[test]
    fn best_trial_is_min_mse() {
        let data = datasets::xor();
        let space = SearchSpace {
            hidden_widths: vec![2, 4, 8],
            hidden_activations: vec![Activation::Tanh],
            trainers: vec![TrainerKind::Rprop],
            epochs: 100,
            memory_budget: None,
        };
        let result = tune(&data, &space, 4).unwrap();
        let min = result
            .trials
            .iter()
            .map(|t| t.val_mse)
            .fold(f32::INFINITY, f32::min);
        assert_eq!(result.best_trial.val_mse, min);
    }
}
