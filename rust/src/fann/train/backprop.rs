//! `FANN_TRAIN_INCREMENTAL` (per-sample SGD with momentum) and
//! `FANN_TRAIN_BATCH` (full-batch gradient descent).

use super::{accumulate_gradient, Gradients};
use crate::fann::data::TrainData;
use crate::fann::net::Network;

/// Hyper-parameters shared by the backprop trainers. Defaults follow
/// FANN (`learning_rate = 0.7`, `learning_momentum = 0.0`).
#[derive(Debug, Clone, Copy)]
pub struct BackpropConfig {
    /// Step size of the gradient update.
    pub learning_rate: f32,
    /// Momentum coefficient (0.0 = plain gradient descent).
    pub momentum: f32,
}

impl Default for BackpropConfig {
    fn default() -> Self {
        Self {
            learning_rate: 0.7,
            momentum: 0.0,
        }
    }
}

/// Incremental (per-sample) trainer with momentum.
#[derive(Debug)]
pub struct Incremental {
    /// Hyper-parameters in use.
    pub config: BackpropConfig,
    grads: Gradients,
    velocity: Gradients,
}

impl Incremental {
    /// Fresh trainer state shaped like `net`.
    pub fn new(net: &Network, config: BackpropConfig) -> Self {
        Self {
            config,
            grads: Gradients::zeros_like(net),
            velocity: Gradients::zeros_like(net),
        }
    }

    /// One epoch over the dataset; returns the epoch MSE (computed from
    /// pre-update forward passes, as FANN reports it).
    pub fn train_epoch(&mut self, net: &mut Network, data: &TrainData) -> f32 {
        let mut sq_sum = 0.0f64;
        for i in 0..data.len() {
            self.grads.clear();
            let sq = accumulate_gradient(net, data.input(i), data.target(i), &mut self.grads);
            sq_sum += sq as f64;
            let lr = self.config.learning_rate;
            let mom = self.config.momentum;
            for (l, layer) in net.layers.iter_mut().enumerate() {
                for (j, w) in layer.weights.iter_mut().enumerate() {
                    let v = mom * self.velocity.d_weights[l][j] - lr * self.grads.d_weights[l][j];
                    self.velocity.d_weights[l][j] = v;
                    *w += v;
                }
                for (j, b) in layer.biases.iter_mut().enumerate() {
                    let v = mom * self.velocity.d_biases[l][j] - lr * self.grads.d_biases[l][j];
                    self.velocity.d_biases[l][j] = v;
                    *b += v;
                }
            }
        }
        (sq_sum / (data.len() * net.num_outputs()) as f64) as f32
    }
}

/// Full-batch gradient-descent trainer (`FANN_TRAIN_BATCH`).
#[derive(Debug)]
pub struct Batch {
    /// Hyper-parameters in use.
    pub config: BackpropConfig,
    grads: Gradients,
}

impl Batch {
    /// Fresh trainer state shaped like `net`.
    pub fn new(net: &Network, config: BackpropConfig) -> Self {
        Self {
            config,
            grads: Gradients::zeros_like(net),
        }
    }

    /// One full-batch epoch; returns the epoch MSE.
    pub fn train_epoch(&mut self, net: &mut Network, data: &TrainData) -> f32 {
        self.grads.clear();
        let mut sq_sum = 0.0f64;
        for i in 0..data.len() {
            sq_sum +=
                accumulate_gradient(net, data.input(i), data.target(i), &mut self.grads) as f64;
        }
        // Average gradient over the batch.
        self.grads.scale(1.0 / data.len() as f32);
        let lr = self.config.learning_rate;
        for (l, layer) in net.layers.iter_mut().enumerate() {
            for (j, w) in layer.weights.iter_mut().enumerate() {
                *w -= lr * self.grads.d_weights[l][j];
            }
            for (j, b) in layer.biases.iter_mut().enumerate() {
                *b -= lr * self.grads.d_biases[l][j];
            }
        }
        (sq_sum / (data.len() * net.num_outputs()) as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::activation::Activation;
    use crate::fann::train::mse;
    use crate::util::rng::Rng;

    fn xor_data() -> TrainData {
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        d
    }

    #[test]
    fn incremental_learns_xor() {
        let mut rng = Rng::new(42);
        let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let mut trainer = Incremental::new(
            &net,
            BackpropConfig {
                learning_rate: 0.7,
                momentum: 0.1,
            },
        );
        for _ in 0..500 {
            trainer.train_epoch(&mut net, &data);
        }
        assert!(mse(&net, &data) < 0.02, "mse {}", mse(&net, &data));
    }

    #[test]
    fn batch_reduces_mse_monotonically_at_small_lr() {
        let mut rng = Rng::new(43);
        let mut net = Network::new(&[2, 6, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let mut trainer = Batch::new(
            &net,
            BackpropConfig {
                learning_rate: 0.05,
                momentum: 0.0,
            },
        );
        let mut prev = mse(&net, &data);
        for _ in 0..50 {
            trainer.train_epoch(&mut net, &data);
            let cur = mse(&net, &data);
            assert!(cur <= prev + 1e-5, "mse increased {prev} -> {cur}");
            prev = cur;
        }
    }

    #[test]
    fn epoch_mse_matches_dataset_mse_before_update() {
        // The value returned by train_epoch is computed from pre-update
        // forwards; for batch training it must equal mse() of the net the
        // epoch started with.
        let mut rng = Rng::new(44);
        let mut net = Network::new(&[2, 3, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let before = mse(&net, &data);
        let mut trainer = Batch::new(&net, BackpropConfig::default());
        let reported = trainer.train_epoch(&mut net, &data);
        assert!((before - reported).abs() < 1e-6);
    }
}
