//! Training algorithms for the FANN substrate.
//!
//! FANN ships four trainers; we implement the two the toolkit's users
//! actually rely on (and that the paper's showcases were trained with):
//!
//! * [`backprop`] — `FANN_TRAIN_INCREMENTAL` (per-sample SGD + momentum)
//!   and `FANN_TRAIN_BATCH` (full-batch gradient descent).
//! * [`rprop`] — `FANN_TRAIN_RPROP`, FANN's default: iRPROP− with
//!   per-weight adaptive step sizes.
//!
//! The shared gradient machinery lives here: MSE loss (FANN's error
//! measure) and a backward pass that mirrors the L1 Pallas backward
//! kernels (activation derivative from the *output*).

pub mod backprop;
pub mod rprop;

use super::data::TrainData;
use super::net::Network;

/// Per-layer gradients, same shapes as the layer parameters.
#[derive(Debug, Clone)]
pub struct Gradients {
    /// Per-layer weight gradients, same shapes as the network.
    pub d_weights: Vec<Vec<f32>>,
    /// Per-layer bias gradients.
    pub d_biases: Vec<Vec<f32>>,
}

impl Gradients {
    /// Zero gradients shaped like `net`.
    pub fn zeros_like(net: &Network) -> Self {
        Self {
            d_weights: net.layers.iter().map(|l| vec![0.0; l.weights.len()]).collect(),
            d_biases: net.layers.iter().map(|l| vec![0.0; l.biases.len()]).collect(),
        }
    }

    /// Reset all gradients to zero.
    pub fn clear(&mut self) {
        for g in &mut self.d_weights {
            g.iter_mut().for_each(|v| *v = 0.0);
        }
        for g in &mut self.d_biases {
            g.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// Multiply every gradient by `s` (batch averaging).
    pub fn scale(&mut self, s: f32) {
        for g in &mut self.d_weights {
            g.iter_mut().for_each(|v| *v *= s);
        }
        for g in &mut self.d_biases {
            g.iter_mut().for_each(|v| *v *= s);
        }
    }
}

/// Mean squared error of the network over a dataset (FANN's `fann_get_MSE`
/// convention: mean over samples *and* output units).
pub fn mse(net: &Network, data: &TrainData) -> f32 {
    let mut acc = 0.0f64;
    let mut scratch = super::net::Scratch::for_network(net);
    for i in 0..data.len() {
        let out = net.run_with(&mut scratch, data.input(i));
        for (o, t) in out.iter().zip(data.target(i)) {
            let e = (o - t) as f64;
            acc += e * e;
        }
    }
    (acc / (data.len() * net.num_outputs()) as f64) as f32
}

/// Classification accuracy (the shared [`crate::util::predict_class`]
/// rule: argmax for multi-output, 0.5 threshold for single-output).
pub fn accuracy(net: &Network, data: &TrainData) -> f32 {
    let mut correct = 0usize;
    let mut scratch = super::net::Scratch::for_network(net);
    for i in 0..data.len() {
        let out = net.run_with(&mut scratch, data.input(i));
        if crate::util::predict_class(out) == data.label(i) {
            correct += 1;
        }
    }
    correct as f32 / data.len() as f32
}

/// Accumulate the gradient of the per-sample MSE
/// `sum_o (out_o - target_o)^2 / num_outputs` into `grads`; returns the
/// sample's squared error. The backward recurrence matches
/// `kernels/matvec.py::_dense_layer_bwd`.
pub fn accumulate_gradient(
    net: &Network,
    input: &[f32],
    target: &[f32],
    grads: &mut Gradients,
) -> f32 {
    let trace = net.forward_trace(input);
    let out = trace.last().unwrap();
    let n_out = net.num_outputs();

    // dL/dy at the output (L = mean over outputs of squared error).
    let mut delta: Vec<f32> = out
        .iter()
        .zip(target)
        .map(|(o, t)| 2.0 * (o - t) / n_out as f32)
        .collect();
    let sq_err: f32 = out
        .iter()
        .zip(target)
        .map(|(o, t)| (o - t) * (o - t))
        .sum();

    for (l, layer) in net.layers.iter().enumerate().rev() {
        let y = &trace[l + 1];
        let x = &trace[l];
        // dz = dy ⊙ act'(y), scaled by steepness (y = act(s·z)).
        let dz: Vec<f32> = delta
            .iter()
            .zip(y)
            .map(|(d, &yy)| d * layer.activation.grad_from_output(yy) * layer.steepness)
            .collect();
        let dw = &mut grads.d_weights[l];
        for o in 0..layer.n_out {
            let g = dz[o];
            let row = &mut dw[o * layer.n_in..(o + 1) * layer.n_in];
            for (wi, xi) in row.iter_mut().zip(x) {
                *wi += g * xi;
            }
            grads.d_biases[l][o] += g;
        }
        if l > 0 {
            // dx = W^T dz.
            let mut dx = vec![0.0f32; layer.n_in];
            for o in 0..layer.n_out {
                let g = dz[o];
                let row = &layer.weights[o * layer.n_in..(o + 1) * layer.n_in];
                for (dxi, wi) in dx.iter_mut().zip(row) {
                    *dxi += g * wi;
                }
            }
            delta = dx;
        }
    }
    sq_err
}

/// Numerical-vs-analytic gradient check used by the test suite.
#[cfg(test)]
pub(crate) fn numeric_gradient(
    net: &Network,
    input: &[f32],
    target: &[f32],
    layer: usize,
    idx: usize,
    bias: bool,
    eps: f32,
) -> f32 {
    let loss = |net: &Network| -> f32 {
        let out = net.run(input);
        out.iter()
            .zip(target)
            .map(|(o, t)| (o - t) * (o - t))
            .sum::<f32>()
            / net.num_outputs() as f32
    };
    let mut plus = net.clone();
    let mut minus = net.clone();
    if bias {
        plus.layers[layer].biases[idx] += eps;
        minus.layers[layer].biases[idx] -= eps;
    } else {
        plus.layers[layer].weights[idx] += eps;
        minus.layers[layer].weights[idx] -= eps;
    }
    (loss(&plus) - loss(&minus)) / (2.0 * eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::activation::Activation;
    use crate::util::rng::Rng;

    fn xor_data() -> TrainData {
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        d
    }

    #[test]
    fn gradient_matches_numeric() {
        let mut rng = Rng::new(17);
        let mut net =
            Network::new(&[3, 5, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let input = [0.3, -0.8, 0.5];
        let target = [1.0, 0.0];

        let mut grads = Gradients::zeros_like(&net);
        accumulate_gradient(&net, &input, &target, &mut grads);

        for (l, layer) in net.layers.iter().enumerate() {
            for idx in [0, layer.weights.len() / 2, layer.weights.len() - 1] {
                let num = numeric_gradient(&net, &input, &target, l, idx, false, 1e-3);
                let ana = grads.d_weights[l][idx];
                assert!(
                    (num - ana).abs() < 2e-3,
                    "layer {l} w[{idx}]: numeric {num} vs analytic {ana}"
                );
            }
            let num = numeric_gradient(&net, &input, &target, l, 0, true, 1e-3);
            let ana = grads.d_biases[l][0];
            assert!((num - ana).abs() < 2e-3, "layer {l} bias: {num} vs {ana}");
        }
    }

    #[test]
    fn mse_zero_for_perfect_net() {
        let mut net = Network::new(&[1, 1], Activation::Linear, Activation::Linear).unwrap();
        net.layers[0].weights = vec![1.0];
        let mut d = TrainData::new(1, 1);
        d.push(&[0.25], &[0.25]);
        assert_eq!(mse(&net, &d), 0.0);
    }

    #[test]
    fn accuracy_on_trivial_classifier() {
        // Single output, w = 1, b = 0, sigmoid: predicts 1 iff x > 0.
        let mut net = Network::new(&[1, 1], Activation::Linear, Activation::Sigmoid).unwrap();
        net.layers[0].weights = vec![10.0];
        let mut d = TrainData::new(1, 1);
        d.push(&[1.0], &[1.0]);
        d.push(&[-1.0], &[0.0]);
        d.push(&[2.0], &[0.0]); // deliberately mislabeled
        let acc = accuracy(&net, &d);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn xor_mse_starts_high() {
        let mut rng = Rng::new(3);
        let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        assert!(mse(&net, &xor_data()) > 0.05);
    }
}
