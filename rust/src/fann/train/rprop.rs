//! iRPROP− — FANN's default trainer (`FANN_TRAIN_RPROP`).
//!
//! Resilient backpropagation with per-weight adaptive step sizes
//! (Igel & Hüsken's iRPROP− variant, which FANN implements): only the
//! *sign* of the batch gradient is used; on a sign change the step is
//! shrunk and the gradient zeroed (no weight revert). Constants follow
//! FANN's defaults.

use super::{accumulate_gradient, Gradients};
use crate::fann::data::TrainData;
use crate::fann::net::Network;

/// iRPROP− hyper-parameters (FANN defaults).
#[derive(Debug, Clone, Copy)]
pub struct RpropConfig {
    /// Step growth factor on gradient-sign agreement (eta+).
    pub increase_factor: f32,
    /// Step shrink factor on sign flip (eta-).
    pub decrease_factor: f32,
    /// Lower clamp of the per-weight step.
    pub delta_min: f32,
    /// Upper clamp of the per-weight step.
    pub delta_max: f32,
    /// Initial per-weight step.
    pub delta_zero: f32,
}

impl Default for RpropConfig {
    fn default() -> Self {
        Self {
            increase_factor: 1.2,
            decrease_factor: 0.5,
            delta_min: 0.0,
            delta_max: 50.0,
            delta_zero: 0.1,
        }
    }
}

/// iRPROP− trainer state: previous gradients + per-parameter step sizes.
#[derive(Debug)]
pub struct Rprop {
    /// The iRPROP- hyper-parameters in use.
    pub config: RpropConfig,
    grads: Gradients,
    prev_grads: Gradients,
    steps: Gradients,
}

impl Rprop {
    /// Fresh trainer state shaped like `net`.
    pub fn new(net: &Network, config: RpropConfig) -> Self {
        let mut steps = Gradients::zeros_like(net);
        for g in steps.d_weights.iter_mut().chain(steps.d_biases.iter_mut()) {
            g.iter_mut().for_each(|v| *v = config.delta_zero);
        }
        Self {
            config,
            grads: Gradients::zeros_like(net),
            prev_grads: Gradients::zeros_like(net),
            steps,
        }
    }

    /// One full-batch iRPROP− epoch; returns the epoch MSE.
    pub fn train_epoch(&mut self, net: &mut Network, data: &TrainData) -> f32 {
        self.grads.clear();
        let mut sq_sum = 0.0f64;
        for i in 0..data.len() {
            sq_sum +=
                accumulate_gradient(net, data.input(i), data.target(i), &mut self.grads) as f64;
        }

        let cfg = self.config;
        let update = |w: &mut f32, g: &mut f32, pg: &mut f32, step: &mut f32| {
            let sign = *g * *pg;
            if sign > 0.0 {
                *step = (*step * cfg.increase_factor).min(cfg.delta_max);
            } else if sign < 0.0 {
                *step = (*step * cfg.decrease_factor).max(cfg.delta_min);
                // iRPROP−: forget the gradient, skip the update this epoch.
                *g = 0.0;
            }
            if *g > 0.0 {
                *w -= *step;
            } else if *g < 0.0 {
                *w += *step;
            }
            *pg = *g;
        };

        for (l, layer) in net.layers.iter_mut().enumerate() {
            for (j, w) in layer.weights.iter_mut().enumerate() {
                update(
                    w,
                    &mut self.grads.d_weights[l][j],
                    &mut self.prev_grads.d_weights[l][j],
                    &mut self.steps.d_weights[l][j],
                );
            }
            for (j, b) in layer.biases.iter_mut().enumerate() {
                update(
                    b,
                    &mut self.grads.d_biases[l][j],
                    &mut self.prev_grads.d_biases[l][j],
                    &mut self.steps.d_biases[l][j],
                );
            }
        }
        (sq_sum / (data.len() * net.num_outputs()) as f64) as f32
    }

    /// Train until MSE <= `desired_error` or `max_epochs`, returning the
    /// per-epoch MSE curve (mirrors `fann_train_on_data`).
    pub fn train_until(
        &mut self,
        net: &mut Network,
        data: &TrainData,
        max_epochs: usize,
        desired_error: f32,
    ) -> Vec<f32> {
        let mut curve = Vec::with_capacity(max_epochs);
        for _ in 0..max_epochs {
            let e = self.train_epoch(net, data);
            curve.push(e);
            if e <= desired_error {
                break;
            }
        }
        curve
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::activation::Activation;
    use crate::fann::train::mse;
    use crate::util::rng::Rng;

    fn xor_data() -> TrainData {
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        d
    }

    #[test]
    fn rprop_learns_xor_fast() {
        let mut rng = Rng::new(7);
        let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let mut trainer = Rprop::new(&net, RpropConfig::default());
        let curve = trainer.train_until(&mut net, &data, 300, 0.001);
        assert!(
            *curve.last().unwrap() <= 0.001,
            "rprop failed to converge: {:?}",
            &curve[curve.len().saturating_sub(5)..]
        );
        assert!(curve.len() < 300);
    }

    #[test]
    fn steps_stay_within_bounds() {
        let mut rng = Rng::new(8);
        let mut net = Network::new(&[2, 3, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let cfg = RpropConfig::default();
        let mut trainer = Rprop::new(&net, cfg);
        for _ in 0..100 {
            trainer.train_epoch(&mut net, &data);
        }
        for s in trainer
            .steps
            .d_weights
            .iter()
            .chain(trainer.steps.d_biases.iter())
            .flatten()
        {
            assert!(*s >= cfg.delta_min && *s <= cfg.delta_max);
        }
    }

    #[test]
    fn rprop_beats_initial_mse() {
        let mut rng = Rng::new(9);
        let mut net = Network::new(&[2, 6, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let data = xor_data();
        let before = mse(&net, &data);
        let mut trainer = Rprop::new(&net, RpropConfig::default());
        for _ in 0..50 {
            trainer.train_epoch(&mut net, &data);
        }
        assert!(mse(&net, &data) < before);
    }
}
