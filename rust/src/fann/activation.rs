//! FANN activation functions (float path) and their derivatives.
//!
//! Matches `python/compile/kernels/ref.py::activation` exactly:
//! `sigmoid(x) = 1/(1+e^-x)`, `tanh`, `relu`, `linear`. FANN's original
//! convention folds an activation *steepness* into the argument
//! (`sigmoid(2·s·x)` with default s = 0.5); we normalize to steepness 1.0
//! applied uniformly (`act(s·x)`) so the Rust, JAX and Pallas paths share
//! one convention — `Network::steepness` stores s and defaults to 1.0.
//!
//! Derivatives are expressed in terms of the activation *output*, as FANN's
//! backprop does (it only retains neuron outputs).

use anyhow::{bail, Result};

/// Activation function selector (FANN enum subset used by the toolkit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    /// Identity (FANN_LINEAR).
    Linear,
    /// Logistic sigmoid (FANN_SIGMOID).
    Sigmoid,
    /// FANN_SIGMOID_SYMMETRIC.
    Tanh,
    /// Rectified linear.
    Relu,
}

impl Activation {
    /// Apply the activation.
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Activation::Tanh => x.tanh(),
            Activation::Relu => x.max(0.0),
        }
    }

    /// Derivative as a function of the activation output `y`.
    #[inline]
    pub fn grad_from_output(self, y: f32) -> f32 {
        match self {
            Activation::Linear => 1.0,
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Tanh => 1.0 - y * y,
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Output range of the activation, used by fixed-point conversion to
    /// bound intermediate magnitudes.
    pub fn output_range(self) -> (f32, f32) {
        match self {
            Activation::Linear => (f32::NEG_INFINITY, f32::INFINITY),
            Activation::Sigmoid => (0.0, 1.0),
            Activation::Tanh => (-1.0, 1.0),
            Activation::Relu => (0.0, f32::INFINITY),
        }
    }

    /// Canonical lowercase name (matches the Python topology registry).
    pub fn name(self) -> &'static str {
        match self {
            Activation::Linear => "linear",
            Activation::Sigmoid => "sigmoid",
            Activation::Tanh => "tanh",
            Activation::Relu => "relu",
        }
    }

    /// Parse from the canonical name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "linear" => Activation::Linear,
            "sigmoid" => Activation::Sigmoid,
            "tanh" | "sigmoid_symmetric" => Activation::Tanh,
            "relu" => Activation::Relu,
            other => bail!("unknown activation {other:?}"),
        })
    }

    /// Approximate cycle cost of one activation evaluation on an MCU using
    /// FANN's step-linear approximation (used by `targets::isa`).
    pub fn mcu_cycle_cost(self) -> u64 {
        match self {
            Activation::Linear => 1,
            // Step-linear table: compare + branch chain + interpolation.
            Activation::Sigmoid | Activation::Tanh => 16,
            Activation::Relu => 2,
        }
    }
}

/// All activations the toolkit supports (iteration helper for tests).
pub const ALL: [Activation; 4] = [
    Activation::Linear,
    Activation::Sigmoid,
    Activation::Tanh,
    Activation::Relu,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_midpoint_and_limits() {
        assert!((Activation::Sigmoid.apply(0.0) - 0.5).abs() < 1e-7);
        assert!(Activation::Sigmoid.apply(20.0) > 0.999);
        assert!(Activation::Sigmoid.apply(-20.0) < 0.001);
    }

    #[test]
    fn tanh_is_odd() {
        for x in [-3.0f32, -0.5, 0.0, 1.25] {
            let a = Activation::Tanh.apply(x);
            let b = Activation::Tanh.apply(-x);
            assert!((a + b).abs() < 1e-6);
        }
    }

    #[test]
    fn gradients_match_numeric_derivative() {
        let eps = 1e-3f32;
        for act in ALL {
            for x in [-2.0f32, -0.7, 0.3, 1.9] {
                if act == Activation::Relu && x.abs() < 2.0 * eps {
                    continue; // kink
                }
                let y = act.apply(x);
                let dydx = (act.apply(x + eps) - act.apply(x - eps)) / (2.0 * eps);
                let got = act.grad_from_output(y);
                assert!(
                    (got - dydx).abs() < 5e-3,
                    "{act:?} x={x}: {got} vs {dydx}"
                );
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for act in ALL {
            assert_eq!(Activation::parse(act.name()).unwrap(), act);
        }
        assert!(Activation::parse("softmax").is_err());
    }

    #[test]
    fn output_ranges_contain_samples() {
        for act in ALL {
            let (lo, hi) = act.output_range();
            for x in [-5.0f32, 0.0, 5.0] {
                let y = act.apply(x);
                assert!(y >= lo && y <= hi);
            }
        }
    }
}
