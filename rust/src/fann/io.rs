//! FANN `.net` file formats: float (`FANN_FLO_2.1`-style) and fixed
//! (`FANN_FIX_2.1`-style).
//!
//! We keep FANN's shape — a versioned header followed by `key=value`
//! lines and a flat connection list — but serialize only the fields the
//! toolkit consumes (layer sizes, per-layer activation + steepness,
//! weights). FANN's full per-neuron connection table is redundant for the
//! dense MLPs the toolkit supports; DESIGN.md §1 records the
//! simplification.

use anyhow::{bail, ensure, Context, Result};

use super::activation::Activation;
use super::fixed::{FixedLayer, FixedNetwork};
use super::net::{Layer, Network};

const FLOAT_MAGIC: &str = "FANN_FLO_2.1";
const FIXED_MAGIC: &str = "FANN_FIX_2.1";

fn join<T: ToString>(xs: impl IntoIterator<Item = T>) -> String {
    xs.into_iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(" ")
}

/// Serialize a float network.
pub fn save_float(net: &Network) -> String {
    let mut out = String::new();
    out.push_str(FLOAT_MAGIC);
    out.push('\n');
    out.push_str(&format!("num_layers={}\n", net.num_fann_layers()));
    out.push_str(&format!("layer_sizes={}\n", join(net.layer_sizes())));
    out.push_str(&format!(
        "activations={}\n",
        join(net.layers.iter().map(|l| l.activation.name()))
    ));
    out.push_str(&format!(
        "steepness={}\n",
        join(net.layers.iter().map(|l| l.steepness))
    ));
    for layer in &net.layers {
        out.push_str(&format!("weights={}\n", join(layer.weights.iter())));
        out.push_str(&format!("biases={}\n", join(layer.biases.iter())));
    }
    out
}

/// Serialize a fixed-point network.
pub fn save_fixed(net: &FixedNetwork) -> String {
    let mut out = String::new();
    out.push_str(FIXED_MAGIC);
    out.push('\n');
    out.push_str(&format!("decimal_point={}\n", net.decimal_point));
    out.push_str(&format!("num_layers={}\n", net.layers.len() + 1));
    out.push_str(&format!("layer_sizes={}\n", join(net.layer_sizes())));
    out.push_str(&format!(
        "activations={}\n",
        join(net.layers.iter().map(|l| l.activation.name()))
    ));
    for layer in &net.layers {
        out.push_str(&format!("weights={}\n", join(layer.weights.iter())));
        out.push_str(&format!("biases={}\n", join(layer.biases.iter())));
    }
    out
}

struct KvReader<'a> {
    lines: std::str::Lines<'a>,
}

impl<'a> KvReader<'a> {
    fn expect(&mut self, key: &str) -> Result<&'a str> {
        let line = self
            .lines
            .next()
            .with_context(|| format!("missing {key}"))?;
        let (k, v) = line.split_once('=').with_context(|| format!("bad line {line:?}"))?;
        ensure!(k == key, "expected key {key}, found {k}");
        Ok(v)
    }
}

fn parse_vec<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::error::Error + Send + Sync + 'static,
{
    s.split_whitespace()
        .map(|v| v.parse::<T>().context("bad value"))
        .collect()
}

/// Largest decimal point a fixed `.net` file may declare: beyond this
/// the Q-format shift itself is meaningless for i32 parameters (and
/// downstream `1 << dec` arithmetic would overflow).
const MAX_DECIMAL_POINT: u32 = 30;

fn validate_shape(num_layers: usize, sizes: &[usize]) -> Result<()> {
    ensure!(
        num_layers >= 2,
        "num_layers {num_layers} invalid: need at least input and output layers"
    );
    ensure!(sizes.len() == num_layers, "layer_sizes length mismatch");
    ensure!(
        sizes.iter().all(|&s| s > 0),
        "zero-width layer in layer_sizes"
    );
    Ok(())
}

fn ensure_finite(vals: &[f32], what: &str, layer: usize) -> Result<()> {
    ensure!(
        vals.iter().all(|v| v.is_finite()),
        "non-finite {what} in layer {layer} (NaN/inf cannot be deployed)"
    );
    Ok(())
}

/// Parse a float `.net` file. Malformed inputs — truncation, NaN/inf
/// parameters, inconsistent layer counts, zero-width layers — are
/// structured errors, never panics (`rust/tests/prop_io_roundtrip.rs`
/// fuzzes this).
pub fn load_float(text: &str) -> Result<Network> {
    let mut lines = text.lines();
    let magic = lines.next().context("empty file")?;
    if magic != FLOAT_MAGIC {
        bail!("not a float FANN net (magic {magic:?})");
    }
    let mut r = KvReader { lines };
    let num_layers: usize = r.expect("num_layers")?.parse()?;
    let sizes: Vec<usize> = parse_vec(r.expect("layer_sizes")?)?;
    validate_shape(num_layers, &sizes)?;
    let acts: Vec<Activation> = r
        .expect("activations")?
        .split_whitespace()
        .map(Activation::parse)
        .collect::<Result<_>>()?;
    ensure!(acts.len() == num_layers - 1, "activations length mismatch");
    let steep: Vec<f32> = parse_vec(r.expect("steepness")?)?;
    ensure!(steep.len() == num_layers - 1, "steepness length mismatch");
    ensure_finite(&steep, "steepness", 0)?;

    let mut layers = Vec::with_capacity(num_layers - 1);
    for (i, w) in sizes.windows(2).enumerate() {
        let weights: Vec<f32> = parse_vec(r.expect("weights")?)?;
        let biases: Vec<f32> = parse_vec(r.expect("biases")?)?;
        // checked_mul: adversarially huge layer_sizes must error, not
        // overflow-panic in debug builds.
        let n_weights = w[0]
            .checked_mul(w[1])
            .with_context(|| format!("layer {i} size product overflows"))?;
        ensure!(weights.len() == n_weights, "weights size mismatch layer {i}");
        ensure!(biases.len() == w[1], "biases size mismatch layer {i}");
        ensure_finite(&weights, "weights", i)?;
        ensure_finite(&biases, "biases", i)?;
        layers.push(Layer {
            n_in: w[0],
            n_out: w[1],
            weights,
            biases,
            activation: acts[i],
            steepness: steep[i],
        });
    }
    Ok(Network { layers })
}

/// Parse a fixed `.net` file. Malformed inputs are structured errors,
/// never panics. (Seed bug fixed here: a file whose `activations` line
/// listed fewer entries than `num_layers - 1` used to index out of
/// bounds and panic instead of erroring; the decimal point was also
/// accepted unbounded.)
pub fn load_fixed(text: &str) -> Result<FixedNetwork> {
    let mut lines = text.lines();
    let magic = lines.next().context("empty file")?;
    if magic != FIXED_MAGIC {
        bail!("not a fixed FANN net (magic {magic:?})");
    }
    let mut r = KvReader { lines };
    let decimal_point: u32 = r.expect("decimal_point")?.parse()?;
    ensure!(
        decimal_point <= MAX_DECIMAL_POINT,
        "decimal_point {decimal_point} out of range (max {MAX_DECIMAL_POINT})"
    );
    let num_layers: usize = r.expect("num_layers")?.parse()?;
    let sizes: Vec<usize> = parse_vec(r.expect("layer_sizes")?)?;
    validate_shape(num_layers, &sizes)?;
    let acts: Vec<Activation> = r
        .expect("activations")?
        .split_whitespace()
        .map(Activation::parse)
        .collect::<Result<_>>()?;
    ensure!(acts.len() == num_layers - 1, "activations length mismatch");

    let mut layers = Vec::with_capacity(num_layers - 1);
    for (i, w) in sizes.windows(2).enumerate() {
        let weights: Vec<i32> = parse_vec(r.expect("weights")?)?;
        let biases: Vec<i32> = parse_vec(r.expect("biases")?)?;
        let n_weights = w[0]
            .checked_mul(w[1])
            .with_context(|| format!("layer {i} size product overflows"))?;
        ensure!(weights.len() == n_weights, "weights size mismatch layer {i}");
        ensure!(biases.len() == w[1], "biases size mismatch layer {i}");
        layers.push(FixedLayer {
            n_in: w[0],
            n_out: w[1],
            weights,
            biases,
            activation: acts[i],
        });
    }
    Ok(FixedNetwork {
        layers,
        decimal_point,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_net() -> Network {
        let mut rng = Rng::new(31);
        let mut net =
            Network::new(&[5, 9, 4], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        net.layers[0].steepness = 0.5;
        net
    }

    #[test]
    fn float_roundtrip_preserves_outputs() {
        let net = random_net();
        let text = save_float(&net);
        let back = load_float(&text).unwrap();
        let x = [0.1f32, -0.3, 0.7, 0.0, -0.9];
        assert_eq!(net.run(&x), back.run(&x));
        assert_eq!(back.layers[0].steepness, 0.5);
    }

    #[test]
    fn fixed_roundtrip_bit_exact() {
        let net = random_net();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let text = save_fixed(&fixed);
        let back = load_fixed(&text).unwrap();
        assert_eq!(back.decimal_point, fixed.decimal_point);
        let xq = fixed.quantize_input(&[0.1, -0.3, 0.7, 0.0, -0.9]);
        assert_eq!(fixed.run_q(&xq), back.run_q(&xq));
    }

    #[test]
    fn rejects_wrong_magic() {
        assert!(load_float("FANN_FIX_2.1\n").is_err());
        assert!(load_fixed("FANN_FLO_2.1\n").is_err());
        assert!(load_float("").is_err());
    }

    #[test]
    fn rejects_truncated_weights() {
        let net = random_net();
        let mut text = save_float(&net);
        // chop the last line
        text.truncate(text.rfind("biases=").unwrap());
        assert!(load_float(&text).is_err());
    }

    #[test]
    fn rejects_nonfinite_parameters() {
        let net = random_net();
        let text = save_float(&net);
        let with_nan = text.replacen("weights=", "weights=NaN ", 1);
        assert!(load_float(&with_nan).is_err());
        let with_inf = text.replacen("biases=", "biases=inf ", 1);
        assert!(load_float(&with_inf).is_err());
    }

    #[test]
    fn rejects_degenerate_layer_counts() {
        // num_layers < 2 and zero-width layers must be errors, not
        // panics further downstream.
        let text = "FANN_FLO_2.1\nnum_layers=1\nlayer_sizes=3\nactivations=\nsteepness=\n";
        assert!(load_float(text).is_err());
        let text = "FANN_FLO_2.1\nnum_layers=2\nlayer_sizes=3 0\nactivations=tanh\nsteepness=1\n";
        assert!(load_float(text).is_err());
    }

    #[test]
    fn fixed_rejects_wrong_activation_count_instead_of_panicking() {
        // Regression for the seed bug: a short activations line used to
        // index out of bounds in the layer loop.
        let net = random_net();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let text = save_fixed(&fixed);
        let broken = text.replacen("activations=tanh sigmoid", "activations=tanh", 1);
        assert_ne!(text, broken, "test setup: activations line not found");
        assert!(load_fixed(&broken).is_err());
    }

    #[test]
    fn huge_layer_sizes_error_instead_of_overflowing() {
        // 2^32 * 2^32 overflows usize: must be a structured error, not a
        // debug-build multiply-overflow panic.
        let fixed = "FANN_FIX_2.1\ndecimal_point=4\nnum_layers=2\n\
                     layer_sizes=4294967296 4294967296\nactivations=tanh\nweights=1\nbiases=1\n";
        assert!(load_fixed(fixed).is_err());
        let float = "FANN_FLO_2.1\nnum_layers=2\n\
                     layer_sizes=4294967296 4294967296\nactivations=tanh\nsteepness=1\n\
                     weights=1\nbiases=1\n";
        assert!(load_float(float).is_err());
    }

    #[test]
    fn fixed_rejects_out_of_range_decimal_point() {
        let net = random_net();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let text = save_fixed(&fixed);
        let dec_line = format!("decimal_point={}", fixed.decimal_point);
        let broken = text.replacen(&dec_line, "decimal_point=99", 1);
        assert_ne!(text, broken);
        assert!(load_fixed(&broken).is_err());
    }
}
