//! The MLP network representation (FANN's `struct fann`, idiomatically).
//!
//! A network is a chain of fully-connected layers; layer `l` maps
//! `sizes[l]` inputs to `sizes[l+1]` outputs through a row-major weight
//! matrix (`w[out][in]`, matching the MCU memory layout the paper streams
//! neuron-by-neuron) plus a bias per output neuron, followed by an
//! activation. This mirrors Eq. (1) of the paper.
//!
//! The forward path here is the *reference float implementation* — the
//! deployment simulator executes the same math through the target's cycle
//! model, and `runtime::` executes the AOT-compiled JAX version; parity
//! tests pin all three together.

use anyhow::{ensure, Result};

use super::activation::Activation;
use crate::util::rng::Rng;

/// Four-lane dot product: independent accumulators expose instruction-
/// level parallelism / SIMD to the compiler. Reassociates float adds
/// (cross-implementation parity tests allow for it: tolerance 3e-5).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// One fully-connected layer.
#[derive(Debug, Clone)]
pub struct Layer {
    pub n_in: usize,
    pub n_out: usize,
    /// Row-major `[n_out][n_in]`: `weights[o * n_in + i]`. Row-major per
    /// output neuron is exactly the order the paper's neuron-wise DMA
    /// streams weights in.
    pub weights: Vec<f32>,
    pub biases: Vec<f32>,
    pub activation: Activation,
    /// Uniform activation steepness `s` (output = act(s · sum)).
    pub steepness: f32,
}

impl Layer {
    pub fn zeros(n_in: usize, n_out: usize, activation: Activation) -> Self {
        Self {
            n_in,
            n_out,
            weights: vec![0.0; n_in * n_out],
            biases: vec![0.0; n_out],
            activation,
            steepness: 1.0,
        }
    }

    /// Forward one sample. `input.len() == n_in`, writes `n_out` outputs.
    pub fn forward_into(&self, input: &[f32], out: &mut [f32]) {
        debug_assert_eq!(input.len(), self.n_in);
        debug_assert_eq!(out.len(), self.n_out);
        for o in 0..self.n_out {
            let row = &self.weights[o * self.n_in..(o + 1) * self.n_in];
            // The dot product — the paper's Table I inner loop. Four
            // accumulator lanes break the FMA dependency chain so LLVM
            // can vectorize (§Perf: 1.6 -> ~4 GMAC/s host-side).
            let acc = self.biases[o] + dot_f32(row, input);
            out[o] = self.activation.apply(self.steepness * acc);
        }
    }

    /// Number of weights (excluding biases).
    pub fn num_weights(&self) -> usize {
        self.weights.len()
    }

    /// Multiply-accumulate count of this layer.
    pub fn macs(&self) -> usize {
        self.n_in * self.n_out
    }
}

/// A multi-layer perceptron.
#[derive(Debug, Clone)]
pub struct Network {
    pub layers: Vec<Layer>,
}

impl Network {
    /// Build a network from layer sizes `[in, h1, ..., out]` with zeroed
    /// parameters.
    pub fn new(sizes: &[usize], hidden_act: Activation, output_act: Activation) -> Result<Self> {
        ensure!(sizes.len() >= 2, "need at least input and output layers");
        ensure!(sizes.iter().all(|&s| s > 0), "zero-width layer");
        let last = sizes.len() - 2;
        let layers = sizes
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                Layer::zeros(w[0], w[1], if i == last { output_act } else { hidden_act })
            })
            .collect();
        Ok(Self { layers })
    }

    /// FANN-style random init: weights uniform in `[-limit, +limit]`
    /// (FANN's `fann_randomize_weights`); biases zero. The default limit
    /// mirrors Glorot scaling per layer when `limit` is `None` (what
    /// FANNTool's "smart" init does and what the JAX path uses).
    pub fn randomize(&mut self, rng: &mut Rng, limit: Option<f32>) {
        for layer in &mut self.layers {
            let lim = limit
                .unwrap_or_else(|| (6.0 / (layer.n_in + layer.n_out) as f32).sqrt());
            for w in &mut layer.weights {
                *w = rng.range_f32(-lim, lim);
            }
            for b in &mut layer.biases {
                *b = 0.0;
            }
        }
    }

    /// Layer sizes `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![self.layers[0].n_in];
        sizes.extend(self.layers.iter().map(|l| l.n_out));
        sizes
    }

    pub fn num_inputs(&self) -> usize {
        self.layers[0].n_in
    }

    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    /// Total weights (excluding biases) — `N_weights` in Eq. (2).
    pub fn num_weights(&self) -> usize {
        self.layers.iter().map(Layer::num_weights).sum()
    }

    /// Total neurons including the per-layer bias pseudo-neuron — the
    /// paper's `N_neurons` convention for Eq. (2).
    pub fn num_neurons_with_bias(&self) -> usize {
        // input layer + its bias, then every layer's outputs + bias.
        let sizes = self.layer_sizes();
        sizes.iter().map(|s| s + 1).sum()
    }

    /// Total number of FANN layers (input + hidden + output) — Eq. (2)'s
    /// `N_fann_layers`.
    pub fn num_fann_layers(&self) -> usize {
        self.layers.len() + 1
    }

    /// Total multiply-accumulates for one inference.
    pub fn macs(&self) -> usize {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Widest layer input length (drives the scratch buffer in Eq. (2)).
    pub fn max_layer_width(&self) -> usize {
        self.layer_sizes().into_iter().max().unwrap()
    }

    /// Run one sample through the network.
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::for_network(self);
        self.run_with(&mut scratch, input).to_vec()
    }

    /// Run with caller-provided scratch (allocation-free hot path).
    pub fn run_with<'s>(&self, scratch: &'s mut Scratch, input: &[f32]) -> &'s [f32] {
        assert_eq!(input.len(), self.num_inputs());
        scratch.a[..input.len()].copy_from_slice(input);
        let mut cur_len = input.len();
        let mut flip = false;
        for layer in &self.layers {
            let (src, dst) = if flip {
                (&scratch.b, &mut scratch.a)
            } else {
                (&scratch.a, &mut scratch.b)
            };
            layer.forward_into(&src[..cur_len], &mut dst[..layer.n_out]);
            cur_len = layer.n_out;
            flip = !flip;
        }
        let buf = if flip { &scratch.b } else { &scratch.a };
        &buf[..cur_len]
    }

    /// Forward pass retaining every layer's output (for backprop). Returns
    /// `outputs[l]` = activations of layer l (l = 0 is the input itself).
    pub fn forward_trace(&self, input: &[f32]) -> Vec<Vec<f32>> {
        let mut outs = Vec::with_capacity(self.layers.len() + 1);
        outs.push(input.to_vec());
        for layer in &self.layers {
            let mut next = vec![0.0; layer.n_out];
            layer.forward_into(outs.last().unwrap(), &mut next);
            outs.push(next);
        }
        outs
    }
}

/// Double buffer sized for the widest layer — the software analogue of the
/// paper's ping-pong activation buffers (`2 · L_data_buffer` in Eq. (2)).
#[derive(Debug, Clone)]
pub struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl Scratch {
    pub fn for_network(net: &Network) -> Self {
        let w = net.max_layer_width();
        Self {
            a: vec![0.0; w],
            b: vec![0.0; w],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        // 2-2-1, hand-set weights: first layer identity-ish, linear acts.
        let mut net = Network::new(&[2, 2, 1], Activation::Linear, Activation::Linear).unwrap();
        net.layers[0].weights = vec![1.0, 0.0, 0.0, 1.0];
        net.layers[0].biases = vec![0.5, -0.5];
        net.layers[1].weights = vec![2.0, 3.0];
        net.layers[1].biases = vec![1.0];
        net
    }

    #[test]
    fn forward_linear_math() {
        let net = tiny();
        // h = [x0+0.5, x1-0.5]; y = 2h0 + 3h1 + 1
        let y = net.run(&[1.0, 2.0]);
        assert_eq!(y, vec![2.0 * 1.5 + 3.0 * 1.5 + 1.0]);
    }

    #[test]
    fn run_with_matches_run() {
        let mut rng = Rng::new(5);
        let mut net =
            Network::new(&[5, 7, 3], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let x: Vec<f32> = (0..5).map(|i| i as f32 * 0.3 - 0.7).collect();
        let mut scratch = Scratch::for_network(&net);
        let a = net.run(&x);
        let b = net.run_with(&mut scratch, &x).to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn counts_match_paper_conventions() {
        // Application A topology: 76-300-200-100-10 => 103800 MACs.
        let net = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        assert_eq!(net.macs(), 103_800);
        assert_eq!(net.num_weights(), 103_800);
        assert_eq!(net.num_fann_layers(), 5);
        assert_eq!(net.num_neurons_with_bias(), 76 + 300 + 200 + 100 + 10 + 5);
        assert_eq!(net.max_layer_width(), 300);
    }

    #[test]
    fn forward_trace_layers() {
        let net = tiny();
        let trace = net.forward_trace(&[1.0, 2.0]);
        assert_eq!(trace.len(), 3);
        assert_eq!(trace[0], vec![1.0, 2.0]);
        assert_eq!(trace[1], vec![1.5, 1.5]);
        assert_eq!(trace[2], net.run(&[1.0, 2.0]));
    }

    #[test]
    fn rejects_degenerate_shapes() {
        assert!(Network::new(&[3], Activation::Tanh, Activation::Sigmoid).is_err());
        assert!(Network::new(&[3, 0, 2], Activation::Tanh, Activation::Sigmoid).is_err());
    }

    #[test]
    fn randomize_within_limit() {
        let mut rng = Rng::new(9);
        let mut net = Network::new(&[4, 4, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, Some(0.1));
        for l in &net.layers {
            assert!(l.weights.iter().all(|w| w.abs() <= 0.1));
            assert!(l.biases.iter().all(|&b| b == 0.0));
        }
    }
}
